"""Batched serving, three layers up the stack: (1) static-batch
prefill+decode across three cache families (attention KV ring buffer, SSM
O(1) state, RG-LRU hybrid), (2) the continuous-batching ServeEngine —
slot-managed requests of different lengths admitted/retired independently,
one vmapped decode step per tick — and (3) the decentralized serving fleet:
per-node engines behind bounded-queue admission control, fed by the seeded
Poisson/Zipf load generator, hot-reloading consensus checkpoints mid-run
(the train-and-serve loop benchmarked by suite S).

This is the serving path the decode_32k / long_500k dry-run shapes lower at
production scale; here it runs reduced configs on CPU.

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import save
from repro.configs import get_config
from repro.launch.serve import main as serve_main
from repro.models import transformer as T
from repro.serving import (
    AdmissionControl,
    FleetNode,
    HotReloader,
    LoadGenConfig,
    LoadGenerator,
    Request,
    ServeEngine,
    ServingFleet,
)

ARCHS = ["qwen3-1.7b", "mamba2-1.3b", "recurrentgemma-2b"]


def static_batches() -> None:
    for arch in ARCHS:
        print(f"\n--- {arch} (static batch) ---")
        sys.argv = [
            "serve", "--arch", arch, "--reduced",
            "--batch", "4", "--prompt-len", "32", "--gen", "16",
        ]
        serve_main()


def continuous_batching() -> None:
    print("\n--- qwen3-1.7b (continuous batching: 6 requests, 2 slots) ---")
    cfg = get_config("qwen3-1.7b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, int(n)).tolist(), max_new_tokens=8)
        for n in rng.integers(5, 25, 6)
    ]
    engine = ServeEngine(cfg, params, max_slots=2, cache_len=64, prompt_bucket=8)
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.output) for r in reqs)
    print(f"completed {done}/6 requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s aggregate on 2 slots)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt len {len(r.prompt):2d} -> {r.output}")


def serving_fleet() -> None:
    """Two nodes serve seeded Poisson/Zipf traffic behind bounded queues,
    hot-reloading a consensus checkpoint that lands mid-run — the same
    stack `launch/serve.py --fleet N --follow` and suite S drive."""
    print("\n--- qwen3-1.7b (serving fleet: 2 nodes x 2 slots, hot reload) ---")
    cfg = get_config("qwen3-1.7b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    gen = LoadGenerator(LoadGenConfig(
        num_nodes=2, rate=0.25, vocab_size=cfg.vocab_size,
        prompt_min=4, prompt_max=16, output_min=1, output_max=6, seed=0,
    ))
    with tempfile.TemporaryDirectory() as tmp:
        prefix = f"{tmp}/consensus"
        nodes = [
            FleetNode(
                i,
                ServeEngine(cfg, params, max_slots=2, cache_len=32, prompt_bucket=8),
                admission=AdmissionControl(max_queue=12, policy="reject"),
                reloader=HotReloader(prefix, params, log=lambda s: None),
            )
            for i in range(2)
        ]
        fleet = ServingFleet(nodes, gen, reload_every=4)
        fleet.run(max_requests=20, max_ticks=10_000)
        # a fresh consensus checkpoint lands (atomic save); the next poll
        # swaps it in between ticks — traffic never sees a torn file
        save(prefix, T.init_model(jax.random.PRNGKey(1), cfg), step=100)
        rep = fleet.run(max_requests=fleet.offered + 20, max_ticks=10_000)
    f = rep.fleet
    reloads = sum(n.reloader.reloads for n in nodes)
    print(f"offered {rep.offered}, completed {f['completed']}, "
          f"rejected {f['rejected']} in {rep.ticks} ticks; "
          f"hot reloads {reloads} (step {nodes[0].reloader.step})")
    print(f"  TTFT ticks p50/p95/p99 = {f['p50_ttft_ticks']:.0f}/"
          f"{f['p95_ttft_ticks']:.0f}/{f['p99_ttft_ticks']:.0f}, "
          f"queue mean/max = {f['mean_queue_depth']:.2f}/{f['max_queue_depth']:.0f}, "
          f"slot occupancy = {f['slot_occupancy']:.2f}")


def main() -> None:
    static_batches()
    continuous_batching()
    serving_fleet()


if __name__ == "__main__":
    main()
