"""Batched serving: (1) static-batch prefill+decode across three cache
families (attention KV ring buffer, SSM O(1) state, RG-LRU hybrid), and
(2) the continuous-batching ServeEngine — slot-managed requests of
different lengths admitted/retired independently, one vmapped decode step
per tick with per-slot positions.

This is the serving path the decode_32k / long_500k dry-run shapes lower at
production scale; here it runs reduced configs on CPU.

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import main as serve_main
from repro.models import transformer as T
from repro.serving import Request, ServeEngine

ARCHS = ["qwen3-1.7b", "mamba2-1.3b", "recurrentgemma-2b"]


def static_batches() -> None:
    for arch in ARCHS:
        print(f"\n--- {arch} (static batch) ---")
        sys.argv = [
            "serve", "--arch", arch, "--reduced",
            "--batch", "4", "--prompt-len", "32", "--gen", "16",
        ]
        serve_main()


def continuous_batching() -> None:
    print("\n--- qwen3-1.7b (continuous batching: 6 requests, 2 slots) ---")
    cfg = get_config("qwen3-1.7b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, int(n)).tolist(), max_new_tokens=8)
        for n in rng.integers(5, 25, 6)
    ]
    engine = ServeEngine(cfg, params, max_slots=2, cache_len=64, prompt_bucket=8)
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.output) for r in reqs)
    print(f"completed {done}/6 requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s aggregate on 2 slots)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt len {len(r.prompt):2d} -> {r.output}")


def main() -> None:
    static_batches()
    continuous_batching()


if __name__ == "__main__":
    main()
