"""End-to-end driver: AD-GDA training of an assigned transformer architecture
on the heterogeneous LM pipeline.

Four decentralized nodes each stream tokens from a *different* unigram
distribution (node-permuted Zipf); the λ dynamics upweight whichever node's
distribution the consensus model currently fits worst, while the model
parameters travel the ring as 4-bit-quantized CHOCO residuals.

On real hardware drop --reduced and point --arch at any of the 10 assigned
configs; the full-scale mesh path is exercised by repro.launch.dryrun.

``--gossip-backend ppermute`` swaps the rolled network *simulation* for the
mesh-native neighbor-exchange substrate (shard_map + collective-permute of
the packed payload — see README "Wire model"); give the host multiple
devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/train_transformer.py --gossip-backend ppermute

  PYTHONPATH=src python examples/train_transformer.py [--arch qwen3-1.7b] [--steps 60]
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or [])

from repro.launch.train import main as train_main  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--gossip-backend", choices=("rolled", "ppermute"), default="rolled")
    args = ap.parse_args()

    sys.argv = [
        "train",
        "--arch", args.arch,
        "--reduced",
        "--steps", str(args.steps),
        "--nodes", str(args.nodes),
        "--batch-per-node", "2",
        "--seq", "64",
        "--compressor", "q4b",
        "--topology", "ring",
        "--gossip-backend", args.gossip_backend,
        "--log-every", "10",
    ]
    train_main()


if __name__ == "__main__":
    main()
