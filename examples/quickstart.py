"""Quickstart: distributionally robust decentralized learning in ~40 lines.

Ten nodes hold heterogeneous data (two of them see a rotated feature space).
We train the same logistic model twice — with standard decentralized learning
(CHOCO-SGD) and with the paper's AD-GDA — using identical 4-bit-quantized
ring gossip, and compare the worst-distribution accuracy.

Both trainers are compositions of the same ``DecentralizedTrainer``: an
``ADGDAConfig`` picks the oracle (microbatches / local steps), the
``repro.optim`` optimizer + schedule (sgd/adam, const/exp/cosine + warmup),
the dual (projected ascent vs. frozen prior) and the CHOCO consensus
(compressor, packed/fused dispatch):

    trainer = adgda_trainer(ADGDAConfig(num_nodes=10, compressor="q4b",
                                        optimizer="sgd", momentum=0.9), loss_fn)
    state = trainer.init(params, key)
    state, aux = trainer.step(state, batch)

``choco_sgd(config, loss_fn)`` is the same composition with the dual frozen
at the prior — the comparison below isolates exactly the robustness delta.

The gossip here runs on the default ``rolled`` backend (the stacked-array
network simulation).  On a multi-device host the same config runs
mesh-native — only compressed payloads travel between ring neighbors as
collective-permutes (README "Wire model"):

    from repro.launch.mesh import make_node_mesh
    cfg = ADGDAConfig(num_nodes=10, compressor="q4b", gossip_backend="ppermute")
    trainer = adgda_trainer(cfg, loss_fn, mesh=make_node_mesh(10))

  PYTHONPATH=src python examples/quickstart.py [--steps 600]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ADGDAConfig, adgda_trainer, choco_sgd
from repro.data import rotated_minority_classification

args = argparse.ArgumentParser()
args.add_argument("--steps", type=int, default=600, help="training rounds per trainer")
args = args.parse_args()

# --- heterogeneous data: nodes 0-1 are the "minority" sub-population -------
data = rotated_minority_classification(num_nodes=10, minority_nodes=2, seed=1)


def loss_fn(params, batch, rng):
    x, y = batch
    logits = x @ params["w"] + params["b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()


def train(trainer, steps):
    params = {"w": jnp.zeros((data.dim, data.num_classes)), "b": jnp.zeros((data.num_classes,))}
    state = trainer.init(params, jax.random.PRNGKey(0))
    gen = data.batches(50, seed=0)
    for _ in range(steps):
        xb, yb = next(gen)
        state, aux = trainer.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
    return trainer.network_mean(state), float(trainer.bits_per_round(state)) * steps


def evaluate(params):
    out = {}
    for name, x, y in zip(data.val_names, data.val_x, data.val_y):
        pred = np.asarray(jnp.argmax(jnp.asarray(x) @ params["w"] + params["b"], -1))
        out[name] = float((pred == y).mean())
    return out


config = ADGDAConfig(
    num_nodes=10, topology="ring", compressor="q4b",  # 4-bit quantized gossip
    alpha=0.05, eta_theta=0.3, eta_lambda=0.2, lr_decay=0.99,
)

robust, bits = train(adgda_trainer(config, loss_fn), args.steps)
standard, _ = train(choco_sgd(config, loss_fn), args.steps)

print(f"transmitted per node: {bits / 8e6:.1f} MB (4-bit compressed ring gossip)")
print(f"{'':12s} {'majority':>9s} {'minority':>9s} {'worst':>9s}")
for name, params in (("AD-GDA", robust), ("CHOCO-SGD", standard)):
    acc = evaluate(params)
    print(f"{name:12s} {acc['majority']:9.3f} {acc['minority']:9.3f} {min(acc.values()):9.3f}")
