"""Sharding-agnostic pytree checkpointing to .npz.

Leaves are addressed by their tree path ("layer/0/mixer/wq"), so save/restore
round-trips any nested dict/list/tuple/NamedTuple of arrays.  Arrays are
pulled to host (fully addressable) before writing — on a real multi-pod run
wrap with ``jax.experimental.multihost_utils.process_allgather`` first.
"""
from __future__ import annotations

import os
import re

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]

_SEP = "|"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def save(path: str, tree, step: int | None = None) -> str:
    """Write `tree` to `<path>[_<step>].npz`. Returns the file written."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {_path_str(p): np.asarray(v) for p, v in flat}
    fname = f"{path}_{step:08d}.npz" if step is not None else (path if path.endswith(".npz") else path + ".npz")
    os.makedirs(os.path.dirname(fname) or ".", exist_ok=True)
    tmp = fname + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, fname)
    return fname


def restore(fname: str, tree_like):
    """Load into the structure of `tree_like` (dtypes/shapes validated)."""
    with np.load(fname) as data:
        flat, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = []
        for p, ref in flat:
            key = _path_str(p)
            if key not in data:
                raise KeyError(f"checkpoint {fname} missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"{key}: shape {arr.shape} != expected {ref.shape}")
            leaves.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(tdef, leaves)


def latest_step(path: str) -> int | None:
    """Largest step among `<path>_<step>.npz` files, or None."""
    d = os.path.dirname(path) or "."
    base = os.path.basename(path)
    pat = re.compile(re.escape(base) + r"_(\d{8})\.npz$")
    steps = [int(m.group(1)) for f in os.listdir(d) if (m := pat.match(f))] if os.path.isdir(d) else []
    return max(steps) if steps else None
