"""Sharding-agnostic pytree checkpointing to .npz.

Leaves are addressed by their tree path ("layer/0/mixer/wq"), so save/restore
round-trips any nested dict/list/tuple/NamedTuple of arrays — including a
full ``TrainerState`` (theta, lam, optimizer moments, CHOCO trackers, rng,
step), which is what ``launch/train.py --resume`` relies on for bit-identical
kill-and-resume.  Arrays are pulled to host (fully addressable) before
writing — on a real multi-pod run wrap with
``jax.experimental.multihost_utils.process_allgather`` first.

Writes are atomic *and durable*: the payload lands in ``<file>.tmp``, is
``fsync``ed, ``os.replace``d into place, and the containing directory is
``fsync``ed too — so a run killed mid-save never leaves a truncated
checkpoint where ``latest_step`` would find it, and a completed save
survives power loss.  :func:`restore_latest` is the defensive entry point
for ``--resume``: it walks the step-tagged files newest-first and falls
back past any unreadable one (e.g. written by an older non-atomic tool) to
the last *complete* checkpoint, reporting what it skipped.
"""
from __future__ import annotations

import os
import re

import jax
import numpy as np

__all__ = [
    "save",
    "restore",
    "restore_latest",
    "latest_step",
    "all_steps",
    "step_path",
]

_SEP = "|"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def _strip_npz(path: str) -> str:
    return path[: -len(".npz")] if path.endswith(".npz") else path


def step_path(path: str, step: int) -> str:
    """The filename :func:`save` writes for (path, step) — the single source
    of truth for the step-tagged naming scheme (consumed by ``--resume``)."""
    return f"{_strip_npz(path)}_{step:08d}.npz"


def save(path: str, tree, step: int | None = None) -> str:
    """Write `tree` to `<path>[_<step>].npz`. Returns the file written.

    With ``step``, a ``.npz`` suffix on ``path`` is stripped first so
    ``save("ckpt.npz", t, step=100)`` writes ``ckpt_00000100.npz`` (not the
    doubled ``ckpt.npz_00000100.npz``), matching what :func:`latest_step`
    discovers.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {_path_str(p): np.asarray(v) for p, v in flat}
    if step is not None:
        fname = step_path(path, step)
    else:
        fname = path if path.endswith(".npz") else path + ".npz"
    os.makedirs(os.path.dirname(fname) or ".", exist_ok=True)
    tmp = fname + ".tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
            f.flush()
            os.fsync(f.fileno())  # durable before it becomes visible
        os.replace(tmp, fname)
        _fsync_dir(os.path.dirname(fname) or ".")  # the rename itself
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return fname


def _fsync_dir(dirname: str) -> None:
    """fsync a directory so a just-completed rename survives power loss.
    Best-effort: some filesystems refuse O_RDONLY fsync on directories."""
    try:
        fd = os.open(dirname, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def restore(fname: str, tree_like):
    """Load into the structure of `tree_like` (dtypes/shapes validated).

    ``tree_like`` may hold concrete arrays or ``jax.ShapeDtypeStruct``s (e.g.
    from ``jax.eval_shape(trainer.init, ...)``) — only shape/dtype are read.
    Shape mismatches raise; dtypes are cast to the reference leaf's dtype
    (checkpoints written by this module already match, so the cast is the
    identity on round-trips).
    """
    with np.load(fname) as data:
        flat, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = []
        for p, ref in flat:
            key = _path_str(p)
            if key not in data:
                raise KeyError(f"checkpoint {fname} missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"{key}: shape {arr.shape} != expected {ref.shape}")
            leaves.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(tdef, leaves)


def all_steps(path: str) -> list[int]:
    """All steps with a `<path>_<step>.npz` file, ascending (may be empty).

    Accepts the same ``path`` spelling as :func:`save` (a trailing ``.npz``
    is ignored) and skips in-flight ``.tmp`` files from interrupted saves.
    """
    path = _strip_npz(path)
    d = os.path.dirname(path) or "."
    base = os.path.basename(path)
    pat = re.compile(re.escape(base) + r"_(\d{8})\.npz$")
    steps = [int(m.group(1)) for f in os.listdir(d) if (m := pat.match(f))] if os.path.isdir(d) else []
    return sorted(steps)


def latest_step(path: str) -> int | None:
    """Largest step among `<path>_<step>.npz` files, or None."""
    steps = all_steps(path)
    return steps[-1] if steps else None


def restore_latest(path: str, tree_like, *, log=print):
    """Restore the newest *loadable* step-tagged checkpoint under ``path``.

    Returns ``(tree, step)``, or ``(None, None)`` when no checkpoint loads.
    The atomic+fsync :func:`save` never leaves a truncated file under the
    final name, but checkpoints written by older tools (or copied around)
    can still be damaged — a corrupt/truncated/mismatched file is reported
    via ``log`` and skipped, falling back to the last complete one instead
    of crashing the resume.
    """
    for step in reversed(all_steps(path)):
        fname = step_path(path, step)
        try:
            return restore(fname, tree_like), step
        except Exception as e:  # BadZipFile / KeyError / ValueError / OSError
            log(
                f"checkpoint {fname} is unreadable ({type(e).__name__}: {e}); "
                f"falling back to the previous complete checkpoint"
            )
    return None, None
