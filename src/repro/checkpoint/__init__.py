from repro.checkpoint.npz import (
    all_steps,
    latest_step,
    restore,
    restore_latest,
    save,
    step_path,
)

__all__ = [
    "all_steps",
    "latest_step",
    "restore",
    "restore_latest",
    "save",
    "step_path",
]
