from repro.checkpoint.npz import latest_step, restore, save, step_path

__all__ = ["latest_step", "restore", "save", "step_path"]
