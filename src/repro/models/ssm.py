"""Mamba2 — state-space duality (SSD) mixer [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of length L; within a chunk the recurrence is evaluated as a masked
attention-like matmul (MXU-friendly), states are passed between chunks with a
lax.scan.  Decode is the O(1)-state recurrent step — this is why mamba2 runs
the long_500k shape natively.

Layout: x [B, S, d]; heads H = expand*d / head_dim P; shared B/C of state
size N (n_groups = 1).  The recurrence per head h:

    state_t = exp(dt_t * A_h) * state_{t-1} + dt_t * x_t ⊗ B_t
    y_t     = C_t · state_t + D_h * x_t
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_ch = di + 2 * N
    return di, H, N, conv_ch


def init_mamba2(key, cfg):
    d = cfg.d_model
    di, H, N, conv_ch = dims(cfg)
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 4)
    return {
        # order: [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": dense_init(ks[0], d, (d, 2 * di + 2 * N + H), dt),
        "conv_w": dense_init(ks[1], cfg.ssm_conv_width, (cfg.ssm_conv_width, conv_ch), dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),  # softplus^-1(1)
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], di, (di, d), dt),
    }


def _split_proj(proj, cfg):
    di, H, N, _ = dims(cfg)
    z = proj[..., :di]
    x = proj[..., di : 2 * di]
    b = proj[..., 2 * di : 2 * di + N]
    c = proj[..., 2 * di + N : 2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N :]
    return z, x, b, c, dt


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d; u: [B, S, C], w: [W, C]."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + u.shape[1], :] * w[i] for i in range(W))
    return out + b


def _gated_norm(y, z, scale, eps=1e-6):
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    ms = (gf**2).mean(-1, keepdims=True)
    return (gf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def apply_mamba2(params, x: jax.Array, cfg) -> jax.Array:
    """Full-sequence (train/prefill) chunked SSD.  x: [B, S, d] -> [B, S, d]."""
    y, _ = mamba2_scan(params, x, cfg, return_state=False)
    return y


def mamba2_scan(params, x: jax.Array, cfg, return_state: bool = True, init_state=None):
    B, S, d = x.shape
    di, H, N, conv_ch = dims(cfg)
    P = cfg.ssm_head_dim
    L = min(cfg.ssm_chunk, S)
    assert S % L == 0, f"seq {S} must be divisible by ssm chunk {L}"
    nc = S // L

    proj = x @ params["in_proj"]
    z, xs, bs, cs, dts = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xs, bs, cs], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"], params["conv_b"]))
    xs, bs, cs = conv_out[..., :di], conv_out[..., di : di + N], conv_out[..., di + N :]

    xh = xs.reshape(B, S, H, P).astype(jnp.float32)
    bs = bs.astype(jnp.float32)
    cs = cs.astype(jnp.float32)
    dt = jax.nn.softplus(dts.astype(jnp.float32) + params["dt_bias"])  # [B, S, H]
    A = -jnp.exp(params["A_log"])  # [H], negative

    # chunk views: [nc, B, L, ...]
    def chunked(a):
        return a.reshape(B, nc, L, *a.shape[2:]).swapaxes(0, 1)

    xh_c, b_c, c_c, dt_c = chunked(xh), chunked(bs), chunked(cs), chunked(dt)

    tril = jnp.tril(jnp.ones((L, L), bool))

    def body(state, inp):
        xc, bc, cc, dtc = inp  # [B,L,H,P], [B,L,N], [B,L,N], [B,L,H]
        dA = dtc * A  # [B,L,H] (<= 0)
        cum = jnp.cumsum(dA, axis=1)  # inclusive
        # intra-chunk: y_t += sum_{s<=t} exp(cum_t - cum_s) (C_t.B_s) dt_s x_s
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # [B,L,L,H]
        decay = jnp.exp(jnp.where(tril[None, :, :, None], diff, -jnp.inf))
        cb = jnp.einsum("bln,bsn->bls", cc, bc)
        m = cb[..., None] * decay * dtc[:, None, :, :]  # [B,l,s,H]
        y = jnp.einsum("blsh,bshp->blhp", m, xc)
        # inter-chunk: contribution of the incoming state
        y = y + jnp.exp(cum)[..., None] * jnp.einsum("bln,bhpn->blhp", cc, state)
        # state to pass on
        to_end = jnp.exp(cum[:, -1:, :] - cum) * dtc  # [B,L,H]
        state = jnp.exp(cum[:, -1, :])[:, :, None, None] * state + jnp.einsum(
            "blh,blhp,bln->bhpn", to_end, xc, bc
        )
        y = y + params["D"][None, None, :, None] * xc
        return state, y

    state0 = init_state if init_state is not None else jnp.zeros((B, H, P, N), jnp.float32)
    final_state, ys = jax.lax.scan(body, state0, (xh_c, b_c, c_c, dt_c))
    y = ys.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype)

    out = _gated_norm(y, z, params["norm_scale"]) @ params["out_proj"]
    if not return_state:
        return out, None
    # conv tail for seamless decode continuation
    conv_tail = jax.lax.dynamic_slice_in_dim(conv_in, S - (cfg.ssm_conv_width - 1), cfg.ssm_conv_width - 1, axis=1)
    return out, {"ssm": final_state, "conv": conv_tail}


# ----------------------------------------------------------------- decode
def init_mamba2_cache(cfg, batch: int):
    di, H, N, conv_ch = dims(cfg)
    return {
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), cfg.activation_dtype),
    }


def decode_mamba2(params, x: jax.Array, cache: dict, cfg):
    """One-token step. x: [B, 1, d] -> (y [B, 1, d], cache)."""
    B = x.shape[0]
    di, H, N, conv_ch = dims(cfg)
    P = cfg.ssm_head_dim

    proj = (x @ params["in_proj"])[:, 0]  # [B, ...]
    z, xs, bs, cs, dts = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xs, bs, cs], axis=-1)  # [B, conv_ch]
    window = jnp.concatenate([cache["conv"], conv_in[:, None, :]], axis=1)  # [B, W, C]
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"])
    new_conv = window[:, 1:, :]

    xs, bs, cs = conv_out[:, :di], conv_out[:, di : di + N], conv_out[:, di + N :]
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dts.astype(jnp.float32) + params["dt_bias"])  # [B, H]
    A = -jnp.exp(params["A_log"])
    da = jnp.exp(dt * A)  # [B, H]

    state = cache["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, bs.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", cs.astype(jnp.float32), state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, di).astype(x.dtype)

    out = _gated_norm(y, z, params["norm_scale"]) @ params["out_proj"]
    return out[:, None, :], {"ssm": state, "conv": new_conv}
