"""Mixture-of-Experts FFN with sort-based capacity dispatch (expert parallel).

Token -> expert routing is top-k softmax; dispatch is the static-shape
sort/scatter scheme (no [T, E, C] one-hot einsum, whose FLOPs would dwarf the
expert matmuls):

  1. top-k experts per token, gates renormalized;
  2. assignments sorted by expert id (stable argsort);
  3. position-in-expert via cumulative counts; tokens beyond the capacity
     C = ceil(cf * T * k / E) are dropped (GShard-style);
  4. tokens gathered into an [E, C, d] buffer; experts run as one batched
     einsum with weights [E, d, f] (expert dim shardable over `model`);
  5. results scattered back, gate-weighted, plus optional shared experts.

The router load-balance auxiliary loss (Switch/GShard form) is returned so
the trainer can add ``router_aux_weight * aux``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import apply_mlp, dense_init, init_mlp


def init_moe(key, cfg):
    d, f, E = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], d, (E, d, f), dt),
        "w_up": dense_init(ks[2], d, (E, d, f), dt),
        "w_down": dense_init(ks[3], f, (E, f, d), dt),
    }
    if cfg.num_shared_experts > 0:
        shared_f = f * cfg.num_shared_experts
        sks = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sks[0], d, (d, shared_f), dt),
            "w_up": dense_init(sks[1], d, (d, shared_f), dt),
            "w_down": dense_init(sks[2], shared_f, (shared_f, d), dt),
        }
    return p


def capacity_for(tokens: int, cfg) -> int:
    c = int(math.ceil(cfg.capacity_factor * tokens * cfg.experts_per_token / cfg.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def apply_moe(params, x: jax.Array, cfg):
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = capacity_for(T, cfg)
    xt = x.reshape(T, d)

    # --- routing (fp32 for numerics) -----------------------------------
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # [T, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- load-balance aux (Switch eq. 4) --------------------------------
    me = probs.mean(0)  # mean router prob per expert
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch (gather form) --------------------------------
    # Expert-parallel sharding note: the [E, C, d] buffer is built by a
    # *gather* from the (replicated) token table, indexed by a slot->token
    # map.  With the expert weights sharded over `model` on E, GSPMD keeps
    # the gather local to each expert shard; the combine is a scatter-add of
    # shard-local partials followed by one [T, d] all-reduce.  (The previous
    # scatter-into-sharded-buffer formulation forced GSPMD to replicate the
    # full [E*C, d] buffer on every shard — see EXPERIMENTS §Perf.)
    flat_e = expert_idx.reshape(T * K)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate_vals.reshape(T * K)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts

    # slot (e, c) <- sorted assignment starts[e] + c (valid while c < counts[e])
    slot_src = starts[:, None] + jnp.arange(C)[None, :]  # [E, C]
    valid = jnp.arange(C)[None, :] < jnp.minimum(counts, C)[:, None]
    slot_src = jnp.where(valid, slot_src, T * K)  # sentinel -> pad row
    st_pad = jnp.concatenate([st, jnp.array([T], st.dtype)])
    sg_pad = jnp.concatenate([sg, jnp.zeros((1,), sg.dtype)])
    src_tok = st_pad[slot_src]  # [E, C] token index feeding each slot
    gate_slot = jnp.where(valid, sg_pad[slot_src], 0.0)  # [E, C]

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)], axis=0)
    eb = xt_pad[src_tok]  # [E, C, d] — local gather per expert shard

    # --- batched expert FFN (E shardable over `model`) -------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", eb, params["w_up"])
    yb = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    # --- combine: shard-local scatter-add of gated outputs + all-reduce ---
    weighted = yb * gate_slot[..., None].astype(yb.dtype)
    y = jnp.zeros((T + 1, d), x.dtype).at[src_tok.reshape(-1)].add(
        weighted.reshape(E * C, d)
    )[:T]

    if "shared" in params:
        y = y + apply_mlp(params["shared"], xt)
    return y.reshape(B, S, d), aux
