"""Shared transformer layers: norms, RoPE, GQA attention (train/prefill/decode),
MLPs.  Functional style: ``init_*`` builds param dicts, ``apply_*`` consumes
them.  Shape convention: activations [batch, seq, d_model]; caches
[batch, seq, kv_heads, head_dim].

Scale-critical choices:
* attention is query-chunked (lax.scan) above ``CHUNK_THRESHOLD`` so 32k+
  prefill never materializes a [S, S] score matrix (flash-style at XLA level);
* sliding-window decode caches are ring buffers of window size (sub-quadratic
  long-context variant for dense archs);
* weights are stored with head/ffn dims explicit so PartitionSpecs can target
  them (see repro/launch/sharding.py).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

CHUNK_THRESHOLD = 4096
QUERY_CHUNK = 1024

# Context-parallel attention (EXPERIMENTS §Perf C4): when the head count does
# not divide the model axis (llama4: 40 heads / 16 ranks), QKV projections
# fall back to replication and every rank computes all heads' scores.  Setting
# this to a mesh axis name shards the *query-sequence* dim of the attention
# inner loop instead — requires the caller's vmap to pass spmd_axis_name so
# the constraint applies under the AD-GDA node vmap.  Off by default.
SEQ_SHARD_AXIS: str | None = None


def _seq_shard(x, dim: int = 1):
    """Best-effort sharding constraint of dim `dim` over SEQ_SHARD_AXIS."""
    if SEQ_SHARD_AXIS is None:
        return x
    try:
        from jax.sharding import PartitionSpec as P

        spec = [None] * x.ndim
        spec[dim] = SEQ_SHARD_AXIS
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


# ---------------------------------------------------------------------- init
def dense_init(key, fan_in, shape, dtype):
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------- norms
def init_norm(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), cfg.activation_dtype), "bias": jnp.zeros((d,), cfg.activation_dtype)}
    return {"scale": jnp.ones((d,), cfg.activation_dtype)}


def apply_norm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in params:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        ms = (xf**2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def init_attention(key, cfg, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (d, H, hd), dt),
        "wk": dense_init(ks[1], d, (d, KV, hd), dt),
        "wv": dense_init(ks[2], d, (d, KV, hd), dt),
        "wo": dense_init(ks[3], H * hd, (H, hd, d), dt),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
        p["bo"] = jnp.zeros((d,), dt)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _qk_normalize(v, scale):
    vf = v.astype(jnp.float32)
    ms = (vf**2).mean(-1, keepdims=True)
    return (vf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(v.dtype)


def _project_qkv(params, x, kv_src, cfg, positions, kv_positions, cross):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if "q_norm" in params:
        q = _qk_normalize(q, params["q_norm"])
        k = _qk_normalize(k, params["k_norm"])
    if not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """[B,S,KV,hd] -> [B,S,H,hd] by repeating groups."""
    kv = k.shape[2]
    if kv == num_heads:
        return k
    return jnp.repeat(k, num_heads // kv, axis=2)


def _attend(q, k, v, mask, scale):
    """q:[B,Sq,H,hd] k,v:[B,Sk,H,hd] mask:[B?,Sq,Sk] or None -> [B,Sq,H,hd]."""
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


@functools.lru_cache(maxsize=64)
def _sparse_pattern(seq: int, window, block: int):
    from repro.kernels.block_sparse import BlockSparsePattern

    if window is None:
        return BlockSparsePattern.causal_pattern(seq, seq, block, block)
    return BlockSparsePattern.windowed(seq, seq, window, block, block)


def _kernel_attention(q, k, v, kernel: str, window: int | None):
    """Route [B,S,H,hd] q/k/v through a kernels/ attention kernel, or return
    None when no kernel fits the shape (caller keeps the XLA path)."""
    from repro.kernels import ops

    S = q.shape[1]
    if kernel == "flash":
        if window is not None and S >= 256:
            return ops.sliding_window_attention(q, k, v, window=window)
        return ops.flash_attention(q, k, v, causal=True, window=window)
    if kernel == "block_sparse":
        block = next((b for b in (128, 64, 32, 16, 8) if S % b == 0), None)
        if block is None:
            return None
        return ops.block_sparse_attention(
            q, k, v, _sparse_pattern(S, window, block)
        )
    raise ValueError(f"unknown attn_kernel {kernel!r}")


def apply_attention(
    params,
    x: jax.Array,
    cfg,
    *,
    causal: bool = True,
    window: int | None = None,
    kv_src: jax.Array | None = None,
    positions: jax.Array | None = None,
) -> jax.Array:
    """Full (train/prefill) attention; query-chunked beyond CHUNK_THRESHOLD."""
    B, S, _ = x.shape
    cross = kv_src is not None
    kv_in = kv_src if cross else x
    Sk = kv_in.shape[1]
    default_positions = positions is None
    if positions is None:
        positions = jnp.arange(S)
    kv_positions = jnp.arange(Sk)
    q, k, v = _project_qkv(params, x, kv_in, cfg, positions, kv_positions, cross)
    k = _repeat_kv(k, cfg.num_heads)
    v = _repeat_kv(v, cfg.num_heads)
    scale = 1.0 / math.sqrt(cfg.hd)

    # Pallas kernel dispatch (cfg.attn_kernel): causal self-attention with
    # contiguous positions only — cross attention and explicit position maps
    # keep the XLA path.  Default (None) is bit-identical pre-kernel XLA.
    kernel = getattr(cfg, "attn_kernel", None)
    if kernel is not None and not cross and causal and default_positions:
        out = _kernel_attention(q, k, v, kernel, window)
        if out is not None:
            y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
            if "bo" in params:
                y = y + params["bo"]
            return y

    def mask_for(q_pos):
        # q_pos: [Sq] absolute query positions
        if cross or (not causal and window is None):
            return None
        kpos = jnp.arange(Sk)
        m = jnp.ones((q_pos.shape[0], Sk), bool)
        if causal:
            m &= q_pos[:, None] >= kpos[None, :]
        if window is not None:
            m &= q_pos[:, None] - kpos[None, :] < window
        return jnp.broadcast_to(m[None], (B, q_pos.shape[0], Sk))

    if S <= CHUNK_THRESHOLD:
        q = _seq_shard(q)  # context parallelism (no-op unless enabled)
        out = _attend(q, k, v, mask_for(jnp.arange(S)), scale)
    else:
        nchunk = S // QUERY_CHUNK
        assert S % QUERY_CHUNK == 0, "long-seq prefill requires seq % QUERY_CHUNK == 0"
        qs = q.reshape(B, nchunk, QUERY_CHUNK, cfg.num_heads, cfg.hd).transpose(1, 0, 2, 3, 4)

        def body(c, qc):
            qpos = c * QUERY_CHUNK + jnp.arange(QUERY_CHUNK)
            qc = _seq_shard(qc)  # context parallelism within the chunk
            o = _attend(qc, k, v, mask_for(qpos), scale)
            return c + 1, o

        _, outs = jax.lax.scan(body, 0, qs)
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, cfg.num_heads, cfg.hd)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if "bo" in params:
        y = y + params["bo"]
    return y


# --------------------------------------------------------------- decode path
def init_attn_cache(cfg, batch: int, length: int, dtype=None):
    dt = dtype or cfg.activation_dtype
    shape = (batch, length, cfg.num_kv_heads, cfg.hd)
    if getattr(cfg, "quantized_kv", False):
        # int8 cache + per-(slot, kv-head) dequant scales: 1/4 the bytes per
        # decode tick, read by the fused decode kernel which dequants inside
        # its contractions (never materializing an f32 copy)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:3], jnp.float32),
            "v_scale": jnp.zeros(shape[:3], jnp.float32),
        }
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_attention(
    params,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
    cfg,
    *,
    window: int | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
) -> tuple[jax.Array, dict]:
    """One-token decode.  x: [B, 1, d]; pos: scalar int32 (tokens so far).

    Self-attention path updates the cache (ring buffer when ``window``).
    ``cross_kv`` (whisper) attends precomputed encoder K/V with no update.
    """
    B = x.shape[0]
    scale = 1.0 / math.sqrt(cfg.hd)

    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
        if "bq" in params:
            q = q + params["bq"]
        k, v = cross_kv
        k = _repeat_kv(k, cfg.num_heads)
        v = _repeat_kv(v, cfg.num_heads)
        out = _attend(q, k, v, None, scale)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
        if "bo" in params:
            y = y + params["bo"]
        return y, cache

    length = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, x, cfg, positions, positions[:, 0:1], cross=False)

    slot = pos % length if window is not None else pos  # ring buffer for windows
    quantized = "k_scale" in cache
    if quantized:
        from repro.kernels import quantize_kv

        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, slot, 0)),
            "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, slot, 0)),
        }
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}

    idx = jnp.arange(length)
    if window is not None:
        # ring buffer slot i holds absolute position: valid iff within window
        # absolute pos of slot i: the latest write to slot i <= pos
        age = (slot - idx) % length  # 0 = newest
        valid = age < jnp.minimum(pos + 1, length)
    else:
        valid = idx <= pos

    if quantized or getattr(cfg, "attn_kernel", None) is not None:
        # fused decode kernel: one pass over the cache, grouped heads handled
        # in-kernel (no _repeat_kv materialization), int8 dequant fused into
        # the contractions when the cache is quantized
        from repro.kernels import decode_attention_kernel

        out = decode_attention_kernel(
            q,
            new_cache["k"],
            new_cache["v"],
            jnp.broadcast_to(valid[None], (B, length)),
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"),
        ).astype(x.dtype)
    else:
        kk = _repeat_kv(new_cache["k"].astype(x.dtype), cfg.num_heads)
        vv = _repeat_kv(new_cache["v"].astype(x.dtype), cfg.num_heads)
        mask = jnp.broadcast_to(valid[None, None, :], (B, 1, length))
        out = _attend(q, kk, vv, mask, scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if "bo" in params:
        y = y + params["bo"]
    return y, new_cache


# ----------------------------------------------------------------------- mlp
def init_mlp(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, (d, f), dt),
            "w_up": dense_init(ks[1], d, (d, f), dt),
            "w_down": dense_init(ks[2], f, (f, d), dt),
        }
    p = {"w1": dense_init(ks[0], d, (d, f), dt), "w2": dense_init(ks[1], f, (f, d), dt)}
    if cfg.use_bias:
        p["b1"] = jnp.zeros((f,), dt)
        p["b2"] = jnp.zeros((d,), dt)
    return p


def apply_mlp(params, x):
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_down"]
    h = x @ params["w1"]
    if "b1" in params:
        h = h + params["b1"]
    h = jax.nn.gelu(h)
    y = h @ params["w2"]
    if "b2" in params:
        y = y + params["b2"]
    return y


# ----------------------------------------------------------------- embedding
def init_embedding(key, cfg):
    return {"table": dense_init(key, cfg.d_model, (cfg.vocab_size, cfg.d_model), cfg.activation_dtype)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    return jnp.einsum("bsd,vd->bsv", x, params["table"])
