"""Full model assembly for every assigned architecture family.

The model is a decoder stack whose per-layer sequence mixer is selected by
``cfg.layer_pattern`` (attn / local_attn / mamba2 / rglru) and whose FFN is
dense or MoE.  Layers are grouped into **repeated blocks of one pattern
period** and the repeats are executed with ``jax.lax.scan`` over *stacked*
parameters — this keeps the lowered HLO O(pattern) instead of O(num_layers),
which is what makes the 80 (arch x shape x mesh) dry-run compiles tractable
and is also the production-sane choice (MaxText does the same).

Layout:

    params = {
      "embed": {...},
      "prefix":  [layer, ...]          # first_dense_layers (unrolled)
      "blocks":  (stacked_layer_0, ..., stacked_layer_{p-1})
                                       # leaves [n_blocks, ...] per pattern pos
      "suffix":  [layer, ...]          # num_layers % p remainder (unrolled)
      "final_norm": {...},
      "encoder": {...}                 # whisper only
    }

Three entry points per model, matching the assigned input shapes:

    forward(params, batch, cfg)                  -> logits       (train_4k)
    prefill(params, batch, cfg, cache_len)       -> logits, cache (prefill_32k)
    decode_step(params, tokens, cache, pos, cfg) -> logits, cache (decode_*)

[audio]/[vlm] carve-out: the modality frontend is a stub — ``batch`` carries
precomputed frame/patch *embeddings* ([B, T, d_model]) next to the tokens.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_attention,
    apply_mlp,
    apply_norm,
    decode_attention,
    embed,
    init_attention,
    init_attn_cache,
    init_embedding,
    init_mlp,
    init_norm,
    unembed,
)
from repro.models.moe import apply_moe, init_moe
from repro.models.rglru import (
    apply_rglru,
    decode_rglru,
    init_rglru,
    init_rglru_cache,
)
from repro.models.ssm import (
    decode_mamba2,
    init_mamba2,
    init_mamba2_cache,
    mamba2_scan,
)

__all__ = [
    "init_model",
    "forward",
    "prefill",
    "decode_step",
    "init_cache",
    "lm_loss",
    "param_count",
    "active_param_count",
]


# ---------------------------------------------------------------- structure
def _pattern_split(cfg: ModelConfig) -> tuple[int, int, int]:
    """-> (prefix_layers, n_blocks, suffix_layers) with p = len(pattern)."""
    p = len(cfg.layer_pattern)
    body = cfg.num_layers - cfg.first_dense_layers
    return cfg.first_dense_layers, body // p, body % p


def _layer_kind(cfg: ModelConfig, global_idx: int) -> str:
    return cfg.mixer_for_layer(global_idx)


# -------------------------------------------------------------------- init
def _init_layer(key, cfg: ModelConfig, kind: str, moe: bool, cross: bool = False):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": init_norm(cfg)}
    if kind in ("attn", "local_attn"):
        p["mixer"] = init_attention(ks[0], cfg)
    elif kind == "mamba2":
        p["mixer"] = init_mamba2(ks[0], cfg)
    elif kind == "rglru":
        p["mixer"] = init_rglru(ks[0], cfg)
    else:  # pragma: no cover
        raise ValueError(f"unknown mixer kind {kind!r}")
    if cross:
        p["norm_cross"] = init_norm(cfg)
        p["cross"] = init_attention(ks[2], cfg, cross=True)
    if cfg.d_ff > 0 or moe:
        p["norm2"] = init_norm(cfg)
        p["ffn"] = init_moe(ks[1], cfg) if moe else init_mlp(ks[1], cfg)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_model(key, cfg: ModelConfig):
    pre, nb, suf = _pattern_split(cfg)
    p_len = len(cfg.layer_pattern)
    keys = jax.random.split(key, cfg.num_layers + 3)
    cross = cfg.is_encdec

    params: dict[str, Any] = {"embed": init_embedding(keys[-1], cfg)}

    li = 0
    prefix = []
    for _ in range(pre):
        prefix.append(
            _init_layer(keys[li], cfg, _layer_kind(cfg, li), moe=False, cross=cross)
        )
        li += 1
    if prefix:
        params["prefix"] = prefix

    blocks = []
    for pos in range(p_len):
        per_pos = []
        for b in range(nb):
            gidx = pre + b * p_len + pos
            per_pos.append(
                _init_layer(
                    keys[pre + pos * nb + b],
                    cfg,
                    _layer_kind(cfg, gidx),
                    moe=cfg.ffn_is_moe(gidx),
                    cross=cross,
                )
            )
        blocks.append(_stack(per_pos) if per_pos else None)
    if nb > 0:
        params["blocks"] = blocks

    suffix = []
    for s in range(suf):
        gidx = pre + nb * p_len + s
        suffix.append(
            _init_layer(keys[li + s], cfg, _layer_kind(cfg, gidx), moe=cfg.ffn_is_moe(gidx), cross=cross)
        )
    if suffix:
        params["suffix"] = suffix

    params["final_norm"] = init_norm(cfg)

    if cfg.is_encdec:
        ek = jax.random.split(keys[-2], cfg.encoder_layers + 1)
        enc_layers = [
            _init_layer(ek[i], cfg, "attn", moe=False, cross=False)
            for i in range(cfg.encoder_layers)
        ]
        params["encoder"] = {"blocks": _stack(enc_layers), "final_norm": init_norm(cfg)}
    return params


# ----------------------------------------------------------------- forward
def _apply_layer(p, x, cfg: ModelConfig, kind: str, moe: bool, *, enc_out=None, causal=True):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x)
    if kind == "attn":
        window = None
    elif kind == "local_attn":
        window = cfg.sliding_window
    if kind in ("attn", "local_attn"):
        y = apply_attention(p["mixer"], h, cfg, causal=causal, window=window)
    elif kind == "mamba2":
        y, _ = mamba2_scan(p["mixer"], h, cfg, return_state=False)
    else:  # rglru
        y = apply_rglru(p["mixer"], h, cfg)
    x = x + y
    if "cross" in p and enc_out is not None:
        h = apply_norm(p["norm_cross"], x)
        x = x + apply_attention(p["cross"], h, cfg, causal=False, kv_src=enc_out)
    if "ffn" in p:
        h = apply_norm(p["norm2"], x)
        if moe:
            y, a = apply_moe(p["ffn"], h, cfg)
            aux = aux + a
        else:
            y = apply_mlp(p["ffn"], h)
        x = x + y
    return x, aux


def _run_blocks(params, x, cfg: ModelConfig, *, enc_out=None):
    """Scan the repeated pattern blocks; returns (x, aux_sum)."""
    pre, nb, suf = _pattern_split(cfg)
    p_len = len(cfg.layer_pattern)
    aux_total = jnp.zeros((), jnp.float32)

    for i, p in enumerate(params.get("prefix", [])):
        x, a = _apply_layer(p, x, cfg, _layer_kind(cfg, i), moe=False, enc_out=enc_out)
        aux_total += a

    if nb > 0:
        kinds = [_layer_kind(cfg, pre + pos) for pos in range(p_len)]
        moes = [cfg.ffn_is_moe(pre + pos) for pos in range(p_len)]

        @jax.checkpoint  # remat: backward recomputes block activations
        def block_fwd(xc, block_params, enc):
            auxc = jnp.zeros((), jnp.float32)
            for pos in range(p_len):
                xc, a = _apply_layer(
                    block_params[pos], xc, cfg, kinds[pos], moes[pos], enc_out=enc
                )
                auxc += a
            return xc, auxc

        def body(carry, block_params):
            xc, auxc = carry
            xc, a = block_fwd(xc, block_params, enc_out)
            return (xc, auxc + a), None

        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), tuple(params["blocks"])
        )

    for s, p in enumerate(params.get("suffix", [])):
        gidx = pre + nb * p_len + s
        x, a = _apply_layer(p, x, cfg, _layer_kind(cfg, gidx), cfg.ffn_is_moe(gidx), enc_out=enc_out)
        aux_total += a
    return x, aux_total


def _encode(params, frames, cfg: ModelConfig):
    """Whisper encoder over precomputed frame embeddings (conv frontend stub)."""
    enc = params["encoder"]
    x = frames.astype(cfg.activation_dtype)

    def body(xc, p):
        xc, _ = _apply_layer(p, xc, cfg, "attn", moe=False, causal=False)
        return xc, None

    x, _ = jax.lax.scan(body, x, enc["blocks"])
    return apply_norm(enc["final_norm"], x)


def _fuse_inputs(params, batch, cfg: ModelConfig):
    """Token embedding + modality splicing. Returns (x, enc_out)."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens).astype(cfg.activation_dtype)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, batch["frames"], cfg)
    if cfg.num_patches > 0 and "patches" in batch:
        # early fusion: first num_patches positions carry patch embeddings
        pe = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, cfg.num_patches :, :]], axis=1)
    return x, enc_out


def forward(params, batch, cfg: ModelConfig):
    """Training/eval forward. batch: {"tokens": [B,S], ("frames"|"patches")}.

    Returns (logits [B,S,V], aux_loss scalar).
    """
    x, enc_out = _fuse_inputs(params, batch, cfg)
    x, aux = _run_blocks(params, x, cfg, enc_out=enc_out)
    x = apply_norm(params["final_norm"], x)
    logits = unembed(params["embed"], x)
    return logits, aux


def lm_loss(params, batch, cfg: ModelConfig, rng=None):
    """Next-token cross entropy (f32), masking pad/patch positions."""
    logits, aux = forward(params, batch, cfg)
    targets = batch["tokens"][:, 1:]
    logits = logits[:, :-1].astype(jnp.float32)
    mask = jnp.ones_like(targets, jnp.float32)
    if cfg.num_patches > 0:
        pos = jnp.arange(targets.shape[1])
        mask = mask * (pos[None, :] >= cfg.num_patches).astype(jnp.float32)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"][:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + cfg.router_aux_weight * aux


# ------------------------------------------------------------------- cache
def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, length: int):
    if kind == "attn":
        if cfg.long_context_window is not None and length > cfg.long_context_window:
            return init_attn_cache(cfg, batch, cfg.long_context_window)
        return init_attn_cache(cfg, batch, length)
    if kind == "local_attn":
        return init_attn_cache(cfg, batch, min(cfg.sliding_window, length))
    if kind == "mamba2":
        return init_mamba2_cache(cfg, batch)
    return init_rglru_cache(cfg, batch)


def init_cache(cfg: ModelConfig, batch: int, length: int):
    """Decode cache for `length` context. Mirrors the params block structure."""
    pre, nb, suf = _pattern_split(cfg)
    p_len = len(cfg.layer_pattern)
    cache: dict[str, Any] = {}
    if pre:
        cache["prefix"] = [
            _init_layer_cache(cfg, _layer_kind(cfg, i), batch, length) for i in range(pre)
        ]
    if nb > 0:
        cache["blocks"] = [
            _stack(
                [
                    _init_layer_cache(cfg, _layer_kind(cfg, pre + b * p_len + pos), batch, length)
                    for b in range(nb)
                ]
            )
            for pos in range(p_len)
        ]
    if suf:
        cache["suffix"] = [
            _init_layer_cache(cfg, _layer_kind(cfg, pre + nb * p_len + s), batch, length)
            for s in range(suf)
        ]
    if cfg.is_encdec:
        # cross K/V computed at prefill from encoder output
        cache["cross_kv"] = [
            (
                jnp.zeros((batch, cfg.encoder_context, cfg.num_kv_heads, cfg.hd), cfg.activation_dtype),
                jnp.zeros((batch, cfg.encoder_context, cfg.num_kv_heads, cfg.hd), cfg.activation_dtype),
            )
            for _ in range(cfg.num_layers)
        ]
    return cache


def _decode_layer(p, x, cache, pos, cfg: ModelConfig, kind: str, moe: bool, cross_kv=None):
    h = apply_norm(p["norm1"], x)
    if kind in ("attn", "local_attn"):
        if kind == "local_attn":
            window = cfg.sliding_window
        else:
            window = cache["k"].shape[1] if cfg.long_context_window is not None else None
        y, cache = decode_attention(p["mixer"], h, cache, pos, cfg, window=window)
    elif kind == "mamba2":
        y, cache = decode_mamba2(p["mixer"], h, cache, cfg)
    else:
        y, cache = decode_rglru(p["mixer"], h, cache, cfg)
    x = x + y
    if "cross" in p and cross_kv is not None:
        h = apply_norm(p["norm_cross"], x)
        y, _ = decode_attention(p["cross"], h, {}, pos, cfg, cross_kv=cross_kv)
        x = x + y
    if "ffn" in p:
        h = apply_norm(p["norm2"], x)
        if moe:
            y, _ = apply_moe(p["ffn"], h, cfg)
        else:
            y = apply_mlp(p["ffn"], h)
        x = x + y
    return x, cache


def decode_step(params, tokens, cache, pos, cfg: ModelConfig):
    """One-token decode. tokens: [B, 1]; pos: scalar int32 (context length so far).

    Returns (logits [B, 1, V], new_cache).
    """
    pre, nb, suf = _pattern_split(cfg)
    p_len = len(cfg.layer_pattern)
    x = embed(params["embed"], tokens).astype(cfg.activation_dtype)
    cross_list = cache.get("cross_kv")

    new_cache = dict(cache)
    li = 0
    if pre:
        pc = []
        for i, p in enumerate(params.get("prefix", [])):
            ckv = cross_list[li] if cross_list else None
            x, c = _decode_layer(p, x, cache["prefix"][i], pos, cfg, _layer_kind(cfg, i), False, ckv)
            pc.append(c)
            li += 1
        new_cache["prefix"] = pc

    if nb > 0:
        kinds = [_layer_kind(cfg, pre + pos_i) for pos_i in range(p_len)]
        moes = [cfg.ffn_is_moe(pre + pos_i) for pos_i in range(p_len)]
        if cross_list:
            # enc-dec: stack cross K/V to scan alongside (whisper: single-pos pattern)
            ck = _stack([cross_list[pre + b * p_len] for b in range(nb)])
        blocks_new = []

        def body(carry, scanned):
            xc = carry
            bp = scanned[: p_len]
            bc = scanned[p_len : 2 * p_len]
            ckv = scanned[2 * p_len] if cross_list else None
            new_cs = []
            for pp in range(p_len):
                xc, c = _decode_layer(bp[pp], xc, bc[pp], pos, cfg, kinds[pp], moes[pp], ckv)
                new_cs.append(c)
            return xc, tuple(new_cs)

        scanned_in = tuple(params["blocks"]) + tuple(cache["blocks"])
        if cross_list:
            scanned_in = scanned_in + (ck,)
        x, cs = jax.lax.scan(body, x, scanned_in)
        blocks_new = list(cs)
        new_cache["blocks"] = blocks_new

    if suf:
        sc = []
        for s, p in enumerate(params.get("suffix", [])):
            gidx = pre + nb * p_len + s
            ckv = cross_list[gidx] if cross_list else None
            x, c = _decode_layer(p, x, cache["suffix"][s], pos, cfg, _layer_kind(cfg, gidx), cfg.ffn_is_moe(gidx), ckv)
            sc.append(c)
        new_cache["suffix"] = sc

    x = apply_norm(params["final_norm"], x)
    logits = unembed(params["embed"], x)
    return logits, new_cache


# ----------------------------------------------------------------- prefill
def prefill(params, batch, cfg: ModelConfig, cache_len: int):
    """Full forward that also returns a primed decode cache.

    For attention layers the K/V of the prompt are written into the cache;
    recurrent layers return their final state.  batch["tokens"]: [B, S<=cache_len].
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x, enc_out = _fuse_inputs(params, batch, cfg)
    cache = init_cache(cfg, B, cache_len)

    pre, nb, suf = _pattern_split(cfg)
    p_len = len(cfg.layer_pattern)

    def prime_layer(p, x, c, kind, moe):
        h = apply_norm(p["norm1"], x)
        if kind in ("attn", "local_attn"):
            window = cfg.sliding_window if kind == "local_attn" else (
                cfg.long_context_window if cfg.long_context_window is not None and cache_len > (cfg.long_context_window or 0) else None
            )
            y = apply_attention(p["mixer"], h, cfg, causal=True, window=window)
            # write prompt K/V into the cache head (positions [0, S))
            from repro.models.layers import _project_qkv  # reuse projection

            positions = jnp.arange(S)
            q, k, v = _project_qkv(p["mixer"], h, h, cfg, positions, positions, False)
            L = c["k"].shape[1]
            if "k_scale" in c:
                # quantized cache: quantize at prefill-store so every decode
                # tick reads int8 (scales stored alongside, see layers)
                from repro.kernels import quantize_kv

                (k, k_sc), (v, v_sc) = quantize_kv(k), quantize_kv(v)
                if S <= L:
                    upd4 = lambda dst, src: jax.lax.dynamic_update_slice(
                        dst, src.astype(dst.dtype), (0, 0, 0, 0))
                    upd3 = lambda dst, src: jax.lax.dynamic_update_slice(
                        dst, src, (0, 0, 0))
                    c = {"k": upd4(c["k"], k), "v": upd4(c["v"], v),
                         "k_scale": upd3(c["k_scale"], k_sc),
                         "v_scale": upd3(c["v_scale"], v_sc)}
                else:
                    ring4 = lambda src, dt: jnp.roll(
                        src[:, S - L:].astype(dt), S % L, axis=1)
                    c = {"k": ring4(k, c["k"].dtype), "v": ring4(v, c["v"].dtype),
                         "k_scale": ring4(k_sc, jnp.float32),
                         "v_scale": ring4(v_sc, jnp.float32)}
            elif S <= L:
                # linear cache (or ring buffer not yet wrapped): slot == pos
                c = {
                    "k": jax.lax.dynamic_update_slice(c["k"], k.astype(c["k"].dtype), (0, 0, 0, 0)),
                    "v": jax.lax.dynamic_update_slice(c["v"], v.astype(c["v"].dtype), (0, 0, 0, 0)),
                }
            else:
                # ring buffer: slot of absolute position p is p % L — the last L
                # keys land rolled by S % L
                c = {
                    "k": jnp.roll(k[:, S - L :].astype(c["k"].dtype), S % L, axis=1),
                    "v": jnp.roll(v[:, S - L :].astype(c["v"].dtype), S % L, axis=1),
                }
        elif kind == "mamba2":
            y, st = mamba2_scan(p["mixer"], h, cfg, return_state=True)
            c = st
        else:
            y, st = apply_rglru(p["mixer"], h, cfg, return_state=True)
            c = st
        x = x + y
        if "cross" in p and enc_out is not None:
            hh = apply_norm(p["norm_cross"], x)
            x = x + apply_attention(p["cross"], hh, cfg, causal=False, kv_src=enc_out)
        if "ffn" in p:
            hh = apply_norm(p["norm2"], x)
            if moe:
                y2, _ = apply_moe(p["ffn"], hh, cfg)
            else:
                y2 = apply_mlp(p["ffn"], hh)
            x = x + y2
        return x, c

    if pre:
        pc = []
        for i, p in enumerate(params.get("prefix", [])):
            x, c = prime_layer(p, x, cache["prefix"][i], _layer_kind(cfg, i), False)
            pc.append(c)
        cache["prefix"] = pc

    if nb > 0:
        kinds = [_layer_kind(cfg, pre + pos_i) for pos_i in range(p_len)]
        moes = [cfg.ffn_is_moe(pre + pos_i) for pos_i in range(p_len)]

        def body(xc, scanned):
            bp = scanned[: p_len]
            bc = scanned[p_len :]
            ncs = []
            for pp in range(p_len):
                xc, c = prime_layer(bp[pp], xc, bc[pp], kinds[pp], moes[pp])
                ncs.append(c)
            return xc, tuple(ncs)

        x, cs = jax.lax.scan(body, x, tuple(params["blocks"]) + tuple(cache["blocks"]))
        cache["blocks"] = list(cs)

    if suf:
        sc = []
        for s, p in enumerate(params.get("suffix", [])):
            gidx = pre + nb * p_len + s
            x, c = prime_layer(p, x, cache["suffix"][s], _layer_kind(cfg, gidx), cfg.ffn_is_moe(gidx))
            sc.append(c)
        cache["suffix"] = sc

    if cfg.is_encdec and enc_out is not None:
        ckv = []
        all_layers = list(params.get("prefix", []))
        # reconstruct per-layer cross params in global order
        if nb > 0:
            for b in range(nb):
                for pp in range(p_len):
                    all_layers.append(jax.tree.map(lambda leaf: leaf[b], params["blocks"][pp]))
        all_layers += list(params.get("suffix", []))
        for p in all_layers:
            k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
            if "bk" in p["cross"]:
                k = k + p["cross"]["bk"]
                v = v + p["cross"]["bv"]
            ckv.append((k.astype(cfg.activation_dtype), v.astype(cfg.activation_dtype)))
        cache["cross_kv"] = ckv

    x = apply_norm(params["final_norm"], x)
    logits = unembed(params["embed"], x)
    return logits, cache


# ------------------------------------------------------------- accounting
def param_count(cfg: ModelConfig) -> int:
    key = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda k: init_model(k, cfg), key)
    return sum(int(math.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k of routed experts + shared)."""
    total = param_count(cfg)
    if cfg.num_experts == 0:
        return total
    f = cfg.moe_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * f
    moe_layers = sum(cfg.ffn_is_moe(i) for i in range(cfg.num_layers))
    inactive = moe_layers * (cfg.num_experts - cfg.experts_per_token) * per_expert
    return total - inactive
