"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Griffin's recurrent block: two branches — a GeLU gate branch and a
(causal conv -> RG-LRU) branch — multiplied and projected out.  The RG-LRU
is a gated linear recurrence

    r_t = sigmoid(W_a u_t);  i_t = sigmoid(W_x u_t)
    log a_t = -c * softplus(Lambda) * r_t            (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ u_t)

evaluated in parallel over the sequence with ``jax.lax.associative_scan``
(first-order linear recurrences compose associatively), and as an O(1) update
in decode — hence native long_500k support for the hybrid arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

C_FACTOR = 8.0
CONV_WIDTH = 4


def init_rglru(key, cfg):
    d = cfg.d_model
    dr = cfg.rglru_width or cfg.d_model
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 6)
    return {
        "w_gate_branch": dense_init(ks[0], d, (d, dr), dt),
        "w_in": dense_init(ks[1], d, (d, dr), dt),
        "conv_w": dense_init(ks[2], CONV_WIDTH, (CONV_WIDTH, dr), dt),
        "conv_b": jnp.zeros((dr,), dt),
        "w_a": dense_init(ks[3], dr, (dr, dr), dt),
        "w_x": dense_init(ks[4], dr, (dr, dr), dt),
        "lamb": jnp.full((dr,), 0.65, jnp.float32),  # softplus -> a ~ exp(-8*1.05*r)
        "w_out": dense_init(ks[5], dr, (dr, d), dt),
    }


def _causal_conv(u, w, b):
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(pad[:, i : i + u.shape[1], :] * w[i] for i in range(W)) + b


def _gates(params, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ params["w_x"].astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(params["lamb"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a**2, 1e-12)) * (i * uf)
    return a, b


def rglru_scan(params, u: jax.Array, init_state=None):
    """u: [B, S, dr] -> (h [B, S, dr], final_state [B, dr]) via associative scan."""
    a, b = _gates(params, u)  # [B, S, dr] f32

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    acc_a, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if init_state is not None:
        h = h + acc_a * init_state[:, None, :].astype(jnp.float32)
    return h.astype(u.dtype), h[:, -1, :]


def apply_rglru(params, x: jax.Array, cfg, init_state=None, return_state: bool = False):
    """Griffin recurrent block. x: [B, S, d] -> [B, S, d]."""
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    conv_in = x @ params["w_in"]
    u = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    h0 = init_state["h"] if init_state is not None else None
    h, final = rglru_scan(params, u, init_state=h0)
    out = (gate * h) @ params["w_out"]
    if not return_state:
        return out
    tail = jax.lax.dynamic_slice_in_dim(conv_in, x.shape[1] - (CONV_WIDTH - 1), CONV_WIDTH - 1, axis=1)
    return out, {"h": final, "conv": tail}


# ------------------------------------------------------------------- decode
def init_rglru_cache(cfg, batch: int):
    dr = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, dr), cfg.activation_dtype),
    }


def decode_rglru(params, x: jax.Array, cache: dict, cfg):
    """x: [B, 1, d] -> (y [B, 1, d], cache)."""
    gate = jax.nn.gelu(x[:, 0] @ params["w_gate_branch"])  # [B, dr]
    cin = x[:, 0] @ params["w_in"]
    window = jnp.concatenate([cache["conv"], cin[:, None, :]], axis=1)
    u = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    a, b = _gates(params, u[:, None, :])
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = (gate * h.astype(x.dtype)) @ params["w_out"]
    return out[:, None, :], {"h": h, "conv": window[:, 1:, :]}
