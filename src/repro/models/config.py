"""Architecture configuration schema covering all assigned families.

One ``ModelConfig`` describes any of: dense GQA decoders, fine-grained MoE,
Mamba2 SSD, RG-LRU hybrids, encoder-decoder (Whisper) and VLM early-fusion
backbones.  ``layer_pattern`` selects the sequence mixer per layer; ``ffn``
behaviour switches on the MoE fields.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
Mixer = Literal["attn", "local_attn", "mamba2", "rglru"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // num_heads

    # attention details
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None  # window for "local_attn" mixers
    # long-context decode: dense archs may switch to a sliding-window variant
    # (sub-quadratic) for the long_500k shape; None => must skip long_500k
    long_context_window: int | None = None

    # layer pattern: cycled to num_layers; default all-attention
    layer_pattern: tuple[Mixer, ...] = ("attn",)

    # MLP
    mlp_type: Literal["swiglu", "gelu"] = "swiglu"
    norm_type: Literal["rmsnorm", "layernorm"] = "rmsnorm"

    # MoE (num_experts == 0 -> dense FFN everywhere)
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # fine-grained expert hidden size (deepseek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    first_dense_layers: int = 0  # deepseek: layer 0 is dense

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # RG-LRU (hybrid)
    rglru_width: int | None = None  # default d_model

    # encoder-decoder
    encoder_layers: int = 0  # > 0 -> enc-dec (whisper)
    cross_attention: bool = False
    encoder_context: int = 1500  # whisper: 30 s of audio frames

    # VLM early fusion
    num_patches: int = 0  # > 0 -> first num_patches inputs are patch embeds

    dtype: str = "bfloat16"
    source: str = ""  # citation for the assigned config

    # attention kernel dispatch (kernels/): None = plain XLA attention
    # (bit-identical to every pre-kernel baseline).  "flash" routes causal
    # self-attention through the flash / sliding-window Pallas kernels;
    # "block_sparse" through the block-bitmap kernel (causal or windowed
    # pattern).  Decode ticks route through the fused decode kernel whenever
    # either knob is on.
    attn_kernel: str | None = None
    # opt-in int8 KV cache: quantize at store (decode + prefill), dequant
    # fused into the decode contractions — 1/4 the cache bytes per tick
    quantized_kv: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def mixer_for_layer(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def ffn_is_moe(self, i: int) -> bool:
        return self.num_experts > 0 and i >= self.first_dense_layers

    @property
    def supports_long_context(self) -> bool:
        """True if decode cost/state is sub-quadratic in context length."""
        mixers = {self.mixer_for_layer(i) for i in range(self.num_layers)}
        if "attn" in mixers:
            return self.long_context_window is not None
        return True  # ssm / rglru / local_attn only

    def reduced(self, layers: int = 2, d_model: int = 256, experts: int = 4) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        heads = max(2, min(4, self.num_heads))
        kv = 1 if self.num_kv_heads == 1 else max(1, heads // 2)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=2 * d_model,
            vocab_size=512,
            num_experts=min(self.num_experts, experts) if self.num_experts else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            moe_d_ff=d_model if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32,
            rglru_width=d_model if self.rglru_width else None,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_context=32,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            long_context_window=min(self.long_context_window, 16) if self.long_context_window else None,
            num_patches=min(self.num_patches, 8) if self.num_patches else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            dtype="float32",
        )
