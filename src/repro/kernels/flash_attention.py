"""Flash attention (online softmax) as a Pallas TPU kernel.

The roofline baseline (EXPERIMENTS §Roofline) shows every attention arch is
memory-bound in training, dominated by the f32 [Sq, Sk] score/softmax chain
hitting HBM ~6x per layer.  Flash attention keeps the running max / sum /
accumulator in VMEM and never materializes the score matrix: HBM traffic
drops to the Q/K/V/O tensors themselves.

TPU adaptation (vs. the CUDA original):
  * block shapes are (block_q, head_dim) x (block_k, head_dim) with
    head_dim padded to the 128-lane register width; the q @ k^T and p @ v
    contractions are MXU-shaped matmuls per block;
  * the kv loop is a ``jax.lax.fori_loop`` *inside* the kernel over VMEM
    slices (grid iteration is reserved for the embarrassingly parallel
    (batch*heads, q-block) dimensions);
  * causal/windowed masking is computed from block indices — fully masked
    kv blocks are skipped by clamping the loop bounds (a warp-divergence-free
    analogue of the CUDA early-exit).

Validated in interpret mode against ``ref.flash_attention_ref`` (pure jnp)
over shape/dtype/mask sweeps — see tests/test_flash_attention.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window,
                  block_q, block_k, seq_k, skip_blocks):
    """One (batch*head, q-block) grid cell: stream kv blocks in VMEM."""
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # [block_q, hd]
    hd = q.shape[-1]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    num_kv = seq_k // block_k
    if causal:
        # kv blocks strictly after the last query position are fully masked
        last_q = (qi + 1) * block_q - 1
        num_live = jnp.minimum((last_q // block_k) + 1, num_kv)
    else:
        num_live = num_kv
    if window is not None and skip_blocks:
        first_q = qi * block_q
        first_live = jnp.maximum((first_q - window + 1) // block_k, 0)
    else:
        first_live = 0

    def body(kj, carry):
        acc, m_prev, l_prev = carry
        # index the leading (size-1) dim with a dslice, not a raw Python int:
        # the interpreter's load-discharge rule requires Slice/array indices
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(kj * block_k, block_k), slice(None)))[0]
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(kj * block_k, block_k), slice(None)))[0]
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        s = q @ k.T  # [block_q, block_k] — MXU matmul
        k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(first_live, num_live, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [BH, Sq, hd]
    k: jax.Array,  # [BH, Sk, hd]
    v: jax.Array,  # [BH, Sk, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
    skip_blocks: bool = True,
) -> jax.Array:
    """skip_blocks=False disables the window's leading-block loop clamp so
    window masking still applies but every kv block is visited — the honest
    mask-only baseline the sliding-window kernel is benchmarked against."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    if scale is None:
        scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        seq_k=sk,
        skip_blocks=skip_blocks,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),  # q tile
            pl.BlockSpec((1, sk, hd), lambda b, i: (b, 0, 0)),  # k stream
            pl.BlockSpec((1, sk, hd), lambda b, i: (b, 0, 0)),  # v stream
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
