"""Pallas-TPU blockwise top-k sparsification kernel.

TPU adaptation of the paper's top-K compression: selection happens per
compression block (default 1024 elements) via **threshold bisection** —
``BISECT_ITERS`` rounds of (compare + row-sum), all VPU-friendly vector ops,
instead of a global sort/top-k which TPUs execute poorly.  The contraction
guarantee is preserved blockwise: keeping the top k_b = fraction*B entries of
every block removes at most (1-fraction) of every block's energy, hence
delta = K/d overall (see ``repro.core.compression.BlockTopK``).

Grid layout: x is reshaped to [num_blocks, block] and tiled in groups of
``TILE_BLOCKS`` rows; block (the compression block, lane dim) must be a
multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BISECT_ITERS

TILE_BLOCKS = 256  # rows per grid step: 256 * 1024 * 4B = 1 MiB VMEM


def _block_topk_kernel(x_ref, out_ref, *, k: int, iters: int):
    x = x_ref[...]
    mag = jnp.abs(x)
    hi = mag.max(axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)
    for _ in range(iters):  # static unroll: pure vector compare + row reduce
        mid = 0.5 * (lo + hi)
        cnt = (mag >= mid).sum(axis=1, keepdims=True)
        too_many = cnt > k
        lo = jnp.where(too_many, mid, lo)
        hi = jnp.where(too_many, hi, mid)
    mask = mag >= hi
    out_ref[...] = x * mask.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("k", "iters", "interpret"))
def block_topk_pallas(x: jax.Array, k: int, iters: int = BISECT_ITERS, interpret: bool = True):
    """x: [num_blocks, block] f32; returns same shape, masked to ~top-k per row."""
    assert x.ndim == 2 and x.shape[1] % 128 == 0
    nb, block = x.shape
    tile = min(TILE_BLOCKS, nb)
    while nb % tile != 0:
        tile //= 2
    tile = max(tile, 1)
    grid = (nb // tile,)
    return pl.pallas_call(
        functools.partial(_block_topk_kernel, k=k, iters=iters),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), x.dtype),
        interpret=interpret,
    )(x)
