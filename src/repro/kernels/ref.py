"""Pure-jnp oracles for the Pallas compression kernels.

These are the ground truth the kernels are tested against (allclose across a
shape/dtype sweep with ``interpret=True``).  They implement *exactly* the same
algorithm as the kernels:

* ``quantize_ref`` / ``dequantize_ref`` — stochastic b-bit quantization with
  2^b levels {0..2^b-1} (one fewer than paper eq. (2), so levels pack into
  b bits exactly; the contraction delta changes by O(2^-b), negligible),
  plus bit-packing: ``8/bits`` levels per uint8 and 8 sign bits per uint8.
* ``block_topk_ref`` — per-block top-k selection via N-iteration threshold
  bisection (the TPU-native form of top-k: vector compares + row reductions,
  no sort).  Keeps all entries with |x| >= tau where tau is the bisection
  threshold whose kept-count is <= k; ties below may drop extra elements,
  exactly as the kernel does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

LANES = 128
BISECT_ITERS = 20


# ----------------------------------------------------------------- quantize
def _rows_for(d: int, pack: int) -> int:
    """Pad flat length d up to a multiple of pack*8*LANES and return rows."""
    unit = pack * 8 * LANES  # pack rows x sign rows x lanes alignment
    padded = ((d + unit - 1) // unit) * unit
    return padded // LANES


def quantize_ref(x: jax.Array, xi: jax.Array, norm: jax.Array, bits: int):
    """Quantize a [rows, 128] f32 array (pre-padded, pre-scaled noise xi in [0,1)).

    Returns (packed_levels [rows/pack, 128] uint8, packed_signs [rows/8, 128] uint8).
    """
    assert x.ndim == 2 and x.shape[1] == LANES
    pack = 8 // bits
    rows = x.shape[0]
    maxlvl = (1 << bits) - 1
    scale = (1 << bits) / jnp.maximum(norm, 1e-30)
    q = jnp.floor(jnp.abs(x) * scale + xi)
    lvl = jnp.clip(q, 0, maxlvl).astype(jnp.uint8)
    sign = (x < 0).astype(jnp.uint8)

    l = lvl.reshape(rows // pack, pack, LANES).astype(jnp.uint32)
    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits).reshape(1, pack, 1)
    packed_lvl = (l << shifts).sum(axis=1).astype(jnp.uint8)

    s = sign.reshape(rows // 8, 8, LANES).astype(jnp.uint32)
    sshift = jnp.arange(8, dtype=jnp.uint32).reshape(1, 8, 1)
    packed_sign = (s << sshift).sum(axis=1).astype(jnp.uint8)
    return packed_lvl, packed_sign


def tau_for(d: int, bits: int) -> float:
    """Paper eq. (2) normalizer: tau = 1 + min(d/2^2b, sqrt(d)/2^b)."""
    lvl = float(1 << bits)
    return 1.0 + min(d / lvl**2, (d**0.5) / lvl)


def dequantize_ref(packed_lvl: jax.Array, packed_sign: jax.Array, scale: jax.Array, bits: int):
    """Inverse of quantize_ref -> [rows, 128] f32 reconstruction.

    ``scale`` = norm / (2^b * tau): the paper's 1/tau shrinkage makes the
    roundtrip a delta = 1/tau contraction (without it the unbiased decode has
    variance (tau-1)||x||^2, which explodes for small b / large d).
    """
    pack = 8 // bits
    rows = packed_lvl.shape[0] * pack
    maxlvl = (1 << bits) - 1
    l = packed_lvl.astype(jnp.uint32)[:, None, :]
    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits).reshape(1, pack, 1)
    lvl = ((l >> shifts) & maxlvl).reshape(rows, LANES).astype(jnp.float32)

    s = packed_sign.astype(jnp.uint32)[:, None, :]
    sshift = jnp.arange(8, dtype=jnp.uint32).reshape(1, 8, 1)
    sign = ((s >> sshift) & 1).reshape(rows, LANES)

    mag = lvl * scale
    return jnp.where(sign == 1, -mag, mag)


# ---------------------------------------------------- fused CHOCO round oracles
def fused_encode_ref(theta_new, hat, xi, scales, bits: int):
    """Oracle for choco_fused.fused_encode_pallas.

    theta_new/hat: [m, rows, 128], xi: [m, rows, 128] f32, scales: [m, 2]
    (encode scale 2^b/||resid||, dequant scale ||resid||/(2^b tau)).
    Returns (packed_lvl [m, rows/pack, 128] u8, packed_sign [m, rows/8, 128]
    u8, hat_new [m, rows, 128]).
    """
    resid = (theta_new - hat).astype(jnp.float32)
    q = jnp.floor(jnp.abs(resid) * scales[:, 0, None, None] + xi)
    lvlf = jnp.clip(q, 0, (1 << bits) - 1)
    neg = resid < 0

    def pack_nodes(vals, per_byte, width):
        m, rows, _ = vals.shape
        v = vals.reshape(m, rows // per_byte, per_byte, LANES).astype(jnp.uint32)
        sh = (jnp.arange(per_byte, dtype=jnp.uint32) * width).reshape(1, 1, per_byte, 1)
        return (v << sh).sum(axis=2).astype(jnp.uint8)

    packed_lvl = pack_nodes(lvlf.astype(jnp.uint32), 8 // bits, bits)
    packed_sign = pack_nodes(neg.astype(jnp.uint32), 8, 1)
    mag = lvlf * scales[:, 1, None, None]
    hat_new = (hat.astype(jnp.float32) + jnp.where(neg, -mag, mag)).astype(hat.dtype)
    return packed_lvl, packed_sign, hat_new


def fused_mix_ref(rolled_lvl, rolled_sign, s, wscale, bits: int):
    """Oracle for choco_fused.fused_mix_pallas.

    rolled_lvl: [K, m, rows/pack, 128] u8, rolled_sign: [K, m, rows/8, 128]
    u8, s: [m, rows, 128], wscale: [K, m] f32.  Returns s_new [m, rows, 128]:
    s + sum_k deq(payload_k) * wscale[k].
    """
    K, m = rolled_lvl.shape[:2]
    acc = jnp.zeros(s.shape, jnp.float32)
    for k in range(K):
        lvl = jax.vmap(lambda pl_, ps_: dequantize_ref(pl_, ps_, 1.0, bits))(
            rolled_lvl[k], rolled_sign[k]
        )
        acc = acc + lvl * wscale[k, :, None, None]
    return (s.astype(jnp.float32) + acc).astype(s.dtype)


# ---------------------------------------------------------------- block top-k
def block_topk_ref(x: jax.Array, k: int, iters: int = BISECT_ITERS) -> jax.Array:
    """Per-row top-k masking via threshold bisection; x: [nb, block] f32.

    Returns x masked to (approximately, ties aside) its k largest-|.| entries
    per row.
    """
    assert x.ndim == 2
    mag = jnp.abs(x)
    hi = mag.max(axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        cnt = (mag >= mid).sum(axis=1, keepdims=True)
        too_many = cnt > k
        lo = jnp.where(too_many, mid, lo)
        hi = jnp.where(too_many, hi, mid)
    mask = mag >= hi
    return x * mask.astype(x.dtype)


# -------------------------------------------------------- flash attention
def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Pure-jnp oracle for the flash attention kernel.

    q, k, v: [BH, S, hd].  Plain materialized-softmax attention with the
    same causal/sliding-window masking.
    """
    import math

    hd = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqk,bsk->bqs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(q.shape[1])
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqs,bsk->bqk", p, v.astype(jnp.float32)).astype(q.dtype)


# --------------------------------------------------- block-sparse attention
def block_sparse_attention_ref(q, k, v, pattern, *, scale=None):
    """Oracle for block_sparse.block_sparse_attention_pallas.

    Expands the pattern's block bitmap to an element mask (block-live AND
    causal/window for PARTIAL blocks) and runs materialized-softmax
    attention.  Patterns keep the diagonal live, so every q row has >= 1
    live key and the softmax is well-defined.
    """
    import math

    hd = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    sq, sk = q.shape[1], k.shape[1]
    bq, bk = pattern.block_q, pattern.block_k
    block = jnp.asarray(pattern.bitmap)  # [nq, nk]
    block_live = jnp.repeat(jnp.repeat(block != 0, bq, axis=0), bk, axis=1)
    block_full = jnp.repeat(jnp.repeat(block == 2, bq, axis=0), bk, axis=1)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    elem = jnp.ones((sq, sk), bool)
    if pattern.causal:
        elem &= qpos[:, None] >= kpos[None, :]
    if pattern.window is not None:
        elem &= qpos[:, None] - kpos[None, :] < pattern.window
    mask = block_live & (block_full | elem)
    s = (
        jnp.einsum("bqk,bsk->bqs", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqs,bsk->bqk", p, v.astype(jnp.float32)).astype(q.dtype)


# ------------------------------------------------------------- decode attention
def quantize_kv_ref(x: jax.Array):
    """Per-(position, kv-head) int8 symmetric quantization of a KV tensor.

    x: [..., hd] -> (int8 values [..., hd], f32 scales [...]).  scale =
    absmax/127 so dequant is ``values * scale``; all-zero rows get scale 0
    and dequant back to exact zeros.
    """
    absmax = jnp.abs(x.astype(jnp.float32)).max(axis=-1)
    scale = absmax / 127.0
    inv = jnp.where(absmax > 0, 127.0 / absmax, 0.0)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) * inv[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def decode_attention_ref(q, k, v, valid, *, scale=None, k_scale=None,
                         v_scale=None):
    """Oracle for decode.decode_attention_pallas.

    Single-query attention over a KV cache with grouped-query heads:
      q: [B, KV, G, hd]           (G = query heads per kv head)
      k, v: [B, L, KV, hd]        (f32/bf16, or int8 when *_scale given)
      valid: [B, L] bool          live cache slots
      k_scale, v_scale: [B, L, KV] f32 — when given, k/v are int8 and
        dequant is fused into the contractions (the kernel's quantized-KV
        mode: the cache is read once at 1/4 the bytes).
    Returns [B, KV, G, hd] f32-accumulated in q.dtype.
    """
    import math

    hd = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bngd,blnd->bngl", q.astype(jnp.float32) * scale, kf)
    if k_scale is not None:
        s = s * k_scale.transpose(0, 2, 1)[:, :, None, :]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = p * v_scale.transpose(0, 2, 1)[:, :, None, :]
    return jnp.einsum("bngl,blnd->bngd", p, vf).astype(q.dtype)
