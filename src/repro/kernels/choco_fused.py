"""Fused single-pass Pallas kernels for one CHOCO gossip round.

The unfused CHOCO round (``core/gossip._round_leaf`` + ``_mix_payload``)
executes ~8+deg full-tensor HBM round trips per leaf: the averaging step, an
f32 residual, the quantize encode, a full dequantize for ``q_self``, one more
full dequantize per topology shift (each materializing a d-element f32
tensor), then separate ``hat`` and ``s`` update passes.  The two kernels here
collapse that to ~3 full-tensor passes plus wire-sized (packed) traffic:

* ``fused_encode_pallas`` — recompute the residual ``theta_new - hat_old`` in
  VMEM, stochastically quantize, bit-pack levels and signs, AND apply the
  ``hat <- hat + Q(resid)`` update, all in one pass.  The full-size f32
  residual and the dense ``q_self`` reconstruction never touch HBM.
* ``fused_mix_pallas`` — multi-shift dequantize-accumulate: decode each
  rolled packed payload tile and accumulate ``sum_k w_k * deq(payload_k)``
  directly into the ``s`` update.  Per-neighbor f32 tensors never
  materialize; the per-(shift, node) dequant scales ride alongside as a
  lane-broadcast row per node.

Both kernels grid over row-blocks only and keep the full node axis inside
each tile ([m, block, 128]): the stacked node axis is small (nodes) while d
is huge, so folding it into the tile amortizes per-step overhead m-fold and
keeps the grid identical in shape to the unfused quantize kernel's.  The
per-operand VMEM footprint is held at ~2 MiB by shrinking the row-block as m
grows (``_pick_block``).

``fused_round_leaf`` stitches them into a full round for one stacked leaf
[m, ...].  The averaging step and the residual norm stay in plain XLA (they
fuse into a read-only reduction) so the payload is bit-identical to the
``packed=False`` oracle path: the same per-node keys, the same uniform noise,
the same norm reduction, the same floor/clip arithmetic.

The packed payload is rolled along the node axis *outside* the kernels
(wire-sized traffic only).  Under the production mesh those rolls lower to
collective-permutes of the compressed payload, exactly like the unfused
packed path — the fused kernels only change the per-device compute.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import LANES, tau_for

# target total VMEM footprint per grid step (inputs + outputs + accumulator)
VMEM_BUDGET = 8 << 20

# max circulant shifts decoded per fused_mix_pallas call: bounds both the
# live rolled-payload copies in HBM (<= SHIFT_BATCH x wire size, vs K = m
# copies on a mesh) and the static unroll inside the kernel
SHIFT_BATCH = 8


def _pick_block(rows: int, unit: int, m: int, f32_operands: float) -> int:
    """Largest multiple of ``unit`` dividing ``rows`` such that the tile's
    f32-equivalent footprint m * block * 128 * 4B * f32_operands stays within
    the VMEM budget.  ``f32_operands`` counts every live buffer in f32 units
    (u8 payload tiles count 1/4 per byte-per-element) — the mix kernel's K
    payload tiles make this K-dependent, not a constant."""
    cap_rows = int(VMEM_BUDGET / (max(m, 1) * LANES * 4 * f32_operands))
    cap = max(unit, cap_rows // unit * unit)
    best = unit
    b = unit
    while b <= min(rows, cap):
        if rows % b == 0:
            best = b
        b += unit
    return best


# ------------------------------------------------------------- fused encode
def _fused_encode_kernel(enc_ref, deq_ref, tn_ref, hat_ref, xi_ref,
                         lvl_ref, sign_ref, hat_new_ref, *maybe_dig,
                         bits: int, with_digest: bool = False):
    """One row-block across all m nodes: residual -> quantize -> pack -> hat
    update.  enc_ref/deq_ref: [m, 128] lane-broadcast per-node scales.

    With ``with_digest`` a per-node int32 wraparound digest of the stored
    ``hat_new`` accumulates in an extra [m, 128] output whose constant index
    map revisits the same tile every grid step (TPU grids are sequential, so
    the read-modify-write accumulation is well-defined)."""
    pack = 8 // bits
    maxlvl = (1 << bits) - 1

    hat = hat_ref[...]
    resid = (tn_ref[...] - hat).astype(jnp.float32)
    m, rows, _ = resid.shape

    # stochastic round (same arithmetic as quantize_ref, bit-identical)
    q = jnp.floor(jnp.abs(resid) * enc_ref[...][:, None, :] + xi_ref[...])
    lvlf = jnp.clip(q, 0, maxlvl)
    neg = resid < 0

    l = lvlf.astype(jnp.uint32).reshape(m, rows // pack, pack, LANES)
    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits).reshape(1, 1, pack, 1)
    lvl_ref[...] = (l << shifts).sum(axis=2).astype(jnp.uint8)

    s = neg.astype(jnp.uint32).reshape(m, rows // 8, 8, LANES)
    sshift = jnp.arange(8, dtype=jnp.uint32).reshape(1, 1, 8, 1)
    sign_ref[...] = (s << sshift).sum(axis=2).astype(jnp.uint8)

    # hat <- hat + deq(payload), without re-reading the packed payload
    mag = lvlf * deq_ref[...][:, None, :]
    q_self = jnp.where(neg, -mag, mag)
    stored = (hat.astype(jnp.float32) + q_self).astype(hat_new_ref.dtype)
    hat_new_ref[...] = stored

    if with_digest:
        (dig_ref,) = maybe_dig
        # same arithmetic as core.faults.digest: bitcast to same-width int,
        # widen to int32, wraparound-sum — int32 addition commutes, so the
        # per-block accumulation order doesn't matter
        nbits = stored.dtype.itemsize * 8
        part = (
            jax.lax.bitcast_convert_type(stored, jnp.dtype(f"int{nbits}"))
            .astype(jnp.int32)
            .sum(axis=1)
        )  # [m, 128]

        @pl.when(pl.program_id(0) == 0)
        def _zero():
            dig_ref[...] = jnp.zeros_like(dig_ref)

        dig_ref[...] += part


@functools.partial(jax.jit, static_argnames=("bits", "interpret", "with_digest"))
def fused_encode_pallas(theta_new, hat, xi, scales, bits: int,
                        interpret: bool = True, with_digest: bool = False):
    """theta_new/hat: [m, R, 128] (leaf dtype), xi: [m, R, 128] f32,
    scales: [m, 2] f32 — per-node (encode scale, dequant scale).

    Returns (packed_levels [m, R/pack, 128] u8, packed_signs [m, R/8, 128] u8,
    hat_new [m, R, 128] in hat.dtype), plus a per-node int32 digest [m] equal
    to ``core.faults.digest(hat_new)`` when ``with_digest`` — the fault lane
    rides the encode pass for free instead of a separate XLA reduction.
    """
    m, rows, lanes = theta_new.shape
    assert lanes == LANES
    pack = 8 // bits
    assert rows % (8 * pack) == 0
    # live buffers: tn, hat, xi, f32 resid, hat_new + packed outputs (~1/4)
    block = _pick_block(rows, 8 * pack, m, f32_operands=5.5)
    grid = (rows // block,)
    # lane-broadcast the per-node scales so the tile is (m, 128)-shaped
    enc = jnp.broadcast_to(scales[:, 0:1], (m, LANES)).astype(jnp.float32)
    deq = jnp.broadcast_to(scales[:, 1:2], (m, LANES)).astype(jnp.float32)
    row_spec = lambda div: pl.BlockSpec((m, block // div, LANES), lambda r: (0, r, 0))
    out_specs = [row_spec(pack), row_spec(8), row_spec(1)]
    out_shape = [
        jax.ShapeDtypeStruct((m, rows // pack, LANES), jnp.uint8),
        jax.ShapeDtypeStruct((m, rows // 8, LANES), jnp.uint8),
        jax.ShapeDtypeStruct((m, rows, LANES), hat.dtype),
    ]
    if with_digest:
        # constant index map: the digest tile is revisited (and accumulated
        # into) on every sequential grid step
        out_specs.append(pl.BlockSpec((m, LANES), lambda r: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((m, LANES), jnp.int32))
    out = pl.pallas_call(
        functools.partial(_fused_encode_kernel, bits=bits, with_digest=with_digest),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, LANES), lambda r: (0, 0)),
            pl.BlockSpec((m, LANES), lambda r: (0, 0)),
            row_spec(1),
            row_spec(1),
            row_spec(1),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(enc, deq, theta_new, hat, xi)
    if with_digest:
        lvl, sign, hat_new, dig = out
        return lvl, sign, hat_new, dig.sum(axis=1)
    return out


# --------------------------------------------------------------- fused mix
def _fused_mix_kernel(wscale_ref, lvl_ref, sign_ref, s_ref, s_new_ref,
                      *, bits: int, nshifts: int):
    """One row-block across all m nodes: decode every rolled payload tile and
    accumulate the weighted sum straight into the s update."""
    pack = 8 // bits
    maxlvl = (1 << bits) - 1
    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits).reshape(1, 1, pack, 1)
    sshift = jnp.arange(8, dtype=jnp.uint32).reshape(1, 1, 8, 1)

    s_blk = s_ref[...]
    wscale = wscale_ref[...]
    acc = jnp.zeros(s_blk.shape, jnp.float32)
    for k in range(nshifts):  # static unroll over the circulant decomposition
        pk_l = lvl_ref[k].astype(jnp.uint32)
        pk_s = sign_ref[k].astype(jnp.uint32)
        m, prows, _ = pk_l.shape
        lvl = ((pk_l[:, :, None, :] >> shifts) & maxlvl).reshape(m, prows * pack, LANES)
        sign = ((pk_s[:, :, None, :] >> sshift) & 1).reshape(m, prows * pack, LANES)
        mag = lvl.astype(jnp.float32) * wscale[k][:, None, :]
        acc = acc + jnp.where(sign == 1, -mag, mag)
    s_new_ref[...] = (s_blk.astype(jnp.float32) + acc).astype(s_new_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def fused_mix_pallas(rolled_lvl, rolled_sign, s, wscale, bits: int, interpret: bool = True):
    """rolled_lvl: [K, m, R/pack, 128] u8, rolled_sign: [K, m, R/8, 128] u8,
    s: [m, R, 128] (leaf dtype), wscale: [K, m] f32 with
    wscale[k, i] = w_k * deq_scale[(i - shift_k) mod m].

    Returns s_new [m, R, 128]: s + sum_k w_k * deq(rolled payload_k).
    """
    K, m, prows, lanes = rolled_lvl.shape
    assert lanes == LANES and K == wscale.shape[0]
    pack = 8 // bits
    rows = prows * pack
    assert s.shape == (m, rows, LANES)
    # live buffers: s, s_new, f32 accumulator, plus K u8 payload tiles of
    # (1/pack + 1/8) bytes per element — K-dependent (mesh has K = m shifts)
    payload_f32 = K * (1.0 / pack + 0.125) / 4.0
    block = _pick_block(rows, 8 * pack, m, f32_operands=3.0 + payload_f32)
    grid = (rows // block,)
    ws = jnp.broadcast_to(wscale[..., None], (K, m, LANES)).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_fused_mix_kernel, bits=bits, nshifts=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, m, LANES), lambda r: (0, 0, 0)),
            pl.BlockSpec((K, m, block // pack, LANES), lambda r: (0, 0, r, 0)),
            pl.BlockSpec((K, m, block // 8, LANES), lambda r: (0, 0, r, 0)),
            pl.BlockSpec((m, block, LANES), lambda r: (0, r, 0)),
        ],
        out_specs=pl.BlockSpec((m, block, LANES), lambda r: (0, r, 0)),
        out_shape=jax.ShapeDtypeStruct((m, rows, LANES), s.dtype),
        interpret=interpret,
    )(ws, rolled_lvl, rolled_sign, s)


# ------------------------------------------------------------- leaf round
def fused_round_leaf(leaf, hat, s, key, shifts: Sequence[tuple[int, float]],
                     gamma, bits: int, interpret: bool = True, *,
                     roll_fn=None, node_keys=None, with_digest: bool = False):
    """One CHOCO round for a stacked leaf [m, ...] on the fused fast path.

    Matches ``gossip._round_leaf`` with a ``KernelQuantization(bits)``
    compressor bit-for-bit on the payload (same keys, noise, norms and
    floor/clip arithmetic); ``s_new`` agrees to f32 rounding (the weighted
    accumulation is reassociated inside the kernel).

    ``roll_fn(x, shift)`` overrides how the packed payload travels the node
    axis — the SPMD neighbor-exchange backend (core/exchange.py) substitutes
    sharded boundary permutes while the kernels run unchanged on the local
    node block; ``node_keys`` then carries that block's slice of the global
    per-node key array (the default is the full ``split(key, m)``).

    Returns (theta_new, hat_new, s_new), all shaped like ``leaf``; with
    ``with_digest`` a fourth element — the per-node int32 wraparound digest of
    ``hat_new``, equal to ``core.faults.digest(hat_new)`` (the zero padding
    rows quantize to exact zeros, so the padded-grid digest matches the
    unpadded one) — computed inside the encode pass at no extra HBM traffic.
    """
    m = leaf.shape[0]
    inner_shape, dtype = leaf.shape[1:], leaf.dtype
    d = int(np.prod(inner_shape)) if len(inner_shape) else 1

    # averaging step + residual norm stay in XLA: one fused read-only
    # reduction, and bit-identical numerics with the unfused oracle
    theta_new = leaf + jnp.asarray(gamma, dtype) * (s - hat).astype(dtype)
    flat_tn = theta_new.reshape(m, -1)
    flat_hat = hat.reshape(m, -1)
    norms = jax.vmap(
        lambda a, b: jnp.linalg.norm((a - b).astype(jnp.float32).reshape(-1))
    )(flat_tn, flat_hat)

    pack = 8 // bits
    unit = 8 * pack * LANES
    pad = (-d) % unit
    rows = (d + pad) // LANES

    def grid3(x):
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad)))
        return x.reshape(m, rows, LANES)

    if node_keys is None:
        node_keys = jax.random.split(key, m)
    xi = jax.vmap(lambda k: jax.random.uniform(k, (rows, LANES)))(node_keys)

    scale_enc = (1 << bits) / jnp.maximum(norms, 1e-30)
    scale_deq = norms / ((1 << bits) * tau_for(d, bits))
    scales = jnp.stack([scale_enc, scale_deq], axis=1).astype(jnp.float32)

    enc_out = fused_encode_pallas(
        grid3(flat_tn), grid3(flat_hat), xi, scales, bits,
        interpret=interpret, with_digest=with_digest,
    )
    if with_digest:
        lvl, sign, hat_new_g, dig = enc_out
    else:
        lvl, sign, hat_new_g = enc_out

    # roll the *packed* payload along the node axis (wire-sized traffic;
    # lowers to collective-permute under a sharded node axis).  Shifts are
    # processed in batches of SHIFT_BATCH so a mesh (K = m shifts) never
    # materializes more than SHIFT_BATCH rolled payload copies at once.
    if roll_fn is None:
        roll0 = lambda x, sh: x if sh == 0 else jnp.roll(x, sh, axis=0)
    else:
        roll0 = lambda x, sh: x if sh == 0 else roll_fn(x, sh)
    # the accumulator stays f32 across batches (cast to the leaf dtype once
    # at the end), so multi-batch topologies match the oracle's
    # accumulate-everything-then-cast semantics for low-precision leaves too
    s_new_g = grid3(s.reshape(m, -1).astype(jnp.float32))
    shifts = tuple(shifts)
    for lo in range(0, len(shifts), SHIFT_BATCH):
        batch = shifts[lo:lo + SHIFT_BATCH]
        rolled_lvl = jnp.stack([roll0(lvl, sh) for sh, _ in batch])
        rolled_sign = jnp.stack([roll0(sign, sh) for sh, _ in batch])
        wscale = jnp.stack(
            [w * roll0(scale_deq, sh) for sh, w in batch]
        ).astype(jnp.float32)
        s_new_g = fused_mix_pallas(
            rolled_lvl, rolled_sign, s_new_g, wscale, bits, interpret=interpret
        )

    unpad = lambda x: x.reshape(m, -1)[:, :d].reshape((m,) + inner_shape)
    if with_digest:
        return theta_new, unpad(hat_new_g), unpad(s_new_g).astype(dtype), dig
    return theta_new, unpad(hat_new_g), unpad(s_new_g).astype(dtype)
