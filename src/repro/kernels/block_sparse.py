"""Block-sparse attention as a Pallas TPU kernel.

The sparsity pattern is a per-(q-block, kv-block) bitmap with three states:

    0 — skip: the kv block is never loaded or computed,
    1 — partial: compute, then apply the element-level causal/window mask,
    2 — full: compute with no element mask (every pair is live).

``BlockSparsePattern`` builds the bitmap host-side (numpy) for the common
patterns — causal, causal+windowed, and strided (local blocks + every
``stride``-th earlier block, the Sparse-Transformer layout) — and
pre-compacts it into per-q-block index lists so the kernel's inner loop
has a *data-dependent but bounded* trip count: ``fori_loop(0, count[qi])``
over ``kv_index[qi, :]``.  Density is whatever the pattern says; the kernel
does O(density · S²) work instead of O(S²).

Patterns must keep the diagonal block live (all constructors do): the
online-softmax carry uses the finite -1e30 sentinel, and a q row with no
live key in *any* visited block would emit a spurious uniform average
rather than the reference's all-masked softmax.  ``from_bitmap`` checks.

Like ``flash_attention.py``, whole K/V rides in VMEM per (bh, q-block)
grid cell — fine at training sequence lengths; the index lists are small
int32 rows mapped per q block via their own BlockSpecs.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import NEG_INF

SKIP, PARTIAL, FULL = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class BlockSparsePattern:
    """Host-side block bitmap + compacted per-q-block kv index lists."""

    seq_q: int
    seq_k: int
    block_q: int
    block_k: int
    bitmap: np.ndarray  # [num_q, num_kv] int32 in {SKIP, PARTIAL, FULL}
    causal: bool
    window: int | None

    @staticmethod
    def _pool(seq_q: int, seq_k: int, block_q: int, block_k: int,
              causal: bool, window: int | None) -> np.ndarray:
        """Pool the element-level (causal ∧ window) mask into block states."""
        qp = np.arange(seq_q)[:, None]
        kp = np.arange(seq_k)[None, :]
        live = np.ones((seq_q, seq_k), bool)
        if causal:
            live &= qp >= kp
        if window is not None:
            live &= (qp - kp) < window
        nq, nk = seq_q // block_q, seq_k // block_k
        blocks = live.reshape(nq, block_q, nk, block_k)
        frac = blocks.sum(axis=(1, 3))
        full = frac == block_q * block_k
        return np.where(full, FULL, np.where(frac > 0, PARTIAL, SKIP)).astype(
            np.int32
        )

    @classmethod
    def causal_pattern(cls, seq_q: int, seq_k: int,
                       block_q: int = 128, block_k: int = 128
                       ) -> "BlockSparsePattern":
        bm = cls._pool(seq_q, seq_k, block_q, block_k, True, None)
        return cls(seq_q, seq_k, block_q, block_k, bm, True, None)

    @classmethod
    def windowed(cls, seq_q: int, seq_k: int, window: int,
                 block_q: int = 128, block_k: int = 128
                 ) -> "BlockSparsePattern":
        bm = cls._pool(seq_q, seq_k, block_q, block_k, True, window)
        return cls(seq_q, seq_k, block_q, block_k, bm, True, window)

    @classmethod
    def strided(cls, seq_q: int, seq_k: int, *, local_blocks: int,
                stride: int, block_q: int = 128, block_k: int = 128
                ) -> "BlockSparsePattern":
        """Sparse-Transformer layout: each q block attends to the nearest
        ``local_blocks`` kv blocks plus every ``stride``-th block before."""
        pool = cls._pool(seq_q, seq_k, block_q, block_k, True, None)
        nq, nk = pool.shape
        qi = np.arange(nq)[:, None]
        kj = np.arange(nk)[None, :]
        allowed = (qi - kj < local_blocks) | (kj % stride == 0)
        bm = np.where(allowed, pool, SKIP).astype(np.int32)
        return cls(seq_q, seq_k, block_q, block_k, bm, True, None)

    @classmethod
    def from_bitmap(cls, bitmap: np.ndarray, *, block_q: int, block_k: int,
                    causal: bool = True, window: int | None = None
                    ) -> "BlockSparsePattern":
        bitmap = np.asarray(bitmap, np.int32)
        nq, nk = bitmap.shape
        pool = cls._pool(nq * block_q, nk * block_k, block_q, block_k,
                         causal, window)
        if np.any((bitmap != SKIP) & (pool == SKIP)):
            raise ValueError("bitmap marks blocks live that the causal/window "
                             "mask fully excludes")
        diag = np.array([((i + 1) * block_q - 1) // block_k for i in range(nq)])
        if np.any(bitmap[np.arange(nq), np.minimum(diag, nk - 1)] == SKIP):
            raise ValueError("diagonal block must stay live (softmax carry "
                             "needs >= 1 live key per row)")
        return cls(nq * block_q, nk * block_k, block_q, block_k, bitmap,
                   causal, window)

    def density(self) -> float:
        """Fraction of kv blocks computed (vs. a dense S x S sweep)."""
        return float((self.bitmap != SKIP).mean())

    def compact(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """Per-q-block (kv_index, kv_state, count, max_count) int32 arrays."""
        nq, nk = self.bitmap.shape
        counts = (self.bitmap != SKIP).sum(axis=1).astype(np.int32)
        width = max(int(counts.max()), 1)
        idx = np.zeros((nq, width), np.int32)
        state = np.zeros((nq, width), np.int32)
        for i in range(nq):
            live = np.nonzero(self.bitmap[i] != SKIP)[0]
            idx[i, : live.size] = live
            state[i, : live.size] = self.bitmap[i, live]
        return idx, state, counts, width


def _block_sparse_kernel(idx_ref, state_ref, cnt_ref, q_ref, k_ref, v_ref,
                         o_ref, *, scale, causal, window, block_q, block_k):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # [block_q, hd]
    hd = q.shape[-1]
    count = cnt_ref[0, 0]

    def body(j, carry):
        acc, m_prev, l_prev = carry
        kb = idx_ref[0, j]
        st = state_ref[0, j]
        k = pl.load(
            k_ref, (pl.dslice(0, 1), pl.dslice(kb * block_k, block_k),
                    pl.dslice(0, hd))
        )[0].astype(jnp.float32)
        v = pl.load(
            v_ref, (pl.dslice(0, 1), pl.dslice(kb * block_k, block_k),
                    pl.dslice(0, hd))
        )[0].astype(jnp.float32)
        s = q @ k.T

        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        live = jnp.ones((block_q, block_k), bool)
        if causal:
            live &= q_pos >= k_pos
        if window is not None:
            live &= q_pos - k_pos < window
        # FULL blocks skip the element mask entirely.
        s = jnp.where((st == FULL) | live, s, NEG_INF)

        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_cur, l_cur

    acc = jnp.zeros((block_q, hd), jnp.float32)
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, count, body, (acc, m, l))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def block_sparse_attention_pallas(
    q: jax.Array,  # [BH, Sq, hd]
    k: jax.Array,  # [BH, Sk, hd]
    v: jax.Array,  # [BH, Sk, hd]
    pattern: BlockSparsePattern,
    *,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert (sq, sk) == (pattern.seq_q, pattern.seq_k), (
        (sq, sk), (pattern.seq_q, pattern.seq_k))
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    block_q, block_k = pattern.block_q, pattern.block_k
    idx, state, counts, width = pattern.compact()
    nq = sq // block_q

    row = lambda b, i: (i, 0)  # noqa: E731 — per-q-block index rows
    kernel = functools.partial(
        _block_sparse_kernel,
        scale=scale,
        causal=pattern.causal,
        window=pattern.window,
        block_q=block_q,
        block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq),
        in_specs=[
            pl.BlockSpec((1, width), row),
            pl.BlockSpec((1, width), row),
            pl.BlockSpec((1, 1), row),
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, sk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        interpret=interpret,
    )(
        jnp.asarray(idx),
        jnp.asarray(state),
        jnp.asarray(counts.reshape(nq, 1)),
        q,
        k,
        v,
    )
