"""Sliding-window local attention as a Pallas TPU kernel.

The flash kernel (``flash_attention.py``) already clamps its in-kernel kv
loop to the live block range, but it maps the ENTIRE key/value sequence into
each grid cell's VMEM block: its working set is O(S), which overflows the
~16 MiB VMEM budget around 32k context (f32, hd=128) and wastes HBM->VMEM
bandwidth streaming keys the window will mask anyway.

This kernel makes the kv iteration part of the *grid* instead: the grid is
(batch*heads, q_blocks, window_blocks) and the K/V BlockSpec index map
computes, per q block, the first kv block the window can reach —

    start(i) = clamp(last_block(i) - nkv + 1, 0)

so Pallas only ever fetches the ``nkv = O(window / block_k)`` kv blocks a
q block can see.  VMEM is O(block), not O(S); blocks left of the window are
never loaded at all (the flash kernel skips computing them but still holds
the full sequence resident).  The online-softmax carry (m / l / acc) lives
in VMEM scratch across the innermost grid dimension — TPU grids execute
sequentially, which is exactly the contract this pattern relies on — and the
output tile is written once, on the last kv step.

Numerics match ``ref.flash_attention_ref(causal=True, window=w)``: the same
finite -1e30 mask sentinel makes rows that have not yet met a live key
self-correct on the first real block (their bogus uniform contribution is
annihilated by the exp(m_prev - m_cur) rescale).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import NEG_INF

DEFAULT_BLOCK = 128


def _num_window_blocks(block_q: int, block_k: int, window: int, num_kv: int) -> int:
    """Static kv-block trip count per q block: the window band [first_q -
    window + 1, last_q] spans at most (block_q + window - 2)//block_k + 2
    kv blocks (one extra for each unaligned edge)."""
    span = (block_q + window - 2) // block_k + 2
    return min(num_kv, span)


def _kv_start(qi, *, block_q: int, block_k: int, nkv: int):
    """First kv block fetched for q block ``qi``: anchored so the last
    fetched block contains the q block's final (diagonal) position."""
    last_block = ((qi + 1) * block_q - 1) // block_k
    return jnp.maximum(last_block - (nkv - 1), 0)


def _sliding_window_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                           *, scale, window, block_q, block_k, nkv):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    kb = _kv_start(qi, block_q=block_q, block_k=block_k, nkv=nkv) + kj

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # [block_q, hd]
    k = k_ref[0].astype(jnp.float32)  # [block_k, hd]
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T  # [block_q, block_k] — MXU matmul

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = (q_pos >= k_pos) & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_cur = jnp.maximum(m_prev, s.max(-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_cur = l_prev * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(kj == nkv - 1)
    def _flush():
        l_fin = l_ref[:, 0]
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_fin, 1e-30)[:, None]
        ).astype(o_ref.dtype)


def sliding_window_attention_pallas(
    q: jax.Array,  # [BH, S, hd]
    k: jax.Array,  # [BH, S, hd]
    v: jax.Array,  # [BH, S, hd]
    *,
    window: int,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Causal sliding-window self-attention; only the live KV band is loaded."""
    bh, s, hd = q.shape
    assert k.shape == v.shape == (bh, s, hd), (q.shape, k.shape, v.shape)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    assert window >= 1
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    num_kv = s // block_k
    nkv = _num_window_blocks(block_q, block_k, window, num_kv)

    kv_spec = pl.BlockSpec(
        (1, block_k, hd),
        lambda b, i, j: (
            b,
            _kv_start(i, block_q=block_q, block_k=block_k, nkv=nkv) + j,
            0,
        ),
    )
    kernel = functools.partial(
        _sliding_window_kernel,
        scale=scale,
        window=window,
        block_q=block_q,
        block_k=block_k,
        nkv=nkv,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, s // block_q, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
