"""Pallas-TPU stochastic b-bit quantization kernel (fused quantize + bit-pack).

The compression operator is AD-GDA's per-step hot spot: it touches every
parameter every round (d ~ 1e9 for the large assigned archs).  The kernel
fuses scale -> stochastic round -> clip -> bit-pack (levels) -> bit-pack
(signs) in one VMEM pass, so HBM traffic is read 4B/elem + write
(bits+1)/8 B/elem instead of several full-size round trips.

Layout: the flat vector is reshaped to [rows, 128] (lane-aligned) and tiled
over the grid in row-blocks of ``BLOCK_ROWS`` (VMEM footprint per step:
BLOCK_ROWS * 128 * 4B * 2 inputs ~= 1 MiB).  The per-tensor norm rides in
SMEM.  Packing is a sublane reshape: ``pack = 8 // bits`` level rows fold
into one uint8 row; 8 sign rows fold into one bitmask row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import LANES

BLOCK_ROWS = 512  # f32 VMEM tile: 512*128*4B = 256 KiB per operand


def _quantize_kernel(norm_ref, x_ref, xi_ref, lvl_ref, sign_ref, *, bits: int):
    pack = 8 // bits
    maxlvl = (1 << bits) - 1
    x = x_ref[...]
    xi = xi_ref[...]
    rows = x.shape[0]

    scale = (1 << bits) / jnp.maximum(norm_ref[0], 1e-30)
    q = jnp.floor(jnp.abs(x) * scale + xi)
    lvl = jnp.clip(q, 0, maxlvl).astype(jnp.uint32)
    sign = (x < 0).astype(jnp.uint32)

    l = lvl.reshape(rows // pack, pack, LANES)
    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits).reshape(1, pack, 1)
    lvl_ref[...] = (l << shifts).sum(axis=1).astype(jnp.uint8)

    s = sign.reshape(rows // 8, 8, LANES)
    sshift = jnp.arange(8, dtype=jnp.uint32).reshape(1, 8, 1)
    sign_ref[...] = (s << sshift).sum(axis=1).astype(jnp.uint8)


def _dequantize_kernel(scale_ref, lvl_ref, sign_ref, out_ref, *, bits: int):
    pack = 8 // bits
    maxlvl = (1 << bits) - 1
    packed_lvl = lvl_ref[...].astype(jnp.uint32)
    packed_sign = sign_ref[...].astype(jnp.uint32)
    prows = packed_lvl.shape[0]
    rows = prows * pack

    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits).reshape(1, pack, 1)
    lvl = ((packed_lvl[:, None, :] >> shifts) & maxlvl).reshape(rows, LANES).astype(jnp.float32)
    sshift = jnp.arange(8, dtype=jnp.uint32).reshape(1, 8, 1)
    sign = ((packed_sign[:, None, :] >> sshift) & 1).reshape(rows, LANES)

    mag = lvl * scale_ref[0]
    out_ref[...] = jnp.where(sign == 1, -mag, mag)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize_pallas(x: jax.Array, xi: jax.Array, norm: jax.Array, bits: int, interpret: bool = True):
    """x, xi: [rows, 128] f32 (rows % (8*pack*BLOCK alignment) handled by caller).

    Returns (packed_levels [rows/pack, 128] u8, packed_signs [rows/8, 128] u8).
    """
    assert x.shape[1] == LANES and x.shape[0] % (8 * (8 // bits)) == 0
    rows = x.shape[0]
    pack = 8 // bits
    block = min(BLOCK_ROWS, rows)
    assert rows % block == 0 and block % (8 * pack) == 0
    grid = (rows // block,)
    norm_arr = jnp.reshape(norm.astype(jnp.float32), (1,))
    return pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block // pack, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block // 8, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows // pack, LANES), jnp.uint8),
            jax.ShapeDtypeStruct((rows // 8, LANES), jnp.uint8),
        ],
        interpret=interpret,
    )(norm_arr, x, xi)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def dequantize_pallas(packed_lvl, packed_sign, scale, bits: int, interpret: bool = True):
    """scale = norm / (2^b * tau) — see ref.tau_for."""
    pack = 8 // bits
    prows = packed_lvl.shape[0]
    rows = prows * pack
    block = min(BLOCK_ROWS // pack, prows)
    assert prows % block == 0
    grid = (prows // block,)
    norm_arr = jnp.reshape(scale.astype(jnp.float32), (1,))
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block * pack // 8, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block * pack, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(norm_arr, packed_lvl, packed_sign)
