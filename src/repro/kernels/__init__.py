"""Pallas-TPU kernels for the paper's compression hot-spots.

quantize.py — fused stochastic b-bit quantization + bit-packing
topk.py     — blockwise top-k sparsification via threshold bisection
ops.py      — jit'd wrappers + gossip-pluggable compressor classes
ref.py      — pure-jnp oracles the kernels are tested against
"""
from repro.kernels.ops import KernelBlockTopK, KernelQuantization, block_topk, dequantize, quantize

__all__ = ["KernelBlockTopK", "KernelQuantization", "block_topk", "dequantize", "quantize"]
