"""Pallas-TPU kernels for the paper's compression + attention hot-spots.

quantize.py        — fused stochastic b-bit quantization + bit-packing
topk.py            — blockwise top-k sparsification via threshold bisection
choco_fused.py     — single-pass fused CHOCO gossip round (+ digest lane)
flash_attention.py — causal/windowed flash attention (training)
sliding_window.py  — O(window)-VMEM local attention (long-context training)
block_sparse.py    — block-bitmap sparse attention + BlockSparsePattern
decode.py          — fused single-query decode over the serving KV cache,
                     opt-in int8 quantized-KV mode
ops.py             — jit'd wrappers + gossip-pluggable compressor classes
ref.py             — pure-jnp oracles the kernels are tested against
"""
from repro.kernels.ops import (
    KernelBlockTopK,
    KernelQuantization,
    block_sparse_attention,
    block_topk,
    decode_attention_kernel,
    dequantize,
    flash_attention,
    quantize,
    quantize_kv,
    sliding_window_attention,
)

__all__ = [
    "KernelBlockTopK",
    "KernelQuantization",
    "block_sparse_attention",
    "block_topk",
    "decode_attention_kernel",
    "dequantize",
    "flash_attention",
    "quantize",
    "quantize_kv",
    "sliding_window_attention",
]
