"""Jit'd public wrappers around the Pallas compression kernels.

These handle padding/reshaping from arbitrary flat vectors to the kernels'
[rows, 128] lane-aligned layout, select interpret mode automatically
(interpret=True off-TPU so the kernel body runs as the correctness oracle on
CPU), and expose compressor classes plugging into the CHOCO gossip layer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import Compressor
from repro.kernels import quantize as qk
from repro.kernels import topk as tk
from repro.kernels.ref import LANES, tau_for


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to_rows(flat: jax.Array, row_unit: int) -> jax.Array:
    d = flat.shape[0]
    unit = row_unit * LANES
    pad = (-d) % unit
    return jnp.pad(flat, (0, pad)).reshape(-1, LANES)


def quantize(x: jax.Array, key: jax.Array, bits: int = 4, interpret: bool | None = None):
    """Stochastically quantize a tensor; returns the packed wire payload."""
    if interpret is None:
        interpret = _interpret_default()
    flat = x.reshape(-1).astype(jnp.float32)
    pack = 8 // bits
    grid = _pad_to_rows(flat, 8 * pack)
    norm = jnp.linalg.norm(flat)
    xi = jax.random.uniform(key, grid.shape)
    lvl, sign = qk.quantize_pallas(grid, xi, norm, bits, interpret=interpret)
    return {"levels": lvl, "signs": sign, "norm": norm}


def dequantize(payload, shape, dtype, bits: int = 4, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    d = int(np.prod(shape)) if shape else 1
    scale = payload["norm"] / ((1 << bits) * tau_for(d, bits))
    out = qk.dequantize_pallas(payload["levels"], payload["signs"], scale, bits, interpret=interpret)
    return out.reshape(-1)[:d].reshape(shape).astype(dtype)


def fused_choco_round_leaf(leaf, hat, s, key, topology, gamma, bits: int,
                           interpret: bool | None = None, *,
                           roll_fn=None, node_keys=None):
    """One fused-kernel CHOCO round for a stacked leaf [m, ...] — see
    kernels/choco_fused.py.  Returns (theta_new, hat_new, s_new).

    ``topology`` is anything with a circulant ``.shifts`` decomposition (a
    :class:`~repro.core.topology.Topology` or ``PermutePlan``); ``roll_fn``/
    ``node_keys`` are the SPMD backend's injection points (the kernels then
    operate on the device-local node block)."""
    from repro.kernels.choco_fused import fused_round_leaf

    if interpret is None:
        interpret = _interpret_default()
    return fused_round_leaf(leaf, hat, s, key, topology.shifts, gamma, bits,
                            interpret=interpret, roll_fn=roll_fn,
                            node_keys=node_keys)


def block_topk(x: jax.Array, fraction: float = 0.25, block: int = 1024, interpret: bool | None = None):
    """Dense blockwise top-k sparsification of a tensor (any shape)."""
    if interpret is None:
        interpret = _interpret_default()
    flat = x.reshape(-1).astype(jnp.float32)
    d = flat.shape[0]
    pad = (-d) % block
    rows = jnp.pad(flat, (0, pad)).reshape(-1, block)
    k = max(1, int(round(fraction * block)))
    out = tk.block_topk_pallas(rows, k, interpret=interpret)
    return out.reshape(-1)[:d].reshape(x.shape).astype(x.dtype)


# ------------------------------------------------------------ gossip plugins
@dataclasses.dataclass(frozen=True)
class KernelQuantization(Compressor):
    """RandomQuantization backed by the Pallas kernel (packed wire format).

    The payload that crosses the gossip collective is the *packed* uint8
    levels + uint8 sign bitmask: (bits + 1)/8 bytes per element instead of 4.

    Supports the single-pass fused gossip round (``fused_round``): the whole
    CHOCO averaging + encode + multi-shift dequant-accumulate runs in two
    Pallas kernels instead of ~8+deg full-tensor HBM passes.
    """

    bits: int = 4
    interpret: bool | None = None

    # capability flag checked by gossip.choco_round's fused dispatch
    supports_fused_round = True

    def fused_round(self, leaf, hat, s, key, topology, gamma):
        """Fused-kernel round for one stacked leaf; see choco_fused.py."""
        return fused_choco_round_leaf(
            leaf, hat, s, key, topology, gamma, self.bits, self.interpret
        )

    @property
    def delta(self):
        return 0.0  # see delta_for

    def delta_for(self, d: int) -> float:
        lvl = float(2**self.bits)
        return 1.0 / (1.0 + min(d / lvl**2, (d**0.5) / lvl))

    def encode(self, x, key=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        return quantize(x, key, self.bits, self.interpret)

    def decode(self, payload, shape, dtype):
        return dequantize(payload, shape, dtype, self.bits, self.interpret)

    def bits_per_element(self, d):
        return self.bits + 1 + 32.0 / max(d, 1)


@dataclasses.dataclass(frozen=True)
class KernelBlockTopK(Compressor):
    """BlockTopK backed by the Pallas bisection kernel.

    encode returns the dense masked residual (the sparse gather to
    values+indices wire format is a separate XLA gather, exercised by the
    core BlockTopK class); contraction factor matches fraction.
    """

    fraction: float = 0.25
    block: int = 1024
    interpret: bool | None = None

    @property
    def delta(self):
        return self.fraction

    def encode(self, x, key=None):
        return block_topk(x, self.fraction, self.block, self.interpret)

    def decode(self, payload, shape, dtype):
        return payload.reshape(shape).astype(dtype)

    def bits_per_element(self, d):
        import math

        return (32.0 + math.log2(self.block)) * self.fraction


# ---------------------------------------------------------- flash attention
def flash_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Sk, H, hd] (kv heads already repeated to H)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention over [B, S, H, hd] layouts (the model's convention).

    Folds (B, H) into the grid's parallel dimension, pads Sq/Sk to block
    multiples, and unpads the output.  On TPU this replaces the XLA
    attention path (layers.ATTENTION_IMPL = "flash"); on CPU it runs the
    Pallas interpreter and serves as the correctness oracle.
    """
    from repro.kernels.flash_attention import flash_attention_pallas

    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, x.shape[1], hd)

    qf, kf, vf = fold(q), fold(k), fold(v)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
        # padded keys must never win the max: causal masking handles pad_q
        # rows, but pad_k columns need masking via the window/causal path —
        # padded positions are beyond every real query position, so causal
        # masking already excludes them.  For non-causal, exclude by window.
        assert causal or window is not None, "non-causal flash requires exact Sk blocks"
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window, block_q=bq, block_k=bk,
        interpret=interpret,
    )
    if pad_q:
        out = out[:, :Sq]
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


def sliding_window_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, S, H, hd] (kv heads already repeated to H)
    v: jax.Array,
    *,
    window: int,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal sliding-window attention over the model's [B, S, H, hd] layout.

    Unlike ``flash_attention`` this grids over the kv window band, so VMEM
    stays O(window) instead of O(S) and fully-out-of-window kv blocks are
    never fetched from HBM — the long-context local_attn fast path.
    """
    from repro.kernels.sliding_window import sliding_window_attention_pallas

    if interpret is None:
        interpret = _interpret_default()
    B, S, H, hd = q.shape
    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, S))
    pad = (-S) % max(bq, bk)

    def fold(x):
        x = x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x

    out = sliding_window_attention_pallas(
        fold(q), fold(k), fold(v), window=window, block_q=bq, block_k=bk,
        interpret=interpret,
    )
    if pad:
        out = out[:, :S]
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def block_sparse_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,
    v: jax.Array,
    pattern,  # BlockSparsePattern — must match the padded sequence length
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Block-sparse attention over [B, S, H, hd]; the pattern's bitmap picks
    which (q-block, kv-block) tiles are computed (see kernels/block_sparse.py).
    """
    from repro.kernels.block_sparse import block_sparse_attention_pallas

    if interpret is None:
        interpret = _interpret_default()
    B, S, H, hd = q.shape
    assert pattern.seq_q == pattern.seq_k == S, (pattern.seq_q, S)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    out = block_sparse_attention_pallas(
        fold(q), fold(k), fold(v), pattern, interpret=interpret
    )
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def decode_attention_kernel(
    q: jax.Array,  # [B, 1, H, hd] — single decode-step query
    k: jax.Array,  # [B, L, KV, hd] cache (int8 when k_scale given)
    v: jax.Array,
    valid: jax.Array,  # [B, L] live cache slots
    *,
    k_scale: jax.Array | None = None,  # [B, L, KV] f32
    v_scale: jax.Array | None = None,
    impl: str | None = None,  # "pallas" | "xla_fused" | None (auto)
    interpret: bool | None = None,
) -> jax.Array:
    """Fused decode attention over the serving cache layout -> [B, 1, H, hd].

    Grouped-query heads are handled inside the kernel (no materialized
    ``_repeat_kv``); with ``k_scale``/``v_scale`` the cache is int8 and
    dequant fuses into the contractions.  ``impl`` auto-resolves to the
    Pallas kernel on TPU and the fused-XLA twin elsewhere (interpret-mode
    Pallas is a correctness oracle, not a serving fast path).
    """
    from repro.kernels.decode import (
        decode_attention_fused_xla,
        decode_attention_pallas,
    )

    B, one, H, hd = q.shape
    assert one == 1, q.shape
    KV = k.shape[2]
    G = H // KV
    # model convention (see layers._repeat_kv): q heads are kv-major — head
    # j*G+g belongs to kv head j — so [B,1,H,hd] reshapes straight to groups
    qg = q.reshape(B, KV, G, hd)
    if impl is None:
        impl = "xla_fused" if _interpret_default() else "pallas"
    if impl == "pallas":
        if interpret is None:
            interpret = _interpret_default()
        L = k.shape[1]
        block_l = next(b for b in (512, 256, 128, 64, 32, 16, 8, 1) if L % b == 0)
        out = decode_attention_pallas(
            qg, k, v, valid, k_scale=k_scale, v_scale=v_scale,
            block_l=block_l, interpret=interpret,
        )
    else:
        out = decode_attention_fused_xla(
            qg, k, v, valid, k_scale=k_scale, v_scale=v_scale
        )
    return out.reshape(B, 1, H, hd)


def quantize_kv(x: jax.Array):
    """Per-(position, kv-head) int8 symmetric KV quantization; see
    ref.quantize_kv_ref.  x: [..., hd] -> (int8 [..., hd], f32 scales [...])."""
    from repro.kernels.ref import quantize_kv_ref

    return quantize_kv_ref(x)
