"""Fused decode-attention kernel for the serving engine.

Per-tick decode is the serving fleet's hottest loop and it is memory-bound:
one query token attends over the whole KV cache, so the arithmetic intensity
is O(1) FLOPs per cache byte and throughput is set by how many bytes the
cache read moves.  The XLA path today (a) materializes ``_repeat_kv`` —
re-reading the kv heads G times for grouped-query attention — and (b) reads
the cache at f32/bf16 width.

This kernel fixes both:

* grid (B, KV): each cell handles one (batch, kv-head) pair's G query heads
  at once, so k/v stream through VMEM exactly once — no repeat.
* opt-in int8 quantized-KV mode (``k_scale``/``v_scale`` per (position,
  kv-head), built by ``ref.quantize_kv_ref`` at cache-store time): dequant
  is fused into the contractions — scores scale by ``k_scale`` *after* the
  int8 QK matmul and probabilities by ``v_scale`` *before* the int8 PV
  matmul — so the cache is read once at 1/4 the f32 bytes and no dequantized
  copy is ever materialized.

A ``valid`` row mask handles both linear caches (slots beyond ``pos``) and
ring-buffer windowed caches (wrapped slot ages); masked slots use the same
finite -1e30 sentinel as the other attention kernels.  The length loop runs
over ``block_l`` slabs inside the kernel (whole-L VMEM residency is fine at
serving cache lengths; L up to ~64k f32 at hd=64 fits comfortably).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import NEG_INF


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, *rest, scale, block_l,
                   num_l, quantized):
    if quantized:
        ks_ref, vs_ref, o_ref = rest
    else:
        (o_ref,) = rest
    g, hd = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * scale  # [G, hd]

    def body(j, carry):
        acc, m_prev, l_prev = carry
        sl = pl.dslice(j * block_l, block_l)
        k = pl.load(k_ref, (pl.dslice(0, 1), sl, pl.dslice(0, 1),
                            pl.dslice(0, hd)))[0, :, 0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), sl, pl.dslice(0, 1),
                            pl.dslice(0, hd)))[0, :, 0].astype(jnp.float32)
        s = q @ k.T  # [G, block_l]
        if quantized:
            ks = pl.load(ks_ref, (pl.dslice(0, 1), sl, pl.dslice(0, 1)))[
                0, :, 0
            ]
            s = s * ks[None, :]
        live = pl.load(valid_ref, (pl.dslice(0, 1), sl))[0] != 0
        s = jnp.where(live[None, :], s, NEG_INF)

        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(-1)
        if quantized:
            vs = pl.load(vs_ref, (pl.dslice(0, 1), sl, pl.dslice(0, 1)))[
                0, :, 0
            ]
            p = p * vs[None, :]
        acc = acc * alpha[:, None] + p @ v
        return acc, m_cur, l_cur

    acc = jnp.zeros((g, hd), jnp.float32)
    m = jnp.full((g,), NEG_INF, jnp.float32)
    l = jnp.zeros((g,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, num_l, body, (acc, m, l))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,  # [B, KV, G, hd]
    k: jax.Array,  # [B, L, KV, hd]  (int8 when k_scale given)
    v: jax.Array,  # [B, L, KV, hd]
    valid: jax.Array,  # [B, L] bool/int — live cache slots
    *,
    scale: float | None = None,
    k_scale: jax.Array | None = None,  # [B, L, KV] f32
    v_scale: jax.Array | None = None,
    block_l: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, kv, g, hd = q.shape
    length = k.shape[1]
    assert k.shape == v.shape == (b, length, kv, hd), (q.shape, k.shape)
    quantized = k_scale is not None
    assert (k_scale is None) == (v_scale is None)
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    block_l = min(block_l, length)
    assert length % block_l == 0, (length, block_l)

    kv_spec = pl.BlockSpec((1, length, 1, hd), lambda bi, h: (bi, 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda bi, h: (bi, h, 0, 0)),
        kv_spec,
        kv_spec,
        pl.BlockSpec((1, length), lambda bi, h: (bi, 0)),
    ]
    operands = [q, k, v, valid.astype(jnp.int32)]
    if quantized:
        sc_spec = pl.BlockSpec((1, length, 1), lambda bi, h: (bi, 0, h))
        in_specs += [sc_spec, sc_spec]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _decode_kernel,
        scale=scale,
        block_l=block_l,
        num_l=length // block_l,
        quantized=quantized,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, h: (bi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(*operands)


def decode_attention_fused_xla(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    *,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """XLA twin of the decode kernel (same fused-dequant math, no Pallas).

    Off-TPU the Pallas path would run under ``interpret=True`` — correct but
    slow — so the CPU serving engine dispatches here instead: grouped heads
    without a materialized ``_repeat_kv`` and int8 dequant fused into the
    einsums.  Identical contraction order to the kernel's per-slab loop up
    to the online-softmax reassociation.
    """
    from repro.kernels.ref import decode_attention_ref

    return decode_attention_ref(
        q, k, v, valid, scale=scale, k_scale=k_scale, v_scale=v_scale
    )
