"""Serving-fleet metrics: per-node and fleet-wide latency/SLO accounting.

One vocabulary, used verbatim everywhere (suite S rows in ``BENCH_S.json``,
the printed benchmark table, ``benchmarks/check_regression.py --suite S``,
``launch/serve.py --metrics-out``, and the README "Serving fleet" section):

* ``p50_ttft_ticks`` / ``p95_ttft_ticks`` / ``p99_ttft_ticks`` — percentiles
  of time-to-first-token in **engine ticks** (the first token rides the
  prefill at admit, so TTFT is exactly queue wait; tick-denominated metrics
  are bit-deterministic given the loadgen seed and gateable across
  machines);
* ``p50_ttft_ms`` / ``p99_ttft_ms`` — the same percentiles in wall
  milliseconds (reported, not gated: host-dependent);
* ``per_token_ms`` — mean wall milliseconds per generated token over the
  run (decode steps amortized over all tokens);
* ``tok_per_s`` — aggregate generated tokens per wall second;
* ``mean_queue_depth`` / ``max_queue_depth`` — pending-queue occupancy
  sampled every tick;
* ``slot_occupancy`` — mean fraction of the slot pool busy per tick;
* ``requests`` / ``completed`` / ``rejected`` / ``shed`` — admission
  accounting (``rejected``: refused at arrival by the bounded queue;
  ``shed``: evicted from the queue to make room under the shed-oldest
  policy);
* ``cache_hit_rate`` — fraction of prefix-cache lookups that hit (0.0 when
  the engine has no prefix cache or it is bypassed — recurrent/windowed
  archs); ``prefill_skipped`` — absolute count of prefill forwards the
  prefix cache avoided.  Both are wall-clock levers only: hits emit the
  bit-identical tokens a prefill would, so tick metrics never move.

Latency stats accept either a list of Request-like objects or a
:class:`RequestStats` accumulator — the streaming form the fleet's
``retain="stats"`` mode uses so a 10^6-request run does not hold every
Request alive.  ``RequestStats`` keeps the raw TTFT samples (ints/floats,
cheap) so percentiles stay exact, not approximated.

The **SLO** suite S gates is stated on these keys: below the measured
latency knee, ``rejected == 0`` and ``p99_ttft_ticks`` stays within a fixed
inflation factor of ``p50_ttft_ticks``.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "LATENCY_KEYS",
    "RequestStats",
    "percentiles",
    "summarize_requests",
    "summarize_node",
    "summarize_fleet",
]

# the shared latency/SLO key vocabulary, in table order
LATENCY_KEYS = (
    "requests",
    "completed",
    "rejected",
    "shed",
    "p50_ttft_ticks",
    "p95_ttft_ticks",
    "p99_ttft_ticks",
    "p50_ttft_ms",
    "p99_ttft_ms",
    "per_token_ms",
    "tok_per_s",
    "mean_queue_depth",
    "max_queue_depth",
    "slot_occupancy",
    "cache_hit_rate",
    "prefill_skipped",
)


class RequestStats:
    """Streaming accumulator over terminal requests (done/rejected/shed).

    Holds the per-request TTFT samples (exact percentiles) plus counters —
    a few machine words per request instead of a live Request object, so
    the fleet's ``retain="stats"`` mode scales to 10^6+ requests.  Merging
    accumulators concatenates the samples, so fleet-wide percentiles are
    pooled over every node's requests exactly like the list-based path.
    """

    __slots__ = ("requests", "completed", "rejected", "shed", "tokens",
                 "ttft_ticks", "ttft_ms")

    def __init__(self):
        self.requests = 0
        self.completed = 0
        self.rejected = 0
        self.shed = 0
        self.tokens = 0
        self.ttft_ticks: list[int] = []
        self.ttft_ms: list[float] = []

    def add(self, r) -> None:
        """Absorb a TERMINAL request (caller checks the status)."""
        self.requests += 1
        if r.status == "done":
            self.completed += 1
            self.tokens += len(r.output)
            self.ttft_ticks.append(r.ttft_ticks)
            self.ttft_ms.append((r.first_wall - r.submit_wall) * 1e3)
        elif r.status == "rejected":
            self.rejected += 1
        elif r.status == "shed":
            self.shed += 1

    @classmethod
    def merged(cls, parts) -> "RequestStats":
        out = cls()
        for p in parts:
            out.requests += p.requests
            out.completed += p.completed
            out.rejected += p.rejected
            out.shed += p.shed
            out.tokens += p.tokens
            out.ttft_ticks.extend(p.ttft_ticks)
            out.ttft_ms.extend(p.ttft_ms)
        return out


def percentiles(xs, qs=(50, 95, 99)) -> dict[float, float]:
    """Empirical percentiles (nearest-rank on the sorted sample); 0.0 when
    the sample is empty so overload rows still render."""
    xs = np.asarray(list(xs), np.float64)
    if xs.size == 0:
        return {q: 0.0 for q in qs}
    # "higher" = conservative nearest-rank: the reported p99 is an actual
    # sample value with >= 99% of the distribution at or below it
    return {q: float(np.percentile(xs, q, method="higher")) for q in qs}


def _as_stats(requests) -> RequestStats:
    if isinstance(requests, RequestStats):
        return requests
    s = RequestStats()
    for r in requests:
        s.add(r)
    return s


def summarize_requests(requests) -> dict:
    """Latency stats over Request-like objects OR a RequestStats accumulator.

    Only the queue/engine timestamps stamped by the engine and admission
    layer are read (duck-typed: the LM ``ServeEngine`` and the classifier
    engine both qualify).
    """
    s = _as_stats(requests)
    p_t = percentiles(s.ttft_ticks)
    p_w = percentiles(s.ttft_ms, (50, 99))
    return {
        "requests": s.requests,
        "completed": s.completed,
        "rejected": s.rejected,
        "shed": s.shed,
        "tokens": s.tokens,
        "p50_ttft_ticks": p_t[50],
        "p95_ttft_ticks": p_t[95],
        "p99_ttft_ticks": p_t[99],
        "p50_ttft_ms": p_w[50],
        "p99_ttft_ms": p_w[99],
    }


def summarize_node(requests, *, queue_samples, occupancy_samples, max_slots,
                   wall_seconds, tokens_generated, engine_stats=None) -> dict:
    """Per-node roll-up: request latency stats + queue/slot telemetry (+
    the engine's fast-path counters when it exposes ``stats()``)."""
    out = summarize_requests(requests)
    q = np.asarray(queue_samples, np.float64)
    occ = np.asarray(occupancy_samples, np.float64)
    out.update({
        "mean_queue_depth": float(q.mean()) if q.size else 0.0,
        "max_queue_depth": float(q.max()) if q.size else 0.0,
        "slot_occupancy": float(occ.mean() / max_slots) if occ.size else 0.0,
        "per_token_ms": (wall_seconds * 1e3 / tokens_generated) if tokens_generated else 0.0,
        "tok_per_s": (tokens_generated / wall_seconds) if wall_seconds > 0 else 0.0,
    })
    es = engine_stats or {}
    out.update({
        "cache_hit_rate": float(es.get("cache_hit_rate", 0.0)),
        "prefill_skipped": float(es.get("prefill_skipped", 0.0)),
        # raw lookup counts so the fleet roll-up can pool hit rates exactly
        "prefix_hits": float(es.get("prefix_hits", 0.0)),
        "prefix_misses": float(es.get("prefix_misses", 0.0)),
    })
    return out


def summarize_fleet(node_summaries: list[dict], all_requests) -> dict:
    """Fleet-wide roll-up: percentiles pooled over every node's requests
    (NOT a mean of per-node percentiles), throughput and admission totals
    summed, queue/occupancy averaged, cache hit rate pooled over lookups."""
    out = summarize_requests(all_requests)
    if not node_summaries:
        return out
    hits = float(np.sum([n.get("prefix_hits", 0.0) for n in node_summaries]))
    lookups = hits + float(np.sum([n.get("prefix_misses", 0.0) for n in node_summaries]))
    out.update({
        "per_token_ms": float(np.mean([n["per_token_ms"] for n in node_summaries])),
        "tok_per_s": float(np.sum([n["tok_per_s"] for n in node_summaries])),
        "mean_queue_depth": float(np.mean([n["mean_queue_depth"] for n in node_summaries])),
        "max_queue_depth": float(np.max([n["max_queue_depth"] for n in node_summaries])),
        "slot_occupancy": float(np.mean([n["slot_occupancy"] for n in node_summaries])),
        "cache_hit_rate": (hits / lookups) if lookups else 0.0,
        "prefill_skipped": float(np.sum([n.get("prefill_skipped", 0.0)
                                         for n in node_summaries])),
    })
    return out
