"""Serving-fleet metrics: per-node and fleet-wide latency/SLO accounting.

One vocabulary, used verbatim everywhere (suite S rows in ``BENCH_S.json``,
the printed benchmark table, ``benchmarks/check_regression.py --suite S``,
``launch/serve.py --metrics-out``, and the README "Serving fleet" section):

* ``p50_ttft_ticks`` / ``p95_ttft_ticks`` / ``p99_ttft_ticks`` — percentiles
  of time-to-first-token in **engine ticks** (the first token rides the
  prefill at admit, so TTFT is exactly queue wait; tick-denominated metrics
  are bit-deterministic given the loadgen seed and gateable across
  machines);
* ``p50_ttft_ms`` / ``p99_ttft_ms`` — the same percentiles in wall
  milliseconds (reported, not gated: host-dependent);
* ``per_token_ms`` — mean wall milliseconds per generated token over the
  run (decode steps amortized over all tokens);
* ``tok_per_s`` — aggregate generated tokens per wall second;
* ``mean_queue_depth`` / ``max_queue_depth`` — pending-queue occupancy
  sampled every tick;
* ``slot_occupancy`` — mean fraction of the slot pool busy per tick;
* ``requests`` / ``completed`` / ``rejected`` / ``shed`` — admission
  accounting (``rejected``: refused at arrival by the bounded queue;
  ``shed``: evicted from the queue to make room under the shed-oldest
  policy).

The **SLO** suite S gates is stated on these keys: below the measured
latency knee, ``rejected == 0`` and ``p99_ttft_ticks`` stays within a fixed
inflation factor of ``p50_ttft_ticks``.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "LATENCY_KEYS",
    "percentiles",
    "summarize_requests",
    "summarize_node",
    "summarize_fleet",
]

# the shared latency/SLO key vocabulary, in table order
LATENCY_KEYS = (
    "requests",
    "completed",
    "rejected",
    "shed",
    "p50_ttft_ticks",
    "p95_ttft_ticks",
    "p99_ttft_ticks",
    "p50_ttft_ms",
    "p99_ttft_ms",
    "per_token_ms",
    "tok_per_s",
    "mean_queue_depth",
    "max_queue_depth",
    "slot_occupancy",
)


def percentiles(xs, qs=(50, 95, 99)) -> dict[float, float]:
    """Empirical percentiles (nearest-rank on the sorted sample); 0.0 when
    the sample is empty so overload rows still render."""
    xs = np.asarray(list(xs), np.float64)
    if xs.size == 0:
        return {q: 0.0 for q in qs}
    # "higher" = conservative nearest-rank: the reported p99 is an actual
    # sample value with >= 99% of the distribution at or below it
    return {q: float(np.percentile(xs, q, method="higher")) for q in qs}


def summarize_requests(requests) -> dict:
    """Latency stats over a set of Request-like objects (done/rejected/shed).

    Only the queue/engine timestamps stamped by the engine and admission
    layer are read (duck-typed: the LM ``ServeEngine`` and the classifier
    engine both qualify).
    """
    done = [r for r in requests if r.status == "done"]
    rejected = sum(r.status == "rejected" for r in requests)
    shed = sum(r.status == "shed" for r in requests)
    ttft_ticks = [r.ttft_ticks for r in done]
    ttft_ms = [(r.first_wall - r.submit_wall) * 1e3 for r in done]
    p_t = percentiles(ttft_ticks)
    p_w = percentiles(ttft_ms, (50, 99))
    tokens = sum(len(r.output) for r in done)
    return {
        "requests": len(requests),
        "completed": len(done),
        "rejected": int(rejected),
        "shed": int(shed),
        "tokens": tokens,
        "p50_ttft_ticks": p_t[50],
        "p95_ttft_ticks": p_t[95],
        "p99_ttft_ticks": p_t[99],
        "p50_ttft_ms": p_w[50],
        "p99_ttft_ms": p_w[99],
    }


def summarize_node(requests, *, queue_samples, occupancy_samples, max_slots,
                   wall_seconds, tokens_generated) -> dict:
    """Per-node roll-up: request latency stats + queue/slot telemetry."""
    out = summarize_requests(requests)
    q = np.asarray(queue_samples, np.float64)
    occ = np.asarray(occupancy_samples, np.float64)
    out.update({
        "mean_queue_depth": float(q.mean()) if q.size else 0.0,
        "max_queue_depth": float(q.max()) if q.size else 0.0,
        "slot_occupancy": float(occ.mean() / max_slots) if occ.size else 0.0,
        "per_token_ms": (wall_seconds * 1e3 / tokens_generated) if tokens_generated else 0.0,
        "tok_per_s": (tokens_generated / wall_seconds) if wall_seconds > 0 else 0.0,
    })
    return out


def summarize_fleet(node_summaries: list[dict], all_requests) -> dict:
    """Fleet-wide roll-up: percentiles pooled over every node's requests
    (NOT a mean of per-node percentiles), throughput and admission totals
    summed, queue/occupancy averaged."""
    out = summarize_requests(all_requests)
    if not node_summaries:
        return out
    out.update({
        "per_token_ms": float(np.mean([n["per_token_ms"] for n in node_summaries])),
        "tok_per_s": float(np.sum([n["tok_per_s"] for n in node_summaries])),
        "mean_queue_depth": float(np.mean([n["mean_queue_depth"] for n in node_summaries])),
        "max_queue_depth": float(np.max([n["max_queue_depth"] for n in node_summaries])),
        "slot_occupancy": float(np.mean([n["slot_occupancy"] for n in node_summaries])),
    })
    return out
