from repro.serving.engine import Request, ServeEngine
from repro.serving.fleet import (
    AdmissionControl,
    BatchedProbe,
    ClassifierEngine,
    EvalRequest,
    FleetNode,
    FleetReport,
    HotReloader,
    ServingFleet,
)
from repro.serving.loadgen import LoadGenConfig, LoadGenerator

__all__ = [
    "Request",
    "ServeEngine",
    "AdmissionControl",
    "BatchedProbe",
    "ClassifierEngine",
    "EvalRequest",
    "FleetNode",
    "FleetReport",
    "HotReloader",
    "ServingFleet",
    "LoadGenConfig",
    "LoadGenerator",
]
