from repro.serving.engine import Request, ServeEngine
from repro.serving.fleet import (
    AdmissionControl,
    ClassifierEngine,
    EvalRequest,
    FleetNode,
    FleetReport,
    HotReloader,
    ServingFleet,
)
from repro.serving.loadgen import LoadGenConfig, LoadGenerator

__all__ = [
    "Request",
    "ServeEngine",
    "AdmissionControl",
    "ClassifierEngine",
    "EvalRequest",
    "FleetNode",
    "FleetReport",
    "HotReloader",
    "ServingFleet",
    "LoadGenConfig",
    "LoadGenerator",
]
