"""Continuous-batching serving engine on the consensus model.

vLLM-style slot management on top of the model zoo's decode path:

* a fixed pool of ``max_slots`` cache slots (attention K/V ring buffers,
  SSM/RG-LRU states — whatever the arch family uses), preallocated once;
* requests are admitted whenever a slot is free: the prompt is prefilled
  into a fresh single-sequence cache (bucketed/padded lengths keep the jit
  cache warm) and spliced into the pool at the slot index;
* every engine tick decodes ONE token for ALL active slots in a single
  vmapped decode step with **per-slot positions** — sequences of different
  lengths progress independently;
* finished requests (max tokens or EOS) release their slot immediately.

Admission is strictly FIFO: each tick runs an admit/finish fixpoint, so a
request that completes *at prefill* (single-token budget, or EOS emitted as
the final prompt-prefill token) releases its slot the same tick and the
next pending request is admitted into it — slot contention never reorders
or starves the queue.  Every ``Request`` carries tick- and wall-clock
timestamps (submit/admit/first-token/finish) consumed by the fleet metrics
layer (`repro.serving.metrics`); ``prefill_traces`` / ``decode_traces``
count jit retraces so the bucketed-prefill warm-cache claim is testable.

This is the production shape of the ``decode_32k`` dry-run: the engine is
the host-side loop, the vmapped decode step is the device program.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine
    rid: int = -1
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle + timing, stamped by the engine/fleet (ticks are engine
    # steps; walls are host seconds).  first token lands at admit (the
    # prefill emits it), so TTFT = admit_tick - submit_tick = queue wait.
    status: str = "queued"  # queued | active | done | rejected | shed
    submit_tick: int = -1
    admit_tick: int = -1
    finish_tick: int = -1
    submit_wall: float = 0.0
    first_wall: float = 0.0
    finish_wall: float = 0.0

    @property
    def ttft_ticks(self) -> int:
        """Time-to-first-token in engine ticks (queue wait; -1 if unserved)."""
        if self.admit_tick < 0 or self.submit_tick < 0:
            return -1
        return self.admit_tick - self.submit_tick


def _batch_axes(cache) -> object:
    """Per-leaf vmap axis of the batch dim: 1 under stacked 'blocks', else 0."""

    def axis_for(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        return 1 if "blocks" in names else 0

    return jax.tree_util.tree_map_with_path(axis_for, cache)


def _round_up(n: int, unit: int) -> int:
    return max(unit, -(-n // unit) * unit)


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_slots: int = 4,
        cache_len: int = 256,
        prompt_bucket: int = 32,
        sample: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
        extra_inputs: dict | None = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.prompt_bucket = prompt_bucket
        self.extra_inputs = extra_inputs or {}

        self.cache = T.init_cache(cfg, max_slots, cache_len)
        self._axes = _batch_axes(self.cache)
        self.pos = np.zeros(max_slots, np.int32)  # context length per slot
        self.last_tok = np.zeros(max_slots, np.int32)
        self.active: dict[int, Request] = {}
        self.pending: deque[Request] = deque()
        self._ids = itertools.count()
        self._steps = 0
        # jit retrace counters (incremented at TRACE time only): one prefill
        # trace per prompt bucket, one decode trace total, is the warm-cache
        # contract pinned by tests/test_serving.py
        self.prefill_traces = 0
        self.decode_traces = 0
        self.tokens_generated = 0

        # one-token decode for every slot, per-slot positions.  The vmapped
        # axis is the pool's batch dim: axis 1 for stacked-blocks leaves
        # ([nb, B, ...]), axis 0 elsewhere — decode_one reinserts a size-1
        # batch dim at the same position for the model.
        def _expand(path, leaf):
            names = [getattr(p, "key", None) for p in path]
            ax = 1 if "blocks" in names else 0
            return jnp.expand_dims(leaf, ax)

        def _squeeze(path, leaf):
            names = [getattr(p, "key", None) for p in path]
            ax = 1 if "blocks" in names else 0
            return jax.lax.index_in_dim(leaf, 0, axis=ax, keepdims=False)

        def decode_one(params, tok, cache_slot, pos):
            self.decode_traces += 1  # python side effect: runs at trace time only
            cache_b = jax.tree_util.tree_map_with_path(_expand, cache_slot)
            logits, new_cache = T.decode_step(params, tok[None, None], cache_b, pos, cfg)
            return logits[0, 0], jax.tree_util.tree_map_with_path(_squeeze, new_cache)

        self._decode = jax.jit(
            jax.vmap(
                decode_one,
                in_axes=(None, 0, self._axes, 0),
                out_axes=(0, self._axes),  # keep the pool's per-leaf batch axis
            )
        )
        self._prefills: dict[int, Callable] = {}
        self._sample = sample or (lambda logits, key: jnp.argmax(logits, -1).astype(jnp.int32))
        self._key = jax.random.PRNGKey(0)
        mixers = {cfg.mixer_for_layer(i) for i in range(cfg.num_layers)}
        self._recurrent = bool(mixers & {"mamba2", "rglru"})
        # windowed ring buffers: once the window wraps, every slot is
        # attendable, so bucket-padding garbage would poison the cache —
        # such archs also prefill at exact prompt length
        self._windowed = ("local_attn" in mixers) or (
            cfg.long_context_window is not None and cache_len > cfg.long_context_window
        )

    # ------------------------------------------------------------- slots
    def _slot_view(self, cache, slot):
        """Extract slot `slot` as a batchless cache pytree."""

        def take(path, leaf):
            names = [getattr(p, "key", None) for p in path]
            ax = 1 if "blocks" in names else 0
            return jax.lax.index_in_dim(leaf, slot, axis=ax, keepdims=False)

        return jax.tree_util.tree_map_with_path(take, cache)

    def _insert_slot(self, cache, cache1, slot):
        """Splice a batch-1 cache into the pool at `slot`."""

        def put(path, pool, new):
            names = [getattr(p, "key", None) for p in path]
            ax = 1 if "blocks" in names else 0
            idx = [0] * pool.ndim
            idx[ax] = slot
            return jax.lax.dynamic_update_slice(pool, new.astype(pool.dtype), tuple(idx))

        flat_pool, tdef = jax.tree_util.tree_flatten_with_path(cache)
        flat_new = jax.tree_util.tree_leaves(cache1)
        out = [put(p, pool, new) for (p, pool), new in zip(flat_pool, flat_new)]
        return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(cache), out)

    # ----------------------------------------------------------- prefill
    def _prefill_fn(self, length: int):
        if length not in self._prefills:
            cfg = self.cfg

            def fn(params, batch):
                self.prefill_traces += 1  # trace-time side effect (retrace counter)
                return T.prefill(params, batch, cfg, cache_len=self.cache_len)

            self._prefills[length] = jax.jit(fn)
        return self._prefills[length]

    def _admit(self, req: Request, slot: int) -> None:
        req.admit_tick = self._steps
        req.first_wall = time.time()
        req.status = "active"
        plen = len(req.prompt)
        if self._recurrent or self._windowed:
            # recurrent states absorb every consumed token, and wrapped ring
            # buffers attend every slot — both need exact-length prefill
            # (mamba2 additionally needs chunk-divisible lengths)
            if self.cfg.ssm_state:
                assert plen % self.cfg.ssm_chunk == 0, (
                    f"mamba2 prompts must be multiples of ssm_chunk={self.cfg.ssm_chunk}"
                )
            bucket = plen
        else:
            bucket = min(_round_up(plen, self.prompt_bucket), self.cache_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        batch = {"tokens": jnp.asarray(toks), **{
            k: v[None] if hasattr(v, "ndim") else v for k, v in self.extra_inputs.items()
        }}
        logits, cache1 = self._prefill_fn(bucket)(self.params, batch)
        # first generated token comes from the last REAL prompt position
        first = int(jnp.argmax(logits[0, plen - 1]))
        # cache1 keeps its size-1 batch dim (already at the per-leaf batch
        # axis), so the splice below is a rank-preserving dynamic_update_slice
        self.cache = self._insert_slot(self.cache, cache1, slot)
        # NOTE: bucket-padded positions beyond plen hold garbage K/V; decode
        # masks by position (pos = plen), so they are never attended.
        self.pos[slot] = plen
        self.last_tok[slot] = first
        req.output.append(first)
        self.tokens_generated += 1
        self.active[slot] = req

    # -------------------------------------------------------------- API
    def submit(self, req: Request) -> int:
        req.rid = next(self._ids)
        if req.submit_tick < 0:  # the fleet may pre-stamp the arrival tick
            req.submit_tick = self._steps
            req.submit_wall = time.time()
        self.pending.append(req)
        return req.rid

    def _finish(self, slot: int) -> None:
        r = self.active[slot]
        r.done = True
        r.status = "done"
        r.finish_tick = self._steps
        r.finish_wall = time.time()
        del self.active[slot]
        self.pos[slot] = 0

    def _complete(self, r: Request) -> bool:
        return len(r.output) >= r.max_new_tokens or (
            r.eos_id is not None and bool(r.output) and r.output[-1] == r.eos_id
        )

    def step(self) -> None:
        """One engine tick: admit (FIFO), decode one token for all active slots.

        Admission runs to a fixpoint with completion: a request that is
        already complete after its prefill (single-token budget, or EOS
        emitted as the final prompt-prefill token) releases its slot THIS
        tick and the next pending request is admitted into it, in strict
        submit order.  Each loop iteration either admits at least one
        pending request or breaks, so the fixpoint terminates.
        """
        while True:
            for slot in list(self.active):
                if self._complete(self.active[slot]):
                    self._finish(slot)
            free = [s for s in range(self.max_slots) if s not in self.active]
            if not (self.pending and free):
                break
            for slot in free:
                if not self.pending:
                    break
                self._admit(self.pending.popleft(), slot)

        if self.active:
            toks = jnp.asarray(self.last_tok)
            pos = jnp.asarray(self.pos)
            logits, new_cache = self._decode(self.params, toks, self.cache, pos)
            self.cache = new_cache
            self._key, sub = jax.random.split(self._key)
            next_tok = np.asarray(self._sample(logits, sub))

            for slot in list(self.active):
                r = self.active[slot]
                tok = int(next_tok[slot])
                r.output.append(tok)
                self.tokens_generated += 1
                self.pos[slot] += 1
                self.last_tok[slot] = tok
                if self._complete(r):
                    self._finish(slot)
        self._steps += 1

    def run(self, requests: list[Request], max_ticks: int = 10_000) -> list[Request]:
        """Submit everything and tick until done.  Returns the requests."""
        for r in requests:
            self.submit(r)
        ticks = 0
        while (self.pending or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return requests
