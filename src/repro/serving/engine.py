"""Continuous-batching serving engine on the consensus model.

vLLM-style slot management on top of the model zoo's decode path:

* a fixed pool of ``max_slots`` cache slots (attention K/V ring buffers,
  SSM/RG-LRU states — whatever the arch family uses), preallocated once;
* requests are admitted whenever a slot is free: the prompt is prefilled
  into a fresh cache (bucketed/padded lengths keep the jit cache warm) and
  spliced into the pool at the slot index;
* every engine tick decodes ONE token for ALL active slots in a single
  vmapped decode step with **per-slot positions** — sequences of different
  lengths progress independently;
* finished requests (max tokens or EOS) release their slot immediately.

The serving **fast path** (on by default, ``fastpath=False`` restores the
original per-request engine bit-for-bit) adds three wall-clock levers that
leave every tick-denominated metric untouched — admission order, completion
ticks and generated tokens are bit-identical, only host seconds change:

* **prefix KV cache** — post-prefill cache slices keyed by the exact prompt
  (bucketed), LRU-bounded (``prefix_cache`` entries), invalidated whenever
  ``engine.params`` is reassigned (hot reload), and bypassed for
  recurrent/windowed archs whose exact-length prefill semantics make a
  cached slice position-dependent.  A hit skips the prefill forward
  entirely (``prefill_skipped``); Zipf traffic makes hot prompts common, so
  the workload's own skew becomes throughput.
* **batched prefill** — all same-bucket pending requests admitted this tick
  run as ONE forward (batch padded to a power of two for a bounded trace
  set) instead of a batch=1 jit call per request.
* **active-slot decode** — at low occupancy the decode gathers the active
  slots (rounded up to a power of two) instead of paying the full
  ``max_slots`` vmapped step; results scatter back with out-of-bounds pad
  rows dropped.  Gathered decode is bit-identical to the full-pool step.

Fast-path programs (prefill/decode/splice) live in a **module-level
LRU-bounded program cache** (``PROGRAMS``) keyed by config + shapes, so a
fleet of engines with the same model shares one compiled program per shape
instead of recompiling per engine — compile time dominated the pre-fastpath
suite.  The legacy path's per-engine ``_prefills`` dict is LRU-bounded too
(``max_prefill_programs``) so many distinct exact-length prefills
(recurrent/windowed archs) can no longer grow the jit cache without bound;
``engine.stats()`` exposes sizes, hits and evictions.

Admission is strictly FIFO: each tick runs an admit/finish fixpoint, so a
request that completes *at prefill* (single-token budget, or EOS emitted as
the final prompt-prefill token) releases its slot the same tick and the
next pending request is admitted into it — slot contention never reorders
or starves the queue.  Every ``Request`` carries tick- and wall-clock
timestamps (submit/admit/first-token/finish) consumed by the fleet metrics
layer (`repro.serving.metrics`); ``prefill_traces`` / ``decode_traces``
count program builds triggered by this engine so the bounded-trace-set
claim stays testable (clear ``PROGRAMS`` first when pinning counts).

This is the production shape of the ``decode_32k`` dry-run: the engine is
the host-side loop, the vmapped decode step is the device program.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict, deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig

__all__ = ["Request", "ServeEngine", "ProgramCache", "PROGRAMS"]


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine
    rid: int = -1
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle + timing, stamped by the engine/fleet (ticks are engine
    # steps; walls are host seconds).  first token lands at admit (the
    # prefill emits it), so TTFT = admit_tick - submit_tick = queue wait.
    status: str = "queued"  # queued | active | done | rejected | shed
    submit_tick: int = -1
    admit_tick: int = -1
    finish_tick: int = -1
    submit_wall: float = 0.0
    first_wall: float = 0.0
    finish_wall: float = 0.0

    @property
    def ttft_ticks(self) -> int:
        """Time-to-first-token in engine ticks (queue wait; -1 if unserved)."""
        if self.admit_tick < 0 or self.submit_tick < 0:
            return -1
        return self.admit_tick - self.submit_tick


def _leaf_axis(path) -> int:
    """Per-leaf batch axis of a cache pytree: 1 under stacked 'blocks', else 0."""
    names = [getattr(p, "key", None) for p in path]
    return 1 if "blocks" in names else 0


def _batch_axes(cache) -> object:
    """Per-leaf vmap axis of the batch dim: 1 under stacked 'blocks', else 0."""
    return jax.tree_util.tree_map_with_path(lambda p, _: _leaf_axis(p), cache)


def _round_up(n: int, unit: int) -> int:
    return max(unit, -(-n // unit) * unit)


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class ProgramCache:
    """LRU-bounded map from (config, shape signature) -> compiled program.

    Shared by every ``ServeEngine`` in the process: a fleet of engines over
    the same model compiles each prefill/decode/splice shape once instead of
    per engine.  ``get`` returns ``(program, built)`` where ``built`` marks
    a fresh compile (the caller's retrace counter); eviction of the
    least-recently-used program is counted, mirroring the per-engine
    ``_prefills`` bound of the legacy path.
    """

    def __init__(self, maxsize: int = 64):
        self.maxsize = maxsize
        self._programs: OrderedDict[tuple, Callable] = OrderedDict()
        self.builds = 0
        self.hits = 0
        self.evictions = 0

    def get(self, key: tuple, build: Callable[[], Callable]):
        if key in self._programs:
            self._programs.move_to_end(key)
            self.hits += 1
            return self._programs[key], False
        fn = build()
        self.builds += 1
        self._programs[key] = fn
        if len(self._programs) > self.maxsize:
            self._programs.popitem(last=False)
            self.evictions += 1
        return fn, True

    def __len__(self) -> int:
        return len(self._programs)

    def clear(self) -> None:
        self._programs.clear()
        self.builds = self.hits = self.evictions = 0


#: process-wide fast-path program cache (tests pinning trace counts should
#: ``PROGRAMS.clear()`` first so a previously built shape does not mask them)
PROGRAMS = ProgramCache()


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_slots: int = 4,
        cache_len: int = 256,
        prompt_bucket: int = 32,
        sample: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
        extra_inputs: dict | None = None,
        fastpath: bool = True,
        prefix_cache: int = 64,
        batched_prefill: bool | None = None,
        active_decode: bool | None = None,
        max_prefill_programs: int = 32,
    ):
        self.cfg = cfg
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.prompt_bucket = prompt_bucket
        self.extra_inputs = extra_inputs or {}
        # fast-path knobs: the master toggle defaults the individual levers;
        # fastpath=False with everything defaulted IS the original engine
        self._fast = bool(fastpath)
        self._batched_prefill = self._fast if batched_prefill is None else batched_prefill
        self._active_decode = self._fast if active_decode is None else active_decode
        self._prefix_max = int(prefix_cache) if self._fast else 0
        self._max_prefill_programs = max_prefill_programs

        self.cache = T.init_cache(cfg, max_slots, cache_len)
        self._axes = _batch_axes(self.cache)
        self.pos = np.zeros(max_slots, np.int32)  # context length per slot
        self.last_tok = np.zeros(max_slots, np.int32)
        self.active: dict[int, Request] = {}
        self.pending: deque[Request] = deque()
        self._ids = itertools.count()
        self._steps = 0
        # program-build counters: one prefill build per (bucket, batch)
        # shape, a log2-bounded decode set, is the warm-cache contract
        # pinned by tests/test_serving.py (fast path counts builds this
        # engine triggered in the shared PROGRAMS cache)
        self.prefill_traces = 0
        self.decode_traces = 0
        self.tokens_generated = 0
        # prefix-cache state + telemetry
        self._prefix: OrderedDict[tuple, tuple] = OrderedDict()
        self.params_version = 0
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_evictions = 0
        self.prefix_invalidations = 0
        self.prefill_skipped = 0
        self.prefill_evictions = 0

        self._params = params
        self._prefills: OrderedDict[int, Callable] = OrderedDict()
        self._decode = None  # legacy per-engine decode program, built lazily
        self._sample = sample or (lambda logits, key: jnp.argmax(logits, -1).astype(jnp.int32))
        self._key = jax.random.PRNGKey(0)
        mixers = {cfg.mixer_for_layer(i) for i in range(cfg.num_layers)}
        self._recurrent = bool(mixers & {"mamba2", "rglru"})
        # windowed ring buffers: once the window wraps, every slot is
        # attendable, so bucket-padding garbage would poison the cache —
        # such archs also prefill at exact prompt length
        self._windowed = ("local_attn" in mixers) or (
            cfg.long_context_window is not None and cache_len > cfg.long_context_window
        )
        # shared-program key prefix: config identity + shapes the programs
        # close over (ModelConfig is a frozen dataclass — repr is total)
        extras = tuple(sorted(
            (k, tuple(np.shape(v)) if hasattr(v, "ndim") else v)
            for k, v in self.extra_inputs.items()
        ))
        self._sig = (repr(cfg), cache_len, extras)

    # ------------------------------------------------------------- params
    @property
    def params(self):
        return self._params

    @params.setter
    def params(self, new):
        """Hot-reload hook: swapping weights invalidates every cached prefix
        (the slices were computed under the old params and would silently
        garble generations otherwise)."""
        self._params = new
        self.params_version += 1
        if self._prefix:
            self.prefix_invalidations += 1
            self._prefix.clear()

    # ---------------------------------------------------------- telemetry
    def stats(self) -> dict:
        """Engine-side fast-path telemetry (floats, fleet-aggregatable)."""
        lookups = self.prefix_hits + self.prefix_misses
        return {
            "prefix_hits": float(self.prefix_hits),
            "prefix_misses": float(self.prefix_misses),
            "prefix_entries": float(len(self._prefix)),
            "prefix_evictions": float(self.prefix_evictions),
            "prefix_invalidations": float(self.prefix_invalidations),
            "cache_hit_rate": (self.prefix_hits / lookups) if lookups else 0.0,
            "prefill_skipped": float(self.prefill_skipped),
            "prefill_programs": float(
                len(PROGRAMS) if self._fast else len(self._prefills)
            ),
            "prefill_evictions": float(
                PROGRAMS.evictions if self._fast else self.prefill_evictions
            ),
            "prefill_traces": float(self.prefill_traces),
            "decode_traces": float(self.decode_traces),
        }

    # ------------------------------------------------------------- slots
    def _slot_view(self, cache, slot):
        """Extract slot `slot` as a batchless cache pytree."""
        return jax.tree_util.tree_map_with_path(
            lambda p, leaf: jax.lax.index_in_dim(
                leaf, slot, axis=_leaf_axis(p), keepdims=False
            ),
            cache,
        )

    def _insert_slot(self, cache, cache1, slot):
        """Splice a batch-1 cache into the pool at `slot` (legacy, unjitted)."""

        def put(path, pool, new):
            idx = [0] * pool.ndim
            idx[_leaf_axis(path)] = slot
            return jax.lax.dynamic_update_slice(pool, new.astype(pool.dtype), tuple(idx))

        flat_pool, _ = jax.tree_util.tree_flatten_with_path(cache)
        flat_new = jax.tree_util.tree_leaves(cache1)
        out = [put(p, pool, new) for (p, pool), new in zip(flat_pool, flat_new)]
        return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(cache), out)

    # ----------------------------------------------- fast-path programs
    def _program(self, kind: str, *shape, counter: str | None = None):
        """Fetch/build a shared program; bump this engine's build counter."""
        key = (kind, self._sig, self.max_slots, *shape)
        fn, built = PROGRAMS.get(key, lambda: self._build(kind, *shape))
        if built and counter is not None:
            setattr(self, counter, getattr(self, counter) + 1)
        return fn

    def _build(self, kind: str, *shape):
        cfg, cache_len, max_slots = self.cfg, self.cache_len, self.max_slots
        axes = self._axes

        if kind == "prefill":  # shape = (bucket, bpad)
            def prefill(params, batch):
                return T.prefill(params, batch, cfg, cache_len=cache_len)

            return jax.jit(prefill)

        if kind == "splice":  # batch-1 cache row -> pool slot (traced index)
            def splice(pool, row, slot):
                def put(path, pool_leaf, new_leaf):
                    idx = [0] * pool_leaf.ndim
                    idx[_leaf_axis(path)] = slot
                    return jax.lax.dynamic_update_slice(
                        pool_leaf, new_leaf.astype(pool_leaf.dtype), tuple(idx)
                    )

                flat, _ = jax.tree_util.tree_flatten_with_path(pool)
                new = jax.tree_util.tree_leaves(row)
                out = [put(p, pl, nl) for (p, pl), nl in zip(flat, new)]
                return jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(pool), out
                )

            return jax.jit(splice)

        if kind == "scatter":  # shape = (bpad,): batched cache rows -> slots
            def scatter(pool, cache_b, sidx):
                # sidx [bpad]: target slot per row; pad rows carry max_slots,
                # dropped by out-of-bounds scatter (deterministic: live slot
                # indices are distinct)
                def put(path, pool_leaf, new_leaf):
                    new_leaf = new_leaf.astype(pool_leaf.dtype)
                    if _leaf_axis(path) == 1:
                        return pool_leaf.at[:, sidx].set(
                            new_leaf, mode="drop", unique_indices=False
                        )
                    return pool_leaf.at[sidx].set(
                        new_leaf, mode="drop", unique_indices=False
                    )

                flat, _ = jax.tree_util.tree_flatten_with_path(pool)
                new = jax.tree_util.tree_leaves(cache_b)
                out = [put(p, pl, nl) for (p, pl), nl in zip(flat, new)]
                return jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(pool), out
                )

            return jax.jit(scatter)

        if kind == "takerow":  # shape = (bpad,): one batch-1 row of a batch
            def takerow(cache_b, row):
                return jax.tree_util.tree_map_with_path(
                    lambda p, leaf: jax.lax.dynamic_slice_in_dim(
                        leaf, row, 1, axis=_leaf_axis(p)
                    ),
                    cache_b,
                )

            return jax.jit(takerow)

        def _expand(path, leaf):
            return jnp.expand_dims(leaf, _leaf_axis(path))

        def _squeeze(path, leaf):
            return jax.lax.index_in_dim(leaf, 0, axis=_leaf_axis(path), keepdims=False)

        def decode_one(params, tok, cache_slot, pos):
            cache_b = jax.tree_util.tree_map_with_path(_expand, cache_slot)
            logits, new_cache = T.decode_step(params, tok[None, None], cache_b, pos, cfg)
            return logits[0, 0], jax.tree_util.tree_map_with_path(_squeeze, new_cache)

        if kind == "decode":  # full-pool vmapped decode (shared legacy shape)
            return jax.jit(
                jax.vmap(decode_one, in_axes=(None, 0, axes, 0), out_axes=(0, axes))
            )

        if kind == "decodeg":  # shape = (bpad,): gather -> decode -> scatter
            def decode_gathered(params, toks, cache, pos, gidx, sidx):
                sub = jax.tree_util.tree_map_with_path(
                    lambda p, leaf: jnp.take(leaf, gidx, axis=_leaf_axis(p)),
                    cache,
                )
                logits, new_sub = jax.vmap(
                    decode_one, in_axes=(None, 0, axes, 0), out_axes=(0, axes)
                )(params, toks, sub, pos)

                def put(path, pool_leaf, new_leaf):
                    new_leaf = new_leaf.astype(pool_leaf.dtype)
                    if _leaf_axis(path) == 1:
                        return pool_leaf.at[:, sidx].set(new_leaf, mode="drop")
                    return pool_leaf.at[sidx].set(new_leaf, mode="drop")

                flat, _ = jax.tree_util.tree_flatten_with_path(cache)
                new = jax.tree_util.tree_leaves(new_sub)
                out = [put(p, pl, nl) for (p, pl), nl in zip(flat, new)]
                new_cache = jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(cache), out
                )
                return logits, new_cache

            return jax.jit(decode_gathered)

        raise ValueError(f"unknown program kind {kind!r}")

    # ----------------------------------------------------------- prefill
    def _prefill_fn(self, length: int):
        """Legacy per-engine batch-1 prefill program, LRU-bounded (many
        distinct exact lengths — recurrent/windowed archs — no longer grow
        the jit cache without bound)."""
        if length in self._prefills:
            self._prefills.move_to_end(length)
            return self._prefills[length]
        cfg = self.cfg

        def fn(params, batch):
            self.prefill_traces += 1  # trace-time side effect (retrace counter)
            return T.prefill(params, batch, cfg, cache_len=self.cache_len)

        self._prefills[length] = jax.jit(fn)
        if len(self._prefills) > self._max_prefill_programs:
            self._prefills.popitem(last=False)
            self.prefill_evictions += 1
        return self._prefills[length]

    def _bucket_for(self, req: Request) -> int:
        plen = len(req.prompt)
        if self._recurrent or self._windowed:
            # recurrent states absorb every consumed token, and wrapped ring
            # buffers attend every slot — both need exact-length prefill
            # (mamba2 additionally needs chunk-divisible lengths)
            if self.cfg.ssm_state:
                assert plen % self.cfg.ssm_chunk == 0, (
                    f"mamba2 prompts must be multiples of ssm_chunk={self.cfg.ssm_chunk}"
                )
            return plen
        return min(_round_up(plen, self.prompt_bucket), self.cache_len)

    def _post_admit(self, req: Request, slot: int, first: int, plen: int) -> None:
        # NOTE: bucket-padded positions beyond plen hold garbage K/V; decode
        # masks by position (pos = plen), so they are never attended.
        self.pos[slot] = plen
        self.last_tok[slot] = first
        req.output.append(first)
        self.tokens_generated += 1
        self.active[slot] = req

    def _admit(self, req: Request, slot: int) -> None:
        """Legacy admission: one batch-1 prefill forward per request."""
        req.admit_tick = self._steps
        req.first_wall = time.time()
        req.status = "active"
        plen = len(req.prompt)
        bucket = self._bucket_for(req)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        batch = {"tokens": jnp.asarray(toks), **{
            k: v[None] if hasattr(v, "ndim") else v for k, v in self.extra_inputs.items()
        }}
        logits, cache1 = self._prefill_fn(bucket)(self.params, batch)
        # first generated token comes from the last REAL prompt position
        first = int(jnp.argmax(logits[0, plen - 1]))
        # cache1 keeps its size-1 batch dim (already at the per-leaf batch
        # axis), so the splice below is a rank-preserving dynamic_update_slice
        self.cache = self._insert_slot(self.cache, cache1, slot)
        self._post_admit(req, slot, first, plen)

    def _admit_many(self, pairs: list) -> None:
        """Fast-path admission: prefix-cache hits splice a stored slice, the
        misses run grouped per bucket as ONE batched prefill forward each.

        Bit-identity with the legacy path: the prefill forward is
        deterministic and batch rows are independent, so per-request first
        tokens and cache rows match the batch-1 result exactly — only the
        number of dispatches (and host seconds) changes.
        """
        hits, misses = [], []
        for req, slot in pairs:
            req.admit_tick = self._steps
            req.first_wall = time.time()
            req.status = "active"
            plen = len(req.prompt)
            bucket = self._bucket_for(req)
            # bypass: exact-length archs (a cached slice is position/window
            # dependent) and extra-input models (the prompt alone does not
            # key the forward)
            cacheable = (
                self._prefix_max > 0
                and not (self._recurrent or self._windowed)
                and not self.extra_inputs
            )
            # keyed by quantization mode too: an int8 cached slice must never
            # splice into an f32 pool after a config flip (or vice versa)
            key = (
                (bucket, bool(getattr(self.cfg, "quantized_kv", False)), tuple(req.prompt))
                if cacheable
                else None
            )
            if key is not None and key in self._prefix:
                row, first = self._prefix[key]
                self._prefix.move_to_end(key)
                self.prefix_hits += 1
                self.prefill_skipped += 1
                hits.append((req, slot, row, first, plen))
            else:
                if key is not None:
                    self.prefix_misses += 1
                misses.append((req, slot, bucket, key, plen))

        splice = None
        for req, slot, row, first, plen in hits:
            if splice is None:
                splice = self._program("splice")
            self.cache = splice(self.cache, row, np.int32(slot))
            self._post_admit(req, slot, first, plen)

        groups: dict[int, list] = {}
        for item in misses:
            groups.setdefault(item[2], []).append(item)
        for bucket, group in groups.items():
            self._prefill_group(bucket, group)

    def _prefill_group(self, bucket: int, group: list) -> None:
        # batch padded to a power of two: the trace set stays log-bounded
        # in the admission burst size
        bpad = _pow2(len(group)) if self._batched_prefill else 1
        chunks = (
            [group] if self._batched_prefill
            else [[item] for item in group]
        )
        for chunk in chunks:
            toks = np.zeros((bpad, bucket), np.int32)
            last = np.zeros(bpad, np.int32)
            for r, (req, _, _, _, plen) in enumerate(chunk):
                toks[r, :plen] = req.prompt
                last[r] = plen - 1
            batch = {"tokens": jnp.asarray(toks), **{
                k: (jnp.broadcast_to(jnp.asarray(v)[None],
                                     (bpad,) + tuple(np.shape(v)))
                    if hasattr(v, "ndim") else v)
                for k, v in self.extra_inputs.items()
            }}
            prefill = self._program(
                "prefill", bucket, bpad, counter="prefill_traces"
            )
            logits, cache_b = prefill(self.params, batch)
            # first generated token per row: argmax at its last REAL position
            firsts = np.asarray(jnp.argmax(
                logits[jnp.arange(bpad), jnp.asarray(last)], axis=-1
            ))
            # one scatter splices every row into its slot; pad rows target
            # max_slots and are dropped out-of-bounds
            sidx = np.full(bpad, self.max_slots, np.int32)
            for r, (_, slot, _, _, _) in enumerate(chunk):
                sidx[r] = slot
            scatter = self._program("scatter", bpad)
            self.cache = scatter(self.cache, cache_b, jnp.asarray(sidx))
            takerow = None
            for r, (req, slot, _, key, plen) in enumerate(chunk):
                if key is not None and key not in self._prefix:
                    if takerow is None:
                        takerow = self._program("takerow", bpad)
                    self._prefix[key] = (takerow(cache_b, np.int32(r)),
                                         int(firsts[r]))
                    if len(self._prefix) > self._prefix_max:
                        self._prefix.popitem(last=False)
                        self.prefix_evictions += 1
                self._post_admit(req, slot, int(firsts[r]), plen)

    # -------------------------------------------------------------- API
    def submit(self, req: Request) -> int:
        req.rid = next(self._ids)
        if req.submit_tick < 0:  # the fleet may pre-stamp the arrival tick
            req.submit_tick = self._steps
            req.submit_wall = time.time()
        self.pending.append(req)
        return req.rid

    def _finish(self, slot: int) -> None:
        r = self.active[slot]
        r.done = True
        r.status = "done"
        r.finish_tick = self._steps
        r.finish_wall = time.time()
        del self.active[slot]
        self.pos[slot] = 0

    def _complete(self, r: Request) -> bool:
        return len(r.output) >= r.max_new_tokens or (
            r.eos_id is not None and bool(r.output) and r.output[-1] == r.eos_id
        )

    def _decode_active(self) -> None:
        """One token for every active slot.

        Fast path: when occupancy is below the pool size, gather the active
        slots (padded to a power of two — pad rows re-decode slot order[0]
        and are dropped at scatter) so low-occupancy ticks stop paying the
        full ``max_slots`` vmap.  The sampler sees one logits row per active
        slot in slot order; the default argmax sampler is row-independent,
        so sampled tokens are bit-identical to the full-pool step.
        """
        order = sorted(self.active)
        n = len(order)
        bpad = _pow2(n) if (self._active_decode and n < self.max_slots) else self.max_slots
        if bpad >= self.max_slots:
            decode = self._program("decode", counter="decode_traces")
            logits, self.cache = decode(
                self.params, jnp.asarray(self.last_tok), self.cache,
                jnp.asarray(self.pos),
            )
            rows = {slot: slot for slot in order}
        else:
            gidx = np.empty(bpad, np.int32)
            gidx[:n] = order
            gidx[n:] = order[0]
            sidx = np.full(bpad, self.max_slots, np.int32)
            sidx[:n] = order
            decode = self._program("decodeg", bpad, counter="decode_traces")
            logits, self.cache = decode(
                self.params, jnp.asarray(self.last_tok[gidx]), self.cache,
                jnp.asarray(self.pos[gidx]), jnp.asarray(gidx),
                jnp.asarray(sidx),
            )
            rows = {slot: r for r, slot in enumerate(order)}
        self._key, sub = jax.random.split(self._key)
        next_tok = np.asarray(self._sample(logits, sub))
        for slot in order:
            r = self.active[slot]
            tok = int(next_tok[rows[slot]])
            r.output.append(tok)
            self.tokens_generated += 1
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if self._complete(r):
                self._finish(slot)

    def step(self) -> None:
        """One engine tick: admit (FIFO), decode one token for all active slots.

        Admission runs to a fixpoint with completion: a request that is
        already complete after its prefill (single-token budget, or EOS
        emitted as the final prompt-prefill token) releases its slot THIS
        tick and the next pending request is admitted into it, in strict
        submit order.  Each loop iteration either admits at least one
        pending request or breaks, so the fixpoint terminates.
        """
        while True:
            for slot in list(self.active):
                if self._complete(self.active[slot]):
                    self._finish(slot)
            free = [s for s in range(self.max_slots) if s not in self.active]
            if not (self.pending and free):
                break
            if self._fast:
                pairs = []
                for slot in free:
                    if not self.pending:
                        break
                    pairs.append((self.pending.popleft(), slot))
                self._admit_many(pairs)
            else:
                for slot in free:
                    if not self.pending:
                        break
                    self._admit(self.pending.popleft(), slot)

        if self.active:
            if self._fast:
                self._decode_active()
            else:
                if self._decode is None:
                    # legacy per-engine decode program (counts retraces at
                    # trace time like the original engine)
                    def _expand(path, leaf):
                        return jnp.expand_dims(leaf, _leaf_axis(path))

                    def _squeeze(path, leaf):
                        return jax.lax.index_in_dim(
                            leaf, 0, axis=_leaf_axis(path), keepdims=False
                        )

                    cfg = self.cfg

                    def decode_one(params, tok, cache_slot, pos):
                        self.decode_traces += 1  # trace-time side effect
                        cache_b = jax.tree_util.tree_map_with_path(_expand, cache_slot)
                        logits, new_cache = T.decode_step(
                            params, tok[None, None], cache_b, pos, cfg
                        )
                        return logits[0, 0], jax.tree_util.tree_map_with_path(
                            _squeeze, new_cache
                        )

                    self._decode = jax.jit(jax.vmap(
                        decode_one, in_axes=(None, 0, self._axes, 0),
                        out_axes=(0, self._axes),
                    ))
                logits, new_cache = self._decode(
                    self.params, jnp.asarray(self.last_tok), self.cache,
                    jnp.asarray(self.pos),
                )
                self.cache = new_cache
                self._key, sub = jax.random.split(self._key)
                next_tok = np.asarray(self._sample(logits, sub))
                for slot in list(self.active):
                    r = self.active[slot]
                    tok = int(next_tok[slot])
                    r.output.append(tok)
                    self.tokens_generated += 1
                    self.pos[slot] += 1
                    self.last_tok[slot] = tok
                    if self._complete(r):
                        self._finish(slot)
        self._steps += 1

    def run(self, requests: list[Request], max_ticks: int = 10_000) -> list[Request]:
        """Submit everything and tick until done.  Returns the requests."""
        for r in requests:
            self.submit(r)
        ticks = 0
        while (self.pending or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return requests
