"""Seeded, checkpointable load generator for the serving fleet.

Synthesizes the traffic a decentralized fleet would see from real node
populations, at up to ~10^6 simulated requests:

* **Poisson arrivals** per node — i.i.d. exponential inter-arrival gaps at a
  per-node ``rate`` (requests per engine tick), so offered load is dialed in
  the same unit the engine serves in;
* **Zipf-distributed prompt and output lengths**, bounded to
  ``[prompt_min, prompt_max]`` / ``[output_min, output_max]`` (heavy-tailed
  like production traces, but with a hard cap so a single request cannot
  wedge a slot);
* **node-skewed prompt tokens**: the same Zipf unigram marginal under a
  node-specific vocabulary permutation — the serving-side mirror of
  ``repro.data.node_token_stream``'s training heterogeneity;
* **three prompt modes** (``prompt_mode``): ``"iid"`` (default, the
  historical stream bit-identically — every prompt token drawn i.i.d., so
  whole-prompt repeats are vanishingly rare), ``"pool"`` (requests draw a
  Zipf-popularity rank into a per-node pool of ``prompt_pool`` fixed
  prompts — the hot-prompt workload the serving prefix cache converts into
  throughput), and ``"unique"`` (the i.i.d. draw with the request index
  stamped into the leading tokens, so every prompt is guaranteed distinct —
  the zero-hit-rate control row of suite S).

Every draw for request ``i`` of node ``n`` comes from a *counter-based* RNG
keyed by ``(seed, n, i)`` (`np.random.SeedSequence`), so the stream is a
pure function of the config: two generators with the same config emit
bit-identical streams regardless of interleaving, and checkpointing needs
only the per-node cursor — :meth:`LoadGenerator.state` is a tiny pytree
that round-trips through ``repro.checkpoint`` (npz), giving kill/resume
bit-parity consistent with the trainer checkpoint discipline.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.engine import Request

__all__ = ["LoadGenConfig", "LoadGenerator", "bounded_zipf_probs"]


def bounded_zipf_probs(a: float, lo: int, hi: int) -> np.ndarray:
    """P(k) ∝ (k - lo + 1)^-a for k in [lo, hi] (rank 1 at the minimum)."""
    assert hi >= lo >= 0, (lo, hi)
    ranks = np.arange(1, hi - lo + 2, dtype=np.float64)
    p = ranks ** (-a)
    return p / p.sum()


@dataclasses.dataclass(frozen=True)
class LoadGenConfig:
    num_nodes: int
    rate: float | tuple[float, ...]  # requests per engine tick, per node
    vocab_size: int
    prompt_zipf: float = 1.3
    prompt_min: int = 4
    prompt_max: int = 32
    output_zipf: float = 1.3
    output_min: int = 1
    output_max: int = 8
    token_zipf: float = 1.2
    seed: int = 0
    # prompt repetition structure (see module docstring): "iid" keeps the
    # historical stream bit-identically; "pool" draws from prompt_pool
    # fixed per-node prompts with Zipf(prompt_pool_zipf) popularity;
    # "unique" makes every prompt provably distinct
    prompt_mode: str = "iid"
    prompt_pool: int = 512
    prompt_pool_zipf: float = 1.1

    def __post_init__(self):
        if self.prompt_mode not in ("iid", "pool", "unique"):
            raise ValueError(f"unknown prompt_mode {self.prompt_mode!r}")

    def rate_for(self, node: int) -> float:
        r = self.rate
        return float(r[node]) if isinstance(r, (tuple, list)) else float(r)

    def mean_prompt_len(self) -> float:
        p = bounded_zipf_probs(self.prompt_zipf, self.prompt_min, self.prompt_max)
        return float(p @ np.arange(self.prompt_min, self.prompt_max + 1))

    def mean_output_len(self) -> float:
        p = bounded_zipf_probs(self.output_zipf, self.output_min, self.output_max)
        return float(p @ np.arange(self.output_min, self.output_max + 1))

    def mean_request_tokens(self) -> float:
        """Expected decode ticks a request occupies a slot for (its output
        length; the first token rides the prefill).  ``max_slots /
        mean_request_tokens`` is the analytic per-node capacity in
        requests/tick, the offered-load unit of suite S."""
        return self.mean_output_len()


class LoadGenerator:
    """Per-node Poisson/Zipf request stream, counter-based and resumable.

    ``payload(node, rng, prompt_len, max_new_tokens)`` may be overridden to
    emit a different request object from the same seeded per-request RNG
    (the train-and-serve benchmark uses this to route classifier eval
    requests through identical arrival statistics); the default builds an
    LM :class:`~repro.serving.engine.Request`.
    """

    def __init__(self, cfg: LoadGenConfig, payload=None):
        self.cfg = cfg
        self._default_payload = payload is None
        self._payload = payload or self._lm_request
        m = cfg.num_nodes
        self._next_index = np.zeros(m, np.int64)   # request counter per node
        self._next_time = np.full(m, np.inf)       # arrival time of request _next_index
        self._prompt_cdf = np.cumsum(
            bounded_zipf_probs(cfg.prompt_zipf, cfg.prompt_min, cfg.prompt_max)
        )
        self._output_cdf = np.cumsum(
            bounded_zipf_probs(cfg.output_zipf, cfg.output_min, cfg.output_max)
        )
        self._token_cdf = np.cumsum(
            bounded_zipf_probs(cfg.token_zipf, 0, cfg.vocab_size - 1)
        )
        # prompt-pool popularity (mode="pool"): rank 0 is the hottest prompt
        self._pool_cdf = np.cumsum(
            bounded_zipf_probs(cfg.prompt_pool_zipf, 0, cfg.prompt_pool - 1)
        )
        self._pool_cache: dict[tuple[int, int], np.ndarray] = {}
        # node-specific vocab permutation (namespaced so it can never collide
        # with a per-request (seed, 3, node, i) key)
        self._perms = [
            np.random.default_rng(np.random.SeedSequence((cfg.seed, 1, n))).permutation(
                cfg.vocab_size
            )
            for n in range(m)
        ]
        for n in range(m):
            self._next_time[n] = self._gap(n, 0)
        self.emitted = 0

    # ------------------------------------------------------- per-request rng
    def _rng(self, node: int, i: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence((self.cfg.seed, 3, node, int(i))))

    def _gap(self, node: int, i: int) -> float:
        """Exponential inter-arrival gap before request i of `node`."""
        rate = self.cfg.rate_for(node)
        if rate <= 0.0:
            return np.inf
        # dedicated lane so arrival times don't depend on payload draws
        rng = np.random.default_rng(np.random.SeedSequence((self.cfg.seed, 2, node, int(i))))
        return rng.exponential(1.0 / rate)

    def _bounded_zipf(self, rng, cdf: np.ndarray, lo: int) -> int:
        return lo + int(np.searchsorted(cdf, rng.random(), side="right"))

    def _lm_request(self, node: int, rng, prompt_len: int, max_new: int) -> Request:
        u = rng.random(prompt_len)
        base = np.searchsorted(self._token_cdf, u, side="right")
        toks = self._perms[node][np.minimum(base, self.cfg.vocab_size - 1)]
        return Request(prompt=toks.astype(int).tolist(), max_new_tokens=max_new)

    def _pool_prompt(self, node: int, rank: int) -> np.ndarray:
        """Pool prompt ``rank`` of ``node``: a pure function of the config
        (its own ``(seed, 4, node, rank)`` lane), memoized for speed."""
        key = (node, int(rank))
        if key not in self._pool_cache:
            rng = np.random.default_rng(
                np.random.SeedSequence((self.cfg.seed, 4, node, int(rank)))
            )
            plen = self._bounded_zipf(rng, self._prompt_cdf, self.cfg.prompt_min)
            u = rng.random(plen)
            base = np.searchsorted(self._token_cdf, u, side="right")
            self._pool_cache[key] = self._perms[node][
                np.minimum(base, self.cfg.vocab_size - 1)
            ]
        return self._pool_cache[key]

    def request(self, node: int, i: int):
        """Materialize request ``i`` of ``node`` (pure function of config)."""
        rng = self._rng(node, i)
        if self.cfg.prompt_mode == "pool":
            rank = self._bounded_zipf(rng, self._pool_cdf, 0)
            prompt = self._pool_prompt(node, rank)
            max_new = self._bounded_zipf(rng, self._output_cdf, self.cfg.output_min)
            if self._default_payload:
                return Request(prompt=prompt.astype(int).tolist(),
                               max_new_tokens=max_new)
            return self._payload(node, rng, len(prompt), max_new)
        plen = self._bounded_zipf(rng, self._prompt_cdf, self.cfg.prompt_min)
        max_new = self._bounded_zipf(rng, self._output_cdf, self.cfg.output_min)
        req = self._payload(node, rng, plen, max_new)
        if self.cfg.prompt_mode == "unique" and self._default_payload:
            # stamp the request index into the leading tokens: prompts are
            # provably distinct for i < vocab_size^min(3, plen) per node —
            # the guaranteed-zero-hit-rate control of suite S
            v = self.cfg.vocab_size
            for p in range(min(3, plen)):
                req.prompt[p] = (i // v ** p) % v
        return req

    # ------------------------------------------------------------- streaming
    def poll(self, until_tick: float) -> list[tuple[int, object]]:
        """All (node, request) arrivals with arrival time <= ``until_tick``.

        Arrivals are merged across nodes in time order (ties broken by node
        id), so a fleet draining one shared queue still sees a well-defined
        deterministic order.
        """
        out: list[tuple[float, int, object]] = []
        for n in range(self.cfg.num_nodes):
            while self._next_time[n] <= until_tick:
                i = int(self._next_index[n])
                out.append((float(self._next_time[n]), n, self.request(n, i)))
                self._next_index[n] = i + 1
                self._next_time[n] += self._gap(n, i + 1)
                self.emitted += 1
        out.sort(key=lambda t: (t[0], t[1]))
        return [(n, req) for _, n, req in out]

    # ----------------------------------------------------------- checkpoints
    def state(self) -> dict[str, np.ndarray]:
        """Resume cursor as a flat pytree of arrays (npz-checkpointable)."""
        return {
            "next_index": self._next_index.copy(),
            "next_time": self._next_time.copy(),
            "emitted": np.asarray(self.emitted, np.int64),
        }

    def restore(self, state: dict) -> None:
        """Adopt a cursor from :meth:`state`; the continuation is
        bit-identical to the uninterrupted stream (draws are keyed by the
        request counter, and the arrival clock rides in the state)."""
        self._next_index = np.asarray(state["next_index"], np.int64).copy()
        self._next_time = np.asarray(state["next_time"], np.float64).copy()
        self.emitted = int(np.asarray(state["emitted"]))
