"""Decentralized serving fleet: one engine per node, admission control, and
train-and-serve hot reload.

The fleet closes the paper's loop at serving time: every node serves its
*local* traffic (the load generator's per-node streams mirror the training
heterogeneity) from the collaboratively trained **consensus model**, and
hot-reloads new consensus weights from the ongoing decentralized training
run through the atomic ``repro.checkpoint`` machinery — so the DRO
worst-distribution guarantee becomes a measurable serving-quality SLO per
node population.

Pieces (each usable standalone):

* :class:`AdmissionControl` — a bounded pending queue per node with a
  ``reject`` (refuse new arrivals) or ``shed_oldest`` (evict the longest
  waiting queued request) overload policy, so offered load beyond the
  latency knee degrades gracefully instead of queueing unboundedly;
* :class:`HotReloader` — polls a step-tagged checkpoint prefix and swaps in
  the newest *loadable* step.  Saves are atomic (tmp → fsync → rename), and
  the reloader walks past unreadable files exactly like
  ``checkpoint.restore_latest`` — a torn or in-flight checkpoint can never
  be served;
* :class:`ClassifierEngine` — a slot-pool engine over any vmappable
  ``apply_fn`` for single-step (classification) serving: same admission /
  queue / timing semantics as the LM ``ServeEngine``, used by the
  train-and-serve benchmark to measure per-node quality *on served
  requests*;
* :class:`FleetNode` / :class:`ServingFleet` — the per-node wrapper and the
  fleet tick loop (arrivals → admission → engine tick → telemetry →
  periodic reload + quality probe).

Engines are duck-typed: anything with ``pending`` / ``active`` /
``max_slots`` / ``params`` / ``submit(req)`` / ``step()`` (and Request-like
objects carrying the timing fields of ``repro.serving.engine.Request``)
plugs in.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import all_steps, restore, step_path
from repro.serving import metrics as M

__all__ = [
    "AdmissionControl",
    "HotReloader",
    "ClassifierEngine",
    "BatchedProbe",
    "EvalRequest",
    "FleetNode",
    "ServingFleet",
    "FleetReport",
]


# ------------------------------------------------------------------ admission
@dataclasses.dataclass
class AdmissionControl:
    """Bounded queue with an overload policy.

    ``max_queue`` bounds the engine's *pending* queue (requests already in a
    slot are not counted).  ``policy``:

    * ``"reject"`` — a full queue refuses the arrival (it is marked
      ``rejected`` and never enters the engine);
    * ``"shed_oldest"`` — the oldest queued request is evicted (marked
      ``shed``) and the arrival is admitted, bounding staleness instead of
      arrival loss.
    """

    max_queue: int = 8
    policy: str = "reject"

    def __post_init__(self):
        if self.policy not in ("reject", "shed_oldest"):
            raise ValueError(f"unknown admission policy {self.policy!r}")

    def offer(self, engine, req, *, tick: int) -> str:
        req.submit_tick = tick
        req.submit_wall = time.time()
        if len(engine.pending) >= self.max_queue:
            if self.policy == "reject":
                req.status = "rejected"
                req.finish_tick = tick
                req.finish_wall = req.submit_wall
                return "rejected"
            victim = engine.pending.popleft()
            victim.status = "shed"
            victim.finish_tick = tick
            victim.finish_wall = time.time()
        engine.submit(req)
        return "admitted"


# ----------------------------------------------------------------- hot reload
class HotReloader:
    """Poll a step-tagged checkpoint prefix; serve only complete checkpoints.

    ``poll()`` returns ``(tree, step)`` when a step newer than the last
    loaded one can be restored, else ``None``.  Unreadable files (torn
    writes from non-atomic tools, in-flight copies) are skipped with a log
    line and the newest *older* loadable step is used instead — the same
    fallback discipline as ``checkpoint.restore_latest``, so a fleet node
    can never serve a torn checkpoint (saves from ``repro.checkpoint.save``
    are atomic+durable to begin with; this guards everything else).
    """

    def __init__(self, path: str, template, *, log: Callable[[str], None] = print):
        self.path = path
        self.template = template
        self.log = log
        self.step: int | None = None  # last successfully loaded step
        self.reloads = 0
        self.skipped = 0

    def poll(self):
        for step in reversed(all_steps(self.path)):
            if self.step is not None and step <= self.step:
                break
            fname = step_path(self.path, step)
            try:
                tree = restore(fname, self.template)
            except Exception as e:  # BadZipFile / KeyError / ValueError / OSError
                self.skipped += 1
                self.log(
                    f"hot reload: {fname} is unreadable ({type(e).__name__}); "
                    f"keeping the last complete checkpoint"
                )
                continue
            self.step = step
            self.reloads += 1
            return tree, step
        return None


# --------------------------------------------------------- classifier engine
@dataclasses.dataclass
class EvalRequest:
    """A single-step (classification) serving request: features in,
    predictions out.  Carries the same lifecycle/timing fields as the LM
    ``Request`` so the metrics layer treats both uniformly."""

    features: np.ndarray
    labels: np.ndarray | None = None
    rid: int = -1
    output: list[int] = dataclasses.field(default_factory=list)  # predicted labels
    done: bool = False
    status: str = "queued"
    submit_tick: int = -1
    admit_tick: int = -1
    finish_tick: int = -1
    submit_wall: float = 0.0
    first_wall: float = 0.0
    finish_wall: float = 0.0

    @property
    def ttft_ticks(self) -> int:
        if self.admit_tick < 0 or self.submit_tick < 0:
            return -1
        return self.admit_tick - self.submit_tick


class ClassifierEngine:
    """Slot-pool serving for single-forward models (one tick per request).

    Each tick admits up to ``max_slots`` pending requests FIFO, runs ONE
    batched forward over their stacked features, and finishes them — the
    classification analog of the LM engine's continuous batching.  Shares
    the engine duck-type (``pending/active/max_slots/params/submit/step``).
    """

    def __init__(self, apply_fn, params, *, max_slots: int = 8):
        self.apply_fn = apply_fn
        self.params = params
        self.max_slots = max_slots
        self.pending: deque[EvalRequest] = deque()
        self.active: dict[int, EvalRequest] = {}
        self._steps = 0
        self._ids = 0
        self.tokens_generated = 0  # one "token" = one prediction
        self.last_busy = 0  # slots used this tick (requests retire in-tick)
        self._jit_apply = None  # padded-batch jitted forward (one trace)

    def _forward(self, x: np.ndarray) -> np.ndarray:
        """argmax predictions for a [B, d] feature batch.

        Batches up to ``max_slots`` rows run through one jitted
        fixed-shape forward (rows padded with zeros, argmax is
        row-independent so predictions are bit-identical to the eager
        variable-shape call); oversized batches fall back to the eager
        path rather than compiling per shape.
        """
        total = x.shape[0]
        if total <= self.max_slots:
            if self._jit_apply is None:
                self._jit_apply = jax.jit(self.apply_fn)
            xp = np.zeros((self.max_slots,) + x.shape[1:], x.dtype)
            xp[:total] = x
            preds = np.asarray(jnp.argmax(
                self._jit_apply(self.params, jnp.asarray(xp)), axis=-1
            ))
            return preds[:total]
        return np.asarray(jnp.argmax(
            self.apply_fn(self.params, jnp.asarray(x)), axis=-1
        ))

    def submit(self, req: EvalRequest) -> int:
        req.rid = self._ids
        self._ids += 1
        if req.submit_tick < 0:
            req.submit_tick = self._steps
            req.submit_wall = time.time()
        self.pending.append(req)
        return req.rid

    def step(self) -> None:
        batch = []
        while self.pending and len(batch) < self.max_slots:
            batch.append(self.pending.popleft())
        self.last_busy = len(batch)
        if batch:
            x = np.concatenate([np.atleast_2d(r.features) for r in batch], axis=0)
            sizes = [np.atleast_2d(r.features).shape[0] for r in batch]
            preds = self._forward(x)
            off = 0
            now = time.time()
            for r, k in zip(batch, sizes):
                r.admit_tick = self._steps
                r.first_wall = now
                r.output = preds[off:off + k].astype(int).tolist()
                off += k
                r.status = "done"
                r.done = True
                r.finish_tick = self._steps
                r.finish_wall = now
                self.tokens_generated += k
        self._steps += 1


# -------------------------------------------------------------- batched probe
class BatchedProbe:
    """Shared quality probe: ONE vmapped/jitted forward over the concatenated
    eval set per checkpoint, memoized per step — instead of one eager
    forward per node per reload.

    Nodes of the same population share the result verbatim: hand each node
    ``probe.quality_fn(name)`` as its FleetNode ``quality_fn``.  The closure
    advertises ``accepts_step`` so FleetNode passes the checkpoint step,
    which keys the memo (per-node HotReloaders restore separate-but-equal
    trees, so object identity cannot).  ``probe_forwards`` counts actual
    device forwards — the batching claim's testable surface.
    """

    def __init__(self, apply_fn, populations: dict, *, loss_fn=None,
                 memo_size: int = 8):
        # populations: name -> (x, y) eval arrays
        self.names = sorted(populations)
        self._pop = {
            n: (jnp.asarray(populations[n][0]), np.asarray(populations[n][1]))
            for n in self.names
        }
        self._x = jnp.concatenate([self._pop[n][0] for n in self.names], axis=0)
        self._sizes = [int(self._pop[n][0].shape[0]) for n in self.names]
        self._jit_apply = jax.jit(apply_fn)
        self._loss = jax.jit(loss_fn) if loss_fn is not None else None
        self._memo: OrderedDict = OrderedDict()
        self._memo_size = memo_size
        self.probe_forwards = 0

    def _evaluate(self, params) -> dict:
        logits = self._jit_apply(params, self._x)
        preds = np.asarray(jnp.argmax(logits, axis=-1))
        self.probe_forwards += 1
        out, off = {}, 0
        for name, size in zip(self.names, self._sizes):
            x, y = self._pop[name]
            pred = preds[off:off + size]
            off += size
            q = {"acc": float((pred == y).mean())}
            if self._loss is not None:
                q["loss"] = float(self._loss(params, (x, jnp.asarray(y)), None))
            out[name] = q
        return out

    def probe(self, params, step=None) -> dict:
        """All populations' quality dicts for one checkpoint (memoized)."""
        key = step if step is not None else ("obj", id(params))
        if key not in self._memo:
            self._memo[key] = self._evaluate(params)
            while len(self._memo) > self._memo_size:
                self._memo.popitem(last=False)
        else:
            self._memo.move_to_end(key)
        return self._memo[key]

    def quality_fn(self, name: str):
        def quality(params, step=None):
            return dict(self.probe(params, step=step)[name])

        quality.accepts_step = True
        return quality


# ----------------------------------------------------------------- the fleet
class FleetNode:
    """One node: engine + admission + (optional) hot reload + quality probe.

    ``quality_fn(params) -> dict`` is evaluated against the node's *local*
    distribution on every successful reload (and once at start), building
    the per-node serving-quality timeline the train-and-serve benchmark
    gates on (a :class:`BatchedProbe` closure additionally receives the
    checkpoint step so equal-step probes are shared across nodes).

    ``retain="all"`` (default) keeps every Request object in
    ``self.requests``; ``retain="stats"`` streams terminal requests into a
    compact :class:`~repro.serving.metrics.RequestStats` accumulator each
    tick — identical summaries (exact pooled percentiles), bounded memory,
    the mode the 10^6-request suite-S scale run uses.
    """

    def __init__(self, node_id: int, engine, *, admission: AdmissionControl | None = None,
                 reloader: HotReloader | None = None, quality_fn=None,
                 retain: str = "all"):
        if retain not in ("all", "stats"):
            raise ValueError(f"unknown retain mode {retain!r}")
        self.node_id = node_id
        self.engine = engine
        self.admission = admission or AdmissionControl(max_queue=8)
        self.reloader = reloader
        self.quality_fn = quality_fn
        self.retain = retain
        self.requests: list = []  # all offered (retain="all") or in-flight
        self.stats = M.RequestStats() if retain == "stats" else None
        self.queue_samples: list[int] = []
        self.occupancy_samples: list[int] = []
        self.quality_timeline: list[tuple[int | None, dict]] = []
        if quality_fn is not None:
            self.quality_timeline.append((None, self._probe(engine.params, None)))

    def _probe(self, params, step):
        if getattr(self.quality_fn, "accepts_step", False):
            return self.quality_fn(params, step=step)
        return self.quality_fn(params)

    def offer(self, req, *, tick: int) -> str:
        self.requests.append(req)
        return self.admission.offer(self.engine, req, tick=tick)

    def _harvest(self) -> None:
        """retain="stats": fold terminal requests into the accumulator and
        drop them; ``self.requests`` stays the bounded in-flight set."""
        if self.stats is None:
            return
        keep = []
        for r in self.requests:
            if r.status in ("done", "rejected", "shed"):
                self.stats.add(r)
            else:
                keep.append(r)
        self.requests = keep

    def tick(self) -> None:
        self.engine.step()
        self.queue_samples.append(len(self.engine.pending))
        # single-step engines retire requests within the tick — their busy
        # count for the tick is last_busy, not the (empty) active pool
        self.occupancy_samples.append(
            getattr(self.engine, "last_busy", 0) or len(self.engine.active)
        )
        self._harvest()

    def maybe_reload(self) -> int | None:
        """Poll for newer consensus weights; swap + probe quality if found.

        The swap happens between engine ticks (the jitted step functions
        close over nothing — params are arguments), so a reload is atomic
        from the traffic's point of view.
        """
        if self.reloader is None:
            return None
        got = self.reloader.poll()
        if got is None:
            return None
        params, step = got
        self.engine.params = params
        if self.quality_fn is not None:
            self.quality_timeline.append((step, self._probe(params, step)))
        return step

    @property
    def drained(self) -> bool:
        return not (self.engine.pending or self.engine.active)

    def request_stats(self) -> M.RequestStats:
        """This node's requests as a RequestStats accumulator (both retain
        modes; in-flight requests count toward ``requests`` only, exactly
        like non-terminal objects in the list-based path)."""
        self._harvest()
        parts = [self.stats] if self.stats is not None else []
        s = M.RequestStats.merged(parts)
        for r in self.requests:
            s.add(r)
        return s

    def summary(self, wall_seconds: float) -> dict:
        return M.summarize_node(
            self.request_stats() if self.stats is not None else self.requests,
            queue_samples=self.queue_samples,
            occupancy_samples=self.occupancy_samples,
            max_slots=self.engine.max_slots,
            wall_seconds=wall_seconds,
            tokens_generated=self.engine.tokens_generated,
            engine_stats=(self.engine.stats() if hasattr(self.engine, "stats")
                          else None),
        )


@dataclasses.dataclass
class FleetReport:
    ticks: int
    wall_seconds: float
    offered: int
    node_summaries: list[dict]
    fleet: dict
    quality: list[list[tuple[int | None, dict]]]  # per node: (ckpt step, metrics)


class ServingFleet:
    """Tick-synchronous fleet driver.

    Each global tick: (1) pull arrivals from the load generator up to the
    current tick and route them through each target node's admission
    control, (2) tick every engine (one decode step), (3) every
    ``reload_every`` ticks poll the hot reloaders.  Runs until
    ``max_requests`` have been offered AND all queues drained, or
    ``max_ticks`` elapses.
    """

    def __init__(self, nodes: list[FleetNode], loadgen=None, *, reload_every: int = 0,
                 progress_every: int = 0, log: Callable[[str], None] = print):
        self.nodes = nodes
        self.loadgen = loadgen
        self.reload_every = reload_every
        self.progress_every = progress_every
        self.log = log
        self.ticks = 0
        self.offered = 0

    def run(self, *, max_requests: int | None = None, max_ticks: int = 1_000_000,
            drain: bool = True) -> FleetReport:
        t0 = time.time()
        start = self.ticks
        while self.ticks - start < max_ticks:
            feeding = self.loadgen is not None and (
                max_requests is None or self.offered < max_requests
            )
            if feeding:
                for node_id, req in self.loadgen.poll(self.ticks):
                    self.nodes[node_id].offer(req, tick=self.ticks)
                    self.offered += 1
            if self.reload_every and self.ticks % self.reload_every == 0:
                for node in self.nodes:
                    node.maybe_reload()
            for node in self.nodes:
                node.tick()
            self.ticks += 1
            if self.progress_every and self.ticks % self.progress_every == 0:
                self.log(
                    f"fleet: tick {self.ticks}, offered {self.offered}"
                    f"{'' if max_requests is None else f'/{max_requests}'}, "
                    f"{time.time() - t0:.1f}s elapsed"
                )
            if not feeding and (not drain or all(n.drained for n in self.nodes)):
                break
        return self.report(time.time() - t0)

    def report(self, wall_seconds: float) -> FleetReport:
        summaries = [n.summary(wall_seconds) for n in self.nodes]
        # pooled-percentile roll-up via RequestStats: identical to pooling
        # the raw request lists, and the only representation retain="stats"
        # nodes still have
        pooled = M.RequestStats.merged([n.request_stats() for n in self.nodes])
        return FleetReport(
            ticks=self.ticks,
            wall_seconds=wall_seconds,
            offered=self.offered,
            node_summaries=summaries,
            fleet=M.summarize_fleet(summaries, pooled),
            quality=[n.quality_timeline for n in self.nodes],
        )
