"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066].

Layer 0 is a dense FFN (the DeepSeekMoE "first dense layer"); layers 1..27
use 64 fine-grained routed experts (d_ff=1408 each) with top-6 routing plus
2 always-on shared experts.  Expert dim shards over `model` (expert
parallelism: 4 experts per shard on the 16-way axis).

long_500k: sliding-window decode variant (window 8192).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,       # fine-grained expert hidden size (also layer-0 dense FFN x 8)
    vocab_size=102400,
    layer_pattern=("attn",),
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    long_context_window=8192,
    source="DeepSeekMoE-16B: 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066]",
)
