"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

The ViT/SigLIP vision encoder + MLP projector are STUBBED per spec:
``input_specs`` supplies 256 precomputed patch embeddings [B, 256, d_model]
that are early-fused (spliced over the first 256 token positions).  We
implement the InternLM2-style GQA language decoder that consumes them.

long_500k: SKIPPED — full-attention VLM backbone (see DESIGN §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    layer_pattern=("attn",),
    mlp_type="swiglu",
    norm_type="rmsnorm",
    num_patches=256,
    source="InternVL2-2B: InternViT-300M + InternLM2-1.8B [arXiv:2404.16821]",
)
