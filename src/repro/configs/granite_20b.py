"""granite-20b [dense] — llama-arch code model, MQA [arXiv:2405.04324].

MQA (kv=1): in decode the single KV head cannot shard over heads, so the
cache *sequence* dimension shards over `model` (flash-decoding layout) —
this is what makes 32k x 128-batch decode fit (see launch/sharding.py).

long_500k: sliding-window decode variant (window 8192).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    layer_pattern=("attn",),
    mlp_type="gelu",  # d_ff = 4*d GELU MLP — matches the 20B parameter count
    long_context_window=8192,
    source="Granite-20B code: llama-arch, MQA [arXiv:2405.04324]",
)
