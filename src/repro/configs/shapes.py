"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

SHAPES maps shape-id -> (seq_len, global_batch, step_kind).  ``input_specs``
returns the exact abstract inputs each arch's step function consumes — no
device allocation, weak-type-correct, shardable.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def supports_shape(cfg, shape: InputShape) -> bool:
    """long_500k requires sub-quadratic decode (see DESIGN §Arch-applicability)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


def batch_specs(cfg, batch: int, seq: int, num_nodes: int | None = None):
    """Abstract train/prefill batch. With num_nodes, adds a leading node axis."""
    lead = (num_nodes, batch // num_nodes) if num_nodes else (batch,)
    spec = {"tokens": jax.ShapeDtypeStruct(lead + (seq,), jnp.int32)}
    if cfg.is_encdec:
        spec["frames"] = jax.ShapeDtypeStruct(
            lead + (cfg.encoder_context, cfg.d_model), jnp.bfloat16
        )
    if cfg.num_patches > 0:
        spec["patches"] = jax.ShapeDtypeStruct(
            lead + (cfg.num_patches, cfg.d_model), jnp.bfloat16
        )
    return spec


def decode_specs(cfg, batch: int):
    """Abstract decode-step inputs: one new token per sequence."""
    return {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg, shape_name: str, num_nodes: int | None = None):
    shape = SHAPES[shape_name]
    if shape.step == "train":
        return batch_specs(cfg, shape.global_batch, shape.seq_len, num_nodes)
    if shape.step == "prefill":
        return batch_specs(cfg, shape.global_batch, shape.seq_len)
    return decode_specs(cfg, shape.global_batch)
