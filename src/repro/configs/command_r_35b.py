"""command-r-35b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

Largest dense assigned arch — the AD-GDA state (theta + CHOCO public copies)
makes it the memory-roofline stress case; see EXPERIMENTS §Perf.

long_500k: sliding-window decode variant (window 8192).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    use_bias=False,
    layer_pattern=("attn",),
    long_context_window=8192,
    source="Command-R 35B: GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]",
)
