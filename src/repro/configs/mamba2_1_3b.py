"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

Attention-free: 48 identical Mamba2 blocks (d_ff=0 -> no interleaved MLP,
as in the Mamba family).  Decode state is O(1) per token (SSM state 128 +
conv tail), so long_500k runs natively.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=16,   # nominal; unused by the SSD mixer
    num_kv_heads=16,
    d_ff=0,         # attn-free Mamba stack: no MLP
    vocab_size=50280,
    layer_pattern=("mamba2",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    source="Mamba2-1.3B SSD [arXiv:2405.21060]",
)
