"""qwen3-1.7b [dense] — qk_norm + GQA [hf:Qwen/Qwen3-8B family].

long_500k: runs via the sliding-window decode variant (window 8192) —
sub-quadratic ring-buffer cache; noted in DESIGN §Arch-applicability.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    layer_pattern=("attn",),
    long_context_window=8192,
    source="Qwen3-1.7B: qk_norm, GQA [hf:Qwen/Qwen3-8B]",
)
