"""whisper-small [audio] — encoder-decoder, conv frontend STUB [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is stubbed per spec:
``input_specs`` supplies 1500 precomputed frame embeddings [B, 1500, 768].
We implement the 12L encoder (non-causal self-attn) + 12L decoder
(causal self-attn + cross-attn), GELU MLPs, LayerNorm, biases — the
Whisper transformer backbone.

long_500k: SKIPPED — full-attention enc-dec (DESIGN §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    use_bias=True,
    layer_pattern=("attn",),
    mlp_type="gelu",
    norm_type="layernorm",
    encoder_layers=12,
    cross_attention=True,
    encoder_context=1500,
    source="Whisper-small enc-dec backbone [arXiv:2212.04356]",
)
