"""Assigned-architecture registry.

Each module defines ``CONFIG`` (exact assigned spec, citation in ``source``).
``get_config(name)`` fetches by id; ``list_archs()`` enumerates; ``SHAPES``
defines the four assigned input shapes and ``input_specs`` builds
ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, input_specs  # noqa: F401

ARCHS = (
    "internvl2_2b",
    "mamba2_1_3b",
    "qwen3_1_7b",
    "deepseek_moe_16b",
    "whisper_small",
    "llama4_scout_17b_a16e",
    "command_r_35b",
    "recurrentgemma_2b",
    "qwen3_4b",
    "granite_20b",
)

_ALIASES = {name.replace("_", "-"): name for name in ARCHS}
_ALIASES.update({
    "internvl2-2b": "internvl2_2b",
    "mamba2-1.3b": "mamba2_1_3b",
    "qwen3-1.7b": "qwen3_1_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-small": "whisper_small",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "command-r-35b": "command_r_35b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen3-4b": "qwen3_4b",
    "granite-20b": "granite_20b",
})


def get_config(name: str):
    key = _ALIASES.get(name, name)
    if key not in ARCHS:
        raise ValueError(f"unknown arch {name!r}; choose from {sorted(_ALIASES)}")
    return importlib.import_module(f"repro.configs.{key}").CONFIG


def list_archs() -> tuple[str, ...]:
    return tuple(n.replace("_", "-") for n in ARCHS)
