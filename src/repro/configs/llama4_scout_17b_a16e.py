"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

Every layer is MoE: one routed expert per token (top-1 of 16) plus one
always-on shared expert — pure expert parallelism (1 expert per shard on the
16-way `model` axis).  The early-fusion vision path is not exercised by the
assigned input shapes (text-only tokens); the text backbone is complete.

long_500k: sliding-window decode variant (window 8192).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=("attn",),
    num_experts=16,
    num_shared_experts=1,
    experts_per_token=1,
    moe_d_ff=8192,
    long_context_window=8192,
    source="Llama-4-Scout-17B-16E: MoE top-1, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E]",
)
