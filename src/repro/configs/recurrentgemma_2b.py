"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 [arXiv:2402.19427].

Griffin pattern: (rglru, rglru, local_attn) cycled over 26 layers —
8 full blocks + 2 remainder recurrent layers.  Local attention window 2048.
Decode state is O(window + d) per layer, so long_500k runs natively.
MQA (kv=1): decode KV cache is tiny; replicated in train.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=("rglru", "rglru", "local_attn"),
    sliding_window=2048,
    rglru_width=2560,
    mlp_type="gelu",
    source="RecurrentGemma-2B: RG-LRU + local attn 1:2 [arXiv:2402.19427]",
)
