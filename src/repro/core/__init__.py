"""Paper core: AD-GDA distributionally robust decentralized learning."""
from repro.core.adgda import ADGDA, ADGDAConfig, ADGDAState
from repro.core.baselines import DRDSGD, DRDSGDConfig, DRFA, DRFAConfig, choco_sgd
from repro.core.compression import (
    BlockTopK,
    Compressor,
    Identity,
    RandomQuantization,
    TopK,
    make_compressor,
)
from repro.core.dro import (
    chi2_regularizer,
    kl_closed_form_weights,
    kl_regularizer,
    make_regularizer,
    project_simplex,
)
from repro.core.gossip import CHOCOState, choco_init, choco_round, mix_stacked, payload_bits
from repro.core.topology import Topology, make_topology, spectral_gap

__all__ = [
    "ADGDA",
    "ADGDAConfig",
    "ADGDAState",
    "DRDSGD",
    "DRDSGDConfig",
    "DRFA",
    "DRFAConfig",
    "choco_sgd",
    "BlockTopK",
    "Compressor",
    "Identity",
    "RandomQuantization",
    "TopK",
    "make_compressor",
    "chi2_regularizer",
    "kl_closed_form_weights",
    "kl_regularizer",
    "make_regularizer",
    "project_simplex",
    "CHOCOState",
    "choco_init",
    "choco_round",
    "mix_stacked",
    "payload_bits",
    "Topology",
    "make_topology",
    "spectral_gap",
]
