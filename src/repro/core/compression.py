"""Compression operators Q for compressed consensus (paper Assumption 3.2).

Every operator satisfies the delta-contraction property

    E_Q ||Q(x) - x||^2 <= (1 - delta) ||x||^2,     delta in (0, 1]

which is what CHOCO-GOSSIP requires.  Implemented operators:

* ``RandomQuantization`` — unbiased-direction b-bit stochastic quantization
  (QSGD-style, paper eq. (2)); delta = 1/tau, tau = 1 + min(d/2^{2b}, sqrt(d)/2^b).
* ``TopK`` — biased top-K magnitude sparsification; delta = K/d.
* ``BlockTopK`` — TPU-native blockwise top-k (top k_b per VMEM block);
  satisfies the same delta = K/d contraction (per-block argument) while
  avoiding a global sort.  This is the form our Pallas kernel implements.
* ``Identity`` — no compression; delta = 1.

Each operator also reports ``bits_per_element`` so experiment harnesses can
account transmitted bits exactly (paper §5.2.2 plots accuracy vs. bits of the
busiest node).

Operators operate on flat vectors; ``compress_pytree`` maps them over a pytree
leaf-wise (each leaf flattened), which mirrors per-tensor compression used in
practice.  The payload returned by ``encode`` is what actually travels over
the wire (packed ints + scales for quantization; values+indices for top-k);
``decode`` reconstructs the dense vector.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "Compressor",
    "Identity",
    "RandomQuantization",
    "TopK",
    "BlockTopK",
    "make_compressor",
    "compress_pytree",
]


class Compressor:
    """Base class: Q(x) = decode(encode(x))."""

    delta: float  # contraction factor in (0, 1]

    def __call__(self, x: jax.Array, key: jax.Array | None = None) -> jax.Array:
        return self.decode(self.encode(x, key), x.shape, x.dtype)

    def encode(self, x: jax.Array, key: jax.Array | None = None) -> Any:
        raise NotImplementedError

    def decode(self, payload: Any, shape, dtype) -> jax.Array:
        raise NotImplementedError

    def bits_per_element(self, d: int) -> float:
        """Transmitted bits per original vector element."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    delta: float = 1.0

    def encode(self, x, key=None):
        return x

    def decode(self, payload, shape, dtype):
        return payload.reshape(shape).astype(dtype)

    def bits_per_element(self, d):
        return 32.0


@dataclasses.dataclass(frozen=True)
class RandomQuantization(Compressor):
    """b-bit random quantization (paper eq. (2), Alistarh et al. 2017).

    x_b = sign(x) * ||x|| / (2^b * tau) * floor(2^b |x| / ||x|| + xi),
    xi ~ U[0,1]^d;  tau = 1 + min(d / 2^{2b}, sqrt(d) / 2^b);  delta = 1/tau.

    The wire format packs the quantization levels into uint8 (1 or 2 levels
    per byte for b<=8) plus one f32 norm per tensor, i.e. ~b+1 bits/element.
    """

    bits: int = 8

    @property
    def delta(self):  # depends on d; report the conservative d->inf value
        return 0.0  # use delta_for(d)

    def delta_for(self, d: int) -> float:
        return 1.0 / self._tau(d)

    def _tau(self, d: int) -> float:
        lvl = float(2**self.bits)
        return 1.0 + min(d / lvl**2, (d**0.5) / lvl)

    def encode(self, x, key=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        # element-wise on the ORIGINAL shape — a reshape(-1) here would break
        # GSPMD sharding propagation and replicate the tensor (and its RNG
        # bits) on every device; see EXPERIMENTS §Perf (llama4 train).
        xf = x.astype(jnp.float32)
        norm = jnp.sqrt(jnp.sum(xf * xf))
        lvl = float(2**self.bits)
        xi = jax.random.uniform(key, xf.shape)
        # levels in [0, 2^b]; signs in {-1, 0, +1}
        q = jnp.floor(lvl * jnp.abs(xf) / jnp.where(norm > 0, norm, 1.0) + xi)
        q = jnp.clip(q, 0, lvl)  # one extra level possible from +xi
        levels = q.astype(jnp.uint8 if self.bits <= 7 else jnp.uint16)
        signs = jnp.signbit(xf)
        return {"levels": levels, "signs": signs, "norm": norm}

    def decode(self, payload, shape, dtype):
        import numpy as _np

        lvl = float(2**self.bits)
        tau = self._tau(int(_np.prod(shape)) if shape else 1)
        mag = payload["norm"] / (lvl * tau) * payload["levels"].astype(jnp.float32)
        out = jnp.where(payload["signs"], -mag, mag)
        return out.reshape(shape).astype(dtype)

    def bits_per_element(self, d):
        # b bits of level + 1 sign bit + amortized 32-bit norm
        return self.bits + 1 + 32.0 / max(d, 1)


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Global top-K magnitude sparsification (Stich et al. 2018); delta = K/d."""

    fraction: float = 0.25

    @property
    def delta(self):
        return self.fraction

    def k_for(self, d: int) -> int:
        return max(1, int(round(self.fraction * d)))

    def encode(self, x, key=None):
        flat = x.reshape(-1).astype(jnp.float32)
        k = self.k_for(flat.shape[0])
        values, idx = jax.lax.top_k(jnp.abs(flat), k)
        return {"values": flat[idx], "indices": idx}

    def decode(self, payload, shape, dtype):
        import numpy as _np

        d = int(_np.prod(shape)) if shape else 1
        out = jnp.zeros((d,), jnp.float32)
        out = out.at[payload["indices"]].set(payload["values"])
        return out.reshape(shape).astype(dtype)

    def bits_per_element(self, d):
        # (32-bit value + 32-bit index) per *actually kept* element: encode
        # transmits k_for(d) pairs, which rounding (and the k >= 1 floor)
        # makes different from fraction*d at small d
        return 64.0 * self.k_for(d) / max(d, 1)


@dataclasses.dataclass(frozen=True)
class BlockTopK(Compressor):
    """Blockwise top-k: keep the top ceil(fraction*B) magnitudes per block.

    TPU adaptation of TopK: selection is local to a VMEM-sized block, so no
    global sort/gather is needed and indices cost log2(B) (<= 16) bits.  The
    per-block tail bound gives the same contraction delta = K/d.
    """

    fraction: float = 0.25
    block: int = 1024

    @property
    def delta(self):
        return self.fraction

    def k_per_block(self) -> int:
        return max(1, int(round(self.fraction * self.block)))

    def encode(self, x, key=None):
        flat = x.reshape(-1).astype(jnp.float32)
        d = flat.shape[0]
        pad = (-d) % self.block
        flat_p = jnp.pad(flat, (0, pad))
        blocks = flat_p.reshape(-1, self.block)
        k = self.k_per_block()
        values, idx = jax.lax.top_k(jnp.abs(blocks), k)
        vals = jnp.take_along_axis(blocks, idx, axis=1)
        del d
        return {"values": vals, "indices": idx.astype(jnp.int32)}

    def decode(self, payload, shape, dtype):
        import numpy as _np

        d = int(_np.prod(shape)) if shape else 1
        nb, k = payload["values"].shape
        blocks = jnp.zeros((nb, self.block), jnp.float32)
        blocks = jax.vmap(lambda b, i, v: b.at[i].set(v))(
            blocks, payload["indices"], payload["values"]
        )
        return blocks.reshape(-1)[:d].reshape(shape).astype(dtype)

    def bits_per_element(self, d):
        import math

        return (32.0 + math.log2(self.block)) * self.fraction


def make_compressor(spec: str) -> Compressor:
    """Parse 'none' | 'qXb' (e.g. q4b) | 'kqXb' (Pallas kernel-backed, packed
    wire format, supports the fused gossip round) | 'topK%' (e.g. top10) |
    'btopK%'."""
    spec = spec.lower().strip()
    if spec in ("none", "identity"):
        return Identity()
    if spec.startswith("kq") and spec.endswith("b"):
        # lazy import: kernels.ops imports this module for the Compressor base
        from repro.kernels.ops import KernelQuantization

        bits = int(spec[2:-1])
        if bits not in (1, 2, 4, 8):
            raise ValueError(
                f"kernel quantization needs bits in (1, 2, 4, 8) so levels "
                f"pack into bytes; got {spec!r}"
            )
        return KernelQuantization(bits=bits)
    if spec.startswith("q") and spec.endswith("b"):
        return RandomQuantization(bits=int(spec[1:-1]))
    if spec.startswith("btop"):
        return BlockTopK(fraction=float(spec[4:]) / 100.0)
    if spec.startswith("top"):
        return TopK(fraction=float(spec[3:]) / 100.0)
    raise ValueError(f"unknown compressor spec {spec!r}")


def compress_pytree(compressor: Compressor, tree, key: jax.Array):
    """Apply Q leaf-wise: returns Q(tree) (dense representation)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [compressor(leaf, k) for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)
