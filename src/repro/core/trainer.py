"""Composable decentralized-DRO trainer (paper Algorithms 1-2 as one loop).

AD-GDA, CHOCO-SGD, DR-DSGD (Issaid et al. 2022) and DRFA (Deng et al. 2021)
are all the same round — local update, dual update, communication — differing
only in which instance fills each slot.  This module factors the training
layer into three small protocols and one driver:

* :class:`LocalUpdate` — the stochastic oracle (single-step, microbatched
  gradient accumulation, or K local steps between communication rounds) with
  parameter updates routed through :class:`repro.optim.Optimizer` and a
  :data:`repro.optim.Schedule` (SGD/momentum/Nesterov/Adam, const/exp/cosine
  + warmup — no hand-rolled SGD in the algorithms anymore);
* :class:`DualUpdate` — how the mixture weights lambda evolve: projected
  ascent with gossip (AD-GDA), the KL closed form (DR-DSGD), frozen at the
  prior (CHOCO-SGD), or sampled ascent on observed losses (DRFA);
* :class:`Consensus` — how models travel the wire: the CHOCO compressed
  round (with the ``packed``/``fused`` Pallas dispatch), exact mixing, or
  federated server averaging.

:class:`DecentralizedTrainer` composes the three and owns the round
skeleton: RNG bookkeeping, the running average of the network mean
(theta_o, Thm 4.1), aux metrics and bits accounting.  The paper's named
algorithms are one-line factories over it — see ``repro.core.adgda`` and
``repro.core.baselines`` — and new combinations (Adam-based AD-GDA, local
steps with momentum, robust federated averaging over a ring, ...) are
compositions, not new classes.

All decentralized state is *stacked*: every pytree leaf carries a leading
node axis of size m, which the production mesh shards over ``data`` (x
``pod``) so the vmapped oracle is plain data parallelism.  How the
consensus maps to collectives is the exchange *backend*'s choice:
``backend="rolled"`` (default) simulates the network on the stacked array
and leaves the lowering to GSPMD, ``backend="ppermute"`` executes it
mesh-native — shard_map + ``lax.ppermute`` moving exactly degree-many
compressed messages between graph neighbors (``repro.core.exchange``).
Federated consensus (:class:`FedAvg`) instead keeps a single server model
in the state and broadcasts it to the node axis at the start of each round.

Numerics are pinned to the pre-refactor monolithic trainers bit-for-bit on
the single-step and microbatched paths (tests/test_trainer_parity.py); the
local-steps path applies the dual weighting before the learning rate (the
seed multiplied in the opposite order) and is pinned to ~ULP instead.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dro
from repro.core.compression import Compressor, Identity
from repro.core import wire
from repro.core.faults import WireBits, parse_fault_spec
from repro.core.gossip import (
    BLOCK_SCAN_ELEMS,
    CHOCOState,
    LaneRound,
    _scan_plan,
    choco_init,
    choco_round,
    choco_round_lanes,
    mix_stacked,
    mix_stacked_with,
    payload_bits,
    payload_total_bits,
)
from repro.core.topology import (
    Topology,
    TopologySchedule,
    compile_permute_plan,
    compile_schedule_plans,
)
from repro.optim import Optimizer, OptState, Schedule

__all__ = [
    "LossFn",
    "TrainerState",
    "LocalUpdate",
    "DualUpdate",
    "ProjectedAscent",
    "FrozenPrior",
    "KLClosedForm",
    "SampledAscent",
    "Consensus",
    "ChocoConsensus",
    "GTState",
    "GradientTrackingConsensus",
    "ExactConsensus",
    "FedAvg",
    "DecentralizedTrainer",
]

LossFn = Callable[[Any, Any, jax.Array], jax.Array]


class TrainerState(NamedTuple):
    step: jax.Array  # round counter
    theta: Any  # stacked pytree [m, ...] (federated: server pytree, no node axis)
    lam: jax.Array  # dual variable: [m, m] decentralized copies or [m] server-side
    opt: OptState  # optimizer moments + its own step counter
    consensus: Any  # CHOCOState or () — whatever Consensus.init returned
    theta_avg: Any  # running mean over time of the network mean (theta_o)
    rng: jax.Array


def _apply_updates(params, updates):
    """p <- p + u in f32, cast back to the parameter dtype."""
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
    )


def _scale_grads(grads, scale: jax.Array, m: int):
    """Per-node dual weighting: g_i <- lam-weight_i * g_i (in f32)."""
    return jax.tree.map(
        lambda g: g.astype(jnp.float32) * scale.reshape((m,) + (1,) * (g.ndim - 1)),
        grads,
    )


def _select_nodes(mask: jax.Array, new_tree, old_tree, m: int):
    """Per-node select: keep ``new`` where mask==1, revert to ``old`` where a
    node sat the round out.  Applied leaf-wise to stacked trees; leaves
    without a leading node axis (e.g. the optimizer's scalar step counter,
    which is per-*round*, not per-node) keep the new value."""
    alive = mask > 0
    def sel(new, old):
        if getattr(new, "ndim", 0) >= 1 and new.shape[0] == m:
            return jnp.where(alive.reshape((m,) + (1,) * (new.ndim - 1)), new, old)
        return new
    return jax.tree.map(sel, new_tree, old_tree)


# ============================================================== local update
@dataclasses.dataclass(frozen=True)
class LocalUpdate:
    """Stochastic oracle + optimizer step on the stacked model.

    One of three shapes, all sharing the dual weighting and the optimizer:

    * ``microbatches == local_steps == 1`` — one vmapped value-and-grad and
      one optimizer update per round;
    * ``microbatches = k > 1`` — gradient accumulation: scan the oracle over
      k microbatches so only one microbatch's activations are live at a
      time, then one optimizer update (same stochastic gradient);
    * ``local_steps = K > 1`` — K full optimizer updates between
      communication rounds (paper §6's event-triggered extension).  The
      optimizer state (momentum, Adam moments) carries across the inner
      steps AND across rounds; the schedule and Adam bias correction are
      evaluated once per *round* (the optimizer's step counter advances by
      one per round regardless of K), matching the seed trainers' per-round
      learning-rate decay.

    ``batch_layout`` fixes how K local batches arrive: ``"flat"`` packs them
    along the per-node batch axis (leaves ``[m, K*b, ...]``, AD-GDA style),
    ``"stacked"`` gives them a dedicated axis (leaves ``[m, K, ...]``, DRFA
    style).
    """

    optimizer: Optimizer
    schedule: Schedule
    microbatches: int = 1
    local_steps: int = 1
    grad_accum_dtype: str = "float32"
    spmd_axis_name: Any = None  # mesh axes the node vmap maps to
    batch_layout: str = "flat"

    def __post_init__(self):
        if self.local_steps > 1 and self.microbatches > 1:
            raise ValueError("local_steps and microbatches do not compose")
        if self.batch_layout not in ("flat", "stacked"):
            raise ValueError(f"unknown batch_layout {self.batch_layout!r}")

    def init(self, theta_stacked) -> OptState:
        return self.optimizer.init(theta_stacked)

    def lr(self, opt_state: OptState) -> jax.Array:
        return self.schedule(opt_state.step)

    def _oracle(self, loss_fn, theta, batch, node_keys):
        return jax.vmap(
            jax.value_and_grad(loss_fn), spmd_axis_name=self.spmd_axis_name
        )(theta, batch, node_keys)

    def step(self, loss_fn: LossFn, theta, opt_state: OptState, batch, node_keys,
             weights_fn: Callable[[jax.Array], jax.Array]):
        """Run the oracle + optimizer; returns (theta_half, opt_state, losses).

        ``weights_fn(losses) -> [m]`` supplies the dual gradient weighting
        (called after every loss evaluation, so closed-form duals see the
        freshest losses).
        """
        m = node_keys.shape[0]

        if self.local_steps > 1:
            return self._local_steps(loss_fn, theta, opt_state, batch, node_keys,
                                     weights_fn, m)
        if self.microbatches > 1:
            losses, grads = self._microbatched(loss_fn, theta, batch, node_keys, m)
        else:
            losses, grads = self._oracle(loss_fn, theta, batch, node_keys)

        scale = weights_fn(losses)
        updates, opt_state = self.optimizer.update(
            _scale_grads(grads, scale, m), opt_state, theta
        )
        return _apply_updates(theta, updates), opt_state, losses

    # -------------------------------------------------- gradient accumulation
    def _microbatched(self, loss_fn, theta, batch, node_keys, m):
        k = self.microbatches
        acc_dt = jnp.dtype(self.grad_accum_dtype)

        def to_mb(leaf):  # [m, b, ...] -> [k, m, b/k, ...]
            assert leaf.shape[1] % k == 0, (
                f"per-node batch {leaf.shape[1]} not divisible by microbatches {k}"
            )
            return leaf.reshape((m, k, leaf.shape[1] // k) + leaf.shape[2:]).swapaxes(0, 1)

        mb = jax.tree.map(to_mb, batch)

        def body(carry, mbatch):
            acc_l, acc_g = carry
            l, g = self._oracle(loss_fn, theta, mbatch, node_keys)
            acc_g = jax.tree.map(lambda a, gg: a + (gg.astype(acc_dt) / k), acc_g, g)
            return (acc_l + l / k, acc_g), None

        zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), theta)
        (losses, grads), _ = jax.lax.scan(
            body, (jnp.zeros((m,), jnp.float32), zeros_g), mb
        )
        return losses, grads

    # ------------------------------------------------------- K local steps
    def _local_steps(self, loss_fn, theta, opt_state, batch, node_keys, weights_fn, m):
        K = self.local_steps
        if self.batch_layout == "stacked":  # [m, K, ...] -> [K, m, ...]
            kb = jax.tree.map(lambda x: x.swapaxes(0, 1), batch)
        else:

            def to_k(leaf):  # [m, K*b, ...] -> [K, m, b, ...]
                assert leaf.shape[1] % K == 0, (
                    f"per-node batch {leaf.shape[1]} not divisible by local_steps {K}"
                )
                return leaf.reshape((m, K, leaf.shape[1] // K) + leaf.shape[2:]).swapaxes(0, 1)

            kb = jax.tree.map(to_k, batch)

        round_step = opt_state.step

        def body(carry, mbatch):
            theta, ostate = carry
            l, g = self._oracle(loss_fn, theta, mbatch, node_keys)
            scale = weights_fn(l)
            updates, ostate = self.optimizer.update(_scale_grads(g, scale, m), ostate, theta)
            # schedule / Adam bias correction are per-round: every inner step
            # sees the round's step count, bumped once after the scan
            ostate = ostate._replace(step=round_step)
            return (_apply_updates(theta, updates), ostate), l

        (theta, opt_state), losses_k = jax.lax.scan(body, (theta, opt_state), kb)
        return theta, opt_state._replace(step=round_step + 1), losses_k.mean(0)


# ================================================================ dual update
class DualUpdate:
    """How the mixture weights lambda evolve across rounds.

    ``grad_weights`` is the per-node scaling the oracle applies to gradients
    (lambda_i / pi_i so that lambda == prior recovers plain SGD, paper
    §5.2.2); ``update`` advances lambda after the oracle using the observed
    per-node losses.  ``begin`` lets a dual draw per-round randomness
    (DRFA's client sampling) and share it with the consensus via ``ctx``.
    """

    needs_key: bool = False

    def init(self, m: int) -> jax.Array:
        raise NotImplementedError

    def begin(self, lam: jax.Array, key: jax.Array | None):
        return None

    def grad_weights(self, lam: jax.Array, losses: jax.Array) -> jax.Array:
        m = losses.shape[0]
        return jnp.ones((m,), jnp.float32)

    def update(self, lam: jax.Array, losses: jax.Array, ctx, *,
               mixing: jax.Array | None = None,
               mask: jax.Array | None = None,
               step=None, fault_key=None) -> jax.Array:
        """Advance lambda.  Under a time-varying/fault-tolerant consensus the
        trainer passes the round index ``step``, the participation ``mask``,
        and — on the rolled backend only — the round's dense ``mixing``
        matrix, so dual gossip travels the same wire as the model (the
        ppermute backend has no dense matrix: the dual rides the union-wire
        ``mix_fn`` instead); duals that don't gossip ignore them.
        ``fault_key`` is the round's message-fault key when a FaultSpec is
        active — the lambda gossip rides the *same* physical messages as the
        model, so it sees the same event draw."""
        raise NotImplementedError

    def bits_per_round(self) -> float:
        return 0.0


@dataclasses.dataclass(frozen=True)
class ProjectedAscent(DualUpdate):
    """AD-GDA's dual: projected gradient ascent + uncompressed lambda gossip.

    Every node keeps its own copy of lambda (state [m, m]); the round is

        lam_i <- sum_j w_ij P_simplex(lam_j + eta_lam (f_j e_j + alpha grad r))

    The lambda gossip is uncompressed — m floats per neighbor, negligible
    next to the model payload but accounted in :meth:`bits_per_round`.

    ``mix_fn`` overrides how the lambda gossip travels: the factories set it
    to the consensus's :meth:`ChocoConsensus.wire_mix` when the ppermute
    backend is on, so the dual rides the same neighbor permutes as the model
    instead of a stacked-array roll — including time-varying rounds, where
    ``wire_mix`` selects the round's weights from the union wire's banks via
    ``step``/``mask``.  (Rolled time-varying rounds receive the dense W(t)
    from the trainer instead — lambda is [m, m], wire cost negligible.)
    """

    prior: jax.Array
    alpha: float
    eta_lambda: float
    regularizer: dro.Regularizer
    topology: Topology
    mix_fn: Callable | None = None

    def init(self, m: int) -> jax.Array:
        return jnp.broadcast_to(self.prior[None], (m, m)).copy()

    def grad_weights(self, lam, losses):
        return (jnp.diagonal(lam) / self.prior).astype(jnp.float32)

    def update(self, lam, losses, ctx, *, mixing=None, mask=None, step=None,
               fault_key=None):
        m = lam.shape[0]
        node_ids = jnp.arange(m)
        dual_grads = jax.vmap(
            lambda f, i, l: dro.dual_gradient(
                f, i, l, self.prior, self.alpha, self.regularizer
            )
        )(losses, node_ids, lam)
        lam_half = jax.vmap(dro.project_simplex)(lam + self.eta_lambda * dual_grads)
        if mask is not None:  # dropped nodes skip their local ascent step too
            lam_half = jnp.where((mask > 0).reshape((m, 1)), lam_half, lam)
        if mixing is not None:
            return mix_stacked_with(lam_half, mixing)
        if self.mix_fn is not None:
            return self.mix_fn(lam_half, step=step, mask=mask,
                               fault_key=fault_key)
        return mix_stacked(lam_half, self.topology)

    def bits_per_round(self) -> float:
        return 32.0 * int(self.prior.shape[0]) * self.topology.max_degree


@dataclasses.dataclass(frozen=True)
class FrozenPrior(DualUpdate):
    """Non-robust baseline (CHOCO-SGD): lambda frozen at the prior."""

    prior: jax.Array

    def init(self, m: int) -> jax.Array:
        return jnp.broadcast_to(self.prior[None], (m, m)).copy()

    def update(self, lam, losses, ctx, **_):
        return lam


@dataclasses.dataclass(frozen=True)
class KLClosedForm(DualUpdate):
    """DR-DSGD's dual: the KL inner max in closed form, lambda_i ∝ pi_i e^{f_i/alpha}.

    No ascent state to carry — lambda is recomputed from the current losses
    every round (state [m], kept for logging).  The normalizer is one scalar
    all-reduce per round (32 bits; accounting difference vs. gossiping it is
    nil, see baselines module docstring).
    """

    prior: jax.Array
    alpha: float

    def init(self, m: int) -> jax.Array:
        return jnp.asarray(self.prior)

    def grad_weights(self, lam, losses):
        w = dro.kl_closed_form_weights(losses, self.prior, self.alpha)
        return (w / self.prior).astype(jnp.float32)

    def update(self, lam, losses, ctx, **_):
        return dro.kl_closed_form_weights(losses, self.prior, self.alpha)


@dataclasses.dataclass(frozen=True)
class SampledAscent(DualUpdate):
    """DRFA's dual: sample |U| clients ~ lambda (Gumbel top-k, no replacement),
    run the round on them, then projected ascent on the importance-corrected
    observed losses.  The sampling mask is shared with :class:`FedAvg`
    through the round ``ctx``."""

    prior: jax.Array
    eta_lambda: float
    local_steps: int
    num_sampled: int

    needs_key = True

    def init(self, m: int) -> jax.Array:
        return jnp.asarray(self.prior)

    def begin(self, lam, key):
        m = lam.shape[0]
        gumbel = -jnp.log(-jnp.log(jax.random.uniform(key, (m,)) + 1e-20) + 1e-20)
        scores = jnp.log(lam + 1e-20) + gumbel
        _, sampled = jax.lax.top_k(scores, self.num_sampled)
        return jnp.zeros((m,), jnp.float32).at[sampled].set(1.0)

    def update(self, lam, losses, ctx, **_):
        sampled = ctx  # the begin() sampling mask, shared with FedAvg
        m = lam.shape[0]
        wsum = sampled.sum()
        loss_vec = losses * sampled * (m / jnp.maximum(wsum, 1.0))
        return dro.project_simplex(lam + self.eta_lambda * self.local_steps * loss_vec)


# ================================================================== consensus
class Consensus:
    """How the half-step models travel the wire.

    ``schedule`` is non-None when the wire is time-varying (a
    :class:`~repro.core.topology.TopologySchedule` with period > 1 and/or
    node dropout); the trainer then threads the round index, the
    participation ``mask`` and the round's dense ``mixing`` matrix into
    :meth:`mix`.  Static consensus implementations ignore them.

    ``backend`` names the exchange implementation the consensus executes on:
    ``"rolled"`` (the stacked-array simulation — rolls / dense matmuls over
    the full node axis) or ``"ppermute"`` (the mesh-native SPMD substrate of
    ``core/exchange.py`` — shard_map + lax.ppermute moving only degree-many
    compressed messages between graph neighbors).
    """

    needs_key: bool = False
    federated: bool = False  # True -> state.theta has no node axis
    schedule: TopologySchedule | None = None
    backend: str = "rolled"

    def init(self, theta_stacked):
        return ()

    def mix(self, theta_half, state, key: jax.Array | None, ctx, *,
            step=None, mask=None, mixing=None, fault_key=None,
            theta_prev=None):
        """Mix the half-step models.  ``theta_prev`` is the round's
        pre-local-update theta (what the trainer held before the oracle ran)
        — gradient-tracking consensus reads the local displacement from it;
        every other implementation ignores it."""
        raise NotImplementedError

    @property
    def wire_format(self) -> wire.WireFormat:
        """Byte format of one per-edge message (see repro.core.wire)."""
        return wire.DENSE

    def bits_per_round(self, theta_template, *, mode: str = "max",
                       step=None, mask=None) -> float:
        """Busiest-node bits per round.  ``mode``: "max" (upper bound,
        default), "expected" (participation-aware phase average), or
        "realized" (actual links of round ``step`` under ``mask``)."""
        raise NotImplementedError

    def bits_realized(self, theta_template, step, mask, consensus_state=None):
        """This round's realized wire bits as a *traced* scalar — the jitted
        form of ``bits_per_round(mode="realized")`` the trainer threads into
        ``aux["bits_realized"]`` so long faulty runs report measured traffic
        without host-side masks.  ``consensus_state`` is the *post-mix*
        consensus state: faulted wires carry an in-graph per-node bits meter
        there (delivered bits only — dropped messages are not billed, dups
        bill twice, resyncs bill their dense payload).  Default: the
        max-degree constant (exact for static full-participation wires)."""
        return jnp.float32(self.bits_per_round(theta_template, mode="max"))


def _resolve_wire_backend(backend: str, mesh, schedule, topology=None, faults=None):
    """Shared ctor validation for the ``backend`` knob: checks the name,
    requires a mesh for ppermute, and compiles the union wire program when
    the wire is time-varying — or when a fault model is active, since fault
    injection lives at the exchange boundary and runs every backend through
    the cached union round body (one plan per consensus instance — the same
    object then sizes the NeighborCache + FaultState, selects round weights,
    and bills bits, so they cannot drift)."""
    if backend not in ("rolled", "ppermute"):
        raise ValueError(f"unknown gossip backend {backend!r}; choose rolled or ppermute")
    if backend == "ppermute" and mesh is None:
        raise ValueError("backend='ppermute' requires a mesh (see launch.mesh.make_node_mesh)")
    needs_union = (backend == "ppermute" and schedule is not None) or faults is not None
    if not needs_union:
        return None
    if schedule is not None:
        return wire.compile_union_wire(
            compile_schedule_plans(schedule), name=schedule.name
        )
    if topology is None:
        raise ValueError("fault injection needs a topology or schedule to compile the wire")
    return wire.compile_union_wire((compile_permute_plan(topology),))


def _union_degree(union, schedule, mode: str, mask) -> float:
    """Billing degree of the union wire: every union edge carries one
    message every round, dropped only when the sender itself is dead (a
    dead receiver's messages are deferred re-sync traffic, not avoided)."""
    if mode == "max":
        return float(union.max_out_degree)
    if mode == "expected":
        rate = schedule.dropout_rate if schedule is not None else 0.0
        return union.max_out_degree * (1.0 - rate)
    if mode == "realized":
        if mask is None:
            raise ValueError("mode='realized' needs the round's participation mask")
        return union.realized_out_degree(mask)
    raise ValueError(f"unknown bits mode {mode!r}; choose max/expected/realized")


def _fault_bits_meter(cons_state):
    """The faulted wire's in-graph per-node bits meter, if ``cons_state``
    carries one: CHOCO keeps it in ``CHOCOState.fault.bits``, the memoryless
    exact wire in a bare :class:`~repro.core.faults.WireBits`, and a
    multi-lane :class:`GTState` sums its lanes' meters (each lane billed its
    own deliveries).  None when the state has no meter (fault-free run, or
    pre-round state)."""
    if hasattr(cons_state, "model") and hasattr(cons_state, "tracker"):
        a = _fault_bits_meter(cons_state.model)
        b = _fault_bits_meter(cons_state.tracker)
        if a is not None and b is not None:
            return a + b
        return None
    fault = getattr(cons_state, "fault", None)
    if hasattr(fault, "bits"):
        return fault.bits
    if hasattr(cons_state, "bits") and not hasattr(cons_state, "theta_hat"):
        return cons_state.bits
    return None


def _split_schedule(topology):
    """Normalize a Topology-or-Schedule ctor arg.

    Returns (representative_topology, schedule_or_None, gamma_source): static
    schedules unwrap to their phase topology so the circulant fast paths (and
    bit-identical numerics) are preserved; time-varying ones keep phase 0 as
    the representative for introspection and use the schedule's worst phase
    for step-size theory.
    """
    if isinstance(topology, TopologySchedule):
        sched = None if topology.is_static else topology
        return topology.topology_at(0), sched, (sched or topology.topology_at(0))
    return topology, None, topology


class ChocoConsensus(Consensus):
    """CHOCO-GOSSIP compressed round (Koloskova et al. 2019) with the
    ``packed`` (mix encoded payload) / ``fused`` (single-pass Pallas,
    kernels/choco_fused.py) dispatch preserved from ``gossip.choco_round``.

    Constructed with a plain :class:`Topology` or a
    :class:`TopologySchedule`; with a time-varying schedule the round mixes
    with the schedule's dense W(t) (packed/fused dispatch does not apply —
    the wire pattern changes every round) and honors the participation mask.
    """

    needs_key = True

    def __init__(self, topology: Topology | TopologySchedule, compressor: Compressor,
                 gamma: float | str | None = None, *, packed: bool = True,
                 fused: bool = False, backend: str = "rolled", mesh=None,
                 node_axes="data", faults=None):
        self.topology, self.schedule, self._gamma_topology = _split_schedule(topology)
        self.compressor = compressor
        self.gamma_spec = gamma
        self.packed = packed
        self.fused = fused
        self.backend = backend
        self.mesh = mesh
        self.node_axes = node_axes
        # the message-fault model (None = perfect wire); faults force the
        # cached union wire on every backend — detection and recovery live
        # at the exchange boundary (see repro.core.faults)
        self.faults = parse_fault_spec(faults)
        # the time-varying ppermute wire: one union program for every phase,
        # and a NeighborCache sized to its op count (see repro.core.wire)
        self.union = _resolve_wire_backend(
            backend, mesh, self.schedule, topology=self.topology, faults=self.faults
        )
        # provisional gamma until init()/mix() see the real leaf sizes
        self.gamma = self._resolve_gamma(4096)

    @staticmethod
    def _encode_dim(theta) -> int:
        """Largest per-node encode size the gossip layer will actually run on
        a *stacked* pytree — the dimension the compressor's contraction
        factor delta depends on.  Mirrors ``gossip._scan_plan``'s chunking
        exactly (a chunk can exceed BLOCK_SCAN_ELEMS when the leaf has no
        suitable divisor, or the whole leaf is encoded when no plan exists)."""
        best = 1
        for leaf in jax.tree_util.tree_leaves(theta):
            inner = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
            plan = _scan_plan(leaf.shape, inner, BLOCK_SCAN_ELEMS)
            best = max(best, inner if plan is None else inner // plan[1])
        return best

    def _resolve_gamma(self, d: int) -> float:
        """Consensus step size gamma for the largest single encode of size d.

        Gamma trades consensus speed against compression-noise injection; the
        right value scales with the compressor's contraction factor delta,
        which for quantization depends on the dimension d being compressed
        (delta = 1/tau, tau = 1 + min(d/2^2b, sqrt(d)/2^b) — paper eq. (2)).
        Resolution order:

        * ``gamma == "theory"`` — the Theorem 4.1 value: provably convergent
          but very conservative in practice;
        * a number — used verbatim (the paper grid-searches gamma per
          compression level, §5.1.1);
        * ``None`` — 0.5 * delta(d), a robust default across our experiments.

        Called with a 4096-element placeholder at construction, then from
        ``init()`` and again at every ``mix()`` trace with the actual pytree's
        leaf shapes — the compressor contracts *leaf-wise* (and the gossip
        layer chunks leaves above BLOCK_SCAN_ELEMS), so the dimension that
        matters is the largest single encode, not the total parameter count.
        """
        delta = getattr(self.compressor, "delta", 1.0)
        if hasattr(self.compressor, "delta_for"):
            delta = self.compressor.delta_for(max(int(d), 1))
        if self.gamma_spec == "theory":
            # worst (smallest-gap) phase when the topology is a schedule
            return self._gamma_topology.consensus_step_size(max(delta, 1e-3))
        if self.gamma_spec is not None:
            return float(self.gamma_spec)
        return 0.5 * max(delta, 1e-3)

    def init(self, theta_stacked) -> CHOCOState:
        # keep ``.gamma`` introspectable for the actual model; mix() re-resolves
        # at trace time so a step traced without init() still gets the right value
        self.gamma = self._resolve_gamma(self._encode_dim(theta_stacked))
        return choco_init(
            theta_stacked,
            cache_ops=self.union.n_ops if self.union is not None else 0,
            fault_ops=self.union.n_ops if self.faults is not None else None,
        )

    def mix(self, theta_half, state, key, ctx, *, step=None, mask=None,
            mixing=None, fault_key=None, theta_prev=None):
        gamma = self._resolve_gamma(self._encode_dim(theta_half))
        if self.backend == "ppermute":
            # the SPMD substrate takes the schedule + round index + mask and
            # compiles its own per-phase wire programs — a dense W(t) has no
            # wire meaning there
            return choco_round(
                theta_half, state, self.topology, gamma, self.compressor, key,
                packed=self.packed, fused=self.fused, mask=mask,
                backend="ppermute", mesh=self.mesh, node_axes=self.node_axes,
                schedule=self.schedule, step=step, union=self.union,
                faults=self.faults, fault_key=fault_key,
            )
        if self.faults is not None:
            # faulted rolled wire: the cached union round (same body as the
            # ppermute backend with a single full-width shard) — a dense
            # W(t) cannot express per-edge delivery faults
            return choco_round(
                theta_half, state, self.topology, gamma, self.compressor, key,
                packed=self.packed, mask=mask, schedule=self.schedule,
                step=step, union=self.union, faults=self.faults,
                fault_key=fault_key,
            )
        if self.schedule is not None and mixing is None:
            # standalone use (no trainer threading): resolve W(t) here
            mixing = self.schedule.mixing_at(0 if step is None else step, mask)
        return choco_round(
            theta_half, state, self.topology, gamma, self.compressor, key,
            packed=self.packed, fused=self.fused, mixing=mixing, mask=mask,
        )

    def wire_mix(self, tree, *, step=None, mask=None, fault_key=None):
        """Uncompressed (dense-format) gossip of a stacked tree over this
        consensus's wire — the dual/lambda gossip rides the same permutes as
        the model on the ppermute backend.  Time-varying rounds select their
        weights from the union wire's per-phase banks via ``step``/``mask``;
        the rolled backend's time-varying duals get the dense W(t) from the
        trainer instead and never reach here (unless faults are active, which
        force the union wire on every backend).  Under faults the dual rides
        the *same* physical messages as the model — same ``fault_key``, same
        event draw — and its delivered bits stay billed at the existing
        constant (negligible next to the model payload)."""
        if self.backend == "ppermute":
            from repro.core.exchange import mix_stacked_ppermute

            out = mix_stacked_ppermute(
                tree, self.topology, mesh=self.mesh, node_axes=self.node_axes,
                schedule=self.schedule, step=step, mask=mask, union=self.union,
                faults=self.faults, fault_key=fault_key,
            )
            return out[0] if self.faults is not None else out
        if self.faults is not None:
            from repro.core.exchange import mix_stacked_faulted_local

            mixed, _ = mix_stacked_faulted_local(
                tree, union=self.union, topology=self.topology,
                schedule=self.schedule, step=step, mask=mask,
                faults=self.faults, fault_key=fault_key,
            )
            return mixed
        return mix_stacked(tree, self.topology)

    @property
    def wire_format(self) -> wire.WireFormat:
        if isinstance(self.compressor, Identity) or not self.packed:
            return wire.DENSE
        return wire.HAT_DELTA if self.union is not None else wire.PAYLOAD

    def bits_per_round(self, theta_template, *, mode: str = "max",
                       step=None, mask=None, compressor=None) -> float:
        comp = compressor if compressor is not None else self.compressor
        if self.union is not None:
            # cached union wire: every union edge carries one hat-delta
            # payload every round (that is what keeps the mirrors exact), so
            # the honest degree is the union out-degree
            return payload_bits(
                comp, theta_template, self.schedule,
                degree=_union_degree(self.union, self.schedule, mode, mask),
            )
        return payload_bits(
            comp, theta_template, self.schedule or self.topology,
            mode=mode, step=step, mask=mask,
        )

    def bits_per_lane(self, theta_template, *, mode: str = "max",
                      step=None, mask=None) -> dict:
        """Per-lane busiest-node bits: one entry per :attr:`wire_format`
        lane, keyed by lane name.  Every lane of a multi-lane CHOCO wire
        carries the same compressed shape over the same edges, so each lane
        bills the single-lane cost; the round total is the sum."""
        one = ChocoConsensus.bits_per_round(
            self, theta_template, mode=mode, step=step, mask=mask
        )
        return {lane.name: one for lane in self.wire_format}

    def bits_realized(self, theta_template, step, mask, consensus_state=None):
        if self.faults is not None:
            meter = _fault_bits_meter(consensus_state)
            if meter is not None:
                # the exchange's own delivered-bits meter: drops unbilled,
                # dups billed twice, resyncs bill their dense payload
                return meter.max()
        total = payload_total_bits(self.compressor, theta_template)
        if self.union is not None:
            return total * self.union.realized_out_degree_traced(mask)
        if self.schedule is not None:
            return total * self.schedule.realized_degree_traced(step, mask)
        return total * self.topology.realized_degree_traced(step, mask)


class GTState(NamedTuple):
    """Gradient-tracking consensus state: one :class:`CHOCOState` per wire
    lane (the model lane and the tracker lane each keep their own hat/s,
    NeighborCache mirrors and fault-recovery machine), plus the tracker
    variable ``y`` — each node's gossiped estimate of the network-average
    local displacement — and ``d_prev``, the node's own displacement from
    the previous round it participated in."""

    model: CHOCOState
    tracker: CHOCOState
    y: Any  # stacked pytree [m, ...], theta-shaped
    d_prev: Any  # stacked pytree [m, ...], theta-shaped


def _gt_bcast(mask, leaf):
    """[m] participation mask broadcast against a [m, ...] leaf (f32)."""
    return mask.astype(jnp.float32).reshape(
        (mask.shape[0],) + (1,) * (leaf.ndim - 1)
    )


class GradientTrackingConsensus(ChocoConsensus):
    """CHOCO-compressed gossip with gradient tracking for K local steps
    (Robust Decentralized Learning with Local Updates and Gradient Tracking,
    arXiv 2405.00965, in CHOCO displacement form).

    Plain local SGD drifts under heterogeneous data: between gossip rounds
    each node descends toward its *local* optimum, and with large K the
    compressed gossip equilibrium is biased.  Gradient tracking gossips a
    second variable ``y`` that tracks the network-average local
    displacement; each node then moves by the tracked average instead of its
    own displacement, so heterogeneous nodes take many local steps without
    client drift.  One round, with ``d_i = theta_half_i - theta_prev_i`` the
    node's K-step displacement::

        y_half_i = y_i + d_i - d_prev_i            # tracker update
        x_half_i = theta_prev_i + y_half_i         # drift-corrected iterate
        theta    <- CHOCO-round(x_half, model lane)
        y        <- CHOCO-round(y_half, tracker lane)
        d_prev_i <- d_i

    Both CHOCO rounds ride the *same* wire round as a two-lane message
    (:func:`~repro.core.gossip.choco_round_lanes`): lane 0 is the model
    hat-delta with the historical key stream, lane 1 the tracker hat-delta
    keyed by ``fold_in(key, 1)``.  Each lane keeps its own NeighborCache and
    fault state, so a corrupted tracker message can never poison a theta
    mirror.  Mean trajectories are preserved (``mean(y_t) ==
    mean(d_{t-1})`` by induction; doubly-stochastic mixing keeps both lane
    means), so with K=1 the dynamics match plain CHOCO local-SGD in the
    network mean while individual nodes stay consensus-anchored.

    ``tracker=False`` disables the second lane entirely and delegates every
    code path to :class:`ChocoConsensus` — bit-identical on both backends
    (the K=1 parity anchor the tests pin).

    Dropped nodes (participation mask 0) freeze ``y`` and ``d_prev`` along
    with their CHOCO trackers: the trainer reverts their theta_half, so
    ``d_i = 0``, and the update above is gated per node — a node rejoins
    with a consistent tracker.
    """

    def __init__(self, topology: Topology | TopologySchedule,
                 compressor: Compressor, gamma: float | str | None = None, *,
                 tracker: bool = True, tracker_gamma: float | None = None,
                 tracker_compressor: Compressor | str | None = None,
                 **kw):
        super().__init__(topology, compressor, gamma, **kw)
        self.tracker = tracker
        self.tracker_gamma_spec = tracker_gamma
        # the tracker lane may run a DIFFERENT compression level than the
        # model lane (arXiv 2405.00965 observes the tracker tolerates
        # coarser quantization): None reuses the model compressor (and the
        # model gamma — bit-identical to the single-compressor wire)
        if isinstance(tracker_compressor, str):
            from repro.core.compression import make_compressor

            tracker_compressor = make_compressor(tracker_compressor)
        self.tracker_compressor = tracker_compressor

    @property
    def _tracker_comp(self) -> Compressor:
        return (self.tracker_compressor if self.tracker_compressor is not None
                else self.compressor)

    def _resolve_tracker_gamma(self, gamma: float, d: int) -> float:
        """Tracker-lane step size: an explicit ``tracker_gamma`` wins; else
        the model gamma when the lanes share a compressor (historical
        behavior, bit-identical), else the default resolution against the
        tracker compressor's own contraction factor."""
        if self.tracker_gamma_spec is not None:
            return float(self.tracker_gamma_spec)
        if self.tracker_compressor is None:
            return gamma
        comp = self.tracker_compressor
        delta = getattr(comp, "delta", 1.0)
        if hasattr(comp, "delta_for"):
            delta = comp.delta_for(max(int(d), 1))
        return 0.5 * max(delta, 1e-3)

    def init(self, theta_stacked):
        base = super().init(theta_stacked)
        if not self.tracker:
            return base
        tracker = choco_init(
            theta_stacked,
            cache_ops=self.union.n_ops if self.union is not None else 0,
            fault_ops=self.union.n_ops if self.faults is not None else None,
        )
        zeros = lambda: jax.tree.map(jnp.zeros_like, theta_stacked)
        return GTState(model=base, tracker=tracker, y=zeros(), d_prev=zeros())

    def mix(self, theta_half, state, key, ctx, *, step=None, mask=None,
            mixing=None, fault_key=None, theta_prev=None):
        if not self.tracker:
            return super().mix(
                theta_half, state, key, ctx, step=step, mask=mask,
                mixing=mixing, fault_key=fault_key,
            )
        if theta_prev is None:
            raise ValueError(
                "GradientTrackingConsensus.mix needs theta_prev (the round's "
                "pre-local-update theta) to form the local displacement — "
                "the trainer threads it; standalone callers must pass it"
            )
        d = self._encode_dim(theta_half)
        gamma = self._resolve_gamma(d)
        tgamma = self._resolve_tracker_gamma(gamma, d)
        f32 = jnp.float32

        def upd(h, p, y, dp):
            d = h.astype(f32) - p.astype(f32)
            if mask is not None:
                a = _gt_bcast(mask, h)
                y_half = y.astype(f32) + a * (d - dp.astype(f32))
                d_new = a * d + (1.0 - a) * dp.astype(f32)
                x_half = h.astype(f32) + a * (y_half - d)
            else:
                y_half = y.astype(f32) + d - dp.astype(f32)
                d_new = d
                x_half = p.astype(f32) + y_half
            return x_half.astype(h.dtype), y_half.astype(h.dtype), d_new.astype(h.dtype)

        trip = jax.tree.map(upd, theta_half, theta_prev, state.y, state.d_prev)
        x_half = jax.tree.map(lambda t: t[0], trip, is_leaf=lambda t: isinstance(t, tuple))
        y_half = jax.tree.map(lambda t: t[1], trip, is_leaf=lambda t: isinstance(t, tuple))
        d_prev_new = jax.tree.map(lambda t: t[2], trip, is_leaf=lambda t: isinstance(t, tuple))

        if (self.backend == "rolled" and self.faults is None
                and self.schedule is not None and mixing is None):
            mixing = self.schedule.mixing_at(0 if step is None else step, mask)
        (x_new, y_new), (model_new, tracker_new) = choco_round_lanes(
            (
                LaneRound(x_half, state.model, gamma, self.compressor),
                LaneRound(y_half, state.tracker, tgamma, self._tracker_comp),
            ),
            self.topology, key, packed=self.packed, fused=self.fused,
            mixing=mixing, mask=mask, backend=self.backend, mesh=self.mesh,
            node_axes=self.node_axes, schedule=self.schedule, step=step,
            union=self.union, faults=self.faults, fault_key=fault_key,
        )
        return x_new, GTState(
            model=model_new, tracker=tracker_new, y=y_new, d_prev=d_prev_new
        )

    @property
    def wire_format(self) -> wire.WireFormat:
        base = super().wire_format
        if not self.tracker:
            return base
        kind = base.lanes[0].kind
        tkind = kind
        if self.tracker_compressor is not None:
            tkind = (wire.DENSE.lanes[0].kind
                     if isinstance(self.tracker_compressor, Identity)
                     or not self.packed else kind)
        return wire.WireFormat(
            (wire.Lane(kind, "model"), wire.Lane(tkind, "tracker"))
        )

    def bits_per_round(self, theta_template, *, mode: str = "max",
                       step=None, mask=None, compressor=None) -> float:
        if compressor is not None:  # a single lane priced explicitly
            return super().bits_per_round(
                theta_template, mode=mode, step=step, mask=mask,
                compressor=compressor,
            )
        return sum(
            self.bits_per_lane(
                theta_template, mode=mode, step=step, mask=mask
            ).values()
        )

    def bits_per_lane(self, theta_template, *, mode: str = "max",
                      step=None, mask=None) -> dict:
        """Per-lane busiest-node bits, each lane priced at its OWN
        compressor (the tracker lane may be coarser, see
        ``tracker_compressor``)."""
        if not self.tracker:
            return super().bits_per_lane(
                theta_template, mode=mode, step=step, mask=mask
            )
        comps = {"model": self.compressor, "tracker": self._tracker_comp}
        return {
            lane.name: super(GradientTrackingConsensus, self).bits_per_round(
                theta_template, mode=mode, step=step, mask=mask,
                compressor=comps[lane.name],
            )
            for lane in self.wire_format
        }

    def bits_realized(self, theta_template, step, mask, consensus_state=None):
        if not self.tracker:
            return super().bits_realized(
                theta_template, step, mask, consensus_state=consensus_state
            )
        if self.faults is not None:
            meter = _fault_bits_meter(consensus_state)
            if meter is not None:
                return meter.max()
        scale = 2.0
        if self.tracker_compressor is not None:
            model_total = payload_total_bits(self.compressor, theta_template)
            scale = 1.0 + (
                payload_total_bits(self.tracker_compressor, theta_template)
                / model_total if model_total else 1.0
            )
        return scale * super().bits_realized(theta_template, step, mask)


class ExactConsensus(Consensus):
    """Uncompressed gossip: theta_i <- sum_j w_ij theta_j (DR-DSGD's wire).

    Accepts a :class:`TopologySchedule` too: the round then mixes with the
    schedule's dense W(t) and dropped nodes (identity row/column) hold their
    model until they rejoin.

    ``backend="ppermute"`` executes the mix on the neighbor-exchange
    substrate: dense-format f32 messages (this *is* the algorithm's wire —
    DR-DSGD sends uncompressed models) travel only between actual graph
    neighbors via ``lax.ppermute``, with zero all-gather; time variation
    rides the union wire's weight banks like the CHOCO consensus.
    """

    def __init__(self, topology: Topology | TopologySchedule, *,
                 backend: str = "rolled", mesh=None, node_axes="data",
                 faults=None):
        self.topology, self.schedule, _ = _split_schedule(topology)
        self.backend = backend
        self.mesh = mesh
        self.node_axes = node_axes
        self.faults = parse_fault_spec(faults)
        self.union = _resolve_wire_backend(
            backend, mesh, self.schedule, topology=self.topology, faults=self.faults
        )

    def init(self, theta_stacked):
        if self.faults is not None:
            # the uncompressed wire is memoryless (no mirrors to heal) —
            # the only fault state is the per-node delivered-bits meter
            m = jax.tree_util.tree_leaves(theta_stacked)[0].shape[0]
            return WireBits(bits=jnp.zeros((m,), jnp.float32))
        return ()

    def mix(self, theta_half, state, key, ctx, *, step=None, mask=None,
            mixing=None, fault_key=None, theta_prev=None):
        if self.backend == "ppermute":
            if mixing is not None:
                raise ValueError(
                    "backend='ppermute' takes step/mask, not a dense mixing "
                    "matrix — the wire program is compiled from the schedule"
                )
            from repro.core.exchange import mix_stacked_ppermute

            out = mix_stacked_ppermute(
                theta_half, self.topology, mesh=self.mesh,
                node_axes=self.node_axes, schedule=self.schedule,
                step=step, mask=mask, union=self.union,
                faults=self.faults, fault_key=fault_key,
            )
            if self.faults is not None:
                mixed, bits = out
                return mixed, WireBits(bits=bits)
            return out, state
        if self.faults is not None:
            from repro.core.exchange import mix_stacked_faulted_local

            mixed, bits = mix_stacked_faulted_local(
                theta_half, union=self.union, topology=self.topology,
                schedule=self.schedule, step=step, mask=mask,
                faults=self.faults, fault_key=fault_key,
            )
            return mixed, WireBits(bits=bits)
        if self.schedule is not None and mixing is None:
            mixing = self.schedule.mixing_at(0 if step is None else step, mask)
        if mixing is not None:
            return mix_stacked_with(theta_half, mixing), state
        return mix_stacked(theta_half, self.topology), state

    def bits_per_round(self, theta_template, *, mode: str = "max",
                       step=None, mask=None) -> float:
        if self.union is not None and self.faults is not None:
            # faulted wire: event draws are indexed per union op, so every
            # union op moves a dense f32 message every round — bill the
            # union degree, like the cached CHOCO wire does.
            return payload_bits(
                Identity(), theta_template, self.schedule,
                degree=_union_degree(self.union, self.schedule, mode, mask),
            )
        # fault-free scheduled ppermute now runs a per-phase wire program
        # (lax.switch over phase branches in mix_stacked_ppermute): only the
        # active phase's edges move bytes, so bill the schedule's own degree.
        return payload_bits(
            Identity(), theta_template, self.schedule or self.topology,
            mode=mode, step=step, mask=mask,
        )

    def bits_realized(self, theta_template, step, mask, consensus_state=None):
        if self.faults is not None:
            meter = _fault_bits_meter(consensus_state)
            if meter is not None:
                return meter.max()
        total = payload_total_bits(Identity(), theta_template)
        if self.union is not None and self.faults is not None:
            return total * self.union.realized_out_degree_traced(mask)
        topo = self.schedule or self.topology
        return total * topo.realized_degree_traced(step, mask)


class FedAvg(Consensus):
    """Federated server averaging over the sampled clients (DRFA's wire).

    Input is the stacked local models [m, ...]; output is the single server
    model (no node axis) — the trainer re-broadcasts it next round.  With no
    sampling ctx every client is averaged (plain FedAvg).

    ``backend="ppermute"`` aggregates mesh-native: per-device partial sums
    + one ``psum`` over the node axes (the ring all-reduce realization of
    "|U| models up, one model down") — zero all-gather, vs. the rolled form
    whose stacked ``sum(0)`` GSPMD may lower to an all-gather of the whole
    model stack.  ``bits_per_round`` keeps billing the server-star wire
    model (2|U|·d·f32) in every mode — that is the *algorithm's* traffic.
    """

    federated = True

    def __init__(self, num_sampled: int, *, backend: str = "rolled",
                 mesh=None, node_axes="data"):
        _resolve_wire_backend(backend, mesh, None)
        self.num_sampled = num_sampled
        self.backend = backend
        self.mesh = mesh
        self.node_axes = node_axes

    def mix(self, theta_locals, state, key, ctx, *, step=None, mask=None,
            mixing=None, fault_key=None, theta_prev=None):
        m = jax.tree_util.tree_leaves(theta_locals)[0].shape[0]
        sampled = ctx  # SampledAscent's per-round client mask (None = all)
        if sampled is None:
            sampled = jnp.ones((m,), jnp.float32)
        if self.backend == "ppermute":
            from repro.core.exchange import server_average_ppermute

            theta_new = server_average_ppermute(
                theta_locals, sampled, mesh=self.mesh, node_axes=self.node_axes
            )
            return theta_new, state
        wsum = sampled.sum()
        theta_new = jax.tree.map(
            lambda x: (
                (x.astype(jnp.float32) * sampled.reshape((m,) + (1,) * (x.ndim - 1))).sum(0)
                / wsum
            ).astype(x.dtype),
            theta_locals,
        )
        return theta_new, state

    def bits_per_round(self, theta_template, *, mode: str = "max",
                       step=None, mask=None) -> float:
        """Busiest node = the server: |U| models down + |U| models up, f32.
        The sample count is fixed, so every mode bills the same.
        ``theta_template`` is the federated trainer's *server* model (no
        node axis — federated state.theta never carries one), so the full
        prod(shape) is the per-model element count."""
        d = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(theta_template))
        return 2.0 * self.num_sampled * d * 32.0


# ==================================================================== trainer
class DecentralizedTrainer:
    """oracle x optimizer x dual x consensus, one round per ``step``.

    Functional interface shared by every algorithm in the repo::

        trainer = DecentralizedTrainer(loss_fn, num_nodes=m, local=..., dual=..., consensus=...)
        state = trainer.init(params, rng)
        state, aux = trainer.step(state, batch)     # jitted, donates state

    ``batch`` leaves are stacked [m, per-node-batch, ...].  See
    ``repro.core.adgda.adgda_trainer`` / ``repro.core.baselines`` for the
    paper's named compositions and ``examples/quickstart.py`` for an
    end-to-end run.
    """

    def __init__(
        self,
        loss_fn: LossFn,
        *,
        num_nodes: int,
        local: LocalUpdate,
        dual: DualUpdate,
        consensus: Consensus,
        prior: jax.Array | None = None,
        track_average: bool = True,
        config: Any = None,
    ):
        self.loss_fn = loss_fn
        self.num_nodes = num_nodes
        self.local = local
        self.dual = dual
        self.consensus = consensus
        self.prior = (
            jnp.full((num_nodes,), 1.0 / num_nodes) if prior is None else jnp.asarray(prior)
        )
        self.track_average = track_average
        self.config = config  # the factory's config, kept for introspection
        self.federated = consensus.federated

    def _init_as(self, composed: "DecentralizedTrainer") -> None:
        """Deprecated-shim helper: adopt a factory-built trainer's composition
        wholesale, so the shims cannot drift from the factories field-by-field."""
        DecentralizedTrainer.__init__(
            self,
            composed.loss_fn,
            num_nodes=composed.num_nodes,
            local=composed.local,
            dual=composed.dual,
            consensus=composed.consensus,
            prior=composed.prior,
            track_average=composed.track_average,
            config=composed.config,
        )

    # convenience introspection (shim/test surface)
    @property
    def topology(self) -> Topology | None:
        return getattr(self.consensus, "topology", None)

    @property
    def schedule(self) -> TopologySchedule | None:
        """The time-varying topology schedule, or None when the wire is static."""
        return getattr(self.consensus, "schedule", None)

    @property
    def compressor(self) -> Compressor | None:
        return getattr(self.consensus, "compressor", None)

    @property
    def gamma(self) -> float | None:
        return getattr(self.consensus, "gamma", None)

    def _stacked(self, params):
        m = self.num_nodes
        return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m,) + p.shape), params)

    # ------------------------------------------------------------------ init
    def init(self, params: Any, rng: jax.Array) -> TrainerState:
        stacked = self._stacked(params)
        if self.federated:
            theta0 = jax.tree.map(lambda x: jnp.array(x, copy=True), params)
        else:
            theta0 = jax.tree.map(lambda x: x.copy(), stacked)
        return TrainerState(
            step=jnp.zeros((), jnp.int32),
            theta=theta0,
            lam=self.dual.init(self.num_nodes),
            opt=self.local.init(stacked),
            consensus=self.consensus.init(stacked),
            theta_avg=(
                jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params)
                if self.track_average
                else ()
            ),
            # defensive copy: step() donates its input state, which would
            # otherwise delete the caller's key buffer
            rng=jnp.array(rng, copy=True),
        )

    # ------------------------------------------------------------------ step
    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def step(self, state: TrainerState, batch: Any) -> tuple[TrainerState, dict]:
        return self.step_impl(state, batch)

    def step_impl(self, state: TrainerState, batch: Any) -> tuple[TrainerState, dict]:
        """Unjitted round — lower/compile with custom shardings via
        ``jax.jit(trainer.step_impl, in_shardings=...)`` (see launch/dryrun.py)."""
        m = self.num_nodes
        schedule = self.schedule
        needs_mask = schedule is not None and schedule.dropout_rate > 0

        # --- RNG: one split per round; extra keys only for the parts that
        # consume randomness, so compositions without them (e.g. DR-DSGD)
        # reproduce the seed trainers' key streams exactly — and a static
        # no-dropout run reproduces the pre-schedule stream exactly
        needs_faults = getattr(self.consensus, "faults", None) is not None
        n_extra = (
            int(self.consensus.needs_key) + int(self.dual.needs_key)
            + int(needs_mask) + int(needs_faults)
        )
        keys = jax.random.split(state.rng, m + 1 + n_extra)
        rng, idx = keys[0], 1
        gossip_key = None
        if self.consensus.needs_key:
            gossip_key, idx = keys[idx], idx + 1
        dual_key = None
        if self.dual.needs_key:
            dual_key, idx = keys[idx], idx + 1
        mask_key = None
        if needs_mask:
            mask_key, idx = keys[idx], idx + 1
        fault_key = None
        if needs_faults:
            # one event key per round, shared by the model gossip and the
            # lambda gossip: the dual rides the same physical messages, so
            # both see the same delivery-fault draw
            fault_key, idx = keys[idx], idx + 1
        node_keys = keys[idx:]

        # --- time-varying wire: participation mask + this round's W(t) ------
        # the dense [m, m] matrix only exists for the rolled backend; the
        # ppermute backend compiles its own union wire program and the dual
        # gossip rides it through mix_fn (wire_mix) instead.  Faulted wires
        # also skip it: per-edge delivery faults have no dense-W expression,
        # so every faulted backend runs the union exchange.
        wire_native = getattr(self.consensus, "backend", "rolled") == "ppermute"
        mask = schedule.mask_at(mask_key, state.step) if needs_mask else None
        mixing = (
            schedule.mixing_at(state.step, mask)
            if schedule is not None and not wire_native and not needs_faults
            else None
        )

        ctx = self.dual.begin(state.lam, dual_key)

        # --- local oracle + optimizer (dual-weighted gradients) -------------
        theta = self._stacked(state.theta) if self.federated else state.theta
        weights_fn = lambda losses: self.dual.grad_weights(state.lam, losses)
        theta_half, opt_new, losses = self.local.step(
            self.loss_fn, theta, state.opt, batch, node_keys, weights_fn
        )
        if mask is not None:
            # dropped nodes skip their local update: model and per-node
            # optimizer moments revert, so a rejoining node resumes from
            # exactly where it left off
            theta_half = _select_nodes(mask, theta_half, theta, m)
            opt_new = _select_nodes(mask, opt_new, state.opt, m)

        # --- dual update ----------------------------------------------------
        lam_new = self.dual.update(
            state.lam, losses, ctx, mixing=mixing, mask=mask, step=state.step,
            fault_key=fault_key,
        )

        # --- consensus ------------------------------------------------------
        theta_new, cons_new = self.consensus.mix(
            theta_half, state.consensus, gossip_key, ctx,
            step=state.step, mask=mask, mixing=mixing, fault_key=fault_key,
            theta_prev=theta,
        )

        # --- running average of the network mean (output theta_o) -----------
        if self.track_average:
            tt = state.step.astype(jnp.float32)
            mean = (lambda th: th.astype(jnp.float32)) if self.federated else (
                lambda th: th.astype(jnp.float32).mean(0)
            )
            theta_avg = jax.tree.map(
                lambda avg, th: (avg * tt + mean(th)) / (tt + 1.0),
                state.theta_avg,
                theta_new,
            )
        else:
            theta_avg = ()

        aux = {
            "losses": losses,
            "worst_loss": losses.max(),
            "mean_loss": losses.mean(),
            "lambda_mean": lam_new.mean(0) if lam_new.ndim == 2 else lam_new,
            "eta_theta": self.local.lr(state.opt),
        }
        if not self.federated:
            aux["consensus_err"] = _consensus_error(theta_new)
        if mask is not None:
            aux["participation"] = mask
        # jitted realized-bits meter: this round's measured wire traffic
        # (model payload + the dual's constant), no host-side masks needed;
        # faulted wires read the exchange's own delivered-bits meter out of
        # the post-mix consensus state instead of a degree formula
        aux["bits_realized"] = self.consensus.bits_realized(
            state.theta, state.step, mask, consensus_state=cons_new
        ) + jnp.float32(self.dual.bits_per_round())

        new_state = TrainerState(
            step=state.step + 1,
            theta=theta_new,
            lam=lam_new,
            opt=opt_new,
            consensus=cons_new,
            theta_avg=theta_avg,
            rng=rng,
        )
        return new_state, aux

    # ------------------------------------------------------------- utilities
    def network_mean(self, state: TrainerState):
        if self.federated:
            return jax.tree.map(lambda x: x.astype(jnp.float32), state.theta)
        return jax.tree.map(lambda x: x.astype(jnp.float32).mean(0), state.theta)

    def bits_per_round(self, state: TrainerState, per_iteration: bool = False,
                       *, mode: str = "max", step=None, mask=None) -> float:
        """Bits transmitted per communication round by the busiest node
        (model payload + dual traffic).

        One round covers ``local_steps`` gradient iterations;
        ``per_iteration=True`` divides by that, putting algorithms with
        different communication intervals (DRFA, AD-GDA-K) on equal footing.

        ``mode`` controls the dropout accounting of the model payload:
        ``"max"`` (default) bills the busiest-phase max degree — the upper
        bound provisioning must budget for; ``"expected"`` bills the
        participation-aware expected active degree (phase-averaged, times
        the (1-rate)^2 link-survival probability); ``"realized"`` bills
        round ``step``'s actual links under the concrete participation
        ``mask`` (e.g. ``aux["participation"]``).  The dual's m-float
        traffic stays at its upper bound in every mode — it is negligible
        next to the model payload and not worth a mask-aware estimate.

        With a fault model active, ``mode="realized"`` reads the exchange's
        in-graph delivered-bits meter out of ``state.consensus`` (last
        round's actual deliveries: drops unbilled, dups twice, resyncs
        dense) instead of a degree formula.
        """
        if mode == "realized" and getattr(self.consensus, "faults", None) is not None:
            meter = _fault_bits_meter(state.consensus)
            if meter is not None:
                bits = float(jnp.max(meter)) + self.dual.bits_per_round()
                if per_iteration:
                    bits /= self.local.local_steps
                return bits
        bits = (
            self.consensus.bits_per_round(state.theta, mode=mode, step=step, mask=mask)
            + self.dual.bits_per_round()
        )
        if per_iteration:
            bits /= self.local.local_steps
        return bits


def _consensus_error(theta_stacked) -> jax.Array:
    """Xi_theta = sum_i ||theta_i - theta_bar||^2 over all leaves."""
    err = 0.0
    for leaf in jax.tree_util.tree_leaves(theta_stacked):
        leaf = leaf.astype(jnp.float32)
        mean = leaf.mean(0, keepdims=True)
        err = err + jnp.sum((leaf - mean) ** 2)
    return err
