"""Distributionally robust optimization primitives (paper §3, eq. (3)).

The network objective is

    min_theta max_{lambda in simplex}  (1/m) sum_i [ lambda_i f_i(theta) + alpha r(lambda) ]

with r a strongly-concave regularizer.  This module provides:

* Euclidean projection onto the probability simplex (the projected ascent
  step in Algorithm 1 uses it).
* The chi^2 and KL regularizers of §3 (with their gradients via autodiff).
* The closed-form inner maximizer for the KL regularizer (used by the
  DR-DSGD baseline, Issaid et al. 2022).
* Worst-node / best-node metrics used throughout the paper's tables.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "project_simplex",
    "chi2_regularizer",
    "kl_regularizer",
    "make_regularizer",
    "kl_closed_form_weights",
    "dual_gradient",
    "Regularizer",
]


def project_simplex(v: jax.Array) -> jax.Array:
    """Euclidean projection of v onto the probability simplex.

    Sort-based algorithm (Held et al. 1974): O(m log m), jit/vmap friendly.
    """
    m = v.shape[-1]
    u = jnp.sort(v, axis=-1)[..., ::-1]
    css = jnp.cumsum(u, axis=-1) - 1.0
    ind = jnp.arange(1, m + 1, dtype=v.dtype)
    cond = u - css / ind > 0
    # rho = largest index where cond holds (guaranteed >= 1)
    rho = jnp.max(jnp.where(cond, ind, 0.0), axis=-1, keepdims=True)
    # gather css at rho-1
    theta = jnp.take_along_axis(css, rho.astype(jnp.int32) - 1, axis=-1) / rho
    return jnp.maximum(v - theta, 0.0)


@dataclasses.dataclass(frozen=True)
class Regularizer:
    """r(lambda): strongly-concave regularizer, with node-prior pi = n_i/n."""

    name: str
    fn: Callable[[jax.Array, jax.Array], jax.Array]

    def __call__(self, lam: jax.Array, prior: jax.Array) -> jax.Array:
        return self.fn(lam, prior)

    def grad(self, lam: jax.Array, prior: jax.Array) -> jax.Array:
        return jax.grad(self.fn)(lam, prior)


def _chi2(lam: jax.Array, prior: jax.Array) -> jax.Array:
    """-chi^2(lambda || prior) = -sum_i (lambda_i - pi_i)^2 / pi_i (concave)."""
    return -jnp.sum((lam - prior) ** 2 / prior)


def _kl(lam: jax.Array, prior: jax.Array) -> jax.Array:
    """-D_KL(lambda || prior) (concave); 0 log 0 := 0."""
    safe = jnp.where(lam > 0, lam, 1.0)
    return -jnp.sum(jnp.where(lam > 0, lam * jnp.log(safe / prior), 0.0))


chi2_regularizer = Regularizer("chi2", _chi2)
kl_regularizer = Regularizer("kl", _kl)

_REGS = {"chi2": chi2_regularizer, "kl": kl_regularizer}


def make_regularizer(name: str) -> Regularizer:
    if name not in _REGS:
        raise ValueError(f"unknown regularizer {name!r}; choose from {sorted(_REGS)}")
    return _REGS[name]


def kl_closed_form_weights(losses: jax.Array, prior: jax.Array, alpha: float) -> jax.Array:
    """Exact inner maximizer for the KL regularizer (DR-DSGD):

    lambda*_i  propto  pi_i * exp(f_i / alpha).
    """
    logits = jnp.log(prior) + losses / alpha
    return jax.nn.softmax(logits)


def dual_gradient(
    local_loss: jax.Array,
    node_index: jax.Array | int,
    lam: jax.Array,
    prior: jax.Array,
    alpha: float,
    regularizer: Regularizer,
) -> jax.Array:
    """grad_lambda g_i(theta, lambda) = f_i(theta) e_i + alpha grad r(lambda).

    Node i observes only its own loss; the regularizer gradient is global in
    lambda (which every node stores locally, size m).
    """
    m = lam.shape[-1]
    e_i = jax.nn.one_hot(node_index, m, dtype=lam.dtype)
    return local_loss * e_i + alpha * regularizer.grad(lam, prior)
