"""The wire layer: what bytes a consensus round puts on an edge, and the
neighbor-cache machinery that lets time-varying rounds ship only those bytes.

Two first-class objects factor every consensus implementation's traffic:

* :class:`WireFormat` — an ordered tuple of :class:`Lane` descriptors, one
  per state variable riding the edge.  Most consensus implementations ship
  exactly one lane: the packed compressed ``payload`` (static CHOCO
  rounds), a ``dense`` f32 tensor (exact/uncompressed gossip, the unpacked
  cross-check paths), or a ``hat-delta`` (the compressed residual that
  doubles as an incremental update to the receiver's mirror of the
  sender's public copy).  Multi-lane messages stack further lanes on the
  SAME edge of the SAME round — e.g. gradient tracking rides its tracker
  variable as a second compressed hat-delta lane — and every lane keeps
  its own NeighborCache mirror, digest, and fault-recovery state, so a
  corrupted tracker lane can never poison the theta mirror.

* :class:`UnionWirePlan` — the single wire program shared by *every* phase
  of a :class:`~repro.core.topology.TopologySchedule`: the union of all
  phases' exchange ops (deduplicated), plus per-phase weight banks indexed
  by ``t % P``.  Selecting a round's mixing weights becomes one
  ``dynamic_index`` into the banks instead of a ``lax.switch`` over
  whole per-phase wire programs at every mix site (the ROADMAP
  phase-switch-hoisting item), and — crucially — a receiver can keep a
  **NeighborCache** (one mirror of the sender's ``theta_hat`` per union op)
  that stays exact across phase changes, because every union edge carries
  the sender's compressed hat-delta every round.

Why the union, not per-phase re-sync: a cache that only covers the current
phase's in-neighbors must be re-synced with a full f32 hat exchange whenever
the phase changes — for a round-robin schedule that is *every round*, which
is exactly the f32 traffic this layer exists to remove.  Shipping the
(compressed, tiny) delta on every union edge instead keeps all mirrors
bit-identical to the sender's own ``theta_hat`` with no re-sync ever, at the
cost of the union degree rather than the phase degree.  Per *edge* the cost
is unchanged: one compressed payload.

The cache state itself is plain data — a tuple (one entry per union op) of
pytrees shaped exactly like ``theta_hat`` — stored in
:class:`~repro.core.gossip.CHOCOState` and threaded through checkpoints and
shardings like any other stacked state.  The executing side lives in
``repro.core.exchange`` (``choco_round_ppermute``'s time-varying path).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from repro.core.topology import PermutePlan

__all__ = [
    "Lane",
    "WireFormat",
    "PAYLOAD",
    "DENSE",
    "HAT_DELTA",
    "DIGEST",
    "HAT_RESYNC",
    "GT_LANES",
    "GT_PAYLOAD",
    "UnionWirePlan",
    "compile_union_wire",
    "init_neighbor_cache",
]


# ================================================================ WireFormat
@dataclasses.dataclass(frozen=True)
class Lane:
    """One state variable's slot in a per-edge message.

    ``kind`` is one of:

    * ``"payload"`` — the compressor's encoded representation (packed levels
      + signs + norms for the quantizers), the static CHOCO wire;
    * ``"dense"`` — the raw f32 tensor (exact consensus, federated model
      up/downloads, and the unpacked cross-check paths);
    * ``"hat-delta"`` — the compressed residual ``Q(theta - theta_hat)``
      shipped on every union edge of a time-varying round: the same bytes
      as ``payload``, but semantically an *increment* the receiver applies
      to its cached mirror of the sender's public copy;
    * ``"digest"`` — the 32-bit wraparound checksum of the sender's
      post-round ``theta_hat`` (one per leaf chunk) riding every hat-delta
      message on a faulted wire, letting the receiver verify its mirror
      *before* committing the delta (repro.core.faults);
    * ``"hat-resync"`` — the full ``theta_hat`` at its own dtype, shipped
      on an edge whose mirror diverged past the staleness bound S: dense
      bytes, but only on requested edges and subject to the same fault
      draws (+ exponential backoff on failure).

    ``name`` identifies *which* variable rides the lane ("model" for theta,
    "tracker" for the gradient-tracking y variable, "dual" for gossiped
    lambda, ...).  Per-lane bits accounting keys off the name: each lane of
    a multi-lane consensus bills its own payload/digest/resync bytes and
    keeps its own fault-recovery state (see
    ``ChocoConsensus.bits_per_lane`` / ``GradientTrackingConsensus``).
    """

    kind: str
    name: str = "model"

    def __str__(self) -> str:
        return self.kind if self.name == "model" else f"{self.name}:{self.kind}"


def _as_lanes(lanes) -> tuple:
    if isinstance(lanes, str):
        return (Lane(lanes),)
    if isinstance(lanes, Lane):
        return (lanes,)
    return tuple(Lane(l) if isinstance(l, str) else l for l in lanes)


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """The byte format of one per-edge message: an ordered tuple of
    :class:`Lane` descriptors, one per state variable on the wire.

    Single-lane formats (the module singletons below) behave exactly like
    the historical scalar tag: ``fmt.kind`` and ``str(fmt)`` give the lane
    kind, and identity checks against the singletons keep working.
    Multi-lane formats stack further variables on the *same* edges of the
    *same* round — gradient tracking ships ``(hat-delta[model],
    hat-delta[tracker])`` — and iterate/index like a tuple.

    This is a dispatch/label tag; the bits each lane puts on an edge are
    billed by ``gossip.payload_bits`` (algorithmic payload accounting) and
    measured by suite X (compiled-HLO collective bytes) — deliberately NOT
    duplicated here, where a third copy could drift from both.
    """

    lanes: tuple[Lane, ...]

    def __post_init__(self):
        object.__setattr__(self, "lanes", _as_lanes(self.lanes))
        if not self.lanes:
            raise ValueError("WireFormat needs at least one lane")

    @property
    def kind(self) -> str:
        """Single-lane compatibility accessor (the pre-lane ``kind`` tag)."""
        if len(self.lanes) != 1:
            raise ValueError(
                f"multi-lane format {self} has no single kind; iterate lanes"
            )
        return self.lanes[0].kind

    def __len__(self) -> int:
        return len(self.lanes)

    def __iter__(self):
        return iter(self.lanes)

    def __getitem__(self, i) -> Lane:
        return self.lanes[i]

    def __str__(self) -> str:  # row/label friendly
        return "+".join(str(l) for l in self.lanes)


PAYLOAD = WireFormat("payload")
DENSE = WireFormat("dense")
HAT_DELTA = WireFormat("hat-delta")
DIGEST = WireFormat("digest")
HAT_RESYNC = WireFormat("hat-resync")

#: The two-lane gradient-tracking wire: model hat-delta + tracker hat-delta
#: on every union edge, each lane with its own mirror/digest/resync state.
GT_LANES = WireFormat((Lane("hat-delta", "model"), Lane("hat-delta", "tracker")))
#: Static-topology twin: two packed payloads per edge, no mirrors needed.
GT_PAYLOAD = WireFormat((Lane("payload", "model"), Lane("payload", "tracker")))


# ============================================================= UnionWirePlan
@dataclasses.dataclass(frozen=True, eq=False)
class UnionWirePlan:
    """One wire program for all phases of a topology schedule.

    ``ops`` is the deduplicated union of every phase's
    :meth:`~repro.core.topology.PermutePlan.exchange_ops`; ``senders`` the
    matching sender maps (``senders[k][i]`` = node whose value node ``i``
    receives on op ``k``, −1 when none).  The per-phase banks are indexed by
    ``t % period``:

    * ``w_bank[p, k, i]`` — the static phase-``p`` receive weight
      ``W_p[i, senders[k][i]]`` (0 when op ``k`` is not part of phase ``p``);
    * ``self_bank[p, i]`` — ``W_p[i, i]``;
    * ``active[p, k, i]`` — 1.0 iff node ``i`` receives on op ``k`` in phase
      ``p`` (the edge-membership mask the masked-Metropolis reweighting runs
      over — identical edge set to ``masked_metropolis`` on the phase
      adjacency).
    """

    name: str
    num_nodes: int
    period: int
    ops: tuple[tuple[str, object], ...]
    senders: tuple[np.ndarray, ...]
    w_bank: np.ndarray  # [P, n_ops, m] f32
    self_bank: np.ndarray  # [P, m] f32
    active: np.ndarray  # [P, n_ops, m] f32

    @property
    def n_ops(self) -> int:
        return len(self.ops)

    @property
    def out_degree(self) -> np.ndarray:
        """[m] hat-delta payloads each node *sends* per round: one per
        (op, receiver) slot it feeds.  Every union edge carries a delta
        every round — that is what keeps the caches exact — so this is the
        honest per-round send count, including the rare duplicate pair that
        two matching phases share through distinct ops."""
        out = np.zeros((self.num_nodes,), np.int64)
        for snd in self.senders:
            js = snd[snd >= 0]
            np.add.at(out, js, 1)
        return out

    @property
    def max_out_degree(self) -> int:
        """Busiest sender's per-round payload count (bits accounting)."""
        return int(self.out_degree.max()) if self.n_ops else 0

    def realized_out_degree(self, mask) -> float:
        """Busiest *alive* sender's payload count under a participation
        mask: dead nodes send nothing (their residual is zero and the alive
        bit that rides each exchange tells receivers to skip the update)."""
        alive = np.asarray(mask, np.float64).reshape(-1)
        return float((alive * self.out_degree).max())

    def realized_out_degree_traced(self, mask):
        """The jittable form of :meth:`realized_out_degree` — used by the
        trainer's per-round ``bits_realized`` aux without host-side masks."""
        import jax.numpy as jnp

        out = jnp.asarray(self.out_degree, jnp.float32)
        if mask is None:
            return out.max()
        return (mask.astype(jnp.float32) * out).max()


def compile_union_wire(plans: Sequence[PermutePlan], name: str | None = None) -> UnionWirePlan:
    """Union of per-phase :class:`~repro.core.topology.PermutePlan` wire
    programs (``compile_schedule_plans`` output) into one
    :class:`UnionWirePlan`.  Ops are deduplicated by their exchange key
    (normalized shift value, or the exact (src, dst) pair set), first-seen
    order — so a single-phase schedule round-trips to its own plan ops."""
    plans = tuple(plans)
    if not plans:
        raise ValueError("compile_union_wire needs at least one phase plan")
    m = plans[0].num_nodes
    if any(p.num_nodes != m for p in plans):
        raise ValueError("all phase plans must share num_nodes")

    ops: list[tuple[str, object]] = []
    senders: list[np.ndarray] = []
    index: dict = {}
    phase_ops: list[list[int]] = []
    for plan in plans:
        idxs = []
        for op, snd in zip(plan.exchange_ops(), plan.sender_maps()):
            key = (op[0], op[1] if op[0] == "shift" else tuple(op[1]))
            if key not in index:
                index[key] = len(ops)
                ops.append(op)
                senders.append(np.asarray(snd, np.int64))
            idxs.append(index[key])
        phase_ops.append(idxs)

    period, n = len(plans), len(ops)
    w_bank = np.zeros((period, n, m), np.float32)
    self_bank = np.zeros((period, m), np.float32)
    active = np.zeros((period, n, m), np.float32)
    for p, plan in enumerate(plans):
        w_full = plan.mixing_matrix()
        self_bank[p] = np.diag(w_full).astype(np.float32)
        for k in phase_ops[p]:
            snd = senders[k]
            i = np.nonzero(snd >= 0)[0]
            active[p, k, i] = 1.0
            w_bank[p, k, i] = w_full[i, snd[i]].astype(np.float32)
    return UnionWirePlan(
        name or "+".join(p.name for p in plans), m, period,
        tuple(ops), tuple(senders), w_bank, self_bank, active,
    )


def init_neighbor_cache(theta_hat: Any, n_ops: int) -> tuple:
    """Fresh NeighborCache state: one zero mirror of ``theta_hat`` per union
    op.  Exact at init because ``theta_hat`` itself initializes to zeros, and
    kept exact thereafter by applying each received hat-delta with the same
    arithmetic the sender applies to its own hat (see
    ``exchange._round_leaf_cached``).

    Multi-lane rounds call this once per lane: each lane's CHOCOState
    carries its *own* mirror tuple (and, under faults, its own FaultState),
    so lanes verify, go stale, and resync independently."""
    import jax
    import jax.numpy as jnp

    return tuple(
        jax.tree.map(jnp.zeros_like, theta_hat) for _ in range(n_ops)
    )
