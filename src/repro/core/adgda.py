"""AD-GDA — Agnostic Decentralized GDA with compressed communication (Alg. 1).

The trainer is *model agnostic*: it consumes a per-node loss function
``loss_fn(params, batch, rng) -> scalar`` and maintains stacked state (every
leaf has a leading node axis of size m).  Under ``jax.jit`` with the
production mesh the node axis is sharded over ``data`` (× ``pod``), which
turns the gossip into collective-permutes and the vmapped local update into
ordinary data parallelism — see ``repro/launch/train.py``.

One step (paper Algorithm 1):

  theta_i^{t+1/2} = theta_i - eta_th * lam_i[i] * grad f_i(theta_i)   # descent
  lam_i^{t+1/2}   = P_simplex(lam_i + eta_lam * (f_i e_i + alpha grad r(lam_i)))
  theta, hat, s   = CHOCO round (compressed gossip)                    # wire
  lam_i^{t+1}     = sum_j w_ij lam_j^{t+1/2}                           # wire (m floats)

Notes kept faithful to the paper:
* single loop — primal and dual updated in parallel from the same oracle call;
* the dual gossip is uncompressed (m ≪ d);
* output solution is the running average of the network mean (Theorem 4.1);
  we track it with an O(1)-memory running mean.

Since the composable-trainer refactor this module is a *factory*:
:func:`adgda_trainer` assembles a :class:`repro.core.trainer.DecentralizedTrainer`
from an :class:`ADGDAConfig` (oracle × ``repro.optim`` optimizer × projected-
ascent dual × CHOCO consensus).  The :class:`ADGDA` class is a deprecated
shim with the pre-refactor signature; its trajectories are pinned to the
seed implementation bit-for-bit (tests/test_trainer_parity.py).
"""
from __future__ import annotations

import dataclasses
import warnings

import jax.numpy as jnp

from repro.core import dro
from repro.core.compression import Compressor, make_compressor
from repro.core.topology import (
    Topology,
    TopologySchedule,
    make_topology,
    make_topology_schedule,
)
from repro.core.trainer import (
    ChocoConsensus,
    DecentralizedTrainer,
    FrozenPrior,
    GradientTrackingConsensus,
    LocalUpdate,
    LossFn,
    ProjectedAscent,
    TrainerState,
)
from repro.optim import adam, make_schedule, sgd

__all__ = ["ADGDAConfig", "ADGDAState", "ADGDA", "adgda_trainer"]

# Deprecated alias: the composed trainer's state replaces the monolithic
# ADGDAState (the hand-rolled ``momentum`` field became the optimizer's
# ``opt: OptState``; ``choco`` became the generic ``consensus`` slot).
ADGDAState = TrainerState


@dataclasses.dataclass(frozen=True)
class ADGDAConfig:
    num_nodes: int = 8
    topology: str = "ring"
    topology_schedule: str | None = None  # time-varying wire: a
    # make_topology_schedule spec ("roundrobin:ring,torus", "matching[:P]",
    # or any static topology name).  None -> the static ``topology``.
    dropout: float = 0.0  # per-round Bernoulli node-dropout probability; > 0
    # wraps the topology (or schedule) in BernoulliDropout — dropped nodes
    # skip their local update and gossip contribution but keep their CHOCO
    # trackers consistent, and Metropolis weights are rescaled per round so
    # W(t) stays doubly stochastic on the surviving subgraph
    topology_p: float | None = None  # edge probability for erdos_renyi
    topology_seed: int = 0  # graph-sampling seed (erdos_renyi, matchings)
    compressor: str = "q8b"
    regularizer: str = "chi2"
    alpha: float = 0.01
    eta_theta: float = 0.1
    eta_lambda: float = 0.01
    lr_decay: float = 1.0  # eta_t = lr_decay^t * eta_0 (paper writes r^{-t}, intent is decay, r=0.995)
    gamma: float | str | None = None  # None -> 0.5*delta; "theory" -> Thm 4.1 value
    momentum: float = 0.0
    gossip_backend: str = "rolled"  # exchange implementation: "rolled" (the
    # stacked-array simulation, the reference oracle) or "ppermute" (the
    # mesh-native SPMD substrate — shard_map + lax.ppermute moving only
    # degree-many compressed messages between graph neighbors; requires the
    # mesh kwarg of adgda_trainer / steps.make_trainer)
    packed_gossip: bool = True
    fused_gossip: bool = False  # dispatch the theta gossip to the single-pass
    # Pallas fast path (kernels/choco_fused.py).  Requires a compressor that
    # advertises ``supports_fused_round`` (e.g. "kq4b"/"kq8b") and a
    # circulant topology; other combinations silently use the reference path
    robust: bool = True  # False -> CHOCO-SGD (fixed lambda = prior)
    track_average: bool = True  # f32 running mean of the network mean (theta_o,
    # Thm 4.1); disable at transformer scale to avoid an extra f32 model copy
    microbatches: int = 1  # gradient accumulation: scan the oracle over k
    # microbatches so only one microbatch's activations are live at a time
    # (same stochastic gradient, Algorithm 1 unchanged; see EXPERIMENTS §Perf)
    grad_accum_dtype: str = "float32"  # accumulator dtype ("bfloat16" halves it)
    local_steps: int = 1  # K local optimizer steps between gossip rounds — the
    # paper's §6 "natural extension" (event-triggered communication): the
    # collective term drops ~K x at the cost of extra consensus drift.
    # Batch leaves must carry K x the per-node samples.  Composes with any
    # optimizer/momentum (the optimizer state is carried in the trainer
    # state); still mutually exclusive with microbatches > 1.
    consensus: str = "choco"  # "choco" (plain CHOCO gossip) or "gt"
    # (gradient tracking, arXiv 2405.00965): "gt" gossips a second
    # CHOCO-compressed tracker variable on lane 2 of the same wire round,
    # cancelling the client drift that large local_steps induce under
    # heterogeneous data — 2x the per-round bits, aimed at K >> 1.
    tracker_gamma: float | None = None  # consensus step size for the tracker
    # lane (None -> same gamma resolution as the model lane)
    tracker_compressor: str | None = None  # compression level for the tracker
    # lane only (consensus="gt"), e.g. "kq2b" under a "kq4b" model lane: the
    # tracker tolerates coarser quantization (arXiv 2405.00965), shaving the
    # second lane's bits.  None -> the model compressor on both lanes
    # (bit-identical to the single-compressor wire)
    fault_spec: str | None = None  # wire-fault injection, e.g.
    # "drop:0.05,corrupt:0.01,stale:2" (repro.core.faults.parse_fault_spec):
    # per-(edge, round) message drop/corrupt/dup/delay at the exchange
    # boundary, with digest-based divergence detection and staleness-bounded
    # self-healing resync.  None (or an all-zero spec) keeps today's perfect
    # wire bit-identically.
    spmd_axis_name: tuple | str | None = None  # mesh axes the node vmap maps
    # to — lets sharding constraints inside the model (context-parallel
    # attention) apply under the per-node vmap
    optimizer: str = "sgd"  # "sgd" (momentum/nesterov) or "adam"
    schedule: str = "exp"  # "const" | "exp" (lr_decay^t, the paper's) | "cosine"
    warmup: int = 0  # linear LR warmup steps (0 = off)
    total_steps: int = 1000  # horizon for the cosine schedule
    nesterov: bool = False  # Nesterov momentum (sgd only)

    def build(self) -> tuple[Topology | TopologySchedule, Compressor]:
        """(topology-or-schedule, compressor) for the consensus layer.

        Returns a plain static :class:`Topology` unless ``topology_schedule``
        or ``dropout`` asks for time variation — so the default configuration
        keeps the circulant/packed/fused fast paths and stays bit-identical
        to the pre-schedule trainer.
        """
        comp = make_compressor(self.compressor)
        spec = self.topology_schedule or self.topology
        kw = {}
        if spec == "erdos_renyi" and self.topology_p is not None:
            kw["p"] = self.topology_p
        if self.topology_schedule is not None or self.dropout > 0.0:
            sched = make_topology_schedule(
                spec, self.num_nodes, dropout=self.dropout,
                seed=self.topology_seed, **kw,
            )
            return sched, comp
        if self.topology == "erdos_renyi":
            kw.setdefault("seed", self.topology_seed)
        return make_topology(self.topology, self.num_nodes, **kw), comp

    def make_optimizer(self):
        """(optimizer, schedule) from the config — the primal update rule."""
        sched = make_schedule(
            self.schedule, self.eta_theta, decay=self.lr_decay,
            total_steps=self.total_steps, warmup=self.warmup,
        )
        if self.optimizer == "sgd":
            return sgd(sched, momentum=self.momentum, nesterov=self.nesterov), sched
        if self.optimizer == "adam":
            if self.momentum != 0.0 or self.nesterov:
                raise ValueError(
                    "momentum/nesterov only apply to optimizer='sgd'; adam's "
                    "first moment is its b1 decay (fixed at the adam() default)"
                )
            return adam(sched), sched
        raise ValueError(f"unknown optimizer {self.optimizer!r}; choose sgd or adam")


def adgda_trainer(config: ADGDAConfig, loss_fn: LossFn, prior=None, *,
                  mesh=None, node_axes="data") -> DecentralizedTrainer:
    """Compose AD-GDA (paper Algorithm 1) as a :class:`DecentralizedTrainer`.

    ``robust=False`` yields CHOCO-SGD (dual frozen at the prior) — same wire,
    same oracle, so the comparison isolates exactly the robustness delta.

    ``mesh``/``node_axes`` place the node shards for
    ``config.gossip_backend == "ppermute"`` (see ``launch.mesh``); both the
    model consensus and the lambda gossip then run on the neighbor-exchange
    substrate.
    """
    m = config.num_nodes
    topology, compressor = config.build()
    prior = jnp.full((m,), 1.0 / m) if prior is None else jnp.asarray(prior)
    optimizer, schedule = config.make_optimizer()

    local = LocalUpdate(
        optimizer=optimizer,
        schedule=schedule,
        microbatches=config.microbatches,
        local_steps=config.local_steps,
        grad_accum_dtype=config.grad_accum_dtype,
        spmd_axis_name=config.spmd_axis_name,
    )
    if config.tracker_compressor is not None and config.consensus != "gt":
        raise ValueError(
            "tracker_compressor only applies to consensus='gt' (there is no "
            f"tracker lane under consensus={config.consensus!r})"
        )
    if config.consensus == "gt":
        consensus = GradientTrackingConsensus(
            topology, compressor, config.gamma,
            tracker_gamma=config.tracker_gamma,
            tracker_compressor=config.tracker_compressor,
            packed=config.packed_gossip, fused=config.fused_gossip,
            backend=config.gossip_backend, mesh=mesh, node_axes=node_axes,
            faults=config.fault_spec,
        )
    elif config.consensus == "choco":
        consensus = ChocoConsensus(
            topology, compressor, config.gamma,
            packed=config.packed_gossip, fused=config.fused_gossip,
            backend=config.gossip_backend, mesh=mesh, node_axes=node_axes,
            faults=config.fault_spec,
        )
    else:
        raise ValueError(
            f"unknown consensus {config.consensus!r}; choose choco or gt"
        )
    # the dual's own gossip: a static schedule unwraps to its phase topology
    # (plain mix_stacked fast path).  On the rolled backend a time-varying
    # schedule is kept whole and the trainer threads the per-round dense
    # W(t) into dual.update; on the ppermute backend the lambda gossip rides
    # the consensus's wire_mix instead — static topologies reuse the model's
    # neighbor permutes, time-varying rounds select their weights from the
    # union wire's per-phase banks (no dense matrix anywhere).
    dual_topology = (
        topology.topology_at(0)
        if isinstance(topology, TopologySchedule) and topology.is_static
        else topology
    )
    if config.robust:
        # faults also route the dual through wire_mix: the lambda gossip
        # rides the same physical (faulted) messages as the model
        wire_dual = config.gossip_backend == "ppermute" or consensus.faults is not None
        dual = ProjectedAscent(
            prior=prior,
            alpha=config.alpha,
            eta_lambda=config.eta_lambda,
            regularizer=dro.make_regularizer(config.regularizer),
            topology=dual_topology,
            mix_fn=consensus.wire_mix if wire_dual else None,
        )
    else:
        dual = FrozenPrior(prior=prior)
    return DecentralizedTrainer(
        loss_fn,
        num_nodes=m,
        local=local,
        dual=dual,
        consensus=consensus,
        prior=prior,
        track_average=config.track_average,
        config=config,
    )


class ADGDA(DecentralizedTrainer):
    """Deprecated shim: the pre-refactor monolithic trainer's signature.

    ``ADGDA(config, loss_fn, prior)`` now composes a
    :class:`DecentralizedTrainer` (see :func:`adgda_trainer`); ``init`` /
    ``step`` / ``network_mean`` / ``bits_per_round`` behave identically.
    """

    def __init__(self, config: ADGDAConfig, loss_fn: LossFn, prior=None):
        warnings.warn(
            "repro.core.ADGDA is deprecated; compose a trainer with "
            "repro.core.adgda.adgda_trainer(config, loss_fn) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        self._init_as(adgda_trainer(config, loss_fn, prior))
        self.regularizer = dro.make_regularizer(config.regularizer)
