"""AD-GDA — Agnostic Decentralized GDA with compressed communication (Alg. 1).

The trainer is *model agnostic*: it consumes a per-node loss function
``loss_fn(params, batch, rng) -> scalar`` and maintains stacked state (every
leaf has a leading node axis of size m).  Under ``jax.jit`` with the
production mesh the node axis is sharded over ``data`` (× ``pod``), which
turns the gossip into collective-permutes and the vmapped local update into
ordinary data parallelism — see ``repro/launch/train.py``.

One step (paper Algorithm 1):

  theta_i^{t+1/2} = theta_i - eta_th * lam_i[i] * grad f_i(theta_i)   # descent
  lam_i^{t+1/2}   = P_simplex(lam_i + eta_lam * (f_i e_i + alpha grad r(lam_i)))
  theta, hat, s   = CHOCO round (compressed gossip)                    # wire
  lam_i^{t+1}     = sum_j w_ij lam_j^{t+1/2}                           # wire (m floats)

Notes kept faithful to the paper:
* single loop — primal and dual updated in parallel from the same oracle call;
* the dual gossip is uncompressed (m ≪ d);
* output solution is the running average of the network mean (Theorem 4.1);
  we track it with an O(1)-memory running mean.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dro
from repro.core.compression import Compressor, make_compressor
from repro.core.gossip import (
    BLOCK_SCAN_ELEMS,
    CHOCOState,
    _scan_plan,
    choco_init,
    choco_round,
    mix_stacked,
    payload_bits,
)
from repro.core.topology import Topology, make_topology

__all__ = ["ADGDAConfig", "ADGDAState", "ADGDA"]

LossFn = Callable[[Any, Any, jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class ADGDAConfig:
    num_nodes: int = 8
    topology: str = "ring"
    compressor: str = "q8b"
    regularizer: str = "chi2"
    alpha: float = 0.01
    eta_theta: float = 0.1
    eta_lambda: float = 0.01
    lr_decay: float = 1.0  # eta_t = lr_decay^t * eta_0 (paper writes r^{-t}, intent is decay, r=0.995)
    gamma: float | str | None = None  # None -> 0.5*delta; "theory" -> Thm 4.1 value
    momentum: float = 0.0
    packed_gossip: bool = True
    fused_gossip: bool = False  # dispatch the theta gossip to the single-pass
    # Pallas fast path (kernels/choco_fused.py).  Requires a compressor that
    # advertises ``supports_fused_round`` (e.g. "kq4b"/"kq8b") and a
    # circulant topology; other combinations silently use the reference path
    robust: bool = True  # False -> CHOCO-SGD (fixed lambda = prior)
    track_average: bool = True  # f32 running mean of the network mean (theta_o,
    # Thm 4.1); disable at transformer scale to avoid an extra f32 model copy
    microbatches: int = 1  # gradient accumulation: scan the oracle over k
    # microbatches so only one microbatch's activations are live at a time
    # (same stochastic gradient, Algorithm 1 unchanged; see EXPERIMENTS §Perf)
    grad_accum_dtype: str = "float32"  # accumulator dtype ("bfloat16" halves it)
    local_steps: int = 1  # K local SGD steps between gossip rounds — the
    # paper's §6 "natural extension" (event-triggered communication): the
    # collective term drops ~K x at the cost of extra consensus drift.
    # Batch leaves must carry K x the per-node samples; mutually exclusive
    # with microbatches > 1.
    spmd_axis_name: tuple | str | None = None  # mesh axes the node vmap maps
    # to — lets sharding constraints inside the model (context-parallel
    # attention) apply under the per-node vmap

    def build(self) -> tuple[Topology, Compressor]:
        return make_topology(self.topology, self.num_nodes), make_compressor(self.compressor)


class ADGDAState(NamedTuple):
    step: jax.Array
    theta: Any  # stacked pytree [m, ...]
    lam: jax.Array  # [m, m] — each node's copy of the dual variable
    choco: CHOCOState
    momentum: Any  # stacked pytree [m, ...] (zeros when momentum == 0)
    theta_avg: Any  # running mean over time of the network mean (theta_o)
    rng: jax.Array


class ADGDA:
    """Functional trainer: ``state = trainer.init(...)``; ``state, aux = trainer.step(...)``."""

    def __init__(self, config: ADGDAConfig, loss_fn: LossFn, prior: jax.Array | None = None):
        self.config = config
        self.loss_fn = loss_fn
        self.topology, self.compressor = config.build()
        m = config.num_nodes
        self.prior = jnp.full((m,), 1.0 / m) if prior is None else jnp.asarray(prior)
        self.regularizer = dro.make_regularizer(config.regularizer)
        # provisional gamma until init()/step_impl() see the real leaf sizes
        self.gamma = self._resolve_gamma(4096)

    @staticmethod
    def _encode_dim(theta) -> int:
        """Largest per-node encode size the gossip layer will actually run on
        a *stacked* pytree — the dimension the compressor's contraction
        factor delta depends on.  Mirrors ``gossip._scan_plan``'s chunking
        exactly (a chunk can exceed BLOCK_SCAN_ELEMS when the leaf has no
        suitable divisor, or the whole leaf is encoded when no plan exists)."""
        best = 1
        for leaf in jax.tree_util.tree_leaves(theta):
            inner = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
            plan = _scan_plan(leaf.shape, inner, BLOCK_SCAN_ELEMS)
            best = max(best, inner if plan is None else inner // plan[1])
        return best

    def _resolve_gamma(self, d: int) -> float:
        """Consensus step size gamma for a model with d parameters.

        Gamma trades consensus speed against compression-noise injection; the
        right value scales with the compressor's contraction factor delta,
        which for quantization depends on the dimension d being compressed
        (delta = 1/tau, tau = 1 + min(d/2^2b, sqrt(d)/2^b) — paper eq. (2)).
        Resolution order:

        * ``config.gamma == "theory"`` — the Theorem 4.1 value
          rho^2 delta / (16 rho + rho^2 + 4 beta^2 + 2 rho beta^2 - 8 rho delta):
          provably convergent but very conservative in practice;
        * ``config.gamma`` a number — used verbatim (the paper grid-searches
          gamma per compression level, §5.1.1);
        * ``config.gamma is None`` — 0.5 * delta(d), a robust default across
          our experiments.

        Called with a 4096-element placeholder at construction, then from
        ``init()`` and again at every ``step_impl()`` trace with the size of
        the largest per-leaf encode of the actual pytree.  The compressor contracts *leaf-wise* (and
        the gossip layer chunks leaves above BLOCK_SCAN_ELEMS), so the
        dimension that matters is the largest single encode — the smallest
        delta any leaf sees — not the total parameter count.
        """
        delta = getattr(self.compressor, "delta", 1.0)
        if hasattr(self.compressor, "delta_for"):
            delta = self.compressor.delta_for(max(int(d), 1))
        if self.config.gamma == "theory":
            return self.topology.consensus_step_size(max(delta, 1e-3))
        if self.config.gamma is not None:
            return float(self.config.gamma)
        return 0.5 * max(delta, 1e-3)

    # ------------------------------------------------------------------ init
    def init(self, params: Any, rng: jax.Array) -> ADGDAState:
        m = self.config.num_nodes
        stacked = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m,) + p.shape).copy(), params)
        # re-resolve gamma from the actual params pytree (the construction-
        # time value used a placeholder d).  step_impl() recomputes this from
        # the state's own leaf shapes at trace time, so a step() traced
        # without init() still gets the right value; this assignment just
        # keeps ``trainer.gamma`` introspectable.
        self.gamma = self._resolve_gamma(self._encode_dim(stacked))
        lam = jnp.broadcast_to(self.prior[None], (m, m)).copy()
        return ADGDAState(
            step=jnp.zeros((), jnp.int32),
            theta=stacked,
            lam=lam,
            choco=choco_init(stacked),
            momentum=jax.tree.map(jnp.zeros_like, stacked) if self.config.momentum > 0 else (),
            theta_avg=(
                jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params)
                if self.config.track_average
                else ()
            ),
            # defensive copy: step() donates its input state, which would
            # otherwise delete the caller's key buffer
            rng=jnp.array(rng, copy=True),
        )

    # ------------------------------------------------------------------ step
    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def step(self, state: ADGDAState, batch: Any) -> tuple[ADGDAState, dict]:
        return self.step_impl(state, batch)

    def step_impl(self, state: ADGDAState, batch: Any) -> tuple[ADGDAState, dict]:
        """Unjitted Algorithm-1 step — lower/compile with custom shardings via
        ``jax.jit(trainer.step_impl, in_shardings=...)`` (see launch/dryrun.py)."""
        cfg = self.config
        m = cfg.num_nodes
        rng, gossip_key, *node_keys = jax.random.split(state.rng, m + 2)
        node_keys = jnp.stack(node_keys)

        t = state.step.astype(jnp.float32)
        eta_th = cfg.eta_theta * jnp.power(cfg.lr_decay, t)

        # node i weights its gradient by its own dual coordinate lam_i[i],
        # normalized by the prior so that lam == prior recovers plain SGD
        # (paper §5.2.2).  CHOCO-SGD (robust=False) keeps scale 1.
        if cfg.robust:
            scale = (jnp.diagonal(state.lam) / self.prior).astype(jnp.float32)
        else:
            scale = jnp.ones((m,), jnp.float32)

        # --- K local steps between gossip rounds (paper §6 extension) ------
        if cfg.local_steps > 1:
            assert cfg.microbatches == 1 and cfg.momentum == 0.0, (
                "local_steps composes with neither microbatches nor momentum"
            )
            K = cfg.local_steps

            def to_k(leaf):  # [m, K*b, ...] -> [K, m, b, ...]
                assert leaf.shape[1] % K == 0, (
                    f"per-node batch {leaf.shape[1]} not divisible by local_steps {K}"
                )
                return leaf.reshape((m, K, leaf.shape[1] // K) + leaf.shape[2:]).swapaxes(0, 1)

            kb = jax.tree.map(to_k, batch)

            def local_body(theta, mbatch):
                l, g = jax.vmap(
                    jax.value_and_grad(self.loss_fn), spmd_axis_name=cfg.spmd_axis_name
                )(theta, mbatch, node_keys)
                theta = jax.tree.map(
                    lambda p, gg: (
                        p.astype(jnp.float32)
                        - eta_th
                        * gg.astype(jnp.float32)
                        * scale.reshape((m,) + (1,) * (gg.ndim - 1))
                    ).astype(p.dtype),
                    theta,
                    g,
                )
                return theta, l

            theta_half, losses_k = jax.lax.scan(local_body, state.theta, kb)
            losses = losses_k.mean(0)
            return self._finish_round(
                state, theta_half, losses, (), rng, gossip_key, eta_th
            )

        # --- local oracle: per-node loss and gradient ---------------------
        if cfg.microbatches > 1:
            k = cfg.microbatches
            acc_dt = jnp.dtype(cfg.grad_accum_dtype)

            def to_mb(leaf):  # [m, b, ...] -> [k, m, b/k, ...]
                assert leaf.shape[1] % k == 0, (
                    f"per-node batch {leaf.shape[1]} not divisible by microbatches {k}"
                )
                return leaf.reshape((m, k, leaf.shape[1] // k) + leaf.shape[2:]).swapaxes(0, 1)

            mb = jax.tree.map(to_mb, batch)

            def mb_body(carry, mbatch):
                acc_l, acc_g = carry
                l, g = jax.vmap(
                    jax.value_and_grad(self.loss_fn), spmd_axis_name=cfg.spmd_axis_name
                )(state.theta, mbatch, node_keys)
                acc_g = jax.tree.map(
                    lambda a, gg: a + (gg.astype(acc_dt) / k), acc_g, g
                )
                return (acc_l + l / k, acc_g), None

            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), state.theta)
            (losses, grads), _ = jax.lax.scan(
                mb_body, (jnp.zeros((m,), jnp.float32), zeros_g), mb
            )
        else:
            losses, grads = jax.vmap(
                jax.value_and_grad(self.loss_fn), spmd_axis_name=cfg.spmd_axis_name
            )(state.theta, batch, node_keys)

        # --- primal descent half-step --------------------------------------
        def sgd(g, mom):
            g = g.astype(jnp.float32) * scale.reshape((m,) + (1,) * (g.ndim - 1))
            if cfg.momentum > 0:
                mom = cfg.momentum * mom + g
                g = mom
            return g, mom

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        if cfg.momentum > 0:
            flat_m = tdef.flatten_up_to(state.momentum)
            stepped = [sgd(g, mo) for g, mo in zip(flat_g, flat_m)]
            update = jax.tree_util.tree_unflatten(tdef, [s[0] for s in stepped])
            momentum = jax.tree_util.tree_unflatten(tdef, [s[1] for s in stepped])
        else:
            stepped = [sgd(g, None) for g in flat_g]
            update = jax.tree_util.tree_unflatten(tdef, [s[0] for s in stepped])
            momentum = ()
        theta_half = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - eta_th * u).astype(p.dtype),
            state.theta,
            update,
        )
        return self._finish_round(state, theta_half, losses, momentum, rng, gossip_key, eta_th)

    def _finish_round(self, state, theta_half, losses, momentum, rng, gossip_key, eta_th):
        """Dual ascent + compressed consensus + bookkeeping (shared by the
        single-step, microbatched and local-steps oracles)."""
        cfg = self.config
        m = cfg.num_nodes
        eta_la = cfg.eta_lambda

        # --- dual projected ascent half-step --------------------------------
        if cfg.robust:
            node_ids = jnp.arange(m)
            dual_grads = jax.vmap(
                lambda f, i, l: dro.dual_gradient(
                    f, i, l, self.prior, cfg.alpha, self.regularizer
                )
            )(losses, node_ids, state.lam)
            lam_half = jax.vmap(dro.project_simplex)(state.lam + eta_la * dual_grads)
            lam_new = mix_stacked(lam_half, self.topology)  # uncompressed gossip
        else:
            lam_new = state.lam

        # --- compressed consensus on theta ----------------------------------
        # gamma is re-resolved from the traced state's own (static) leaf
        # shapes, so it is correct even if step() was traced without init()
        gamma = self._resolve_gamma(self._encode_dim(theta_half))
        theta_new, choco_new = choco_round(
            theta_half,
            state.choco,
            self.topology,
            gamma,
            self.compressor,
            gossip_key,
            packed=cfg.packed_gossip,
            fused=cfg.fused_gossip,
        )

        # --- running average of the network mean (output theta_o) -----------
        if cfg.track_average:
            tt = state.step.astype(jnp.float32)
            theta_avg = jax.tree.map(
                lambda avg, th: (avg * tt + th.astype(jnp.float32).mean(0)) / (tt + 1.0),
                state.theta_avg,
                theta_new,
            )
        else:
            theta_avg = ()

        aux = {
            "losses": losses,
            "worst_loss": losses.max(),
            "mean_loss": losses.mean(),
            "lambda_mean": lam_new.mean(0),
            "consensus_err": _consensus_error(theta_new),
            "eta_theta": eta_th,
        }
        new_state = ADGDAState(
            step=state.step + 1,
            theta=theta_new,
            lam=lam_new,
            choco=choco_new,
            momentum=momentum,
            theta_avg=theta_avg,
            rng=rng,
        )
        return new_state, aux

    # ------------------------------------------------------------- utilities
    def network_mean(self, state: ADGDAState):
        return jax.tree.map(lambda x: x.astype(jnp.float32).mean(0), state.theta)

    def bits_per_round(self, state: ADGDAState) -> float:
        """Bits transmitted per round by the busiest node (theta + lambda)."""
        theta_bits = payload_bits(self.compressor, state.theta, self.topology)
        lam_bits = 32.0 * self.config.num_nodes * self.topology.max_degree
        return theta_bits + lam_bits


def _consensus_error(theta_stacked) -> jax.Array:
    """Xi_theta = sum_i ||theta_i - theta_bar||^2 over all leaves."""
    err = 0.0
    for leaf in jax.tree_util.tree_leaves(theta_stacked):
        leaf = leaf.astype(jnp.float32)
        mean = leaf.mean(0, keepdims=True)
        err = err + jnp.sum((leaf - mean) ** 2)
    return err
