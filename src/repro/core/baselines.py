"""Baselines the paper compares against (Table 1, §5.2).

* CHOCO-SGD (Koloskova et al. 2019) — standard (non-robust) decentralized SGD
  with compressed gossip.  Obtained from :class:`repro.core.adgda.ADGDA` with
  ``robust=False`` (fixed lambda = prior); no separate code path so the
  comparison isolates exactly the distributional-robustness delta.

* DR-DSGD (Issaid et al. 2022) — decentralized distributionally robust SGD
  restricted to the KL regularizer, for which the inner max has the closed
  form lambda_i ∝ pi_i exp(f_i / alpha).  Uncompressed gossip.  The closed
  form needs the normalizer sum_j pi_j exp(f_j/alpha); we obtain it with one
  scalar all-reduce per round (the original paper gossips it — identical in
  expectation, and the scalar is 32 bits so the accounting difference is nil).

* DRFA (Deng et al. 2021) — federated (star topology) distributionally robust
  averaging: each round the server samples |U| = ceil(m/2) clients according
  to lambda, clients run K local SGD steps, the server averages the returned
  models and periodically updates lambda by projected ascent on the observed
  losses.

All trainers share the ADGDA interface: ``init(params, rng)``,
``step(state, batch) -> (state, aux)``, ``network_mean(state)``,
``bits_per_round(state)`` — so the communication-efficiency benchmark
(paper Fig. 5) treats them uniformly.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dro
from repro.core.adgda import ADGDA, ADGDAConfig, LossFn
from repro.core.gossip import mix_stacked, payload_bits
from repro.core.compression import Identity
from repro.core.topology import make_topology

__all__ = ["choco_sgd", "DRDSGD", "DRDSGDConfig", "DRFA", "DRFAConfig"]


def choco_sgd(config: ADGDAConfig, loss_fn: LossFn, prior=None) -> ADGDA:
    """CHOCO-SGD = AD-GDA with the dual frozen at the prior."""
    return ADGDA(dataclasses.replace(config, robust=False), loss_fn, prior)


# --------------------------------------------------------------------- DR-DSGD
@dataclasses.dataclass(frozen=True)
class DRDSGDConfig:
    num_nodes: int = 8
    topology: str = "ring"
    alpha: float = 6.0  # KL temperature (paper uses alpha = 6)
    eta_theta: float = 0.1
    lr_decay: float = 1.0
    momentum: float = 0.0


class DRDSGDState(NamedTuple):
    step: jax.Array
    theta: Any
    momentum: Any
    theta_avg: Any
    rng: jax.Array


class DRDSGD:
    def __init__(self, config: DRDSGDConfig, loss_fn: LossFn, prior=None):
        self.config = config
        self.loss_fn = loss_fn
        self.topology = make_topology(config.topology, config.num_nodes)
        m = config.num_nodes
        self.prior = jnp.full((m,), 1.0 / m) if prior is None else jnp.asarray(prior)

    def init(self, params: Any, rng: jax.Array) -> DRDSGDState:
        m = self.config.num_nodes
        stacked = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m,) + p.shape).copy(), params)
        return DRDSGDState(
            step=jnp.zeros((), jnp.int32),
            theta=stacked,
            momentum=jax.tree.map(jnp.zeros_like, stacked),
            theta_avg=jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params),
            rng=jnp.array(rng, copy=True),
        )

    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def step(self, state: DRDSGDState, batch: Any):
        cfg = self.config
        m = cfg.num_nodes
        rng, *node_keys = jax.random.split(state.rng, m + 1)
        node_keys = jnp.stack(node_keys)

        losses, grads = jax.vmap(jax.value_and_grad(self.loss_fn))(state.theta, batch, node_keys)

        # closed-form KL dual weights (normalized over the network)
        lam = dro.kl_closed_form_weights(losses, self.prior, cfg.alpha)
        scale = (lam / self.prior).astype(jnp.float32)  # = m * lam for uniform prior

        t = state.step.astype(jnp.float32)
        eta = cfg.eta_theta * jnp.power(cfg.lr_decay, t)

        def upd(p, g, mo):
            g = g.astype(jnp.float32) * scale.reshape((m,) + (1,) * (g.ndim - 1))
            mo = cfg.momentum * mo + g
            return (p.astype(jnp.float32) - eta * mo).astype(p.dtype), mo

        flat_p, tdef = jax.tree_util.tree_flatten(state.theta)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.momentum)
        stepped = [upd(p, g, mo) for p, g, mo in zip(flat_p, flat_g, flat_m)]
        theta_half = jax.tree_util.tree_unflatten(tdef, [s[0] for s in stepped])
        momentum = jax.tree_util.tree_unflatten(tdef, [s[1] for s in stepped])

        theta_new = mix_stacked(theta_half, self.topology)  # uncompressed gossip

        tt = state.step.astype(jnp.float32)
        theta_avg = jax.tree.map(
            lambda avg, th: (avg * tt + th.astype(jnp.float32).mean(0)) / (tt + 1.0),
            state.theta_avg,
            theta_new,
        )
        aux = {"losses": losses, "worst_loss": losses.max(), "mean_loss": losses.mean(), "lambda_mean": lam}
        return DRDSGDState(state.step + 1, theta_new, momentum, theta_avg, rng), aux

    def network_mean(self, state):
        return jax.tree.map(lambda x: x.astype(jnp.float32).mean(0), state.theta)

    def bits_per_round(self, state) -> float:
        return payload_bits(Identity(), state.theta, self.topology)


# ------------------------------------------------------------------------ DRFA
@dataclasses.dataclass(frozen=True)
class DRFAConfig:
    num_nodes: int = 8
    participation: float = 0.5  # fraction of clients sampled per round
    local_steps: int = 10  # K
    eta_theta: float = 0.1
    eta_lambda: float = 0.1
    lr_decay: float = 1.0
    momentum: float = 0.0


class DRFAState(NamedTuple):
    step: jax.Array
    theta: Any  # server model (no node axis)
    lam: jax.Array  # [m] server dual
    theta_avg: Any
    rng: jax.Array


class DRFA:
    """Distributionally Robust Federated Averaging (client-server)."""

    def __init__(self, config: DRFAConfig, loss_fn: LossFn, prior=None):
        self.config = config
        self.loss_fn = loss_fn
        m = config.num_nodes
        self.prior = jnp.full((m,), 1.0 / m) if prior is None else jnp.asarray(prior)
        self.num_sampled = max(1, int(round(config.participation * m)))

    def init(self, params: Any, rng: jax.Array) -> DRFAState:
        return DRFAState(
            step=jnp.zeros((), jnp.int32),
            theta=jax.tree.map(lambda x: jnp.array(x, copy=True), params),
            lam=self.prior,
            theta_avg=jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params),
            rng=jnp.array(rng, copy=True),
        )

    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def step(self, state: DRFAState, batch: Any):
        """One communication round.

        ``batch`` is stacked [m, K, ...]: K local micro-batches per client.
        """
        cfg = self.config
        m = cfg.num_nodes
        k = self.num_sampled
        rng, sample_key, *node_keys = jax.random.split(state.rng, m + 2)
        node_keys = jnp.stack(node_keys)

        # --- sample |U| clients according to lambda (Gumbel top-k, no repl.)
        gumbel = -jnp.log(-jnp.log(jax.random.uniform(sample_key, (m,)) + 1e-20) + 1e-20)
        scores = jnp.log(state.lam + 1e-20) + gumbel
        _, sampled = jax.lax.top_k(scores, k)
        mask = jnp.zeros((m,), jnp.float32).at[sampled].set(1.0)

        t = state.step.astype(jnp.float32)
        eta = cfg.eta_theta * jnp.power(cfg.lr_decay, t)

        # --- K local SGD steps at EVERY client (masked average afterwards):
        # running all clients keeps the step shape static; only sampled ones
        # contribute, matching partial participation.
        def local_train(theta0, client_batch, key):
            def body(theta, mb):
                loss, g = jax.value_and_grad(self.loss_fn)(theta, mb, key)
                theta = jax.tree.map(
                    lambda p, gg: (p.astype(jnp.float32) - eta * gg.astype(jnp.float32)).astype(p.dtype),
                    theta,
                    g,
                )
                return theta, loss

            theta_k, losses = jax.lax.scan(body, theta0, client_batch)
            return theta_k, losses.mean()

        theta_rep = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m,) + p.shape), state.theta)
        theta_locals, local_losses = jax.vmap(local_train)(theta_rep, batch, node_keys)

        # --- server: average sampled client models
        wsum = mask.sum()
        theta_new = jax.tree.map(
            lambda x: (
                (x.astype(jnp.float32) * mask.reshape((m,) + (1,) * (x.ndim - 1))).sum(0) / wsum
            ).astype(x.dtype),
            theta_locals,
        )

        # --- dual update: projected ascent on observed losses (sampled only,
        # importance-corrected as in Deng et al.)
        loss_vec = local_losses * mask * (m / jnp.maximum(wsum, 1.0))
        lam_new = dro.project_simplex(state.lam + cfg.eta_lambda * cfg.local_steps * loss_vec)

        tt = state.step.astype(jnp.float32)
        theta_avg = jax.tree.map(
            lambda avg, th: (avg * tt + th.astype(jnp.float32)) / (tt + 1.0),
            state.theta_avg,
            theta_new,
        )
        aux = {
            "losses": local_losses,
            "worst_loss": local_losses.max(),
            "mean_loss": local_losses.mean(),
            "lambda_mean": lam_new,
        }
        return DRFAState(state.step + 1, theta_new, lam_new, theta_avg, rng), aux

    def network_mean(self, state):
        return jax.tree.map(lambda x: x.astype(jnp.float32), state.theta)

    def bits_per_round(self, state) -> float:
        """Busiest node = the server: |U| models down + |U| models up, f32.

        One DRFA round covers K local iterations; callers comparing against
        per-iteration algorithms should divide by ``config.local_steps``.
        """
        d = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(state.theta))
        return 2.0 * self.num_sampled * d * 32.0
