"""Baselines the paper compares against (Table 1, §5.2).

* CHOCO-SGD (Koloskova et al. 2019) — standard (non-robust) decentralized SGD
  with compressed gossip.  Obtained from :func:`repro.core.adgda.adgda_trainer`
  with ``robust=False`` (dual frozen at the prior); no separate code path so
  the comparison isolates exactly the distributional-robustness delta.

* DR-DSGD (Issaid et al. 2022) — decentralized distributionally robust SGD
  restricted to the KL regularizer, for which the inner max has the closed
  form lambda_i ∝ pi_i exp(f_i / alpha).  Uncompressed gossip.  The closed
  form needs the normalizer sum_j pi_j exp(f_j/alpha); we obtain it with one
  scalar all-reduce per round (the original paper gossips it — identical in
  expectation, and the scalar is 32 bits so the accounting difference is nil).

* DRFA (Deng et al. 2021) — federated (star topology) distributionally robust
  averaging: each round the server samples |U| = ceil(m/2) clients according
  to lambda, clients run K local SGD steps, the server averages the returned
  models and periodically updates lambda by projected ascent on the observed
  losses.

All three are factory compositions of
:class:`repro.core.trainer.DecentralizedTrainer` — pick a
:class:`LocalUpdate` oracle, a dual, a consensus — and therefore share the
uniform interface ``init(params, rng)``, ``step(state, batch) -> (state,
aux)``, ``network_mean(state)``, ``bits_per_round(state, per_iteration=...)``
that the communication-efficiency benchmark (paper Fig. 5) relies on.  The
``DRDSGD`` / ``DRFA`` classes are deprecated shims over the factories.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax.numpy as jnp

from repro.core.adgda import ADGDAConfig, LossFn, adgda_trainer
from repro.core.topology import make_topology
from repro.core.trainer import (
    DecentralizedTrainer,
    ExactConsensus,
    FedAvg,
    KLClosedForm,
    LocalUpdate,
    SampledAscent,
    TrainerState,
)
from repro.optim import make_schedule, sgd

__all__ = [
    "choco_sgd",
    "DRDSGD",
    "DRDSGDConfig",
    "DRDSGDState",
    "drdsgd_trainer",
    "DRFA",
    "DRFAConfig",
    "DRFAState",
    "drfa_trainer",
]

# Deprecated aliases: both baselines now run on the shared composed state.
DRDSGDState = TrainerState
DRFAState = TrainerState


def choco_sgd(config: ADGDAConfig, loss_fn: LossFn, prior=None, *,
              mesh=None, node_axes="data") -> DecentralizedTrainer:
    """CHOCO-SGD = AD-GDA with the dual frozen at the prior."""
    return adgda_trainer(dataclasses.replace(config, robust=False), loss_fn, prior,
                         mesh=mesh, node_axes=node_axes)


# --------------------------------------------------------------------- DR-DSGD
@dataclasses.dataclass(frozen=True)
class DRDSGDConfig:
    num_nodes: int = 8
    topology: str = "ring"
    alpha: float = 6.0  # KL temperature (paper uses alpha = 6)
    eta_theta: float = 0.1
    lr_decay: float = 1.0
    momentum: float = 0.0
    gossip_backend: str = "rolled"  # "rolled" | "ppermute" (wire-honest
    # neighbor exchange of the dense f32 models — DR-DSGD's actual wire;
    # requires the factory's mesh kwarg)
    fault_spec: str | None = None  # wire-fault injection (repro.core.faults):
    # DR-DSGD's dense wire is memoryless, so a faulted edge is simply cut
    # from the round's mix (no mirror to heal) and the meter bills only
    # delivered messages
    track_average: bool = True


def drdsgd_trainer(config: DRDSGDConfig, loss_fn: LossFn, prior=None, *,
                   mesh=None, node_axes="data") -> DecentralizedTrainer:
    """Compose DR-DSGD: closed-form KL dual × exact (uncompressed) gossip."""
    m = config.num_nodes
    topology = make_topology(config.topology, config.num_nodes)
    prior = jnp.full((m,), 1.0 / m) if prior is None else jnp.asarray(prior)
    sched = make_schedule("exp", config.eta_theta, decay=config.lr_decay)
    return DecentralizedTrainer(
        loss_fn,
        num_nodes=m,
        local=LocalUpdate(optimizer=sgd(sched, momentum=config.momentum), schedule=sched),
        dual=KLClosedForm(prior=prior, alpha=config.alpha),
        consensus=ExactConsensus(
            topology, backend=config.gossip_backend, mesh=mesh,
            node_axes=node_axes, faults=config.fault_spec,
        ),
        prior=prior,
        track_average=config.track_average,
        config=config,
    )


class DRDSGD(DecentralizedTrainer):
    """Deprecated shim over :func:`drdsgd_trainer` (pre-refactor signature)."""

    def __init__(self, config: DRDSGDConfig, loss_fn: LossFn, prior=None):
        warnings.warn(
            "repro.core.DRDSGD is deprecated; use "
            "repro.core.baselines.drdsgd_trainer(config, loss_fn) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        self._init_as(drdsgd_trainer(config, loss_fn, prior))


# ------------------------------------------------------------------------ DRFA
@dataclasses.dataclass(frozen=True)
class DRFAConfig:
    num_nodes: int = 8
    participation: float = 0.5  # fraction of clients sampled per round
    local_steps: int = 10  # K
    eta_theta: float = 0.1
    eta_lambda: float = 0.1
    lr_decay: float = 1.0
    momentum: float = 0.0
    gossip_backend: str = "rolled"  # "rolled" | "ppermute" (mesh-native
    # server aggregation: per-device partial sums + one psum, zero
    # all-gather; requires the factory's mesh kwarg)
    track_average: bool = True


def drfa_trainer(config: DRFAConfig, loss_fn: LossFn, prior=None, *,
                 mesh=None, node_axes="data") -> DecentralizedTrainer:
    """Compose DRFA: K-local-step oracle × sampled dual ascent × server averaging.

    ``batch`` is stacked [m, K, ...]: K local micro-batches per client.  All
    clients run the K local steps (static step shape); only the sampled ones
    contribute to the server average and the dual ascent, matching partial
    participation.

    Behavior change vs. the seed ``DRFA`` class: ``config.momentum`` is now
    honored (the seed declared but silently ignored it, always running plain
    local SGD).  The per-client momentum buffer persists across rounds even
    though theta resets to the server broadcast.  The default (0.0)
    reproduces the seed trajectories bit-for-bit.
    """
    m = config.num_nodes
    prior = jnp.full((m,), 1.0 / m) if prior is None else jnp.asarray(prior)
    num_sampled = max(1, int(round(config.participation * m)))
    sched = make_schedule("exp", config.eta_theta, decay=config.lr_decay)
    return DecentralizedTrainer(
        loss_fn,
        num_nodes=m,
        local=LocalUpdate(
            optimizer=sgd(sched, momentum=config.momentum),
            schedule=sched,
            local_steps=config.local_steps,
            batch_layout="stacked",
        ),
        dual=SampledAscent(
            prior=prior,
            eta_lambda=config.eta_lambda,
            local_steps=config.local_steps,
            num_sampled=num_sampled,
        ),
        consensus=FedAvg(
            num_sampled, backend=config.gossip_backend, mesh=mesh,
            node_axes=node_axes,
        ),
        prior=prior,
        track_average=config.track_average,
        config=config,
    )


class DRFA(DecentralizedTrainer):
    """Deprecated shim over :func:`drfa_trainer` (pre-refactor signature)."""

    def __init__(self, config: DRFAConfig, loss_fn: LossFn, prior=None):
        warnings.warn(
            "repro.core.DRFA is deprecated; use "
            "repro.core.baselines.drfa_trainer(config, loss_fn) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        self._init_as(drfa_trainer(config, loss_fn, prior))
        self.num_sampled = self.consensus.num_sampled
