"""CHOCO-GOSSIP compressed consensus (Koloskova et al. 2019) on stacked node axes.

All state is stored *stacked*: every pytree leaf has a leading node axis of
size m.  Under ``jax.jit`` with the production mesh, that axis is sharded over
the ``data`` (and ``pod``) mesh axes, so each node's state lives on its own
data-parallel group and the mixing below becomes real inter-node
communication:

* circulant topologies (ring / torus / mesh): ``sum_k w_k * roll(x, k)`` along
  the node axis -> XLA ``collective-permute`` chains (sparse, ICI-friendly);
* arbitrary W: einsum over the node axis -> all-gather + local reduction.

The memory-efficient CHOCO scheme (paper Algorithm 1) keeps two extra
variables per node: the public copy ``theta_hat_i`` and the neighbor tracker
``s_i``.  One round:

    theta_i   <- theta_half_i + gamma * (s_i - theta_hat_i)      # averaging
    q_i       <- Q(theta_i - theta_hat_i)                        # compress
    exchange q with neighbors                                    # the wire
    theta_hat <- theta_hat + q_i
    s_i       <- s_i + sum_j w_ij q_j

``packed=True`` mixes the *encoded payload* (rolled packed ints), which is the
production path: the collective moves ~delta x fewer bytes.  ``packed=False``
decodes first (identical numerics, used as a cross-check oracle).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import Compressor, Identity
from repro.core.topology import Topology, masked_metropolis

__all__ = [
    "CHOCOState",
    "LaneRound",
    "choco_init",
    "choco_round",
    "choco_round_lanes",
    "mix_stacked",
    "mix_stacked_with",
    "payload_bits",
    "payload_total_bits",
]


class CHOCOState(NamedTuple):
    theta_hat: object  # pytree, leaves [m, ...]
    s: object  # pytree, leaves [m, ...]
    # NeighborCache (cached union wire only): tuple over union wire ops of
    # theta_hat-shaped mirrors of each in-neighbor's public copy — see
    # repro.core.wire.  () for every other configuration.
    cache: Any = ()
    # Per-edge fault-recovery state machine (repro.core.faults.FaultState):
    # synced/staleness/backoff counters + the realized-bits meter.  () unless
    # a FaultSpec is active — faults off adds no leaves, so existing
    # checkpoints restore unchanged.
    fault: Any = ()


class LaneRound(NamedTuple):
    """One lane of a multi-lane consensus round: the variable to gossip, its
    CHOCO trackers (own hat/s/NeighborCache/FaultState — lanes verify, go
    stale and resync independently), and the lane's step size + compressor.
    Lane 0 is always the model lane; its RNG stream is the round key itself
    so a single-lane round stays bit-identical to the historical wire.  Lane
    k > 0 draws from ``fold_in(key, k)`` (and ``fold_in(fault_key, k)``)."""

    theta: object  # pytree, leaves [m, ...]
    state: CHOCOState
    gamma: float
    compressor: Compressor


def lane_key(key, k: int):
    """Lane ``k``'s RNG stream: the round key itself for lane 0 (bit-parity
    with the single-lane wire), an independent fold for every other lane."""
    if key is None or k == 0:
        return key
    return jax.random.fold_in(key, k)


def choco_round_lanes(
    lanes,
    topology: Topology,
    key: jax.Array,
    *,
    packed: bool = True,
    fused: bool = False,
    block_scan_elems: int = None,
    mixing: jax.Array | None = None,
    mask: jax.Array | None = None,
    backend: str = "rolled",
    mesh=None,
    node_axes="data",
    schedule=None,
    step=None,
    union=None,
    faults=None,
    fault_key=None,
):
    """One multi-lane compressed-consensus round: every edge of the round's
    wire program carries a *tuple* of messages, one per :class:`LaneRound`.

    Returns ``(thetas, states)`` tuples, one entry per lane.  All lanes ride
    the same edges of the same round — on the ppermute backend they run
    inside one ``shard_map`` body, so the per-edge message really is the
    lane tuple — but each lane keeps its own compressed residual stream,
    NeighborCache mirrors and (under faults) its own per-edge event draws
    and recovery state: a corrupted lane-1 message stales only lane 1's
    mirror.  A single-lane call is bit-identical to :func:`choco_round`.
    """
    if block_scan_elems is None:
        block_scan_elems = BLOCK_SCAN_ELEMS
    lanes = tuple(LaneRound(*l) for l in lanes)
    if not lanes:
        raise ValueError("choco_round_lanes needs at least one lane")
    if backend == "ppermute":
        from repro.core.exchange import choco_round_ppermute_lanes

        if mixing is not None:
            raise ValueError(
                "backend='ppermute' takes schedule/step/mask, not a dense "
                "mixing matrix — the wire program is compiled per phase"
            )
        if mesh is None:
            raise ValueError("backend='ppermute' requires a mesh")
        return choco_round_ppermute_lanes(
            lanes, topology, key, mesh=mesh, node_axes=node_axes,
            packed=packed, fused=fused, block_scan_elems=block_scan_elems,
            schedule=schedule, step=step, mask=mask, union=union,
            faults=faults, fault_key=fault_key,
        )
    if backend != "rolled":
        raise ValueError(f"unknown gossip backend {backend!r}; choose rolled or ppermute")
    if faults is not None:
        from repro.core.exchange import choco_round_cached_local_lanes

        return choco_round_cached_local_lanes(
            lanes, key, union=union, packed=packed,
            block_scan_elems=block_scan_elems, schedule=schedule,
            topology=topology, step=step, mask=mask, faults=faults,
            fault_key=fault_key,
        )
    # rolled fault-free path: lanes are arithmetically independent given
    # their (folded) keys, so per-lane rounds over the same topology/mixing
    # ARE the lane-tuple wire — the stacked simulation has no per-edge
    # messages to actually concatenate.
    outs = [
        choco_round(
            l.theta, l.state, topology, l.gamma, l.compressor,
            lane_key(key, k), packed=packed, fused=fused,
            block_scan_elems=block_scan_elems, mixing=mixing, mask=mask,
        )
        for k, l in enumerate(lanes)
    ]
    return tuple(o[0] for o in outs), tuple(o[1] for o in outs)


def choco_init(theta_stacked, *, cache_ops: int = 0,
               fault_ops: int | None = None) -> CHOCOState:
    """Fresh CHOCO trackers.  ``cache_ops > 0`` additionally allocates the
    NeighborCache for a cached union wire (one ``theta_hat`` mirror per
    union exchange op — ``ChocoConsensus.init`` sizes this from its compiled
    :class:`~repro.core.wire.UnionWirePlan`).  ``fault_ops`` (the same op
    count) additionally allocates the per-edge
    :class:`~repro.core.faults.FaultState` when a fault spec is active."""
    from repro.core.faults import init_fault_state
    from repro.core.wire import init_neighbor_cache

    m = jax.tree_util.tree_leaves(theta_stacked)[0].shape[0]
    zeros = jax.tree.map(jnp.zeros_like, theta_stacked)
    return CHOCOState(
        theta_hat=zeros,
        s=jax.tree.map(jnp.zeros_like, theta_stacked),
        cache=init_neighbor_cache(theta_stacked, cache_ops) if cache_ops else (),
        fault=init_fault_state(m, fault_ops) if fault_ops is not None else (),
    )


def _mix_leaf(x: jax.Array, topology: Topology) -> jax.Array:
    """sum_j w_ij x_j along the leading node axis."""
    if topology.shifts is not None:
        out = jnp.zeros_like(x)
        for shift, weight in topology.shifts:
            term = x if shift == 0 else jnp.roll(x, shift, axis=0)
            out = out + weight * term
        return out
    w = jnp.asarray(topology.mixing, dtype=x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32)
    flat = x.reshape(x.shape[0], -1).astype(w.dtype)
    return (w @ flat).reshape(x.shape).astype(x.dtype)


def mix_stacked(tree, topology: Topology):
    """Gossip-average a stacked pytree: leaf[i] <- sum_j w_ij leaf[j]."""
    return jax.tree.map(lambda x: _mix_leaf(x, topology), tree)


def mix_stacked_with(tree, w: jax.Array):
    """Gossip-average a stacked pytree with an explicit (possibly traced,
    e.g. per-round masked) dense [m, m] mixing matrix."""
    return jax.tree.map(lambda x: _mix_leaf_dense(x, w), tree)


def _roll_payload(payload, shift: int):
    if shift == 0:
        return payload
    return jax.tree.map(lambda x: jnp.roll(x, shift, axis=0), payload)


def _vdecode(compressor: Compressor, payload, shape, dtype):
    return jax.vmap(lambda p: compressor.decode(p, shape, dtype))(payload)


def _mix_payload(compressor, payload, shape, dtype, topology: Topology):
    """sum_j w_ij decode(q_j) — rolling the *packed* payload (production path)."""
    out = None
    for shift, weight in topology.shifts:
        deq = _vdecode(compressor, _roll_payload(payload, shift), shape, dtype)
        out = weight * deq if out is None else out + weight * deq
    return out


# leaves with more inner elements than this are gossiped with a lax.scan over
# their leading inner (layer-stack) axis, so the f32 residual / RNG / payload
# transients are per-layer instead of per-40-layer-stack — see EXPERIMENTS
# §Perf (command-r-35b train iteration 2).  Quantization norms become
# per-(node, block), a strictly finer scale that still satisfies Assumption 3.2.
BLOCK_SCAN_ELEMS = 1 << 24


def _scan_plan(shape, inner_elems: int, block_scan_elems: int):
    """How to gossip a large stacked leaf [m, ...] in chunks.

    Returns (axis, chunks, rows) or None (whole-leaf):
      * layer-stack leaves (axis-1 size <= 128, e.g. [m, nb_layers, ...]):
        scan axis 1 — it is never sharded;
      * otherwise (e.g. embeddings [m, V, d] with V sharded over `model`):
        split the LAST axis — chunking a sharded axis would force
        cross-shard indexing every scan step (measured regression,
        EXPERIMENTS §Perf B3).
    """
    if len(shape) <= 1 or inner_elems <= block_scan_elems:
        return None
    nb = shape[1] if len(shape) > 2 else 1
    if 1 < nb <= 128:
        per_row = inner_elems // nb
        target_rows = max(1, block_scan_elems // max(per_row, 1))
        rows = 1
        for r in range(min(target_rows, nb), 0, -1):
            if nb % r == 0:
                rows = r
                break
        chunks = nb // rows
        if 1 < chunks <= 512:
            return (1, chunks, rows)
        return None
    last = shape[-1]
    per_col = inner_elems // last
    want = max(2, -(-inner_elems // block_scan_elems))  # ceil
    for c in range(min(want, last), min(513, last + 1)):
        if last % c == 0:
            return (len(shape) - 1, c, last // c)
    return None


def _mix_leaf_dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """sum_j w_ij x_j with an explicit (possibly traced) [m, m] matrix."""
    flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
    return (w.astype(jnp.float32) @ flat).reshape(x.shape).astype(x.dtype)


def _round_leaf_masked(leaf, hat, s, key, mixing, gamma, compressor, mask):
    """One fault-tolerant CHOCO round for a stacked leaf [m, ...].

    ``mixing`` is the round's dense doubly-stochastic [m, m] matrix (time
    varying and/or Metropolis-rescaled on the surviving subgraph); ``mask``
    is the 0/1 participation vector (None == everyone alive).  Dropped nodes
    skip the averaging step, contribute q_i = 0 to the wire and receive
    nothing (their ``mixing`` row/column is the identity), so theta_hat_i
    stays frozen and remains consistent with what their neighbors last saw —
    a node can rejoin on any later round without resetting trackers.

    Time-varying W forces the *memory-full* CHOCO form (Koloskova et al.
    Algorithm 1): the averaging step uses sum_j w_ij(t) theta_hat_j computed
    fresh from the current public copies instead of the accumulated tracker
    ``s``.  The accumulation trick ``s += sum_j w_ij q_j`` is a pure memory
    optimization that is only sound for a static W — one round under
    different weights leaves a permanent inconsistency e = s - W theta_hat,
    and the gossip then settles at a biased fixed point with consensus error
    (I - W)^+ e (amplified by 1 / spectral-gap).  A physical deployment
    realizes this form by storing neighbors' hat copies and re-syncing them
    when a node rejoins or the graph changes; our stacked simulation gets
    that re-sync for free.  ``s`` is still maintained (for alive nodes) as
    the true tracker sum_j w_ij(t) theta_hat_j(t) so introspection and
    checkpoints keep their meaning, but the masked path never reads it.

    With a constant W and everyone alive this is numerically the unpacked
    static path (s == W theta_hat inductively), though not bit-identical —
    the dense matmul replaces the shift accumulation.
    """
    m = leaf.shape[0]
    inner_shape, dtype = leaf.shape[1:], leaf.dtype
    alive = jnp.ones((m,), jnp.float32) if mask is None else mask.astype(jnp.float32)
    ab = alive.reshape((m,) + (1,) * (leaf.ndim - 1))
    s_cur = _mix_leaf_dense(hat.astype(jnp.float32), mixing)  # sum_j w_ij(t) hat_j
    theta_new = leaf + (ab * gamma).astype(dtype) * (s_cur - hat.astype(jnp.float32)).astype(dtype)
    resid = ((theta_new - hat).astype(jnp.float32)) * ab
    if isinstance(compressor, Identity):
        q_self = resid
    else:
        node_keys = jax.random.split(key, m)
        payload = jax.vmap(compressor.encode)(resid, node_keys)
        # a zero residual encodes/decodes to exactly zero for every operator
        # in this repo; the mask multiply makes "dropped nodes send nothing"
        # robust to compressors without that property
        q_self = _vdecode(compressor, payload, inner_shape, jnp.float32) * ab
    hat_new = (hat.astype(jnp.float32) + q_self).astype(hat.dtype)
    s_post = s_cur + _mix_leaf_dense(q_self, mixing)  # sum_j w_ij(t) hat_j(t)
    s_new = (ab * s_post + (1.0 - ab) * s.astype(jnp.float32)).astype(s.dtype)
    return theta_new, hat_new, s_new


def _round_leaf(leaf, hat, s, key, topology, gamma, compressor, use_packed,
                use_fused=False):
    """One CHOCO round for a single stacked leaf [m, ...]."""
    if use_fused:
        # single-pass fused kernels: averaging + residual + quantize + pack +
        # hat update in one VMEM pass, then a multi-shift dequant-accumulate
        # into s — never materializing per-neighbor f32 tensors.  Payload is
        # bit-identical to the packed/unpacked oracle paths below.
        return compressor.fused_round(leaf, hat, s, key, topology, gamma)
    m = leaf.shape[0]
    inner_shape, dtype = leaf.shape[1:], leaf.dtype
    # averaging step (uses the *old* public variables)
    theta_new = leaf + jnp.asarray(gamma, dtype) * (s - hat).astype(dtype)
    resid = (theta_new - hat).astype(jnp.float32)
    if isinstance(compressor, Identity):
        q_self = resid
        mixed = _mix_leaf(q_self, topology)
    else:
        node_keys = jax.random.split(key, m)
        payload = jax.vmap(compressor.encode)(resid, node_keys)
        q_self = _vdecode(compressor, payload, inner_shape, jnp.float32)
        if use_packed:
            mixed = _mix_payload(compressor, payload, inner_shape, jnp.float32, topology)
        else:
            mixed = _mix_leaf(q_self, topology)
    hat_new = (hat.astype(jnp.float32) + q_self).astype(hat.dtype)
    s_new = (s.astype(jnp.float32) + mixed).astype(s.dtype)
    return theta_new, hat_new, s_new


def choco_round(
    theta_half,
    state: CHOCOState,
    topology: Topology,
    gamma: float,
    compressor: Compressor,
    key: jax.Array,
    packed: bool = True,
    fused: bool = False,
    block_scan_elems: int = BLOCK_SCAN_ELEMS,
    mixing: jax.Array | None = None,
    mask: jax.Array | None = None,
    *,
    backend: str = "rolled",
    mesh=None,
    node_axes="data",
    schedule=None,
    step=None,
    union=None,
    faults=None,
    fault_key=None,
):
    """One compressed-consensus round over all leaves of a stacked pytree.

    Returns (theta_new, state_new).  theta_half leaves are [m, ...].

    ``backend`` selects the exchange implementation:

    * ``"rolled"`` (default) — this module's stacked-array simulation:
      rolls over the full node axis / dense [m, m] matmuls.  Kept verbatim
      as the reference oracle; how it maps to collectives is up to GSPMD.
    * ``"ppermute"`` — the mesh-native SPMD substrate (core/exchange.py):
      the round runs under ``shard_map`` over ``mesh``'s ``node_axes`` and
      only degree-many compressed payloads travel between actual graph
      neighbors via ``lax.ppermute``.  Requires ``mesh``; time variation is
      expressed as ``schedule`` + ``step`` + ``mask`` (a dense ``mixing``
      matrix has no wire meaning there and is rejected).

    ``fused=True`` dispatches to the compressor's single-pass Pallas fast
    path (kernels/choco_fused.py) when the compressor advertises
    ``supports_fused_round`` and the topology is circulant; other
    (compressor, topology) combinations silently fall back to the
    packed/unpacked reference paths, which serve as cross-check oracles.

    ``mixing``/``mask`` enter the time-varying fault-tolerant regime: the
    round mixes with the explicit dense [m, m] matrix (e.g. a
    ``TopologySchedule.mixing_at(t, mask)``) and nodes with ``mask == 0``
    skip the averaging step, send q = 0 and receive nothing — their CHOCO
    trackers stay frozen so they can rejoin later.  This path bypasses the
    packed/fused dispatch (the wire pattern is round-dependent); with
    ``mixing is None and mask is None`` the static fast paths are taken and
    the round is bit-identical to pre-schedule behavior.

    ``faults`` (a :class:`~repro.core.faults.FaultSpec`) + ``fault_key``
    enter the message-fault regime: the round runs against the NeighborCache
    on the union wire program (``union`` required — both backends share the
    cached round body, the rolled one executing it with the whole node axis
    as a single local block) with per-edge drop/corrupt/dup/delay events,
    digest verification and staleness/resync recovery (repro.core.faults).
    """
    if backend == "ppermute":
        from repro.core.exchange import choco_round_ppermute

        if mixing is not None:
            raise ValueError(
                "backend='ppermute' takes schedule/step/mask, not a dense "
                "mixing matrix — the wire program is compiled per phase"
            )
        if mesh is None:
            raise ValueError("backend='ppermute' requires a mesh")
        return choco_round_ppermute(
            theta_half, state, topology, gamma, compressor, key,
            mesh=mesh, node_axes=node_axes, packed=packed, fused=fused,
            block_scan_elems=block_scan_elems, schedule=schedule, step=step,
            mask=mask, union=union, faults=faults, fault_key=fault_key,
        )
    if backend != "rolled":
        raise ValueError(f"unknown gossip backend {backend!r}; choose rolled or ppermute")
    if faults is not None:
        # faulted rounds run the cached union-wire body (the same code the
        # ppermute backend shard_maps) with the whole node axis as one local
        # block — rolled/ppermute bit-parity under faults is structural
        from repro.core.exchange import choco_round_cached_local

        return choco_round_cached_local(
            theta_half, state, gamma, compressor, key, union=union,
            packed=packed, block_scan_elems=block_scan_elems,
            schedule=schedule, topology=topology, step=step, mask=mask,
            faults=faults, fault_key=fault_key,
        )
    if schedule is not None or step is not None or union is not None:
        raise ValueError(
            "backend='rolled' does not consume schedule/step — resolve the "
            "round's dense matrix yourself and pass mixing="
            "schedule.mixing_at(step, mask) (what ChocoConsensus.mix does)"
        )
    leaves, treedef = jax.tree_util.tree_flatten(theta_half)
    hat_leaves = treedef.flatten_up_to(state.theta_hat)
    s_leaves = treedef.flatten_up_to(state.s)
    keys = jax.random.split(key, len(leaves))

    time_varying = mixing is not None or mask is not None
    if time_varying and mixing is None:
        # a mask without an explicit W(t) still must honor the dropped-node
        # contract (identity row/column): rescale the static topology's
        # Metropolis weights on the surviving subgraph
        mixing = masked_metropolis(np.asarray(topology.adjacency), mask)
    use_packed = packed and topology.shifts is not None and not isinstance(compressor, Identity)
    use_fused = (
        fused
        and topology.shifts is not None
        and getattr(compressor, "supports_fused_round", False)
    )

    def round_one(leaf, hat, s, k):
        if time_varying:
            return _round_leaf_masked(leaf, hat, s, k, mixing, gamma, compressor, mask)
        return _round_leaf(leaf, hat, s, k, topology, gamma, compressor,
                           use_packed, use_fused)

    new_theta, new_hat, new_s, _, _ = _round_leaves(
        leaves, hat_leaves, s_leaves, keys, round_one, block_scan_elems
    )
    unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    # the rolled backend never consumes the NeighborCache (its time-varying
    # oracle re-mixes the full hats); pass it through so state shapes are
    # stable across backends
    return unf(new_theta), CHOCOState(
        theta_hat=unf(new_hat), s=unf(new_s), cache=state.cache,
        fault=state.fault,
    )


def _round_leaves(leaves, hat_leaves, s_leaves, keys, round_one,
                  block_scan_elems: int, extra_leaves=None, verdict_init=None):
    """Apply ``round_one(leaf, hat, s, key)`` to every stacked leaf, scanning
    large leaves in _scan_plan chunks.  Shared by the rolled backend above
    and the SPMD backend (core/exchange.py): the chunk layout and the
    per-chunk key stream are part of the bit-parity contract between them —
    ``_scan_plan`` reads only the inner dims, which a device-local shard
    shares with the global leaf.

    ``extra_leaves`` (cached union wire only): per-leaf tuples of extra
    leaf-shaped arrays (the NeighborCache mirrors) chunked alongside; the
    callback then has the signature ``round_one(leaf, hat, s, key, extras)
    -> (theta, hat, s, extras)``.

    ``verdict_init`` (faulted wire only, implies ``extra_leaves``): a bool
    array the callback's extra trailing return value is AND-reduced into —
    across scan chunks (the scan carry) and across leaves.  Fault events are
    whole-message, so a per-edge digest verdict must hold for *every* leaf
    chunk of the message; the reduction happens here so the chunked and
    unchunked layouts agree bit-for-bit.

    Returns ``(theta, hat, s, extras, verdict)`` leaf lists, with ``extras``
    / ``verdict`` ``None`` when not requested.
    """
    has_extra = extra_leaves is not None
    has_verdict = verdict_init is not None
    new_theta, new_hat, new_s = [], [], []
    new_extra = [] if has_extra else None
    verdict = verdict_init
    for i, (leaf, hat, s, k) in enumerate(zip(leaves, hat_leaves, s_leaves, keys)):
        extras = extra_leaves[i] if has_extra else ()
        inner_elems = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
        plan = _scan_plan(leaf.shape, inner_elems, block_scan_elems)
        if plan is not None:
            # scan over chunks (layer-stack axis, or last-axis column groups):
            # transients become per-chunk.  Slice inside the body — a
            # pre-scan swapaxes would be fused into the loop as a
            # full-tensor transpose every iteration.
            axis, chunks, rows = plan
            if axis == 1:
                reshape = lambda x: x.reshape((x.shape[0], chunks, rows) + x.shape[2:])
            else:  # split the last axis: [..., L] -> [..., chunks, L/chunks]
                reshape = lambda x: x.reshape(x.shape[:-1] + (chunks, rows))
            lc, hc, sc = reshape(leaf), reshape(hat), reshape(s)
            ec = tuple(reshape(e) for e in extras)
            bk = jax.random.split(k, chunks)

            def body(carry, xs, lc=lc, hc=hc, sc=sc, ec=ec, axis=axis):
                i, kb = xs
                take = lambda x: jax.lax.dynamic_index_in_dim(x, i, axis=axis, keepdims=False)
                if has_extra:
                    out = round_one(take(lc), take(hc), take(sc), kb,
                                    tuple(take(e) for e in ec))
                else:
                    out = round_one(take(lc), take(hc), take(sc), kb)
                if has_verdict:
                    return carry & out[-1], out[:-1]
                return carry, out

            init = jnp.ones_like(verdict_init) if has_verdict else None
            vc, ys = jax.lax.scan(body, init, (jnp.arange(chunks), bk))

            def unshape(x, axis=axis, shape=leaf.shape):
                # ys: [chunks, <leaf dims without the chunk axis position>]
                x = jnp.moveaxis(x, 0, axis)
                return x.reshape(shape)

            out = jax.tree.map(unshape, ys)
        else:
            out = round_one(leaf, hat, s, k, extras) if has_extra else round_one(leaf, hat, s, k)
            if has_verdict:
                out, vc = out[:-1], out[-1]
        if has_verdict:
            verdict = verdict & vc
        if has_extra:
            theta_new, hat_new, s_new, ex_new = out
            new_extra.append(ex_new)
        else:
            theta_new, hat_new, s_new = out
        new_theta.append(theta_new)
        new_hat.append(hat_new)
        new_s.append(s_new)
    return new_theta, new_hat, new_s, new_extra, (verdict if has_verdict else None)


def payload_total_bits(compressor: Compressor, theta_template) -> float:
    """Per-neighbor payload bits of one full model message.

    ``theta_template`` leaves are *stacked* [m, ...]: the per-node payload of
    a leaf is its inner size prod(shape[1:]).  A 1-D stacked leaf [m] is one
    scalar per node (d = 1), not m elements — billing shape[0] there inflated
    every scalar leaf's bit count by m x.
    """
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(theta_template):
        d = int(np.prod(leaf.shape[1:]))
        total += compressor.bits_per_element(d) * d
    return total


def payload_bits(compressor: Compressor, theta_template, topology, *,
                 mode: str = "max", step: int | None = None, mask=None,
                 degree: float | None = None) -> float:
    """Bits transmitted per round by the busiest node (degree x payload).

    ``topology`` is anything with a ``max_degree`` (a :class:`Topology` or a
    ``TopologySchedule``); an explicit ``degree`` overrides the topology's
    (the cached union wire bills its own out-degree — see
    :class:`repro.core.wire.UnionWirePlan`).

    ``mode`` picks the degree the payload is billed against:

    * ``"max"`` (default) — the busiest-phase ``max_degree`` upper bound,
      mask-oblivious: what provisioning must budget for;
    * ``"expected"`` — the participation-aware ``expected_degree``
      (phase-averaged busiest-node degree x the probability both endpoints
      of a link survive): what a realized-bits meter converges to;
    * ``"realized"`` — the actual active links of round ``step`` under the
      concrete participation ``mask``.
    """
    if mode not in ("max", "expected", "realized"):
        raise ValueError(f"unknown bits mode {mode!r}; choose max/expected/realized")
    total = payload_total_bits(compressor, theta_template)
    if degree is not None:
        return total * degree
    if mode == "max":
        degree = topology.max_degree
    elif mode == "expected":
        degree = topology.expected_degree
    else:
        if mask is None:
            raise ValueError("mode='realized' needs the round's participation mask")
        degree = topology.realized_degree(0 if step is None else step, mask)
    return total * degree
