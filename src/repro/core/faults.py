"""Message-level wire faults and the self-healing machinery that survives them.

The NeighborCache contract (repro.core.wire) assumes every compressed
hat-delta arrives intact on every union edge every round — one lost or
garbled payload silently diverges the receiver's mirror of the sender's
``theta_hat`` forever, and the memory-full averaging then gossips against a
phantom neighbor (the same biased-fixed-point failure mode PR 3 eliminated
for time-varying W).  This module makes that failure injectable, detectable
and recoverable:

* :class:`FaultSpec` — the seeded fault model: per-edge per-round i.i.d.
  message events (``drop`` / ``corrupt`` / ``dup`` / ``delay``), the bounded
  staleness ``stale`` (S) a diverged mirror is still mixed for, and the
  exponential resync backoff.  Parsed from the CLI syntax
  ``"drop:0.05,corrupt:0.01,stale:2"``.

* :func:`sample_events` — one uniform draw per (union op, receiver) per
  round, classified into the event lanes.  The draw is a pure function of
  the round's fault key, so both exchange backends (and a test
  reconstructing ground truth) see byte-identical events.

* :func:`digest` — the detection primitive: a 32-bit wraparound sum of the
  tensor's integer-bitcast bits.  Integer addition commutes and wraps
  identically everywhere, so ``digest(x) == digest(y)`` iff the byte content
  matches (up to the 2^-32 collision budget) regardless of evaluation order
  or backend.  The sender's per-leaf-chunk digest of its post-round
  ``theta_hat`` rides every union edge (32 bits per chunk — the digest
  lane); the receiver verifies ``digest(mirror + delta)`` against it
  *before* committing the delta, so divergence is detected the round it
  happens and garbage is never applied.

* :class:`FaultState` — the per-edge recovery state machine, stored inside
  :class:`~repro.core.gossip.CHOCOState` so kill-and-resume mid-faulted-run
  is bit-identical: synced flags, staleness counters, resync wait/backoff,
  and the realized-bits meter (delivered payloads + resync traffic + digest
  lane — what ``bits_realized`` bills).

Event semantics (whole-message: one draw gates the delta, its digest, and
any resync payload sharing the edge that round):

========  ==========================  =================================
event     wire effect                 receiver outcome (digest-verified)
========  ==========================  =================================
drop      nothing arrives             mirror misses the delta -> diverged
corrupt   payload garbled in flight   digest mismatch -> discarded -> diverged
dup       two copies arrive           1st verifies and applies, 2nd fails
                                      the digest (mirror already advanced)
                                      -> deduplicated; bills 2x
delay     arrives after the round     discarded as stale on arrival ==
                                      drop for state; bills 1x
========  ==========================  =================================

Recovery: a diverged mirror is still a *valid past value* of the neighbor's
hat, so it stays in the masked-Metropolis mix for up to S further rounds
(bounded staleness).  Beyond S the edge is dropped from the mix (PR 3's
surviving-subgraph rescale redistributes its weight) and the receiver
requests a full-hat resync — the sender ships its current ``theta_hat``
dense at the hat dtype (a lossy compressed resync would re-diverge the
mirror by the compression error forever; this is the documented departure
from the issue's "compressed full-hat", mirroring PR 5's exactness
argument).  Resync deliveries ride the same faulty wire: a failed attempt
doubles the per-edge backoff (capped), a verified one restores the mirror
bit-exact and resets the edge.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FaultSpec",
    "FaultState",
    "FaultEvents",
    "WireBits",
    "parse_fault_spec",
    "sample_events",
    "digest",
    "garble",
    "init_fault_state",
    "update_fault_state",
    "receiver_maps",
]


# ================================================================= FaultSpec
_RATE_KEYS = ("drop", "corrupt", "dup", "delay")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded message-fault model for the union wire.

    ``drop``/``corrupt``/``dup``/``delay`` are per-edge per-round i.i.d.
    event probabilities (mutually exclusive lanes of one uniform draw);
    ``stale`` is the bounded-staleness budget S — how many rounds a diverged
    mirror may still be mixed before the edge is cut and resync starts;
    ``backoff_base``/``backoff_cap`` shape the exponential resync retry
    schedule (wait = base^k rounds after the k-th failed attempt, capped).
    """

    drop: float = 0.0
    corrupt: float = 0.0
    dup: float = 0.0
    delay: float = 0.0
    stale: int = 2
    backoff_base: int = 2
    backoff_cap: int = 32

    def __post_init__(self):
        for k in _RATE_KEYS:
            v = getattr(self, k)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"fault rate {k}={v} must be in [0, 1]")
        if sum(getattr(self, k) for k in _RATE_KEYS) > 1.0:
            raise ValueError("fault rates must sum to <= 1 (one event per message)")
        if self.stale < 0:
            raise ValueError(f"stale bound must be >= 0, got {self.stale}")
        if self.backoff_base < 1 or self.backoff_cap < 1:
            raise ValueError("backoff base/cap must be >= 1")

    @property
    def active(self) -> bool:
        """Whether any fault lane can fire — inactive specs must leave every
        code path byte-identical to ``faults=None``."""
        return any(getattr(self, k) > 0.0 for k in _RATE_KEYS)

    def __str__(self) -> str:
        parts = [f"{k}:{getattr(self, k):g}" for k in _RATE_KEYS if getattr(self, k) > 0]
        parts.append(f"stale:{self.stale}")
        return ",".join(parts)


def parse_fault_spec(spec) -> FaultSpec | None:
    """``"drop:0.05,corrupt:0.01,stale:2"`` -> :class:`FaultSpec`.

    Accepts an existing spec (returned as-is), None/"" (no faults), the rate
    keys, ``stale`` and ``backoff``/``backoff_cap``.  A spec whose rates are
    all zero parses to None — "no faults configured" and "faults at rate 0"
    are the same program, and tests pin that equivalence.
    """
    if spec is None or isinstance(spec, FaultSpec):
        return spec if spec is None or spec.active else None
    text = str(spec).strip()
    if not text:
        return None
    kw: dict[str, Any] = {}
    for item in text.split(","):
        item = item.strip()
        if not item:
            continue
        if ":" not in item:
            raise ValueError(
                f"bad fault-spec item {item!r}; expected key:value pairs like "
                "'drop:0.05,corrupt:0.01,stale:2'"
            )
        k, v = (s.strip() for s in item.split(":", 1))
        if k in _RATE_KEYS:
            kw[k] = float(v)
        elif k == "stale":
            kw["stale"] = int(v)
        elif k in ("backoff", "backoff_base"):
            kw["backoff_base"] = int(v)
        elif k == "backoff_cap":
            kw["backoff_cap"] = int(v)
        else:
            raise ValueError(
                f"unknown fault-spec key {k!r}; valid: "
                f"{', '.join(_RATE_KEYS + ('stale', 'backoff', 'backoff_cap'))}"
            )
    out = FaultSpec(**kw)
    return out if out.active else None


# ============================================================== fault events
class FaultEvents(NamedTuple):
    """One round's classified message events, [n_ops, m] each (global node
    axis — every device derives the same arrays from the replicated fault
    key, so receiver-side gating and sender-side billing agree by
    construction)."""

    drop: jax.Array  # bool: nothing arrives
    corrupt: jax.Array  # bool: arrives garbled, digest discards it
    dup: jax.Array  # bool: arrives twice, second copy deduplicated
    delay: jax.Array  # bool: arrives too late, discarded == drop


def sample_events(spec: FaultSpec, key: jax.Array, n_ops: int, m: int) -> FaultEvents:
    """Classify one uniform draw per (op, receiver) into the event lanes.

    Pure function of ``key`` — the rolled and ppermute backends (and tests
    reconstructing ground truth) call this with the same round key and get
    byte-identical events.
    """
    u = jax.random.uniform(key, (n_ops, m))
    t0 = spec.drop
    t1 = t0 + spec.corrupt
    t2 = t1 + spec.dup
    t3 = t2 + spec.delay
    return FaultEvents(
        drop=u < t0,
        corrupt=(u >= t0) & (u < t1),
        dup=(u >= t1) & (u < t2),
        delay=(u >= t2) & (u < t3),
    )


# ==================================================================== digest
def digest(x: jax.Array, axis_start: int = 1) -> jax.Array:
    """32-bit wraparound checksum of the raw bits, reduced over the inner
    dims: [block, ...] -> [block] int32.

    Bitcast to the same-width integer type, widen to int32, sum (int32
    addition wraps identically on every backend, and commutes — the
    reduction order XLA picks cannot change the value).  Two arrays digest
    equal iff their byte content matches, modulo the 2^-32 collision
    budget; in particular a mirror kept bit-identical to the sender's hat
    (the PR 5 invariant) digests equal *by construction*, with no dtype or
    rounding caveats.
    """
    nbits = x.dtype.itemsize * 8
    if not jnp.issubdtype(x.dtype, jnp.integer):
        x = jax.lax.bitcast_convert_type(x, jnp.dtype(f"int{nbits}"))
    x = x.astype(jnp.int32)
    axes = tuple(range(axis_start, x.ndim))
    return x.sum(axes) if axes else x


_GARBLE32 = np.int32(np.uint32(0x5A5A5A5A).view(np.int32))
_GARBLE16 = np.int16(np.uint16(0x5A5A).view(np.int16))


def garble(x: jax.Array) -> jax.Array:
    """Deterministic in-flight corruption: XOR every element's bits with a
    fixed pattern.  Bijective (so distinct payloads stay distinct) and never
    the identity, which makes the digest mismatch structural rather than
    probabilistic."""
    nbits = x.dtype.itemsize * 8
    if jnp.issubdtype(x.dtype, jnp.integer):
        return x ^ jnp.asarray(_GARBLE16 if nbits == 16 else _GARBLE32, x.dtype)
    it = jnp.dtype(f"int{nbits}")
    bits = jax.lax.bitcast_convert_type(x, it)
    bits = bits ^ jnp.asarray(_GARBLE16 if nbits == 16 else _GARBLE32, it)
    return jax.lax.bitcast_convert_type(bits, x.dtype)


# ================================================================ FaultState
class FaultState(NamedTuple):
    """Per-edge recovery state machine + realized-bits meter.

    Edge arrays are [m, n_ops] (receiver-major so the node axis shards like
    every other stacked leaf); telemetry is per-node [m].  Lives in
    ``CHOCOState.fault`` and threads through checkpoints untouched — resume
    restores the exact staleness/backoff/meter picture, which is what makes
    kill-and-resume under faults bit-identical.
    """

    synced: jax.Array  # [m, n_ops] f32: 1 = mirror bit-identical to sender hat
    stale: jax.Array  # [m, n_ops] i32: rounds since the mirror last verified
    wait: jax.Array  # [m, n_ops] i32: rounds until the next resync attempt
    backoff: jax.Array  # [m, n_ops] i32: failed-resync count (wait = base^k)
    detected: jax.Array  # [m] i32: cumulative divergence detections (receiver)
    resyncs: jax.Array  # [m] i32: cumulative verified resyncs (receiver)
    bits: jax.Array  # [m] f32: wire bits this node delivered last round


def init_fault_state(m: int, n_ops: int) -> FaultState:
    return FaultState(
        synced=jnp.ones((m, n_ops), jnp.float32),
        stale=jnp.zeros((m, n_ops), jnp.int32),
        wait=jnp.zeros((m, n_ops), jnp.int32),
        backoff=jnp.zeros((m, n_ops), jnp.int32),
        detected=jnp.zeros((m,), jnp.int32),
        resyncs=jnp.zeros((m,), jnp.int32),
        bits=jnp.zeros((m,), jnp.float32),
    )


def update_fault_state(fs: FaultState, delta_ok, resync_ok, want,
                       spec: FaultSpec, bits_sent) -> FaultState:
    """Advance the per-edge recovery state machine by one round.

    ``delta_ok`` / ``resync_ok`` / ``want`` are op-major ``[n_ops, block]``
    (the layout the round body produces them in); the state arrays are
    receiver-major ``[block, n_ops]`` (the layout they shard in).  An edge is
    *verified* this round when either its hat-delta applied cleanly or a
    requested resync landed; any other outcome ages the mirror.  A
    wanted-but-failed resync escalates the retry schedule — the next attempt
    waits ``base^(k+1)`` rounds (capped) after the k-th failure — while a
    verified edge resets staleness, wait and backoff to zero.
    """
    d_ok, r_ok, want_t = delta_ok.T, resync_ok.T, want.T
    now = d_ok | r_ok
    newly = (fs.synced > 0.0) & ~now
    failed = want_t & ~r_ok
    # the power in f32: the exponent is traced, and an int32 base**k would
    # silently wrap past k ~ 31; inf from a huge base still minimums to cap
    pw = jnp.minimum(
        jnp.power(jnp.float32(spec.backoff_base),
                  jnp.minimum(fs.backoff + 1, 16).astype(jnp.float32)),
        jnp.float32(spec.backoff_cap),
    ).astype(jnp.int32)
    return FaultState(
        synced=now.astype(jnp.float32),
        stale=jnp.where(now, 0, fs.stale + 1),
        wait=jnp.where(now, 0, jnp.where(failed, pw, jnp.maximum(fs.wait - 1, 0))),
        backoff=jnp.where(now, 0, jnp.where(failed, fs.backoff + 1, fs.backoff)),
        detected=fs.detected + newly.sum(1).astype(jnp.int32),
        resyncs=fs.resyncs + (want_t & r_ok).sum(1).astype(jnp.int32),
        bits=bits_sent,
    )


class WireBits(NamedTuple):
    """Realized-bits meter for *memoryless* faulted wires (exact consensus,
    the dual/lambda gossip): there are no mirrors to heal — a faulted message
    simply leaves that round's mix — so the whole per-round fault state is
    the bits each node's sends actually delivered.  Kept as a NamedTuple so
    the consensus state keeps a stable pytree structure whether or not a
    fault spec is active on the exact path."""

    bits: jax.Array  # [m] f32


def receiver_maps(union) -> tuple[np.ndarray, ...]:
    """Static inverse of the union's sender maps: ``rcv[k][j]`` = the node
    that receives node ``j``'s message on op ``k`` (-1 when ``j`` does not
    send).  Lets sender-side billing gather receiver-indexed event arrays
    with static indices — no extra wire traffic to meter the wire."""
    out = []
    for snd in union.senders:
        rcv = np.full_like(np.asarray(snd, np.int64), -1)
        idx = np.nonzero(np.asarray(snd) >= 0)[0]
        rcv[np.asarray(snd)[idx]] = idx
        out.append(rcv)
    return tuple(out)
