"""Communication topologies and mixing matrices for decentralized gossip.

The paper (Assumption 3.1) requires a symmetric, doubly-stochastic mixing
matrix W with spectral gap rho = 1 - |lambda_2(W)| in (0, 1].  We provide the
topologies used in the paper's experiments (ring, 2D torus, fully-connected
mesh, star for the DRFA baseline) plus Erdos-Renyi graphs with Metropolis
weights for irregular degree distributions.

A ``Topology`` also knows its *neighbor shift structure*: for
circulant-symmetric graphs (ring, torus, mesh) the mixing
``sum_j w_ij x_j`` can be executed as a sum of ``jnp.roll`` operations along
the node axis, which XLA lowers to ``collective-permute`` on TPU instead of an
all-gather — this is what makes sparse gossip cheap on ICI/DCN.

Real deployments are not static graphs where every node survives every
round: links flap, nodes drop out and rejoin (the setting of Ghiasvand et
al. 2025 and DRFA's sampled participation).  :class:`TopologySchedule`
models that — a round-indexed family of topologies (static, round-robin
over a graph family, random one-peer matchings) optionally decorated with
Bernoulli node dropout.  The schedule side stays host/numpy for graph
construction but exposes ``mixing_at(t, mask)`` which works on *traced*
round indices and participation masks: the per-phase mixing matrices are
stacked into a bank gathered with ``dynamic_index_in_dim``, and the dropout
rescale recomputes Metropolis weights on the surviving subgraph in-graph,
so W(t) stays symmetric doubly-stochastic every round (dead nodes get the
identity row/column and simply hold their state).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "Topology",
    "TopologySchedule",
    "StaticSchedule",
    "RoundRobinSchedule",
    "MatchingSchedule",
    "BernoulliDropout",
    "EdgeStep",
    "PermutePlan",
    "compile_permute_plan",
    "compile_schedule_plans",
    "ring",
    "torus_2d",
    "mesh",
    "star",
    "erdos_renyi",
    "metropolis_weights",
    "masked_metropolis",
    "spectral_gap",
    "make_topology",
    "make_topology_schedule",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip communication topology.

    Attributes:
      name: human-readable identifier.
      adjacency: [m, m] 0/1 numpy array (with self-loops on the diagonal).
      mixing: [m, m] symmetric doubly-stochastic numpy array, supported on
        the adjacency.
      shifts: optional circulant decomposition — list of (shift, weight)
        pairs such that ``sum_j w_ij x_j == sum_k weight_k * roll(x, shift_k)``
        along the node axis.  ``None`` when the graph is not circulant.
    """

    name: str
    adjacency: np.ndarray
    mixing: np.ndarray
    shifts: tuple[tuple[int, float], ...] | None = None

    @property
    def num_nodes(self) -> int:
        return self.mixing.shape[0]

    @property
    def spectral_gap(self) -> float:
        return spectral_gap(self.mixing)

    @property
    def beta(self) -> float:
        """beta = ||I - W||_2 as in Assumption 3.1."""
        m = self.mixing.shape[0]
        return float(np.linalg.norm(np.eye(m) - self.mixing, ord=2))

    @property
    def max_degree(self) -> int:
        """Max number of neighbors (excluding self) — the 'busiest node'."""
        return int((self.adjacency - np.eye(self.num_nodes)).sum(axis=1).max())

    @property
    def expected_degree(self) -> float:
        """Expected per-round active links of the busiest node.  A static
        graph with full participation realizes its max degree every round."""
        return float(self.max_degree)

    def realized_degree(self, t: int, mask) -> float:
        """Busiest node's *realized* active links under a concrete
        participation mask: a dropped node sends nothing, and links to
        dropped neighbors carry nothing."""
        alive = np.asarray(mask, np.float64).reshape(-1)
        off = self.adjacency - np.eye(self.num_nodes)
        return float((alive * (off * alive[None, :]).sum(axis=1)).max())

    def realized_degree_traced(self, t, mask):
        """Jittable :meth:`realized_degree` — a traced scalar the trainer
        threads into its per-round ``bits_realized`` aux."""
        import jax.numpy as jnp

        off = jnp.asarray(
            self.adjacency - np.eye(self.num_nodes), jnp.float32
        )
        if mask is None:
            return jnp.float32(self.max_degree)
        alive = mask.astype(jnp.float32)
        return (alive * (off @ alive)).max()

    def consensus_step_size(self, delta: float) -> float:
        """Theorem 4.1/4.3 consensus step size gamma for compression factor delta."""
        return _theorem_gamma(self.spectral_gap, self.beta, delta)


def spectral_gap(w: np.ndarray) -> float:
    """rho = 1 - |lambda_2|: gap between the two largest eigenvalue moduli."""
    eig = np.sort(np.abs(np.linalg.eigvalsh(w)))[::-1]
    return float(1.0 - eig[1]) if eig.shape[0] > 1 else 1.0


def _theorem_gamma(rho: float, beta: float, delta: float) -> float:
    """Theorem 4.1/4.3 gamma from spectral gap rho and beta = ||I - W||."""
    return rho**2 * delta / (
        16 * rho + rho**2 + 4 * beta**2 + 2 * rho * beta**2 - 8 * rho * delta
    )


def _circulant_mixing(m: int, shifts: Sequence[tuple[int, float]]) -> np.ndarray:
    w = np.zeros((m, m))
    for shift, weight in shifts:
        w += weight * np.roll(np.eye(m), shift, axis=1)
    return w


def ring(m: int, self_weight: float | None = None) -> Topology:
    """Ring: each node talks to its two neighbors (paper §5.1)."""
    if m < 2:
        return mesh(1)
    if m == 2:
        return mesh(2)
    w_self = 1.0 / 3.0 if self_weight is None else self_weight
    w_side = (1.0 - w_self) / 2.0
    shifts = ((0, w_self), (1, w_side), (-1, w_side))
    w = _circulant_mixing(m, shifts)
    adj = (w > 0).astype(np.float64)
    return Topology("ring", adj, w, shifts)


def torus_2d(m: int) -> Topology:
    """2D torus: each node has 4 neighbors (paper §5.2, Metropolis weights).

    For non-square m we fall back to a circulant 4-regular graph
    (neighbors at offsets ±1, ±floor(sqrt(m))), which preserves the degree
    structure and the roll decomposition.
    """
    side = int(round(math.sqrt(m)))
    stride = side if side * side == m else max(2, side)
    if m <= 4:
        return mesh(m)
    # uniform (Metropolis on a regular graph) weights: 1/5 each incl. self
    w_each = 1.0 / 5.0
    shifts = ((0, w_each), (1, w_each), (-1, w_each), (stride, w_each), (-stride, w_each))
    # degenerate overlap (e.g. m=4, stride=2): rebuild by accumulation
    w = _circulant_mixing(m, shifts)
    adj = (w > 0).astype(np.float64)
    return Topology("torus", adj, w, shifts)


def mesh(m: int) -> Topology:
    """Fully-connected: W = (1/m) 11^T — one-shot consensus."""
    w = np.full((m, m), 1.0 / m)
    adj = np.ones((m, m))
    shifts = tuple((k, 1.0 / m) for k in range(m))
    return Topology("mesh", adj, w, shifts)


def star(m: int) -> Topology:
    """Star topology (used by the DRFA client-server baseline).

    Metropolis weights keep W doubly stochastic; note rho degrades as O(1/m).
    """
    adj = np.eye(m)
    adj[0, :] = 1.0
    adj[:, 0] = 1.0
    w = metropolis_weights(adj)
    return Topology("star", adj, w, None)


def erdos_renyi(m: int, p: float, seed: int = 0) -> Topology:
    """Connected Erdos-Renyi graph with Metropolis weights (resampled until
    connected)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        upper = rng.random((m, m)) < p
        adj = np.triu(upper, 1)
        adj = adj + adj.T + np.eye(m, dtype=bool)
        if _connected(adj):
            w = metropolis_weights(adj.astype(np.float64))
            return Topology("erdos_renyi", adj.astype(np.float64), w, None)
    raise ValueError(f"could not sample a connected G({m}, {p})")


def _connected(adj: np.ndarray) -> bool:
    m = adj.shape[0]
    reach = np.eye(m, dtype=bool)
    frontier = reach
    for _ in range(m):
        frontier = (frontier @ adj) > 0
        new = frontier & ~reach
        if not new.any():
            break
        reach |= new
    return bool(reach[0].all())


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings weights: symmetric doubly-stochastic on any graph.

    w_ij = 1 / (1 + max(deg_i, deg_j)) for edges, diagonal absorbs the rest.
    """
    m = adj.shape[0]
    deg = (adj - np.eye(m)).sum(axis=1)
    w = np.zeros((m, m))
    for i in range(m):
        for j in range(m):
            if i != j and adj[i, j] > 0:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def _erdos_renyi_factory(m: int, p: float = 0.3, seed: int = 0) -> Topology:
    """`make_topology` adapter: defaults ``p`` so ``--topology erdos_renyi``
    works without extra flags while still accepting ``p``/``seed`` kwargs."""
    return erdos_renyi(m, p=p, seed=seed)


_FACTORIES = {
    "ring": ring,
    "torus": torus_2d,
    "mesh": mesh,
    "star": star,
    "erdos_renyi": _erdos_renyi_factory,
}


def make_topology(name: str, m: int, **kwargs) -> Topology:
    if name not in _FACTORIES:
        raise ValueError(f"unknown topology {name!r}; choose from {sorted(_FACTORIES)}")
    return _FACTORIES[name](m, **kwargs)


# =========================================================== time variation
def masked_metropolis(adjacency, alive):
    """Metropolis weights on the subgraph induced by ``alive`` (jnp, traceable).

    ``adjacency`` is [m, m] (self-loops on the diagonal), ``alive`` a 0/1
    float [m] participation mask.  Edges touching a dead node are removed and
    degrees recomputed on the survivors, so the result is symmetric
    doubly-stochastic for *every* mask: dead nodes degenerate to the identity
    row/column (w_ii = 1 — they hold their state and contribute nothing).

    Implemented with jnp ops only so it can run inside a jitted round on a
    per-round Bernoulli mask.
    """
    import jax.numpy as jnp

    adjacency = jnp.asarray(adjacency, jnp.float32)
    alive = jnp.asarray(alive, jnp.float32)
    m = adjacency.shape[0]
    eye = jnp.eye(m, dtype=jnp.float32)
    off = adjacency * (1.0 - eye) * alive[:, None] * alive[None, :]
    deg = off.sum(axis=1)
    w = off / (1.0 + jnp.maximum(deg[:, None], deg[None, :]))
    return w + jnp.diag(1.0 - w.sum(axis=1))


class TopologySchedule:
    """A round-indexed sequence of topologies W(t) with period P.

    Host-side analysis (spectral gaps, gamma resolution, bits accounting)
    uses the numpy phase topologies; the jitted training step calls
    :meth:`mixing_at` with a traced round index (and optional participation
    mask) and gets the round's dense [m, m] mixing matrix.

    ``dropout_rate == 0`` here; :class:`BernoulliDropout` decorates any
    schedule with per-round node dropout.  A schedule with ``period == 1``
    and no dropout is *static* — consumers can (and do) unwrap it to the
    plain :class:`Topology` fast paths (circulant shifts, packed/fused
    gossip), which keeps the static case bit-identical to the pre-schedule
    code.
    """

    dropout_rate: float = 0.0

    def __init__(self, topologies: Sequence[Topology], name: str | None = None):
        topologies = tuple(topologies)
        if not topologies:
            raise ValueError("schedule needs at least one topology")
        m = topologies[0].num_nodes
        if any(t.num_nodes != m for t in topologies):
            raise ValueError("all phases of a schedule must have the same num_nodes")
        self.topologies = topologies
        self.name = name or "+".join(t.name for t in topologies)
        # [P, m, m] banks, gathered by t % P inside the jitted step
        self.mixing_bank = np.stack([t.mixing for t in topologies])
        self.adjacency_bank = np.stack([t.adjacency for t in topologies])

    # ------------------------------------------------------------- host side
    @property
    def period(self) -> int:
        return len(self.topologies)

    @property
    def num_nodes(self) -> int:
        return self.topologies[0].num_nodes

    @property
    def is_static(self) -> bool:
        return self.period == 1 and self.dropout_rate == 0.0

    def topology_at(self, t: int) -> Topology:
        return self.topologies[int(t) % self.period]

    @property
    def spectral_gap(self) -> float:
        """Worst phase — conservative for step-size theory."""
        return min(t.spectral_gap for t in self.topologies)

    @property
    def beta(self) -> float:
        return max(t.beta for t in self.topologies)

    @property
    def max_degree(self) -> int:
        """Busiest node over all phases (bits accounting upper bound)."""
        return max(t.max_degree for t in self.topologies)

    @property
    def expected_degree(self) -> float:
        """Expected per-round active links of the busiest node, participation
        aware: the busiest node's *phase-averaged* degree times the
        probability both endpoints of a link survive the round
        ((1 - rate)^2 under i.i.d. Bernoulli dropout).  This is what a
        realized-bits meter converges to, vs. the ``max_degree`` upper bound
        that bills every round at the busiest phase with everyone alive."""
        m = self.num_nodes
        deg = np.stack(
            [(t.adjacency - np.eye(m)).sum(axis=1) for t in self.topologies]
        )
        keep = (1.0 - self.dropout_rate) ** 2
        return float(deg.mean(axis=0).max() * keep)

    def realized_degree(self, t: int, mask) -> float:
        """Busiest node's realized active links in round ``t``'s phase under
        a concrete participation mask."""
        return self.topology_at(t).realized_degree(t, mask)

    def realized_degree_traced(self, t, mask):
        """Jittable :meth:`realized_degree`: gathers round ``t``'s phase
        adjacency from the bank and counts surviving links in-graph."""
        import jax.numpy as jnp

        m = self.num_nodes
        off = self.adjacency_at(t) * (1.0 - jnp.eye(m, dtype=jnp.float32))
        if mask is None:
            return off.sum(axis=1).max()
        alive = mask.astype(jnp.float32)
        return (alive * (off @ alive)).max()

    def consensus_step_size(self, delta: float) -> float:
        """Theorem 4.1 gamma, evaluated conservatively for the schedule.

        Uses the worst (smallest-gap) phase when every phase is connected.
        Schedules whose individual phases are disconnected (e.g. one-peer
        matchings: each W(t) = I/2 + M/2 has |lambda_2| = 1) only mix *over
        the period*, so the worst-phase formula would silently return
        gamma = 0 and consensus would never move; fall back to the
        period-mean mixing matrix W-bar = (1/P) sum_t W(t), whose gap is
        positive whenever the union graph is connected.  Raise if even the
        union never connects — gamma='theory' is meaningless there.
        """
        worst = min(self.topologies, key=lambda t: t.spectral_gap)
        if worst.spectral_gap > 1e-9:
            return worst.consensus_step_size(delta)
        wbar = self.mixing_bank.mean(axis=0)
        rho = spectral_gap(wbar)
        if rho <= 1e-9:
            raise ValueError(
                f"schedule {self.name!r} never connects (union graph gap 0); "
                "gamma='theory' is undefined — pass a numeric gamma instead"
            )
        beta = float(np.linalg.norm(np.eye(self.num_nodes) - wbar, ord=2))
        return _theorem_gamma(rho, beta, delta)

    # ----------------------------------------------------------- traced side
    def _phase(self, t):
        import jax.numpy as jnp

        if self.period == 1:
            return jnp.zeros((), jnp.int32)
        return jnp.asarray(t, jnp.int32) % self.period

    def mask_at(self, key, t):
        """Participation mask for round ``t`` (None == everyone alive)."""
        return None

    def adjacency_at(self, t):
        import jax
        import jax.numpy as jnp

        bank = jnp.asarray(self.adjacency_bank, jnp.float32)
        if self.period == 1:
            return bank[0]
        return jax.lax.dynamic_index_in_dim(bank, self._phase(t), 0, keepdims=False)

    def mixing_at(self, t, mask=None):
        """Dense [m, m] mixing matrix for round ``t`` under ``mask``.

        With a mask the phase's *adjacency* is re-weighted with Metropolis
        weights on the surviving subgraph (doubly stochastic for every mask);
        without one the phase's own mixing matrix is used verbatim.
        """
        import jax
        import jax.numpy as jnp

        if mask is not None:
            return masked_metropolis(self.adjacency_at(t), mask)
        bank = jnp.asarray(self.mixing_bank, jnp.float32)
        if self.period == 1:
            return bank[0]
        return jax.lax.dynamic_index_in_dim(bank, self._phase(t), 0, keepdims=False)


class StaticSchedule(TopologySchedule):
    """Trivial schedule: the same topology every round."""

    def __init__(self, topology: Topology):
        super().__init__((topology,), name=topology.name)


class RoundRobinSchedule(TopologySchedule):
    """Cycle deterministically over a family of graphs (e.g. ring -> torus)."""

    def __init__(self, topologies: Sequence[Topology]):
        super().__init__(topologies)


class MatchingSchedule(TopologySchedule):
    """Random one-peer matchings: each round every node gossips with (at
    most) one partner, chosen from ``period`` pre-sampled perfect matchings.

    The per-phase mixing is W = I/2 + M/2 for the matching's permutation
    matrix M (odd node out keeps w_ii = 1) — symmetric doubly stochastic
    with max degree 1, the cheapest possible round.
    """

    def __init__(self, m: int, period: int = 8, seed: int = 0):
        rng = np.random.default_rng(seed)
        phases = []
        for _ in range(max(1, period)):
            perm = rng.permutation(m)
            w = np.eye(m)
            for a in range(0, m - 1, 2):
                i, j = int(perm[a]), int(perm[a + 1])
                w[i, i] = w[j, j] = 0.5
                w[i, j] = w[j, i] = 0.5
            adj = (w > 0).astype(np.float64)
            phases.append(Topology("matching", adj, w, None))
        super().__init__(phases, name="matching")


class BernoulliDropout(TopologySchedule):
    """Decorator: i.i.d. per-node Bernoulli dropout on top of any schedule.

    Each round every node survives with probability ``1 - rate``; the
    surviving subgraph's Metropolis weights keep W(t) doubly stochastic, and
    dead nodes get the identity row (they hold their state until they
    rejoin).  Note that for ``rate > 0`` even the all-alive mask routes
    through the Metropolis rescale, so custom self-weights of the base graph
    are replaced by Metropolis ones (identical for ring/torus/mesh).
    """

    def __init__(self, base: TopologySchedule | Topology, rate: float):
        if isinstance(base, Topology):
            base = StaticSchedule(base)
        if not 0.0 <= rate < 1.0:
            raise ValueError(f"dropout rate must be in [0, 1); got {rate}")
        super().__init__(base.topologies, name=f"{base.name}+drop{rate:g}")
        self.base = base
        self.dropout_rate = float(rate)

    def mask_at(self, key, t):
        import jax
        import jax.numpy as jnp

        if self.dropout_rate == 0.0:
            return None
        keep = jax.random.bernoulli(
            key, 1.0 - self.dropout_rate, (self.num_nodes,)
        ).astype(jnp.float32)
        return keep


def make_topology_schedule(
    spec: str,
    m: int,
    *,
    dropout: float = 0.0,
    period: int = 8,
    seed: int = 0,
    **topo_kwargs,
) -> TopologySchedule:
    """Parse a schedule spec into a :class:`TopologySchedule`.

    Specs:
      * any ``make_topology`` name (``"ring"``, ``"erdos_renyi"`` ...) — static;
      * ``"roundrobin:ring,torus"`` — deterministic cycle over the family;
      * ``"matching"`` / ``"matching:P"`` — P random one-peer matchings.

    ``dropout > 0`` wraps the result in :class:`BernoulliDropout`.
    ``topo_kwargs`` go to the single-topology (static) factory only (e.g.
    ``p``/``seed`` for ``erdos_renyi``); roundrobin phases use factory
    defaults and the explicit ``seed`` kwarg seeds matchings.
    """
    spec = spec.strip()
    if spec.startswith("roundrobin:"):
        names = [s for s in spec[len("roundrobin:"):].split(",") if s]
        if not names:
            raise ValueError(f"empty roundrobin schedule spec {spec!r}")
        sched: TopologySchedule = RoundRobinSchedule(
            [make_topology(n.strip(), m) for n in names]
        )
    elif spec == "matching" or spec.startswith("matching:"):
        p = int(spec.split(":", 1)[1]) if ":" in spec else period
        sched = MatchingSchedule(m, period=p, seed=seed)
    else:
        kw = dict(topo_kwargs)
        if spec == "erdos_renyi":
            kw.setdefault("seed", seed)
        sched = StaticSchedule(make_topology(spec, m, **kw))
    if dropout > 0.0:
        sched = BernoulliDropout(sched, dropout)
    return sched


# ======================================================== permute schedules
# Compilation of a mixing matrix into an explicit *neighbor-exchange*
# schedule: the wire program the SPMD gossip backend (core/exchange.py)
# executes with ``jax.lax.ppermute`` instead of simulating the network with
# ``jnp.roll``/dense matmuls on the full stacked array.
#
# Two forms, matching the two graph families:
#
# * circulant graphs (ring / torus / mesh) keep their shift decomposition —
#   every shift is one global roll of the node axis, which the backend
#   executes as (at most) two collective-permutes of boundary slabs per
#   shift, independent of the per-device node-block size;
# * irregular graphs (erdos_renyi, star, matching phases) are decomposed
#   into :class:`EdgeStep` barriers — partial permutations with distinct
#   senders and receivers.  The greedy scheduler below always sends each
#   receiver's *smallest pending sender*, so every node receives its
#   neighbors in ascending id order (deterministic, and the closest
#   permute-order analogue of the dense oracle's row-major accumulation).


@dataclasses.dataclass(frozen=True)
class EdgeStep:
    """One barrier of pairwise sends: a partial permutation of the nodes.

    ``perm`` is a tuple of (src, dst) node pairs with distinct sources and
    distinct destinations (the ``jax.lax.ppermute`` contract); ``weights``
    is the length-m receive weight vector — ``weights[dst] = W[dst, src]``
    for every pair, 0.0 for nodes that receive nothing this step.
    """

    perm: tuple[tuple[int, int], ...]
    weights: tuple[float, ...]


@dataclasses.dataclass(frozen=True)
class PermutePlan:
    """Neighbor-exchange schedule realizing one mixing matrix W.

    Exactly one of the two wire forms is populated:

    * ``shifts`` — the circulant decomposition, verbatim from
      :attr:`Topology.shifts` (order preserved: the SPMD mix accumulates in
      the same order as the rolled oracle, which is what makes the static
      circulant path bit-identical);
    * ``steps`` — per-edge :class:`EdgeStep` barriers for irregular graphs.

    ``self_weight`` is the diagonal of W (every node's own weight).
    ``mixing_matrix()`` reconstructs the dense W exactly (element-level
    copies, no arithmetic beyond the circulant accumulation the factories
    themselves used) — the round-trip tested by tests/test_permute_plan.py.
    """

    name: str
    num_nodes: int
    shifts: tuple[tuple[int, float], ...] | None
    steps: tuple[EdgeStep, ...]
    self_weight: tuple[float, ...]

    @property
    def is_circulant(self) -> bool:
        return self.shifts is not None

    @property
    def num_exchanges(self) -> int:
        """Neighbor exchanges per round (the wire's barrier count)."""
        return len(self.exchange_ops())

    def exchange_ops(self) -> tuple[tuple[str, object], ...]:
        """The executable op list, aligned index-for-index with
        :meth:`sender_maps`: ``("shift", s)`` for a circulant roll by ``s``
        (normalized mod m, deduplicated), ``("perm", pairs)`` for an
        irregular edge step's (src, dst) partial permutation."""
        m = self.num_nodes
        ops: list[tuple[str, object]] = []
        if self.shifts is not None:
            seen = set()
            for shift, _ in self.shifts:
                s = shift % m
                if s == 0 or s in seen:
                    continue
                seen.add(s)
                ops.append(("shift", s))
        else:
            for step in self.steps:
                ops.append(("perm", step.perm))
        return tuple(ops)

    def sender_maps(self) -> tuple[np.ndarray, ...]:
        """One int array [m] per exchange, derived from (and therefore always
        aligned index-for-index with) :meth:`exchange_ops`: ``snd[i]`` = the
        node whose value node i receives (−1 when i receives nothing).  Each
        adjacency edge appears exactly once — this is the op list the
        masked-Metropolis weight computation runs over.
        """
        m = self.num_nodes
        maps = []
        for kind, arg in self.exchange_ops():
            if kind == "shift":
                maps.append((np.arange(m) - arg) % m)
            else:
                snd = np.full((m,), -1, np.int64)
                for src, dst in arg:
                    snd[dst] = src
                maps.append(snd)
        return tuple(maps)

    def mixing_matrix(self) -> np.ndarray:
        """Dense W reconstructed from the schedule — exact round-trip."""
        m = self.num_nodes
        if self.shifts is not None:
            return _circulant_mixing(m, self.shifts)
        w = np.zeros((m, m))
        for step in self.steps:
            for src, dst in step.perm:
                w[dst, src] = step.weights[dst]
        w[np.diag_indices(m)] = np.asarray(self.self_weight)
        return w

    def masked_mixing_matrix(self, mask) -> np.ndarray:
        """Masked-Metropolis W on the surviving subgraph, computed the way
        the SPMD backend computes it *locally*: participation bits travel the
        plan's own exchanges, degrees are per-op sums of alive bits, and the
        self weight is 1 − the op-ordered sum of edge weights.  Mirrors
        :func:`masked_metropolis` (same formula on the same edge set) up to
        f32 summation order — the host-side oracle for the dropout-rescale
        round-trip test.
        """
        m = self.num_nodes
        alive = np.asarray(mask, np.float32).reshape(m)
        senders = self.sender_maps()
        deg = np.zeros((m,), np.float32)
        for snd in senders:
            has = snd >= 0
            deg[has] += alive[has] * alive[snd[has]]
        w = np.zeros((m, m), np.float32)
        off = np.zeros((m,), np.float32)
        for snd in senders:
            has = snd >= 0
            i = np.nonzero(has)[0]
            j = snd[i]
            wij = alive[i] * alive[j] / (1.0 + np.maximum(deg[i], deg[j]))
            w[i, j] = wij
            off[i] += wij
        w[np.diag_indices(m)] = 1.0 - off
        return w


def compile_permute_plan(topology: Topology) -> PermutePlan:
    """Compile a :class:`Topology` into a :class:`PermutePlan`.

    Circulant graphs keep their shift decomposition verbatim.  Irregular
    graphs get a greedy edge decomposition: repeatedly form a partial
    permutation by giving every receiver its smallest not-yet-received
    sender (skipping receivers whose turn would reuse a sender already
    claimed this step).  The step count is within one of the max degree for
    every graph in the repo, and every node receives in ascending sender
    order.
    """
    m = topology.num_nodes
    self_weight = tuple(float(x) for x in np.diag(topology.mixing))
    if topology.shifts is not None:
        return PermutePlan(topology.name, m, tuple(topology.shifts), (), self_weight)
    adj = np.asarray(topology.adjacency) - np.eye(m)
    mixing = np.asarray(topology.mixing)
    pending = {i: [int(j) for j in np.nonzero(adj[i] > 0)[0]] for i in range(m)}
    steps: list[EdgeStep] = []
    while any(pending.values()):
        used_src: set[int] = set()
        perm: list[tuple[int, int]] = []
        weights = [0.0] * m
        for i in range(m):
            if pending[i] and pending[i][0] not in used_src:
                j = pending[i].pop(0)
                used_src.add(j)
                perm.append((j, i))
                weights[i] = float(mixing[i, j])
        steps.append(EdgeStep(tuple(perm), tuple(weights)))
    return PermutePlan(topology.name, m, None, tuple(steps), self_weight)


def compile_schedule_plans(schedule: TopologySchedule) -> tuple[PermutePlan, ...]:
    """One :class:`PermutePlan` per phase of a :class:`TopologySchedule` —
    the per-phase wire programs the SPMD backend selects between with
    ``lax.switch`` on the (traced) round index."""
    return tuple(compile_permute_plan(t) for t in schedule.topologies)
