"""Communication topologies and mixing matrices for decentralized gossip.

The paper (Assumption 3.1) requires a symmetric, doubly-stochastic mixing
matrix W with spectral gap rho = 1 - |lambda_2(W)| in (0, 1].  We provide the
topologies used in the paper's experiments (ring, 2D torus, fully-connected
mesh, star for the DRFA baseline) plus Erdos-Renyi graphs with Metropolis
weights for irregular degree distributions.

A ``Topology`` also knows its *neighbor shift structure*: for
circulant-symmetric graphs (ring, torus, mesh) the mixing
``sum_j w_ij x_j`` can be executed as a sum of ``jnp.roll`` operations along
the node axis, which XLA lowers to ``collective-permute`` on TPU instead of an
all-gather — this is what makes sparse gossip cheap on ICI/DCN.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "Topology",
    "ring",
    "torus_2d",
    "mesh",
    "star",
    "erdos_renyi",
    "metropolis_weights",
    "spectral_gap",
    "make_topology",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip communication topology.

    Attributes:
      name: human-readable identifier.
      adjacency: [m, m] 0/1 numpy array (with self-loops on the diagonal).
      mixing: [m, m] symmetric doubly-stochastic numpy array, supported on
        the adjacency.
      shifts: optional circulant decomposition — list of (shift, weight)
        pairs such that ``sum_j w_ij x_j == sum_k weight_k * roll(x, shift_k)``
        along the node axis.  ``None`` when the graph is not circulant.
    """

    name: str
    adjacency: np.ndarray
    mixing: np.ndarray
    shifts: tuple[tuple[int, float], ...] | None = None

    @property
    def num_nodes(self) -> int:
        return self.mixing.shape[0]

    @property
    def spectral_gap(self) -> float:
        return spectral_gap(self.mixing)

    @property
    def beta(self) -> float:
        """beta = ||I - W||_2 as in Assumption 3.1."""
        m = self.mixing.shape[0]
        return float(np.linalg.norm(np.eye(m) - self.mixing, ord=2))

    @property
    def max_degree(self) -> int:
        """Max number of neighbors (excluding self) — the 'busiest node'."""
        return int((self.adjacency - np.eye(self.num_nodes)).sum(axis=1).max())

    def consensus_step_size(self, delta: float) -> float:
        """Theorem 4.1/4.3 consensus step size gamma for compression factor delta."""
        rho, beta = self.spectral_gap, self.beta
        return rho**2 * delta / (
            16 * rho + rho**2 + 4 * beta**2 + 2 * rho * beta**2 - 8 * rho * delta
        )


def spectral_gap(w: np.ndarray) -> float:
    """rho = 1 - |lambda_2|: gap between the two largest eigenvalue moduli."""
    eig = np.sort(np.abs(np.linalg.eigvalsh(w)))[::-1]
    return float(1.0 - eig[1]) if eig.shape[0] > 1 else 1.0


def _circulant_mixing(m: int, shifts: Sequence[tuple[int, float]]) -> np.ndarray:
    w = np.zeros((m, m))
    for shift, weight in shifts:
        w += weight * np.roll(np.eye(m), shift, axis=1)
    return w


def ring(m: int, self_weight: float | None = None) -> Topology:
    """Ring: each node talks to its two neighbors (paper §5.1)."""
    if m < 2:
        return mesh(1)
    if m == 2:
        return mesh(2)
    w_self = 1.0 / 3.0 if self_weight is None else self_weight
    w_side = (1.0 - w_self) / 2.0
    shifts = ((0, w_self), (1, w_side), (-1, w_side))
    w = _circulant_mixing(m, shifts)
    adj = (w > 0).astype(np.float64)
    return Topology("ring", adj, w, shifts)


def torus_2d(m: int) -> Topology:
    """2D torus: each node has 4 neighbors (paper §5.2, Metropolis weights).

    For non-square m we fall back to a circulant 4-regular graph
    (neighbors at offsets ±1, ±floor(sqrt(m))), which preserves the degree
    structure and the roll decomposition.
    """
    side = int(round(math.sqrt(m)))
    stride = side if side * side == m else max(2, side)
    if m <= 4:
        return mesh(m)
    # uniform (Metropolis on a regular graph) weights: 1/5 each incl. self
    w_each = 1.0 / 5.0
    shifts = ((0, w_each), (1, w_each), (-1, w_each), (stride, w_each), (-stride, w_each))
    # degenerate overlap (e.g. m=4, stride=2): rebuild by accumulation
    w = _circulant_mixing(m, shifts)
    adj = (w > 0).astype(np.float64)
    return Topology("torus", adj, w, shifts)


def mesh(m: int) -> Topology:
    """Fully-connected: W = (1/m) 11^T — one-shot consensus."""
    w = np.full((m, m), 1.0 / m)
    adj = np.ones((m, m))
    shifts = tuple((k, 1.0 / m) for k in range(m))
    return Topology("mesh", adj, w, shifts)


def star(m: int) -> Topology:
    """Star topology (used by the DRFA client-server baseline).

    Metropolis weights keep W doubly stochastic; note rho degrades as O(1/m).
    """
    adj = np.eye(m)
    adj[0, :] = 1.0
    adj[:, 0] = 1.0
    w = metropolis_weights(adj)
    return Topology("star", adj, w, None)


def erdos_renyi(m: int, p: float, seed: int = 0) -> Topology:
    """Connected Erdos-Renyi graph with Metropolis weights (resampled until
    connected)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        upper = rng.random((m, m)) < p
        adj = np.triu(upper, 1)
        adj = adj + adj.T + np.eye(m, dtype=bool)
        if _connected(adj):
            w = metropolis_weights(adj.astype(np.float64))
            return Topology("erdos_renyi", adj.astype(np.float64), w, None)
    raise ValueError(f"could not sample a connected G({m}, {p})")


def _connected(adj: np.ndarray) -> bool:
    m = adj.shape[0]
    reach = np.eye(m, dtype=bool)
    frontier = reach
    for _ in range(m):
        frontier = (frontier @ adj) > 0
        new = frontier & ~reach
        if not new.any():
            break
        reach |= new
    return bool(reach[0].all())


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings weights: symmetric doubly-stochastic on any graph.

    w_ij = 1 / (1 + max(deg_i, deg_j)) for edges, diagonal absorbs the rest.
    """
    m = adj.shape[0]
    deg = (adj - np.eye(m)).sum(axis=1)
    w = np.zeros((m, m))
    for i in range(m):
        for j in range(m):
            if i != j and adj[i, j] > 0:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


_FACTORIES = {
    "ring": ring,
    "torus": torus_2d,
    "mesh": mesh,
    "star": star,
}


def make_topology(name: str, m: int, **kwargs) -> Topology:
    if name not in _FACTORIES:
        raise ValueError(f"unknown topology {name!r}; choose from {sorted(_FACTORIES)}")
    return _FACTORIES[name](m, **kwargs)
