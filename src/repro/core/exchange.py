"""Mesh-native neighbor-exchange gossip: the SPMD consensus substrate.

``core/gossip.py`` *simulates* the network on a single stacked array — every
mix is a ``jnp.roll`` over the full node axis or a dense ``[m, m]`` matmul,
and whether that turns into degree-many neighbor messages or an all-gather of
the whole stacked payload is left to GSPMD's sharding propagation.  This
module makes the wire model explicit: ``choco_round_ppermute`` runs the same
CHOCO round under ``jax.experimental.shard_map`` over the mesh's node axes,
where each device holds a contiguous block of nodes and *only compressed
payloads travel between actual graph neighbors* via ``jax.lax.ppermute``:

* circulant graphs (ring / torus / mesh) execute each shift of the
  :class:`~repro.core.topology.PermutePlan` as a global roll of the sharded
  node axis — at most two collective-permutes of boundary slabs per shift,
  independent of the nodes-per-device block size;
* irregular graphs (erdos_renyi, star, matching phases) execute the plan's
  :class:`~repro.core.topology.EdgeStep` barriers — per-edge partial
  permutations (one node per device required; see ROADMAP open items for the
  uneven-ratio generalization);
* time-varying schedules share ONE wire program — the
  :class:`~repro.core.wire.UnionWirePlan` union of all phases' exchange ops
  — whose per-phase mixing weights are gathered from banks by ``t % P``
  (one ``dynamic_index`` per round; the old per-mix-site ``lax.switch`` over
  whole phase programs is gone), and dropout-masked rounds compute the
  masked-Metropolis weights *locally from permuted participation bits*
  (alive bits travel the union's own exchanges, then degrees do) — no
  ``[m, m]`` matrix is ever materialized on the wire path;
* time-varying rounds run the memory-full CHOCO averaging against a
  **NeighborCache** — per-op mirrors of each in-neighbor's ``theta_hat``
  kept exact by the compressed hat-deltas that ride every union edge every
  round — so masked/scheduled rounds put only compressed payload bytes on
  the wire (the pre-refactor form shipped the f32 public copies).

Numerics: the static circulant paths (unpacked, packed, fused-Pallas)
replicate the rolled oracle's accumulation order operation-for-operation and
are bit-identical to ``gossip.choco_round`` jitted-vs-jitted; dense-matmul
oracle paths (irregular graphs, masked rounds) reassociate the neighbor sum
and agree to f32 rounding (~1 ULP per round) — tests/test_exchange.py pins
both levels.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.compression import Compressor, Identity
from repro.core.faults import (
    digest,
    garble,
    receiver_maps,
    sample_events,
    update_fault_state,
)
from repro.core.gossip import (
    BLOCK_SCAN_ELEMS,
    CHOCOState,
    LaneRound,
    _round_leaves,
    _scan_plan,
    _vdecode,
    lane_key,
    payload_total_bits,
)
from repro.core.topology import (
    PermutePlan,
    Topology,
    TopologySchedule,
    compile_permute_plan,
    compile_schedule_plans,
)

__all__ = [
    "choco_round_ppermute",
    "choco_round_ppermute_lanes",
    "choco_round_cached_local",
    "choco_round_cached_local_lanes",
    "mix_stacked_ppermute",
    "mix_stacked_faulted_local",
    "server_average_ppermute",
    "node_mesh_info",
]


def node_mesh_info(mesh, node_axes, num_nodes: int) -> tuple[tuple[str, ...], int, int]:
    """Validated (axes, ndev, block) for sharding ``num_nodes`` over the
    mesh's node axes.  ``block`` is the nodes-per-device contiguous block."""
    axes = (node_axes,) if isinstance(node_axes, str) else tuple(node_axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    missing = [a for a in axes if a not in sizes]
    if missing:
        raise ValueError(f"mesh {mesh.axis_names} has no axes {missing}")
    ndev = 1
    for a in axes:
        ndev *= int(sizes[a])
    if num_nodes % ndev != 0:
        raise ValueError(
            f"num_nodes={num_nodes} must be divisible by the node-axis device "
            f"count {ndev} (mesh axes {axes}); uneven node/device ratios are a "
            "ROADMAP open item"
        )
    return axes, ndev, num_nodes // ndev


def _flat_axis_index(axes: tuple[str, ...], sizes: dict[str, int]):
    """Row-major flat device index along the (possibly multi-axis) node dim."""
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * sizes[a] + jax.lax.axis_index(a)
    return idx


def _dev_perm(ndev: int, q: int) -> list[tuple[int, int]]:
    return [(i, (i + q) % ndev) for i in range(ndev)]


def _local_slice(arr, idx, block: int):
    """Device-local [block, ...] slice of a replicated [m, ...] array."""
    return jax.lax.dynamic_slice_in_dim(arr, idx * block, block, axis=0)


def _shard_roll(x, shift: int, axes, ndev: int, block: int):
    """``jnp.roll(x, shift, axis=0)`` over a node-sharded leading axis.

    Decomposes the global shift into a whole-block device permute plus one
    boundary-slab permute — the wire moves only what crosses a device
    boundary, so a ring shift of ±1 costs one node-row per device however
    many nodes a device hosts.  The shift is taken in the *minimal-|s|*
    signed representative: normalizing -1 to m-1 would turn the ring's
    backward edge into a full-block permute plus a (block-1)-row slab.
    """
    m = ndev * block
    s = shift % m
    if s == 0:
        return x
    if ndev == 1:
        return jnp.roll(x, s, axis=0)
    if s > m // 2:  # roll backward by m - s: fewer boundary rows on the wire
        b = m - s
        q, r = divmod(b, block)
        if q:
            x = jax.lax.ppermute(x, axes, _dev_perm(ndev, -q))
        if r:
            bot = jax.lax.ppermute(x[:r], axes, _dev_perm(ndev, -1))
            x = jnp.concatenate([x[r:], bot], axis=0)
        return x
    q, r = divmod(s, block)
    if q:
        x = jax.lax.ppermute(x, axes, _dev_perm(ndev, q))
    if r:
        top = jax.lax.ppermute(x[block - r :], axes, _dev_perm(ndev, 1))
        x = jnp.concatenate([top, x[: block - r]], axis=0)
    return x


def _recv(x, op, axes, ndev: int, block: int):
    """Receive the neighbor value for one plan exchange op.

    ``("shift", s)`` → global roll; ``("perm", pairs)`` → per-edge partial
    permutation (block == 1, node index == device index).  Nodes that
    receive nothing in a perm step get zeros — their receive weight is zero
    by construction.
    """
    kind, arg = op
    if kind == "shift":
        return _shard_roll(x, arg, axes, ndev, block)
    if ndev == 1:  # single-device degenerate mesh: permute rows locally
        out = jnp.zeros_like(x)
        for src, dst in arg:
            out = out.at[dst].set(x[src])
        return out
    return jax.lax.ppermute(x, axes, list(arg))


def _bcast(w, ndim: int):
    """[block] per-node weights broadcast against a [block, ...] leaf."""
    return w.reshape((w.shape[0],) + (1,) * (ndim - 1))


# ---------------------------------------------------------------- static mix
def _mix_local(x, plan: PermutePlan, axes, ndev, block, idx):
    """``sum_j w_ij x_j`` on the local shard — mirrors ``gossip._mix_leaf``.

    Circulant plans accumulate ``weight * shard_roll(x, shift)`` in the
    oracle's shift order (bit-identical); irregular plans accumulate the
    self term plus per-edge permutes (the dense-matmul oracle reassociated,
    ~1 ULP).
    """
    if plan.shifts is not None:
        out = jnp.zeros_like(x)
        for shift, weight in plan.shifts:
            term = x if shift == 0 else _shard_roll(x, shift, axes, ndev, block)
            out = out + weight * term
        return out
    wdt = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
    xw = x.astype(wdt)
    sw = _local_slice(jnp.asarray(plan.self_weight, wdt), idx, block)
    out = _bcast(sw, x.ndim) * xw
    for step in plan.steps:
        w = _local_slice(jnp.asarray(step.weights, wdt), idx, block)
        out = out + _bcast(w, x.ndim) * _recv(xw, ("perm", step.perm), axes, ndev, block)
    return out.astype(x.dtype)


def _mix_payload_local(compressor, payload, shape, dtype, plan: PermutePlan,
                       axes, ndev, block, idx):
    """``sum_j w_ij decode(q_j)`` with the *packed payload* on the wire —
    mirrors ``gossip._mix_payload`` for circulant plans (bit-identical) and
    extends it to irregular plans (the rolled backend cannot pack those: it
    falls back to a dense mix of decoded tensors, all-gathering f32)."""
    troll = lambda p, op: jax.tree.map(lambda t: _recv(t, op, axes, ndev, block), p)
    if plan.shifts is not None:
        out = None
        for shift, weight in plan.shifts:
            rolled = payload if shift == 0 else troll(payload, ("shift", shift))
            deq = _vdecode(compressor, rolled, shape, dtype)
            out = weight * deq if out is None else out + weight * deq
        return out
    sw = _local_slice(jnp.asarray(plan.self_weight, jnp.float32), idx, block)
    out = _bcast(sw, len(shape) + 1) * _vdecode(compressor, payload, shape, dtype)
    for step in plan.steps:
        recv = troll(payload, ("perm", step.perm))
        deq = _vdecode(compressor, recv, shape, dtype)
        w = _local_slice(jnp.asarray(step.weights, jnp.float32), idx, block)
        out = out + _bcast(w, deq.ndim) * deq
    return out


# --------------------------------------------------- time-varying wire layer
# The union wire (repro.core.wire): every phase of a schedule shares ONE
# program — the deduplicated union of all phases' exchange ops — and the
# round's mixing weights come from per-phase banks gathered by t % P.  This
# replaces the old per-mix-site ``lax.switch`` over whole phase programs
# (ROADMAP phase-switch item: weights are now resolved ONCE per round, before
# the per-leaf loop) and enables the NeighborCache: because the compressed
# hat-delta travels every union edge every round, each device holds an exact
# mirror of every in-neighbor's theta_hat and the memory-full averaging step
# sum_j w_ij(t) theta_hat_j needs NOTHING on the wire — the f32 public-copy
# exchange the old masked round shipped is gone.


def _slice_bank(bank, phase, idx, block):
    """Per-phase bank [P, ..., m] -> phase row, local [..., block] slice."""
    row = bank[0] if bank.shape[0] == 1 else jax.lax.dynamic_index_in_dim(
        bank, phase, 0, keepdims=False
    )
    return jax.lax.dynamic_slice_in_dim(row, idx * block, block, axis=row.ndim - 1)


def _union_round_weights(union, phase, alive, masked: bool, axes, ndev, block,
                         idx, usable=None):
    """This round's wire weights, resolved once per round.

    Returns ``(self_w [block], ws list-of-[block], alive_nb list-or-None)``.
    Unmasked rounds read the static phase banks; masked rounds recompute
    masked-Metropolis weights locally from permuted participation bits (the
    distributed form of ``topology.masked_metropolis``, restricted to the
    phase's edges by the ``active`` bank): alive bits travel the union's own
    exchanges, per-node surviving degrees are summed on-device, then degrees
    travel the same exchanges to form w_ij = a_i a_j / (1 + max(deg_i,
    deg_j)).  ``alive_nb`` (each sender's participation bit, per op) is also
    what gates the receiver-side NeighborCache update.

    ``usable`` ([n_ops, block] f32, faulted wires) additionally masks each
    receiver's in-edges — an edge whose mirror diverged past the staleness
    bound is cut from the mix and its weight redistributed by the same
    surviving-subgraph rescale.  Usability is receiver-side knowledge, so
    under asymmetric faults W(t) is row- but not column-stochastic (the
    self-healing layer's documented bias/availability tradeoff; the digest
    layer bounds how long it persists).
    """
    ops = union.ops
    if not masked and usable is None:
        wb = _slice_bank(jnp.asarray(union.w_bank, jnp.float32), phase, idx, block)
        self_w = _slice_bank(jnp.asarray(union.self_bank, jnp.float32), phase, idx, block)
        return self_w, [wb[k] for k in range(len(ops))], None
    act = _slice_bank(jnp.asarray(union.active, jnp.float32), phase, idx, block)
    if usable is not None:
        act = act * usable
    alive_nb = [_recv(alive, op, axes, ndev, block) for op in ops]
    deg = jnp.zeros_like(alive)
    for k, nb in enumerate(alive_nb):
        deg = deg + act[k] * alive * nb
    deg_nb = [_recv(deg, op, axes, ndev, block) for op in ops]
    ws = [
        act[k] * alive * nb / (1.0 + jnp.maximum(deg, dnb))
        for k, (nb, dnb) in enumerate(zip(alive_nb, deg_nb))
    ]
    self_w = jnp.ones_like(alive)
    for w in ws:
        self_w = self_w - w
    return self_w, ws, alive_nb


def _phase_round_weights(union, p: int, alive, masked: bool, axes, ndev,
                         block, idx):
    """Phase-``p`` wire weights restricted to phase ``p``'s *active* ops —
    the literal-phase twin of :func:`_union_round_weights` used by the
    per-phase ``lax.switch`` branches of the dense-format mix.

    ``p`` is a Python int (each switch branch closes over its own phase), so
    the op subset and the weight rows are host-side constants: a branch
    exchanges only the edges its phase actually uses, which is what drops
    scheduled exact-gossip traffic from the union edge set to the active
    edge set (ROADMAP per-phase wire program item).  Numerics match the
    union path exactly — the ops skipped here carried weight 0.0 there.

    Returns ``(self_w [block], ws list-of-[block], ops)``.
    """
    act_np = np.asarray(union.active[p])  # [n_ops, m]
    ops_sel = [k for k in range(union.n_ops) if act_np[k].any()]
    ops = [union.ops[k] for k in ops_sel]
    loc = lambda row: jax.lax.dynamic_slice_in_dim(
        jnp.asarray(row, jnp.float32), idx * block, block
    )
    if not masked:
        self_w = loc(union.self_bank[p])
        return self_w, [loc(union.w_bank[p][k]) for k in ops_sel], ops
    act = [loc(act_np[k]) for k in ops_sel]
    alive_nb = [_recv(alive, op, axes, ndev, block) for op in ops]
    deg = jnp.zeros_like(alive)
    for a, nb in zip(act, alive_nb):
        deg = deg + a * alive * nb
    deg_nb = [_recv(deg, op, axes, ndev, block) for op in ops]
    ws = [
        a * alive * nb / (1.0 + jnp.maximum(deg, dnb))
        for a, nb, dnb in zip(act, alive_nb, deg_nb)
    ]
    self_w = jnp.ones_like(alive)
    for w in ws:
        self_w = self_w - w
    return self_w, ws, ops


def _weighted_mix(x, self_w, ws, ops, axes, ndev, block):
    """``sum_j w_ij(t) x_j`` in f32 with pre-resolved per-op weights — the
    dense-format union mix (exact consensus, lambda gossip)."""
    xf = x.astype(jnp.float32)
    out = _bcast(self_w, x.ndim) * xf
    for op, w in zip(ops, ws):
        out = out + _bcast(w, x.ndim) * _recv(xf, op, axes, ndev, block)
    return out


# ----------------------------------------------------------- faulted wire
def _inv_op(op):
    """The reverse exchange of a union op: moves a receiver-side value to its
    sender.  The resync-request lane — one ``want`` bit travels *against*
    each union edge so the sender knows to ship (and bill) the dense hat."""
    kind, arg = op
    if kind == "shift":
        return (kind, -arg)
    return (kind, tuple((d, s) for (s, d) in arg))


def _wire_msg_bits(compressor, theta_template, block_scan_elems):
    """Static per-message bit sizes on a faulted wire:
    ``(payload, digest, dense)``.

    ``payload`` — one compressed hat-delta for the whole tree (what every
    union edge carries every round); ``digest`` — 32 bits per leaf chunk (the
    chunking is ``_scan_plan``'s, so the lane is billed exactly as it is
    computed); ``dense`` — the full hat at its own dtype (the resync
    payload, shipped only on requested edges).
    """
    payload = payload_total_bits(compressor, theta_template)
    dense = dig = 0.0
    for leaf in jax.tree_util.tree_leaves(theta_template):
        d = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
        dense += float(d) * leaf.dtype.itemsize * 8.0
        plan = _scan_plan(leaf.shape, d, block_scan_elems)
        dig += 32.0 * (plan[1] if plan is not None else 1)
    return payload, dig, dense


class _FaultCtx(NamedTuple):
    """One round's resolved fault picture on the local node block: the
    receiver-side message gates (``[n_ops, block]``) plus the sender-side
    realized-bits meter (``[block]``).  One draw gates the whole message —
    the hat-delta, its digest, and any resync payload sharing the edge."""

    arrived: jax.Array  # bool: the message landed this round (vs drop/delay)
    corrupt: jax.Array  # bool: landed garbled — the digest will discard it
    want: jax.Array  # bool: receiver requests a full-hat resync this round
    bits: jax.Array  # f32: wire bits this node's own sends realize


def _fault_context(faults, fault_key, union, fstate, alive_local, alive_nb,
                   msg_bits, axes, ndev, block, idx, m):
    """Sample the round's message events and resolve them into receiver-side
    gates and sender-side billing.

    Events are drawn on the *global* ``[n_ops, m]`` edge set from the
    replicated fault key, so every device (and both backends, and a test
    reconstructing ground truth) classifies the same draw identically; each
    device then slices its receiver block.  Faults only exist on live edges:
    a slot with no sender (``senders[k][i] < 0``) or a masked-out sender
    carries no message to fault — its ``arrived`` is vacuously True so the
    recovery state machine never ages an edge that had nothing to deliver.

    Billing is *delivered* bits, credited to the sender: drops bill zero,
    duplicates twice, corrupt/late deliveries once (the bytes moved; the
    digest just refuses to apply them).  Receiver-indexed event lanes reach
    the sender through the static receiver maps — no wire traffic to meter
    the wire — while the ``want`` bit travels the reverse exchange, and a
    requested resync adds the dense hat to that edge's message.
    """
    ev = sample_events(faults, fault_key, union.n_ops, m)
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * block, block, axis=1)
    exist = sl(jnp.asarray(
        np.stack([np.asarray(s) >= 0 for s in union.senders]), bool
    ))
    live = exist
    if alive_nb is not None:
        live = live & (jnp.stack(alive_nb) > 0.0)
    arrived = jnp.where(live, ~(sl(ev.drop) | sl(ev.delay)), True)
    corrupt = sl(ev.corrupt) & live
    want = live & (fstate.stale.T > faults.stale) & (fstate.wait.T <= 0)
    payload_b, digest_b, dense_b = msg_bits
    mult = jnp.where(ev.drop, 0.0, jnp.where(ev.dup, 2.0, 1.0))
    bits = jnp.zeros((block,), jnp.float32)
    for k, (op, rcv) in enumerate(zip(union.ops, receiver_maps(union))):
        rcv_l = _local_slice(jnp.asarray(rcv, jnp.int32), idx, block)
        mult_k = jnp.where(rcv_l >= 0, mult[k][jnp.clip(rcv_l, 0)], 0.0)
        want_sent = _recv(
            want[k].astype(jnp.float32), _inv_op(op), axes, ndev, block
        )
        bits = bits + mult_k * (payload_b + digest_b + want_sent * dense_b)
    return _FaultCtx(arrived, corrupt, want, bits * alive_local)


# ------------------------------------------------------------- leaf rounds
def _round_leaf_local(leaf, hat, s, key, plan, gamma, compressor: Compressor,
                      use_packed, use_fused, axes, ndev, block, idx, m_global):
    """One static CHOCO round on the local node block — mirrors
    ``gossip._round_leaf`` operation-for-operation."""
    if use_fused:
        return _fused_round_local(
            leaf, hat, s, key, plan, gamma, compressor, axes, ndev, block, idx, m_global
        )
    inner_shape, dtype = leaf.shape[1:], leaf.dtype
    theta_new = leaf + jnp.asarray(gamma, dtype) * (s - hat).astype(dtype)
    resid = (theta_new - hat).astype(jnp.float32)
    if isinstance(compressor, Identity):
        q_self = resid
        mixed = _mix_local(q_self, plan, axes, ndev, block, idx)
    else:
        node_keys = _local_slice(jax.random.split(key, m_global), idx, block)
        payload = jax.vmap(compressor.encode)(resid, node_keys)
        q_self = _vdecode(compressor, payload, inner_shape, jnp.float32)
        if use_packed:
            mixed = _mix_payload_local(
                compressor, payload, inner_shape, jnp.float32, plan,
                axes, ndev, block, idx,
            )
        else:
            mixed = _mix_local(q_self, plan, axes, ndev, block, idx)
    hat_new = (hat.astype(jnp.float32) + q_self).astype(hat.dtype)
    s_new = (s.astype(jnp.float32) + mixed).astype(s.dtype)
    return theta_new, hat_new, s_new


def _fused_round_local(leaf, hat, s, key, plan, gamma, compressor,
                       axes, ndev, block, idx, m_global):
    """Single-pass Pallas fast path on the local shard: the fused encode /
    multi-shift dequant-accumulate kernels run on the [block, ...] slab and
    the packed payload travels the wire via :func:`_shard_roll`."""
    from repro.kernels.ops import fused_choco_round_leaf

    node_keys = _local_slice(jax.random.split(key, m_global), idx, block)
    roll_fn = lambda x, sh: _shard_roll(x, sh, axes, ndev, block)
    return fused_choco_round_leaf(
        leaf, hat, s, key, plan, gamma, compressor.bits,
        getattr(compressor, "interpret", None),
        roll_fn=roll_fn, node_keys=node_keys,
    )


def _round_leaf_cached(leaf, hat, s, key, caches, union, weights, gamma,
                       compressor: Compressor, alive, masked: bool,
                       use_payload: bool, axes, ndev, block, idx, m_global,
                       fctx=None):
    """Time-varying / fault-tolerant round on the local block — the
    memory-full CHOCO form of ``gossip._round_leaf_masked`` executed against
    the NeighborCache: the averaging step ``sum_j w_ij(t) theta_hat_j`` reads
    each in-neighbor's hat from its local mirror (``caches``, one per union
    op) instead of shipping f32 public copies, and the only model-sized wire
    traffic is the compressed hat-delta payload — which each receiver both
    mixes into ``s`` and applies to its mirror with the *same arithmetic the
    sender applies to its own hat*, keeping every mirror bit-identical to the
    sender's ``theta_hat`` (the invariant tests/test_wire_cache.py pins).

    Dropped senders contribute a zero delta (their residual is masked before
    encode) and the alive bit riding each exchange gates the mirror update,
    so a mirror of a dead neighbor freezes exactly like the neighbor's own
    hat does.

    ``fctx`` (a :class:`_FaultCtx`) switches the wire to the faulted regime:
    corrupt messages are garbled in flight, the sender's hat digest rides
    every message, and the receiver verifies ``digest(mirror + delta)``
    against it *before* committing — a missing or garbled delta leaves the
    mirror untouched (and out of this round's ``s`` increment, so the
    tracker stays consistent with what the mirrors actually did).  A
    requested resync ships the sender's post-round hat dense on the same
    message, subject to the same draw.  Returns a fifth element, the
    ``[2, n_ops, block]`` (delta-ok, resync-ok) verdict for this chunk.
    """
    self_w, ws, alive_nb = weights
    inner_shape, dtype = leaf.shape[1:], leaf.dtype
    hat32 = hat.astype(jnp.float32)
    ab = _bcast(alive, leaf.ndim)
    # averaging from cached neighbor hats — nothing on the wire
    s_cur = _bcast(self_w, leaf.ndim) * hat32
    for w, c in zip(ws, caches):
        s_cur = s_cur + _bcast(w, leaf.ndim) * c.astype(jnp.float32)
    theta_new = leaf + (ab * gamma).astype(dtype) * (s_cur - hat32).astype(dtype)
    resid = ((theta_new - hat).astype(jnp.float32)) * ab
    payload = None
    if isinstance(compressor, Identity):
        q_self = resid
    else:
        node_keys = _local_slice(jax.random.split(key, m_global), idx, block)
        payload = jax.vmap(compressor.encode)(resid, node_keys)
        q_self = _vdecode(compressor, payload, inner_shape, jnp.float32) * ab
    hat_new = (hat32 + q_self).astype(hat.dtype)
    dig_self = digest(hat_new) if fctx is not None else None
    # the wire: one compressed hat-delta per union op (decode commutes with
    # the permute, so decode-after-receive == receive-after-decode bitwise)
    mix_q = _bcast(self_w, leaf.ndim) * q_self
    new_caches = []
    d_oks, r_oks = [], []
    for k, op in enumerate(union.ops):
        if use_payload and payload is not None:
            recv_p = jax.tree.map(
                lambda t: _recv(t, op, axes, ndev, block), payload
            )
            q_r = _vdecode(compressor, recv_p, inner_shape, jnp.float32)
        else:
            q_r = _recv(q_self, op, axes, ndev, block)
        if masked:
            q_r = q_r * _bcast(alive_nb[k], leaf.ndim)
        if fctx is None:
            new_caches.append(
                (caches[k].astype(jnp.float32) + q_r).astype(caches[k].dtype)
            )
            mix_q = mix_q + _bcast(ws[k], leaf.ndim) * q_r
            continue
        cb = _bcast(fctx.corrupt[k], leaf.ndim)
        q_r = jnp.where(cb, garble(q_r), q_r)
        cand = (caches[k].astype(jnp.float32) + q_r).astype(caches[k].dtype)
        dig_nb = _recv(dig_self, op, axes, ndev, block)
        ok_d = fctx.arrived[k] & (digest(cand) == dig_nb)
        hat_recv = _recv(hat_new, op, axes, ndev, block)
        hat_recv = jnp.where(cb, garble(hat_recv), hat_recv)
        ok_r = fctx.want[k] & fctx.arrived[k] & (digest(hat_recv) == dig_nb)
        okd_b, okr_b = _bcast(ok_d, leaf.ndim), _bcast(ok_r, leaf.ndim)
        new_caches.append(
            jnp.where(okr_b, hat_recv, jnp.where(okd_b, cand, caches[k]))
        )
        # only committed deltas enter the tracker increment (a jnp.where,
        # not a multiply — a garbled q_r may carry NaN bit patterns)
        mix_q = mix_q + _bcast(ws[k], leaf.ndim) * jnp.where(okd_b, q_r, 0.0)
        d_oks.append(ok_d)
        r_oks.append(ok_r)
    s_post = s_cur + mix_q
    s_new = (ab * s_post + (1.0 - ab) * s.astype(jnp.float32)).astype(s.dtype)
    if fctx is None:
        return theta_new, hat_new, s_new, tuple(new_caches)
    verdict = jnp.stack([jnp.stack(d_oks), jnp.stack(r_oks)])
    return theta_new, hat_new, s_new, tuple(new_caches), verdict


# ------------------------------------------------------------------- rounds
def _cached_round_body(theta, st, key, alive, step_arg, fault_key, *, union,
                       gamma, compressor, use_packed, masked, faults,
                       msg_bits, axes, ndev, block, idx, m,
                       block_scan_elems):
    """One cached union-wire round on a local node block — the body both
    backends execute: ``choco_round_ppermute`` shard_maps it over the mesh's
    node axes; ``choco_round_cached_local`` runs it with the whole node axis
    as one block (``ndev == 1``).  Sharing the body makes rolled/ppermute
    bit-parity under faults *structural* rather than something numerics have
    to deliver."""
    lv, td = jax.tree_util.tree_flatten(theta)
    hv = td.flatten_up_to(st.theta_hat)
    sv = td.flatten_up_to(st.s)
    keys = jax.random.split(key, len(lv))
    alive_local = (
        jnp.ones((block,), jnp.float32) if alive is None
        else alive.astype(jnp.float32)
    )
    phase = (
        jnp.zeros((), jnp.int32) if union.period == 1
        else step_arg % union.period
    )
    fstate = st.fault
    usable = None
    if faults is not None:
        # an edge past the staleness bound leaves the mix (its weight
        # redistributes by the surviving-subgraph rescale) until resync lands
        usable = (fstate.stale.T <= faults.stale).astype(jnp.float32)
    # the round's mixing weights, resolved ONCE — not per leaf, not per mix
    # site, and with no lax.switch over phase programs
    weights = _union_round_weights(
        union, phase, alive_local, masked, axes, ndev, block, idx, usable
    )
    fctx = None
    if faults is not None:
        fctx = _fault_context(
            faults, fault_key, union, fstate, alive_local, weights[2],
            msg_bits, axes, ndev, block, idx, m,
        )
    cache_lv = [td.flatten_up_to(c) for c in st.cache]
    extra = [
        tuple(cache_lv[k][i] for k in range(union.n_ops))
        for i in range(len(lv))
    ]

    def round_one(leaf, hat, s, k, caches):
        return _round_leaf_cached(
            leaf, hat, s, k, caches, union, weights, gamma, compressor,
            alive_local, masked, use_packed, axes, ndev, block, idx, m,
            fctx=fctx,
        )

    verdict_init = (
        jnp.ones((2, union.n_ops, block), bool) if faults is not None else None
    )
    # the chunk layout and per-chunk key stream come from the SAME driver
    # as the static rolled backend — bit-parity across backends is structural
    new_theta, new_hat, new_s, new_extra, verdict = _round_leaves(
        lv, hv, sv, keys, round_one, block_scan_elems,
        extra_leaves=extra, verdict_init=verdict_init,
    )
    unf = lambda ls: jax.tree_util.tree_unflatten(td, ls)
    cache_new = tuple(
        unf([new_extra[i][k] for i in range(len(lv))])
        for k in range(union.n_ops)
    )
    fault_new = fstate
    if faults is not None:
        fault_new = update_fault_state(
            fstate, verdict[0], verdict[1], fctx.want, faults, fctx.bits
        )
    return unf(new_theta), CHOCOState(
        theta_hat=unf(new_hat), s=unf(new_s), cache=cache_new,
        fault=fault_new,
    )


def _check_fault_state(state, faults, fault_key, union):
    if faults is None:
        return
    if fault_key is None:
        raise ValueError(
            "faulted rounds need the round's fault_key — one PRNG key per "
            "round, split from the trainer's per-step stream so kill-and-"
            "resume replays the same events"
        )
    if (not hasattr(state.fault, "stale")
            or state.fault.stale.shape[-1] != union.n_ops):
        raise ValueError(
            "faulted rounds keep a per-edge FaultState in CHOCOState.fault "
            f"(need one for {union.n_ops} union ops) — initialize the state "
            "with gossip.choco_init(theta, cache_ops=n, fault_ops=n) or let "
            "trainer.ChocoConsensus.init size it from the fault spec"
        )


def choco_round_ppermute(
    theta_half,
    state: CHOCOState,
    topology: Topology,
    gamma: float,
    compressor: Compressor,
    key: jax.Array,
    *,
    mesh,
    node_axes="data",
    packed: bool = True,
    fused: bool = False,
    block_scan_elems: int = BLOCK_SCAN_ELEMS,
    schedule: TopologySchedule | None = None,
    step=None,
    mask=None,
    union=None,
    faults=None,
    fault_key=None,
):
    """One compressed-consensus round on the SPMD neighbor-exchange backend.

    Drop-in for ``gossip.choco_round`` (reached via its ``backend="ppermute"``
    dispatch): same state threading, same RNG stream, same scan-plan leaf
    chunking — but executed under ``shard_map`` over ``mesh``'s
    ``node_axes``, with only compressed payloads on the wire: the static
    packed/fused formats, or (time-varying rounds) the hat-delta format
    applied against the NeighborCache.

    ``schedule`` + ``step`` + ``mask`` replace the rolled backend's dense
    ``mixing`` argument: all phases compile into ONE
    :class:`~repro.core.wire.UnionWirePlan` wire program whose per-phase
    mixing weights are gathered by ``step % P`` (no ``lax.switch``), and a
    participation mask triggers the locally-computed masked-Metropolis
    weights.  Time-varying rounds require the state's NeighborCache (one
    ``theta_hat`` mirror per union op, allocated by
    ``gossip.choco_init(theta, cache_ops=...)`` /
    ``trainer.ChocoConsensus.init``): the averaging step reads the cached
    mirrors and only the compressed hat-delta payload travels the wire.

    ``faults`` (a :class:`~repro.core.faults.FaultSpec`) + ``fault_key``
    switch the wire to the faulted regime — always the cached union path,
    even for a static topology, because only the NeighborCache form has a
    mirror to verify and heal.
    """
    thetas, states = choco_round_ppermute_lanes(
        (LaneRound(theta_half, state, gamma, compressor),), topology, key,
        mesh=mesh, node_axes=node_axes, packed=packed, fused=fused,
        block_scan_elems=block_scan_elems, schedule=schedule, step=step,
        mask=mask, union=union, faults=faults, fault_key=fault_key,
    )
    return thetas[0], states[0]


def choco_round_ppermute_lanes(
    lanes,
    topology: Topology,
    key: jax.Array,
    *,
    mesh,
    node_axes="data",
    packed: bool = True,
    fused: bool = False,
    block_scan_elems: int = BLOCK_SCAN_ELEMS,
    schedule: TopologySchedule | None = None,
    step=None,
    mask=None,
    union=None,
    faults=None,
    fault_key=None,
):
    """The multi-lane SPMD round: every edge of the round's wire program
    carries a *tuple* of messages, one per :class:`~repro.core.gossip.LaneRound`.

    All lanes run inside ONE ``shard_map`` body, so the per-edge message
    really is the lane tuple — the same ops of the same round move lane 0's
    payload and lane 1's payload together (XLA is free to coalesce the
    adjacent collective-permutes).  Each lane keeps its own compressed
    residual stream (lane ``k``'s RNG is ``lane_key(key, k)``), its own
    NeighborCache mirrors, and — under faults — its own per-edge event draws,
    digests and recovery state: a corrupted lane-1 message stales only lane
    1's mirror, never the theta mirror.  A single-lane call (what
    :func:`choco_round_ppermute` delegates to) is bit-identical to the
    historical single-payload wire because lane 0's keys are the round keys
    themselves.

    Returns ``(thetas, states)`` tuples, one entry per lane.
    """
    from repro.core.wire import compile_union_wire

    lanes = tuple(lanes)
    n_lanes = len(lanes)
    leaves = jax.tree_util.tree_leaves(lanes[0].theta)
    m = leaves[0].shape[0]
    axes, ndev, block = node_mesh_info(mesh, node_axes, m)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    time_varying = (
        (schedule is not None and not getattr(schedule, "is_static", True))
        or mask is not None
        or faults is not None
    )
    if time_varying:
        if union is None:
            # standalone use; the consensus layer passes its precompiled
            # plan (the same one that sized the state's cache) instead
            if schedule is not None:
                plans = compile_schedule_plans(schedule)
            else:
                plans = (compile_permute_plan(topology),)
            union = compile_union_wire(plans)
        _check_block(any(k == "perm" for k, _ in union.ops), block, ndev)
        use_packed = [
            packed and not isinstance(l.compressor, Identity) for l in lanes
        ]
        use_fused = [False] * n_lanes
        plan = None
        for li, l in enumerate(lanes):
            if len(l.state.cache) != union.n_ops:
                raise ValueError(
                    "time-varying ppermute rounds keep a NeighborCache (one "
                    f"theta_hat mirror per union wire op; lane {li} needs "
                    f"{union.n_ops}, has {len(l.state.cache)}) — initialize "
                    "each lane's state with gossip.choco_init(theta, "
                    "cache_ops=n) or let the consensus init size it"
                )
            _check_fault_state(l.state, faults, fault_key, union)
    else:
        plan = compile_permute_plan(topology)
        _check_block(not plan.is_circulant, block, ndev)
        union = None
        use_packed = [
            packed and not isinstance(l.compressor, Identity) for l in lanes
        ]
        use_fused = [
            fused
            and plan.is_circulant
            and getattr(l.compressor, "supports_fused_round", False)
            for l in lanes
        ]

    masked = mask is not None
    faulted = faults is not None
    msg_bits = [
        _wire_msg_bits(l.compressor, l.theta, block_scan_elems) if faulted
        else None
        for l in lanes
    ]
    args = [*(l.theta for l in lanes), *(l.state for l in lanes), key]
    specs = [P(axes)] * (2 * n_lanes) + [P()]
    if masked:
        args.append(mask)
        specs.append(P(axes))
    if time_varying:
        step_arr = jnp.zeros((), jnp.int32) if step is None else jnp.asarray(step, jnp.int32)
        args.append(step_arr)
        specs.append(P())
    if faulted:
        args.append(fault_key)
        specs.append(P())

    def body(*sharded):
        rest = list(sharded)
        thetas = [rest.pop(0) for _ in range(n_lanes)]
        sts = [rest.pop(0) for _ in range(n_lanes)]
        key_ = rest.pop(0)
        alive = rest.pop(0) if masked else None
        step_arg = rest.pop(0) if time_varying else None
        fkey = rest.pop(0) if faulted else None
        idx = _flat_axis_index(axes, sizes)

        out_t, out_s = [], []
        for li, lane in enumerate(lanes):
            lk = lane_key(key_, li)
            lfk = lane_key(fkey, li)
            if time_varying:
                t_new, s_new = _cached_round_body(
                    thetas[li], sts[li], lk, alive, step_arg, lfk,
                    union=union, gamma=lane.gamma, compressor=lane.compressor,
                    use_packed=use_packed[li], masked=masked, faults=faults,
                    msg_bits=msg_bits[li], axes=axes, ndev=ndev, block=block,
                    idx=idx, m=m, block_scan_elems=block_scan_elems,
                )
            else:
                lv, td = jax.tree_util.tree_flatten(thetas[li])
                hv = td.flatten_up_to(sts[li].theta_hat)
                sv = td.flatten_up_to(sts[li].s)
                keys = jax.random.split(lk, len(lv))

                def round_one(leaf, hat, s, k, lane=lane, li=li):
                    return _round_leaf_local(
                        leaf, hat, s, k, plan, lane.gamma, lane.compressor,
                        use_packed[li], use_fused[li], axes, ndev, block,
                        idx, m,
                    )

                # the chunk layout and per-chunk key stream come from the
                # SAME driver as the rolled backend — bit-parity of the two
                # is structural
                new_theta, new_hat, new_s, _, _ = _round_leaves(
                    lv, hv, sv, keys, round_one, block_scan_elems
                )
                unf = lambda ls, td=td: jax.tree_util.tree_unflatten(td, ls)
                t_new = unf(new_theta)
                s_new = CHOCOState(
                    theta_hat=unf(new_hat), s=unf(new_s),
                    cache=sts[li].cache, fault=sts[li].fault,
                )
            out_t.append(t_new)
            out_s.append(s_new)
        return tuple(out_t), tuple(out_s)

    out_specs = ((P(axes),) * n_lanes, (P(axes),) * n_lanes)
    fn = shard_map(
        body, mesh, in_specs=tuple(specs), out_specs=out_specs,
        check_rep=False,
    )
    return fn(*args)


def choco_round_cached_local(
    theta_half,
    state: CHOCOState,
    gamma: float,
    compressor: Compressor,
    key: jax.Array,
    *,
    union=None,
    packed: bool = True,
    block_scan_elems: int = BLOCK_SCAN_ELEMS,
    schedule: TopologySchedule | None = None,
    topology: Topology | None = None,
    step=None,
    mask=None,
    faults=None,
    fault_key=None,
):
    """The cached union-wire round without a mesh: the whole node axis is one
    local block (``ndev == 1``), every exchange a local roll/permute.  This
    is how the rolled backend (``gossip.choco_round``) runs faulted rounds —
    the *same* ``_cached_round_body`` the ppermute backend shard_maps, so the
    two backends agree bit-for-bit under faults by construction."""
    thetas, states = choco_round_cached_local_lanes(
        (LaneRound(theta_half, state, gamma, compressor),), key, union=union,
        packed=packed, block_scan_elems=block_scan_elems, schedule=schedule,
        topology=topology, step=step, mask=mask, faults=faults,
        fault_key=fault_key,
    )
    return thetas[0], states[0]


def choco_round_cached_local_lanes(
    lanes,
    key: jax.Array,
    *,
    union=None,
    packed: bool = True,
    block_scan_elems: int = BLOCK_SCAN_ELEMS,
    schedule: TopologySchedule | None = None,
    topology: Topology | None = None,
    step=None,
    mask=None,
    faults=None,
    fault_key=None,
):
    """Multi-lane cached union-wire round without a mesh — the rolled twin of
    :func:`choco_round_ppermute_lanes`, sharing its per-lane key folding and
    the per-lane ``_cached_round_body``, so rolled/ppermute bit-parity holds
    lane-by-lane under faults by construction.  Returns ``(thetas, states)``
    tuples, one entry per lane."""
    from repro.core.wire import compile_union_wire

    lanes = tuple(lanes)
    leaves = jax.tree_util.tree_leaves(lanes[0].theta)
    m = leaves[0].shape[0]
    if union is None:
        if schedule is not None:
            plans = compile_schedule_plans(schedule)
        else:
            plans = (compile_permute_plan(topology),)
        union = compile_union_wire(plans)
    for li, l in enumerate(lanes):
        if len(l.state.cache) != union.n_ops:
            raise ValueError(
                "cached union-wire rounds keep a NeighborCache (one theta_hat "
                f"mirror per union wire op; lane {li} needs {union.n_ops}, "
                f"has {len(l.state.cache)}) — initialize each lane's state "
                "with gossip.choco_init(theta, cache_ops=n) or let the "
                "consensus init size it from the schedule"
            )
        _check_fault_state(l.state, faults, fault_key, union)
    step_arr = jnp.zeros((), jnp.int32) if step is None else jnp.asarray(step, jnp.int32)
    out_t, out_s = [], []
    for li, lane in enumerate(lanes):
        msg_bits = (
            _wire_msg_bits(lane.compressor, lane.theta, block_scan_elems)
            if faults is not None else None
        )
        t_new, s_new = _cached_round_body(
            lane.theta, lane.state, lane_key(key, li), mask, step_arr,
            lane_key(fault_key, li), union=union, gamma=lane.gamma,
            compressor=lane.compressor,
            use_packed=packed and not isinstance(lane.compressor, Identity),
            masked=mask is not None, faults=faults, msg_bits=msg_bits,
            axes=(), ndev=1, block=m, idx=0, m=m,
            block_scan_elems=block_scan_elems,
        )
        out_t.append(t_new)
        out_s.append(s_new)
    return tuple(out_t), tuple(out_s)


def _dense_msg_bits(tree) -> float:
    """Bits of one dense-format message (the whole tree at leaf dtype) plus
    its 32-bit-per-leaf digest lane — what a faulted memoryless mix bills
    per delivered edge."""
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(tree):
        d = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
        total += float(d) * leaf.dtype.itemsize * 8.0 + 32.0
    return total


def _memoryless_fault(faults, fault_key, union, dense_msg, axes, ndev, block,
                      idx, m):
    """Memoryless fault resolution for the dense-format union mix (exact
    consensus, lambda gossip): there is no mirror to heal, so a message that
    dropped / garbled / arrived late simply leaves this round's mix — the
    digest vets delivery, the masked-Metropolis rescale redistributes the
    weight, and next round the edge is fresh again.  Returns
    ``(usable [n_ops, block] f32, bits [block] f32)`` — usability for the
    weight recompute, delivered bits (dup bills twice) for the meter."""
    ev = sample_events(faults, fault_key, union.n_ops, m)
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * block, block, axis=1)
    usable = sl(~(ev.drop | ev.corrupt | ev.delay)).astype(jnp.float32)
    mult = jnp.where(ev.drop, 0.0, jnp.where(ev.dup, 2.0, 1.0))
    bits = jnp.zeros((block,), jnp.float32)
    for k, rcv in enumerate(receiver_maps(union)):
        rcv_l = _local_slice(jnp.asarray(rcv, jnp.int32), idx, block)
        bits = bits + jnp.where(rcv_l >= 0, mult[k][jnp.clip(rcv_l, 0)], 0.0)
    return usable, bits * dense_msg


def mix_stacked_ppermute(tree, topology: Topology, *, mesh, node_axes="data",
                         schedule: TopologySchedule | None = None,
                         step=None, mask=None, union=None,
                         faults=None, fault_key=None):
    """Uncompressed (dense-format) gossip mix of a stacked pytree over the
    neighbor-exchange wire — the SPMD counterpart of ``gossip.mix_stacked``
    / ``mix_stacked_with``.  The dual/lambda gossip and
    :class:`~repro.core.trainer.ExactConsensus` ride exactly these permutes
    when the ppermute backend is on; ``schedule``/``step``/``mask`` select
    the round's weights from the union wire's per-phase banks (dense [m, m]
    matrices never exist on this path — dropped nodes degenerate to the
    identity row locally, exactly like ``masked_metropolis``).

    ``faults`` + ``fault_key`` run the memoryless faulted regime (see
    :func:`_memoryless_fault`); the call then returns ``(mixed, bits)`` with
    ``bits`` the [m] per-sender delivered-bits meter."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    axes, ndev, block = node_mesh_info(mesh, node_axes, m)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    time_varying = (
        (schedule is not None and not getattr(schedule, "is_static", True))
        or mask is not None
        or faults is not None
    )
    if not time_varying:
        plan = compile_permute_plan(topology)
        _check_block(not plan.is_circulant, block, ndev)

        def body(t):
            idx = _flat_axis_index(axes, sizes)
            return jax.tree.map(
                lambda x: _mix_local(x, plan, axes, ndev, block, idx), t
            )

        return shard_map(body, mesh, in_specs=P(axes), out_specs=P(axes), check_rep=False)(tree)

    from repro.core.wire import compile_union_wire

    if union is None:
        if schedule is not None:
            plans = compile_schedule_plans(schedule)
        else:
            plans = (compile_permute_plan(topology),)
        union = compile_union_wire(plans)
    _check_block(any(k == "perm" for k, _ in union.ops), block, ndev)
    masked = mask is not None
    faulted = faults is not None
    if faulted and fault_key is None:
        raise ValueError("faulted mixes need the round's fault_key")
    dense_msg = _dense_msg_bits(tree) if faulted else 0.0

    args = [tree]
    specs = [P(axes)]
    if masked:
        args.append(mask)
        specs.append(P(axes))
    step_arr = jnp.zeros((), jnp.int32) if step is None else jnp.asarray(step, jnp.int32)
    args.append(step_arr)
    specs.append(P())
    if faulted:
        args.append(fault_key)
        specs.append(P())

    def body_tv(t, *rest):
        rest = list(rest)
        alive = rest.pop(0) if masked else None
        step_arg = rest.pop(0)
        fkey = rest.pop(0) if faulted else None
        idx = _flat_axis_index(axes, sizes)
        alive_local = (
            jnp.ones((block,), jnp.float32) if alive is None
            else alive.astype(jnp.float32)
        )
        phase = (
            jnp.zeros((), jnp.int32) if union.period == 1
            else step_arg % union.period
        )
        usable, bits = None, None
        if faulted:
            usable, bits = _memoryless_fault(
                faults, fkey, union, dense_msg, axes, ndev, block, idx, m
            )
            bits = bits * alive_local
        if union.period > 1 and not faulted:
            # per-phase wire program: one lax.switch over phase branches,
            # each exchanging only its phase's active edges — scheduled
            # dense-format traffic drops from the union edge set to the
            # active set.  Faulted mixes stay on the union path: the event
            # draw is indexed per union op and the masked rescale needs the
            # usable bits of every op.
            def make_branch(p):
                def branch(operand):
                    t_, alive_ = operand
                    self_w, ws, ops = _phase_round_weights(
                        union, p, alive_, masked, axes, ndev, block, idx
                    )
                    return jax.tree.map(
                        lambda x: _weighted_mix(
                            x, self_w, ws, ops, axes, ndev, block
                        ).astype(x.dtype),
                        t_,
                    )
                return branch

            return jax.lax.switch(
                phase, [make_branch(p) for p in range(union.period)],
                (t, alive_local),
            )
        self_w, ws, _ = _union_round_weights(
            union, phase, alive_local, masked, axes, ndev, block, idx, usable
        )
        mixed = jax.tree.map(
            lambda x: _weighted_mix(
                x, self_w, ws, union.ops, axes, ndev, block
            ).astype(x.dtype),
            t,
        )
        return (mixed, bits) if faulted else mixed

    out_specs = (P(axes), P(axes)) if faulted else P(axes)
    return shard_map(
        body_tv, mesh, in_specs=tuple(specs), out_specs=out_specs, check_rep=False
    )(*args)


def mix_stacked_faulted_local(tree, *, union=None, topology=None,
                              schedule=None, step=None, mask=None,
                              faults, fault_key):
    """The memoryless faulted mix without a mesh (rolled backend): the whole
    node axis is one local block, same code path as the ppermute body — the
    two agree bit-for-bit by construction.  Returns ``(mixed, bits)``."""
    from repro.core.wire import compile_union_wire

    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    if union is None:
        if schedule is not None:
            plans = compile_schedule_plans(schedule)
        else:
            plans = (compile_permute_plan(topology),)
        union = compile_union_wire(plans)
    if fault_key is None:
        raise ValueError("faulted mixes need the round's fault_key")
    alive = (
        jnp.ones((m,), jnp.float32) if mask is None
        else mask.astype(jnp.float32)
    )
    step_arr = jnp.zeros((), jnp.int32) if step is None else jnp.asarray(step, jnp.int32)
    phase = (
        jnp.zeros((), jnp.int32) if union.period == 1
        else step_arr % union.period
    )
    usable, bits = _memoryless_fault(
        faults, fault_key, union, _dense_msg_bits(tree), (), 1, m, 0, m
    )
    bits = bits * alive
    self_w, ws, _ = _union_round_weights(
        union, phase, alive, mask is not None, (), 1, m, 0, usable
    )
    mixed = jax.tree.map(
        lambda x: _weighted_mix(x, self_w, ws, union.ops, (), 1, m).astype(x.dtype),
        tree,
    )
    return mixed, bits


def server_average_ppermute(tree, sampled, *, mesh, node_axes="data"):
    """Weighted server average of a stacked pytree — the mesh-native wire of
    :class:`~repro.core.trainer.FedAvg`.  Each device reduces its local node
    block, then one ``psum`` over the node axes aggregates and re-broadcasts:
    the ring all-reduce realization of "|U| models up, one model down", with
    zero all-gather traffic (the rolled form's ``sum(0)`` of the stacked
    array lets GSPMD all-gather the whole model stack instead).  Output is
    replicated (no node axis)."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    axes, ndev, block = node_mesh_info(mesh, node_axes, m)

    def body(t, sm):
        sm = sm.astype(jnp.float32)
        wsum = jax.lax.psum(sm.sum(), axes)

        def avg(x):
            part = (x.astype(jnp.float32) * _bcast(sm, x.ndim)).sum(0)
            return (jax.lax.psum(part, axes) / wsum).astype(x.dtype)

        return jax.tree.map(avg, t)

    return shard_map(
        body, mesh, in_specs=(P(axes), P(axes)), out_specs=P(), check_rep=False
    )(tree, sampled)


def _check_block(irregular: bool, block: int, ndev: int) -> None:
    """Irregular (non-circulant) wire programs need one node per device: a
    perm/EdgeStep exchange is a *device* permutation.  A single-device mesh
    is exempt — there is no wire, and ``_recv`` executes the node
    permutation locally."""
    if ndev > 1 and block > 1 and irregular:
        raise ValueError(
            "the ppermute backend runs irregular (non-circulant) graphs with "
            "exactly one node per device; got a block of "
            f"{block} nodes/device — use the rolled backend or a mesh whose "
            "node axes match num_nodes (uneven ratios: ROADMAP open item)"
        )
