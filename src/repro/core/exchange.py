"""Mesh-native neighbor-exchange gossip: the SPMD consensus substrate.

``core/gossip.py`` *simulates* the network on a single stacked array — every
mix is a ``jnp.roll`` over the full node axis or a dense ``[m, m]`` matmul,
and whether that turns into degree-many neighbor messages or an all-gather of
the whole stacked payload is left to GSPMD's sharding propagation.  This
module makes the wire model explicit: ``choco_round_ppermute`` runs the same
CHOCO round under ``jax.experimental.shard_map`` over the mesh's node axes,
where each device holds a contiguous block of nodes and *only compressed
payloads travel between actual graph neighbors* via ``jax.lax.ppermute``:

* circulant graphs (ring / torus / mesh) execute each shift of the
  :class:`~repro.core.topology.PermutePlan` as a global roll of the sharded
  node axis — at most two collective-permutes of boundary slabs per shift,
  independent of the nodes-per-device block size;
* irregular graphs (erdos_renyi, star, matching phases) execute the plan's
  :class:`~repro.core.topology.EdgeStep` barriers — per-edge partial
  permutations (one node per device required; see ROADMAP open items for the
  uneven-ratio generalization);
* time-varying schedules select their phase's wire program with
  ``lax.switch`` on the traced round index, and dropout-masked rounds
  compute the masked-Metropolis weights *locally from permuted participation
  bits* (alive bits travel the plan's own exchanges, then degrees do) — no
  ``[m, m]`` matrix is ever materialized on the wire path.

Numerics: the static circulant paths (unpacked, packed, fused-Pallas)
replicate the rolled oracle's accumulation order operation-for-operation and
are bit-identical to ``gossip.choco_round`` jitted-vs-jitted; dense-matmul
oracle paths (irregular graphs, masked rounds) reassociate the neighbor sum
and agree to f32 rounding (~1 ULP per round) — tests/test_exchange.py pins
both levels.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.compression import Compressor, Identity
from repro.core.gossip import BLOCK_SCAN_ELEMS, CHOCOState, _round_leaves, _vdecode
from repro.core.topology import (
    PermutePlan,
    Topology,
    TopologySchedule,
    compile_permute_plan,
    compile_schedule_plans,
)

__all__ = [
    "choco_round_ppermute",
    "mix_stacked_ppermute",
    "node_mesh_info",
]


def node_mesh_info(mesh, node_axes, num_nodes: int) -> tuple[tuple[str, ...], int, int]:
    """Validated (axes, ndev, block) for sharding ``num_nodes`` over the
    mesh's node axes.  ``block`` is the nodes-per-device contiguous block."""
    axes = (node_axes,) if isinstance(node_axes, str) else tuple(node_axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    missing = [a for a in axes if a not in sizes]
    if missing:
        raise ValueError(f"mesh {mesh.axis_names} has no axes {missing}")
    ndev = 1
    for a in axes:
        ndev *= int(sizes[a])
    if num_nodes % ndev != 0:
        raise ValueError(
            f"num_nodes={num_nodes} must be divisible by the node-axis device "
            f"count {ndev} (mesh axes {axes}); uneven node/device ratios are a "
            "ROADMAP open item"
        )
    return axes, ndev, num_nodes // ndev


def _flat_axis_index(axes: tuple[str, ...], sizes: dict[str, int]):
    """Row-major flat device index along the (possibly multi-axis) node dim."""
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * sizes[a] + jax.lax.axis_index(a)
    return idx


def _dev_perm(ndev: int, q: int) -> list[tuple[int, int]]:
    return [(i, (i + q) % ndev) for i in range(ndev)]


def _local_slice(arr, idx, block: int):
    """Device-local [block, ...] slice of a replicated [m, ...] array."""
    return jax.lax.dynamic_slice_in_dim(arr, idx * block, block, axis=0)


def _shard_roll(x, shift: int, axes, ndev: int, block: int):
    """``jnp.roll(x, shift, axis=0)`` over a node-sharded leading axis.

    Decomposes the global shift into a whole-block device permute plus one
    boundary-slab permute — the wire moves only what crosses a device
    boundary, so a ring shift of ±1 costs one node-row per device however
    many nodes a device hosts.  The shift is taken in the *minimal-|s|*
    signed representative: normalizing -1 to m-1 would turn the ring's
    backward edge into a full-block permute plus a (block-1)-row slab.
    """
    m = ndev * block
    s = shift % m
    if s == 0:
        return x
    if ndev == 1:
        return jnp.roll(x, s, axis=0)
    if s > m // 2:  # roll backward by m - s: fewer boundary rows on the wire
        b = m - s
        q, r = divmod(b, block)
        if q:
            x = jax.lax.ppermute(x, axes, _dev_perm(ndev, -q))
        if r:
            bot = jax.lax.ppermute(x[:r], axes, _dev_perm(ndev, -1))
            x = jnp.concatenate([x[r:], bot], axis=0)
        return x
    q, r = divmod(s, block)
    if q:
        x = jax.lax.ppermute(x, axes, _dev_perm(ndev, q))
    if r:
        top = jax.lax.ppermute(x[block - r :], axes, _dev_perm(ndev, 1))
        x = jnp.concatenate([top, x[: block - r]], axis=0)
    return x


def _recv(x, op, axes, ndev: int, block: int):
    """Receive the neighbor value for one plan exchange op.

    ``("shift", s)`` → global roll; ``("perm", pairs)`` → per-edge partial
    permutation (block == 1, node index == device index).  Nodes that
    receive nothing in a perm step get zeros — their receive weight is zero
    by construction.
    """
    kind, arg = op
    if kind == "shift":
        return _shard_roll(x, arg, axes, ndev, block)
    if ndev == 1:  # single-device degenerate mesh: permute rows locally
        out = jnp.zeros_like(x)
        for src, dst in arg:
            out = out.at[dst].set(x[src])
        return out
    return jax.lax.ppermute(x, axes, list(arg))


def _bcast(w, ndim: int):
    """[block] per-node weights broadcast against a [block, ...] leaf."""
    return w.reshape((w.shape[0],) + (1,) * (ndim - 1))


# ---------------------------------------------------------------- static mix
def _mix_local(x, plan: PermutePlan, axes, ndev, block, idx):
    """``sum_j w_ij x_j`` on the local shard — mirrors ``gossip._mix_leaf``.

    Circulant plans accumulate ``weight * shard_roll(x, shift)`` in the
    oracle's shift order (bit-identical); irregular plans accumulate the
    self term plus per-edge permutes (the dense-matmul oracle reassociated,
    ~1 ULP).
    """
    if plan.shifts is not None:
        out = jnp.zeros_like(x)
        for shift, weight in plan.shifts:
            term = x if shift == 0 else _shard_roll(x, shift, axes, ndev, block)
            out = out + weight * term
        return out
    wdt = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
    xw = x.astype(wdt)
    sw = _local_slice(jnp.asarray(plan.self_weight, wdt), idx, block)
    out = _bcast(sw, x.ndim) * xw
    for step in plan.steps:
        w = _local_slice(jnp.asarray(step.weights, wdt), idx, block)
        out = out + _bcast(w, x.ndim) * _recv(xw, ("perm", step.perm), axes, ndev, block)
    return out.astype(x.dtype)


def _mix_payload_local(compressor, payload, shape, dtype, plan: PermutePlan,
                       axes, ndev, block, idx):
    """``sum_j w_ij decode(q_j)`` with the *packed payload* on the wire —
    mirrors ``gossip._mix_payload`` for circulant plans (bit-identical) and
    extends it to irregular plans (the rolled backend cannot pack those: it
    falls back to a dense mix of decoded tensors, all-gathering f32)."""
    troll = lambda p, op: jax.tree.map(lambda t: _recv(t, op, axes, ndev, block), p)
    if plan.shifts is not None:
        out = None
        for shift, weight in plan.shifts:
            rolled = payload if shift == 0 else troll(payload, ("shift", shift))
            deq = _vdecode(compressor, rolled, shape, dtype)
            out = weight * deq if out is None else out + weight * deq
        return out
    sw = _local_slice(jnp.asarray(plan.self_weight, jnp.float32), idx, block)
    out = _bcast(sw, len(shape) + 1) * _vdecode(compressor, payload, shape, dtype)
    for step in plan.steps:
        recv = troll(payload, ("perm", step.perm))
        deq = _vdecode(compressor, recv, shape, dtype)
        w = _local_slice(jnp.asarray(step.weights, jnp.float32), idx, block)
        out = out + _bcast(w, deq.ndim) * deq
    return out


# ------------------------------------------------------- masked / per-phase
def _masked_weights(plan: PermutePlan, alive, axes, ndev, block):
    """Masked-Metropolis weights computed locally from permuted participation
    bits (the distributed form of ``topology.masked_metropolis``): alive bits
    travel the plan's exchanges, per-node degrees are summed on-device, then
    degrees travel the same exchanges to form w_ij = a_i a_j / (1 + max(deg_i,
    deg_j)).  Returns (self_w [block], per-op weight vectors)."""
    ops = plan.exchange_ops()
    alive_nb = [_recv(alive, op, axes, ndev, block) for op in ops]
    deg = jnp.zeros_like(alive)
    for nb in alive_nb:
        deg = deg + alive * nb
    deg_nb = [_recv(deg, op, axes, ndev, block) for op in ops]
    ws = [
        alive * nb / (1.0 + jnp.maximum(deg, dnb))
        for nb, dnb in zip(alive_nb, deg_nb)
    ]
    self_w = jnp.ones_like(alive)
    for w in ws:
        self_w = self_w - w
    return self_w, ws


def _phase_mix(x, alive, plan: PermutePlan, masked: bool, axes, ndev, block, idx):
    """One phase's ``sum_j w_ij(t) x_j`` in f32: static phase weights when
    unmasked, locally recomputed masked-Metropolis weights otherwise."""
    xf = x.astype(jnp.float32)
    if not masked:
        return _mix_local(xf, plan, axes, ndev, block, idx)
    self_w, ws = _masked_weights(plan, alive, axes, ndev, block)
    out = _bcast(self_w, x.ndim) * xf
    for op, w in zip(plan.exchange_ops(), ws):
        out = out + _bcast(w, x.ndim) * _recv(xf, op, axes, ndev, block)
    return out


def _make_mix_t(plans, phase, alive, masked: bool, axes, ndev, block, idx):
    """mix(x) = sum_j w_ij(t) x_j for the (traced) round phase."""
    if len(plans) == 1:
        return lambda x: _phase_mix(x, alive, plans[0], masked, axes, ndev, block, idx)

    def mix(x):
        branches = [
            functools.partial(
                _phase_mix, plan=p, masked=masked, axes=axes, ndev=ndev,
                block=block, idx=idx,
            )
            for p in plans
        ]
        return jax.lax.switch(phase, branches, x, alive)

    return mix


# ------------------------------------------------------------- leaf rounds
def _round_leaf_local(leaf, hat, s, key, plan, gamma, compressor: Compressor,
                      use_packed, use_fused, axes, ndev, block, idx, m_global):
    """One static CHOCO round on the local node block — mirrors
    ``gossip._round_leaf`` operation-for-operation."""
    if use_fused:
        return _fused_round_local(
            leaf, hat, s, key, plan, gamma, compressor, axes, ndev, block, idx, m_global
        )
    inner_shape, dtype = leaf.shape[1:], leaf.dtype
    theta_new = leaf + jnp.asarray(gamma, dtype) * (s - hat).astype(dtype)
    resid = (theta_new - hat).astype(jnp.float32)
    if isinstance(compressor, Identity):
        q_self = resid
        mixed = _mix_local(q_self, plan, axes, ndev, block, idx)
    else:
        node_keys = _local_slice(jax.random.split(key, m_global), idx, block)
        payload = jax.vmap(compressor.encode)(resid, node_keys)
        q_self = _vdecode(compressor, payload, inner_shape, jnp.float32)
        if use_packed:
            mixed = _mix_payload_local(
                compressor, payload, inner_shape, jnp.float32, plan,
                axes, ndev, block, idx,
            )
        else:
            mixed = _mix_local(q_self, plan, axes, ndev, block, idx)
    hat_new = (hat.astype(jnp.float32) + q_self).astype(hat.dtype)
    s_new = (s.astype(jnp.float32) + mixed).astype(s.dtype)
    return theta_new, hat_new, s_new


def _fused_round_local(leaf, hat, s, key, plan, gamma, compressor,
                       axes, ndev, block, idx, m_global):
    """Single-pass Pallas fast path on the local shard: the fused encode /
    multi-shift dequant-accumulate kernels run on the [block, ...] slab and
    the packed payload travels the wire via :func:`_shard_roll`."""
    from repro.kernels.ops import fused_choco_round_leaf

    node_keys = _local_slice(jax.random.split(key, m_global), idx, block)
    roll_fn = lambda x, sh: _shard_roll(x, sh, axes, ndev, block)
    return fused_choco_round_leaf(
        leaf, hat, s, key, plan, gamma, compressor.bits,
        getattr(compressor, "interpret", None),
        roll_fn=roll_fn, node_keys=node_keys,
    )


def _round_leaf_masked_local(leaf, hat, s, key, mix_t, gamma,
                             compressor: Compressor, alive, idx, block, m_global):
    """Time-varying / fault-tolerant round on the local block — the
    memory-full CHOCO form of ``gossip._round_leaf_masked`` with the two
    dense ``W(t)`` products replaced by neighbor exchanges (``mix_t``)."""
    inner_shape, dtype = leaf.shape[1:], leaf.dtype
    ab = _bcast(alive, leaf.ndim)
    s_cur = mix_t(hat.astype(jnp.float32))
    theta_new = leaf + (ab * gamma).astype(dtype) * (s_cur - hat.astype(jnp.float32)).astype(dtype)
    resid = ((theta_new - hat).astype(jnp.float32)) * ab
    if isinstance(compressor, Identity):
        q_self = resid
    else:
        node_keys = _local_slice(jax.random.split(key, m_global), idx, block)
        payload = jax.vmap(compressor.encode)(resid, node_keys)
        q_self = _vdecode(compressor, payload, inner_shape, jnp.float32) * ab
    hat_new = (hat.astype(jnp.float32) + q_self).astype(hat.dtype)
    s_post = s_cur + mix_t(q_self)
    s_new = (ab * s_post + (1.0 - ab) * s.astype(jnp.float32)).astype(s.dtype)
    return theta_new, hat_new, s_new


# ------------------------------------------------------------------- rounds
def choco_round_ppermute(
    theta_half,
    state: CHOCOState,
    topology: Topology,
    gamma: float,
    compressor: Compressor,
    key: jax.Array,
    *,
    mesh,
    node_axes="data",
    packed: bool = True,
    fused: bool = False,
    block_scan_elems: int = BLOCK_SCAN_ELEMS,
    schedule: TopologySchedule | None = None,
    step=None,
    mask=None,
):
    """One compressed-consensus round on the SPMD neighbor-exchange backend.

    Drop-in for ``gossip.choco_round`` (reached via its ``backend="ppermute"``
    dispatch): same state threading, same RNG stream, same scan-plan leaf
    chunking — but executed under ``shard_map`` over ``mesh``'s
    ``node_axes``, with only packed compressed payloads (static rounds) or
    public-copy/neighbor-q exchanges (time-varying rounds) on the wire.

    ``schedule`` + ``step`` + ``mask`` replace the rolled backend's dense
    ``mixing`` argument: phases are compiled to per-phase
    :class:`~repro.core.topology.PermutePlan` wire programs selected by
    ``lax.switch``, and a participation mask triggers the locally-computed
    masked-Metropolis weights.
    """
    leaves, treedef = jax.tree_util.tree_flatten(theta_half)
    m = leaves[0].shape[0]
    axes, ndev, block = node_mesh_info(mesh, node_axes, m)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    time_varying = (
        schedule is not None and not getattr(schedule, "is_static", True)
    ) or mask is not None
    if time_varying:
        if schedule is not None:
            plans = compile_schedule_plans(schedule)
        else:
            plans = (compile_permute_plan(topology),)
        _check_block(plans, block, ndev)
        period = len(plans)
        use_packed = use_fused = False
        plan = None
    else:
        plan = compile_permute_plan(topology)
        _check_block((plan,), block, ndev)
        use_packed = packed and not isinstance(compressor, Identity)
        use_fused = (
            fused
            and plan.is_circulant
            and getattr(compressor, "supports_fused_round", False)
        )
        period = 1

    masked = mask is not None
    args = [theta_half, state, key]
    specs = [P(axes), P(axes), P()]
    if masked:
        args.append(mask)
        specs.append(P(axes))
    if time_varying:
        step_arr = jnp.zeros((), jnp.int32) if step is None else jnp.asarray(step, jnp.int32)
        args.append(step_arr)
        specs.append(P())

    def body(theta, st, key, *rest):
        rest = list(rest)
        alive = rest.pop(0) if masked else None
        step_arg = rest.pop(0) if time_varying else None
        idx = _flat_axis_index(axes, sizes)
        lv, td = jax.tree_util.tree_flatten(theta)
        hv = td.flatten_up_to(st.theta_hat)
        sv = td.flatten_up_to(st.s)
        keys = jax.random.split(key, len(lv))

        if time_varying:
            alive_local = (
                jnp.ones((block,), jnp.float32)
                if alive is None
                else alive.astype(jnp.float32)
            )
            phase = (
                jnp.zeros((), jnp.int32) if period == 1 else step_arg % period
            )
            mix_t = _make_mix_t(plans, phase, alive_local, masked, axes, ndev, block, idx)

            def round_one(leaf, hat, s, k):
                return _round_leaf_masked_local(
                    leaf, hat, s, k, mix_t, gamma, compressor, alive_local,
                    idx, block, m,
                )

        else:

            def round_one(leaf, hat, s, k):
                return _round_leaf_local(
                    leaf, hat, s, k, plan, gamma, compressor, use_packed,
                    use_fused, axes, ndev, block, idx, m,
                )

        # the chunk layout and per-chunk key stream come from the SAME driver
        # as the rolled backend — bit-parity of the two is structural
        new_theta, new_hat, new_s = _round_leaves(
            lv, hv, sv, keys, round_one, block_scan_elems
        )
        unf = lambda ls: jax.tree_util.tree_unflatten(td, ls)
        return unf(new_theta), CHOCOState(theta_hat=unf(new_hat), s=unf(new_s))

    fn = shard_map(
        body, mesh, in_specs=tuple(specs), out_specs=(P(axes), P(axes)),
        check_rep=False,
    )
    return fn(*args)


def mix_stacked_ppermute(tree, topology: Topology, *, mesh, node_axes="data"):
    """Uncompressed gossip mix of a stacked pytree over the neighbor-exchange
    wire — the SPMD counterpart of ``gossip.mix_stacked`` (the dual/lambda
    gossip rides exactly these permutes when the ppermute backend is on)."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    axes, ndev, block = node_mesh_info(mesh, node_axes, m)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    plan = compile_permute_plan(topology)
    _check_block((plan,), block, ndev)

    def body(t):
        idx = _flat_axis_index(axes, sizes)
        return jax.tree.map(
            lambda x: _mix_local(x, plan, axes, ndev, block, idx), t
        )

    return shard_map(body, mesh, in_specs=P(axes), out_specs=P(axes), check_rep=False)(tree)


def _check_block(plans: Sequence[PermutePlan], block: int, ndev: int) -> None:
    """Irregular (non-circulant) graphs need one node per device: an EdgeStep
    is a *device* permutation.  A single-device mesh is exempt — there is no
    wire, and ``_recv`` executes the node permutation locally."""
    if ndev > 1 and block > 1 and any(not p.is_circulant for p in plans):
        raise ValueError(
            "the ppermute backend runs irregular (non-circulant) graphs with "
            "exactly one node per device; got a block of "
            f"{block} nodes/device — use the rolled backend or a mesh whose "
            "node axes match num_nodes (uneven ratios: ROADMAP open item)"
        )
