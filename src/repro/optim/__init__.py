from repro.optim.sgd import (
    OptState,
    adam,
    make_schedule,
    sgd,
)

__all__ = ["OptState", "adam", "make_schedule", "sgd"]
