from repro.optim.sgd import (
    Optimizer,
    OptState,
    Schedule,
    adam,
    make_schedule,
    sgd,
)

__all__ = ["Optimizer", "OptState", "Schedule", "adam", "make_schedule", "sgd"]
