"""Minimal functional optimizers + LR schedules (no external deps).

API mirrors optax: ``opt = sgd(...)``; ``state = opt.init(params)``;
``updates, state = opt.update(grads, state, params)``; apply with
``jax.tree.map(lambda p, u: p + u, params, updates)``.

The paper's experiments use SGD with a geometrically decaying learning rate
eta_t = r^t * eta_0 (r = 0.995 / 0.998) — ``make_schedule("exp", ...)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def make_schedule(kind: str, base: float, *, decay: float = 0.995, total_steps: int = 1000, warmup: int = 0) -> Schedule:
    def sched(step):
        t = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        if kind == "const":
            lr = jnp.float32(base)
        elif kind == "exp":
            lr = base * jnp.power(decay, t)
        elif kind == "cosine":
            frac = jnp.clip(t / max(total_steps, 1), 0.0, 1.0)
            lr = base * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            raise ValueError(f"unknown schedule {kind!r}")
        if warmup > 0:
            lr = lr * jnp.clip(t / warmup, 0.0, 1.0)
        return lr
    return sched


class OptState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment / momentum
    nu: Any  # second moment (adam only; zeros for sgd)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]


def sgd(lr: float | Schedule, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    sched = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        if momentum == 0:  # no momentum buffer to carry
            return OptState(jnp.zeros((), jnp.int32), (), ())
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), zeros, ())

    def update(grads, state, params=None):
        lr_t = sched(state.step)

        if momentum == 0:
            updates = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
            return updates, OptState(state.step + 1, (), ())

        def upd(g, m):
            g = g.astype(jnp.float32)
            m = momentum * m + g
            g = momentum * m + g if nesterov else m
            return -lr_t * g, m

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        pairs = [upd(g, m) for g, m in zip(flat_g, flat_m)]
        updates = jax.tree_util.tree_unflatten(tdef, [p[0] for p in pairs])
        mu = jax.tree_util.tree_unflatten(tdef, [p[1] for p in pairs])
        return updates, OptState(state.step + 1, mu, ())

    return Optimizer(init, update)


def adam(lr: float | Schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), zeros(), zeros())

    def update(grads, state, params=None):
        t = state.step + 1
        lr_t = sched(state.step)
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step_ = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return -lr_t * step_, m, v

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        flat_p = tdef.flatten_up_to(params) if params is not None else [None] * len(flat_g)
        trip = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        unf = lambda i: jax.tree_util.tree_unflatten(tdef, [tr[i] for tr in trip])
        return unf(0), OptState(t, unf(1), unf(2))

    return Optimizer(init, update)
