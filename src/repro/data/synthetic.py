"""Synthetic heterogeneous data pipeline (deterministic, shardable).

The paper's three experimental regimes, reproduced without external
downloads (the container is offline):

* **class-shard** (Fashion-MNIST analog, §5.1): Gaussian-mixture
  classification where node i stores samples from class i only — the
  extreme label-skew that makes standard decentralized learning unfair.
* **contrast-shift** (CIFAR-10 analog, §5.2): all nodes share the label
  distribution but a few nodes see a covariate-shifted (contrast-like
  nonlinearity) version of the features — the "camera network" setup.
* **instrument-shift** (COOS7 analog, §5.2): two sub-populations generated
  by different "instruments" (distinct feature transforms); a minority of
  nodes uses instrument 2.

For transformer-scale runs, ``node_token_stream`` yields per-node token
batches whose unigram distribution is node-skewed (distinct Zipf
permutations) — heterogeneity at the LM level.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "HeterogeneousDataset",
    "class_shard_classification",
    "contrast_shift_classification",
    "instrument_shift_classification",
    "node_token_stream",
]


@dataclasses.dataclass
class HeterogeneousDataset:
    """Per-node splits. x: [m, n, d]; y: [m, n] int labels. Plus held-out
    per-distribution validation sets for worst-case evaluation."""

    x: np.ndarray
    y: np.ndarray
    val_x: list[np.ndarray]  # one per latent distribution
    val_y: list[np.ndarray]
    val_names: list[str]

    @property
    def num_nodes(self) -> int:
        return self.x.shape[0]

    @property
    def dim(self) -> int:
        return self.x.shape[-1]

    @property
    def num_classes(self) -> int:
        return int(max(y.max() for y in [self.y] + self.val_y)) + 1

    def batches(self, batch_size: int, seed: int = 0):
        """Infinite generator of per-node minibatches ([m, b, d], [m, b])."""
        rng = np.random.default_rng(seed)
        m, n, _ = self.x.shape
        while True:
            idx = rng.integers(0, n, size=(m, batch_size))
            xb = np.take_along_axis(self.x, idx[:, :, None], axis=1)
            yb = np.take_along_axis(self.y, idx, axis=1)
            yield xb, yb


def _mixture(rng, num_classes: int, dim: int, n: int, labels: np.ndarray, sep: float):
    means = rng.normal(size=(num_classes, dim)) * sep
    x = means[labels] + rng.normal(size=(n, dim))
    return x.astype(np.float32)


def class_shard_classification(
    num_nodes: int = 10,
    num_classes: int | None = None,
    dim: int = 32,
    n_per_node: int = 512,
    n_val: int = 512,
    sep: float = 1.8,
    seed: int = 0,
) -> HeterogeneousDataset:
    """Node i stores samples of class (i mod C) only (paper §5.1 class split)."""
    num_classes = num_classes or num_nodes
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_classes, dim)) * sep
    xs, ys = [], []
    for i in range(num_nodes):
        c = i % num_classes
        x = means[c] + rng.normal(size=(n_per_node, dim))
        xs.append(x.astype(np.float32))
        ys.append(np.full((n_per_node,), c, np.int32))
    val_x, val_y, names = [], [], []
    for c in range(num_classes):
        x = means[c] + rng.normal(size=(n_val, dim))
        val_x.append(x.astype(np.float32))
        val_y.append(np.full((n_val,), c, np.int32))
        names.append(f"class_{c}")
    return HeterogeneousDataset(np.stack(xs), np.stack(ys), val_x, val_y, names)


def _contrast(x: np.ndarray, c: float) -> np.ndarray:
    """Paper eq. (11) analog on standardized features: nonlinear contrast."""
    z = c * x
    return np.sign(z) * np.abs(z) ** 1.1


def contrast_shift_classification(
    num_nodes: int = 20,
    num_classes: int = 10,
    dim: int = 32,
    n_per_node: int = 512,
    n_val: int = 512,
    low_nodes: int = 2,
    high_nodes: int = 2,
    sep: float = 1.5,
    seed: int = 0,
) -> HeterogeneousDataset:
    """CIFAR-contrast analog: a few nodes see c=0.5 / c=1.5 transformed data."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_classes, dim)) * sep
    contrasts = [0.5] * low_nodes + [1.5] * high_nodes + [1.0] * (num_nodes - low_nodes - high_nodes)
    xs, ys = [], []
    for i in range(num_nodes):
        labels = rng.integers(0, num_classes, n_per_node)
        x = means[labels] + rng.normal(size=(n_per_node, dim))
        xs.append(_contrast(x, contrasts[i]).astype(np.float32))
        ys.append(labels.astype(np.int32))
    val_x, val_y, names = [], [], []
    for cname, c in (("low_contrast", 0.5), ("high_contrast", 1.5), ("original", 1.0)):
        labels = rng.integers(0, num_classes, n_val)
        x = means[labels] + rng.normal(size=(n_val, dim))
        val_x.append(_contrast(x, c).astype(np.float32))
        val_y.append(labels.astype(np.int32))
        names.append(cname)
    return HeterogeneousDataset(np.stack(xs), np.stack(ys), val_x, val_y, names)


def instrument_shift_classification(
    num_nodes: int = 10,
    num_classes: int = 7,
    dim: int = 32,
    n_per_node: int = 512,
    n_val: int = 512,
    minority_nodes: int = 2,
    sep: float = 1.5,
    seed: int = 0,
) -> HeterogeneousDataset:
    """COOS7 analog: minority nodes sample via a different 'microscope'
    (a fixed random linear distortion + offset of the features)."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_classes, dim)) * sep
    # instrument 2: fixed rotation-ish distortion + bias
    a = rng.normal(size=(dim, dim)) * (0.4 / np.sqrt(dim))
    distort = np.eye(dim) + a
    offset = rng.normal(size=(dim,)) * 0.8

    def instrument2(x):
        return x @ distort.T + offset

    xs, ys = [], []
    for i in range(num_nodes):
        labels = rng.integers(0, num_classes, n_per_node)
        x = means[labels] + rng.normal(size=(n_per_node, dim))
        if i < minority_nodes:
            x = instrument2(x)
        xs.append(x.astype(np.float32))
        ys.append(labels.astype(np.int32))
    val_x, val_y, names = [], [], []
    for name, fn in (("microscope_1", lambda x: x), ("microscope_2", instrument2)):
        labels = rng.integers(0, num_classes, n_val)
        x = means[labels] + rng.normal(size=(n_val, dim))
        val_x.append(fn(x).astype(np.float32))
        val_y.append(labels.astype(np.int32))
        names.append(name)
    return HeterogeneousDataset(np.stack(xs), np.stack(ys), val_x, val_y, names)


def rotated_minority_classification(
    num_nodes: int = 10,
    num_classes: int = 4,
    dim: int = 16,
    n_per_node: int = 512,
    n_val: int = 512,
    minority_nodes: int = 2,
    rot_scale: float = 2.0,
    sep: float = 1.5,
    seed: int = 0,
) -> HeterogeneousDataset:
    """The hard heterogeneity benchmark: minority nodes see a *rotated* view
    of the feature space, so no linear predictor fits both sub-populations —
    average-risk training sacrifices the minority (worst-node accuracy
    collapses) while the DRO objective trades majority slack for minority
    accuracy.  This is the construction that reproduces the paper's
    AD-GDA >> CHOCO-SGD worst-node gap at laptop scale."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_classes, dim)) * sep
    r = np.linalg.qr(np.eye(dim) + rot_scale * rng.normal(size=(dim, dim)) / np.sqrt(dim))[0]

    def sample(n, rotated):
        lab = rng.integers(0, num_classes, n)
        x = means[lab] + rng.normal(size=(n, dim))
        if rotated:
            x = x @ r.T
        return x.astype(np.float32), lab.astype(np.int32)

    xs, ys = [], []
    for i in range(num_nodes):
        x, lab = sample(n_per_node, rotated=i < minority_nodes)
        xs.append(x)
        ys.append(lab)
    val_x, val_y, names = [], [], []
    for name, rot in (("majority", False), ("minority", True)):
        x, lab = sample(n_val, rot)
        val_x.append(x)
        val_y.append(lab)
        names.append(name)
    return HeterogeneousDataset(np.stack(xs), np.stack(ys), val_x, val_y, names)


def node_token_stream(
    num_nodes: int,
    batch_per_node: int,
    seq_len: int,
    vocab_size: int,
    zipf_a: float = 1.2,
    seed: int = 0,
):
    """Infinite per-node LM batches [m, b, S] with node-skewed unigram stats.

    Each node uses the same Zipf marginal but a node-specific vocabulary
    permutation — distinct local distributions with equal entropy.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    perms = np.stack([rng.permutation(vocab_size) for _ in range(num_nodes)])
    while True:
        base = rng.choice(vocab_size, size=(num_nodes, batch_per_node, seq_len), p=probs)
        tokens = np.take_along_axis(
            perms[:, None, None, :].repeat(batch_per_node, 1).repeat(seq_len, 2),
            base[..., None],
            axis=-1,
        )[..., 0]
        yield tokens.astype(np.int32)
