from repro.data.synthetic import (
    HeterogeneousDataset,
    class_shard_classification,
    contrast_shift_classification,
    instrument_shift_classification,
    node_token_stream,
    rotated_minority_classification,
)

__all__ = [
    "HeterogeneousDataset",
    "class_shard_classification",
    "contrast_shift_classification",
    "instrument_shift_classification",
    "node_token_stream",
    "rotated_minority_classification",
]
