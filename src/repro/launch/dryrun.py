import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers, compiles, and fits — without real hardware.

For each pair this script builds the production step function:

  train_4k     -> AD-GDA Algorithm-1 step (lambda-weighted loss, compressed
                  ring gossip, dual averaging) over m = 16 (single-pod) or
                  32 (multi-pod) nodes,
  prefill_32k  -> full forward + cache priming on the consensus model,
  decode_32k / long_500k -> one-token serve step against a seq_len cache,

then ``jax.jit(step, in_shardings=...).lower(*abstract).compile()`` on the
(16, 16) = 256-chip and (2, 16, 16) = 512-chip meshes, prints
``memory_analysis()`` / ``cost_analysis()`` and writes the roofline terms to
``experiments/dryrun/<arch>_<shape>_<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, input_specs, supports_shape
from repro.launch import sharding as sh
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh, node_axes, num_nodes
from repro.launch.roofline import model_flops_for, roofline_terms

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _mesh_name(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def lower_pair(arch: str, shape_name: str, multi_pod: bool, *, compressor: str = "q4b",
               microbatches: int = 1, grad_accum_dtype: str = "float32", attn_chunk: int | None = None,
               seq_shard_attn: bool = False):
    """Build + lower + compile one (arch, shape, mesh). Returns (compiled, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not supports_shape(cfg, shape):
        return None, {"skipped": f"{arch} does not support {shape_name} (full attention; see DESIGN)"}

    if attn_chunk is not None:
        from repro.models import layers as _layers

        _layers.CHUNK_THRESHOLD = attn_chunk
    if seq_shard_attn:
        from repro.models import layers as _layers

        _layers.SEQ_SHARD_AXIS = "model"
    mesh = make_production_mesh(multi_pod=multi_pod)
    lead = ("pod", "data") if multi_pod else ("data",)

    with mesh:
        if shape.step == "train":
            m = num_nodes(mesh)
            trainer = st.make_trainer(cfg, m, compressor=compressor, track_average=False,
                                      microbatches=microbatches, grad_accum_dtype=grad_accum_dtype,
                                      spmd_axis_name=(lead if seq_shard_attn else None))
            state_abs = st.abstract_trainer_state(trainer, cfg)
            pspec = sh.param_pspecs(state_abs.theta, mesh, node_axes=lead)
            state_spec = sh.trainer_state_pspecs(state_abs, pspec, mesh, lead)
            batch_abs = input_specs(cfg, shape_name, num_nodes=m)
            batch_spec = sh.batch_pspecs(batch_abs, mesh, lead_axes=lead)
            jitted = jax.jit(
                trainer.step_impl,
                in_shardings=(sh.shardings(mesh, state_spec), sh.shardings(mesh, batch_spec)),
                donate_argnums=0,
            )
            lowered = jitted.lower(state_abs, batch_abs)
        elif shape.step == "prefill":
            params_abs = st.abstract_params(cfg)
            pspec = sh.param_pspecs(params_abs, mesh)
            batch_abs = input_specs(cfg, shape_name)
            batch_spec = sh.batch_pspecs(batch_abs, mesh, lead_axes=lead)
            step = st.make_prefill_step(cfg, cache_len=shape.seq_len)
            jitted = jax.jit(
                step,
                in_shardings=(sh.shardings(mesh, pspec), sh.shardings(mesh, batch_spec)),
            )
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            params_abs = st.abstract_params(cfg)
            pspec = sh.param_pspecs(params_abs, mesh)
            cache_abs = st.abstract_cache(cfg, shape.global_batch, shape.seq_len)
            cache_spec = sh.cache_pspecs(cache_abs, mesh, shape.global_batch, lead_axes=lead)
            dec = input_specs(cfg, shape_name)
            tok_spec = sh.batch_pspecs({"tokens": dec["tokens"]}, mesh, lead_axes=lead)["tokens"]
            step = st.make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(
                    sh.shardings(mesh, pspec),
                    sh.shardings(mesh, cache_spec),
                    sh.shardings(mesh, tok_spec),
                    sh.shardings(mesh, jax.sharding.PartitionSpec()),
                ),
                donate_argnums=1,
            )
            lowered = jitted.lower(params_abs, cache_abs, dec["tokens"], dec["pos"])

        t0 = time.time()
        compiled = lowered.compile()
        meta = {
            "arch": arch,
            "shape": shape_name,
            "mesh": _mesh_name(multi_pod),
            "compile_s": round(time.time() - t0, 1),
        }
        return compiled, meta


def run_pair(arch: str, shape_name: str, multi_pod: bool, *, verbose: bool = True, compressor: str = "q4b", tag: str = "", **lower_kw):
    cfg = get_config(arch)
    arch = cfg.name  # canonical id (e.g. "qwen3-1.7b")
    shape = SHAPES[shape_name]
    try:
        compiled, meta = lower_pair(arch, shape_name, multi_pod, compressor=compressor, **lower_kw)
    except Exception as e:
        traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": _mesh_name(multi_pod), "error": f"{type(e).__name__}: {e}"}
    if compiled is None:
        if verbose:
            print(f"SKIP {arch} x {shape_name}: {meta['skipped']}")
        return {"arch": arch, "shape": shape_name, "mesh": _mesh_name(multi_pod), **meta}

    chips = 512 if multi_pod else 256
    report = roofline_terms(
        compiled,
        arch=arch,
        shape=shape_name,
        mesh_name=meta["mesh"],
        chips=chips,
        model_flops=model_flops_for(cfg, shape),
    )
    row = report.row()
    row["compile_s"] = meta["compile_s"]
    if tag:
        row["tag"] = tag

    if verbose:
        try:
            print(compiled.memory_analysis())
        except Exception as e:
            print(f"(memory_analysis unavailable: {e})")
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        print({k: v for k, v in sorted(cost.items()) if k in ("flops", "bytes accessed")})
        print(
            f"{arch} x {shape_name} @ {meta['mesh']}: "
            f"compute={report.compute_s*1e3:.2f}ms memory={report.memory_s*1e3:.2f}ms "
            f"collective={report.collective_s*1e3:.2f}ms dominant={report.dominant} "
            f"useful_flops={report.useful_flops_frac:.2%} (compiled in {meta['compile_s']}s)"
        )

    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fname = os.path.join(OUT_DIR, f"{arch.replace('.', '_')}_{shape_name}_{meta['mesh']}{suffix}.json")
    with open(fname, "w") as f:
        json.dump(row, f, indent=1)
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES), help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true", help="use the 2x16x16 512-chip mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every (arch x shape)")
    ap.add_argument("--compressor", default="q4b")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="", help="variant tag for perf experiments")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-accum-dtype", default="float32")
    ap.add_argument("--attn-chunk", type=int, default=None,
                    help="override layers.CHUNK_THRESHOLD (query-chunked attention)")
    ap.add_argument("--seq-shard-attn", action="store_true",
                    help="context-parallel attention: shard the query-seq dim over `model`")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else [a.replace("_", "-") for a in ARCHS]
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                suffix = f"_{args.tag}" if args.tag else ""
                fname = os.path.join(
                    OUT_DIR, f"{arch.replace('.', '_')}_{shape}_{_mesh_name(mp)}{suffix}.json"
                )
                if args.skip_existing and os.path.exists(fname):
                    print(f"EXISTS {arch} x {shape} @ {_mesh_name(mp)}")
                    continue
                results.append(run_pair(arch, shape, mp, compressor=args.compressor, tag=args.tag,
                                        microbatches=args.microbatches,
                                        grad_accum_dtype=args.grad_accum_dtype,
                                        attn_chunk=args.attn_chunk,
                                        seq_shard_attn=args.seq_shard_attn))

    errs = [r for r in results if "error" in r]
    print(f"\n== dry-run summary: {len(results) - len(errs)}/{len(results)} OK ==")
    for r in errs:
        print(f"FAIL {r['arch']} x {r['shape']} @ {r['mesh']}: {r['error']}")


if __name__ == "__main__":
    main()
