"""Serving driver: batched autoregressive decode on the consensus model.

Demonstrates the decode path every assigned arch implements (KV ring
buffers, SSM/RG-LRU O(1) state).  CPU-scale by default (--reduced).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, step_path
from repro.configs import get_config
from repro.launch import steps as st
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--restore", default=None,
                    help="model checkpoint to load: an exact .npz file, the "
                         "same path without the .npz suffix, or a step-tagged "
                         "prefix (resolves to the latest <prefix>_<step>.npz, "
                         "the spelling launch/train.py --checkpoint writes)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    S = args.prompt_len
    if cfg.ssm_state:
        S = max(S, cfg.ssm_chunk)
        S -= S % cfg.ssm_chunk
    cache_len = args.cache_len or (S + args.gen)

    key = jax.random.PRNGKey(args.seed)
    params = T.init_model(key, cfg)
    if args.restore:
        # accept the same path spellings checkpoint.latest_step does: an
        # exact file, a missing-.npz suffix, or a step-tagged prefix
        fname = args.restore
        if not os.path.exists(fname):
            if os.path.exists(fname + ".npz"):
                fname += ".npz"
            else:
                found = latest_step(fname)
                if found is None:
                    raise SystemExit(
                        f"--restore: no checkpoint at {args.restore!r} (tried the "
                        "exact path, with a .npz suffix, and as a step-tagged prefix)"
                    )
                fname = step_path(fname, found)
        try:
            params = restore(fname, params)
        except KeyError as e:
            raise SystemExit(
                f"--restore: {fname} does not hold a bare model parameter tree "
                f"({e}); full trainer-state checkpoints from launch/train.py "
                "serve via their companion '<prefix>_model.npz' consensus file"
            ) from None
        print(f"restored params from {fname}")

    batch = {"tokens": jax.random.randint(key, (args.batch, S), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_context, cfg.d_model), jnp.float32
        ) * 0.02
    if cfg.num_patches > 0:
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model), jnp.float32
        ) * 0.02

    prefill = jax.jit(st.make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(st.make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1:, :], axis=-1)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(S + i))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1, :] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1:, :], axis=-1)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0

    tokens = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} prefill({args.batch}x{S})={t_prefill:.2f}s "
          f"decode {args.gen - 1} steps={t_decode:.2f}s "
          f"({t_decode / max(args.gen - 1, 1) * 1e3:.1f} ms/token)")
    print("generated token ids (first row):", tokens[0][:24].tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN in decode logits"


if __name__ == "__main__":
    main()
