"""Serving driver: batched autoregressive decode on the consensus model.

Demonstrates the decode path every assigned arch implements (KV ring
buffers, SSM/RG-LRU O(1) state).  CPU-scale by default (--reduced).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --reduced \
      --batch 4 --prompt-len 64 --gen 32

``--fleet N`` switches to the decentralized serving fleet: N nodes of
continuous-batching engines behind bounded-queue admission control, fed by
the seeded Poisson/Zipf load generator, reporting the suite-S latency/SLO
vocabulary (p50/p95/p99 TTFT in ticks and ms, tokens/s, queue depth, slot
occupancy).  With ``--follow`` the fleet polls ``--restore`` (a step-tagged
checkpoint prefix, the spelling launch/train.py --checkpoint writes) and
hot-reloads new consensus weights while serving — the train-and-serve loop:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --fleet 2 --rate 0.2 --requests 64 --follow --restore /tmp/run/consensus \
      --metrics-out serve_metrics.json

``--metrics-out`` writes the final metrics JSON (same flag vocabulary as
launch/train.py).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, step_path
from repro.configs import get_config
from repro.launch import steps as st
from repro.models import transformer as T


def _resolve_restore(path: str) -> str:
    """Accept the path spellings checkpoint.latest_step does: an exact file,
    a missing-.npz suffix, or a step-tagged prefix."""
    if os.path.exists(path):
        return path
    if os.path.exists(path + ".npz"):
        return path + ".npz"
    found = latest_step(path)
    if found is None:
        raise SystemExit(
            f"--restore: no checkpoint at {path!r} (tried the exact path, "
            "with a .npz suffix, and as a step-tagged prefix)"
        )
    return step_path(path, found)


def _run_fleet(args, cfg, params) -> None:
    """The decentralized serving fleet: N nodes, admission control, seeded
    Poisson/Zipf traffic, optional --follow hot reload from --restore."""
    from repro.serving import (
        AdmissionControl,
        FleetNode,
        HotReloader,
        LoadGenConfig,
        LoadGenerator,
        ServeEngine,
        ServingFleet,
    )

    bucket = 8
    prompt_max = max(args.prompt_len, 4)
    padded = -(-prompt_max // bucket) * bucket
    cache_len = args.cache_len or (padded + args.gen)
    gen = LoadGenerator(LoadGenConfig(
        num_nodes=args.fleet, rate=args.rate, vocab_size=cfg.vocab_size,
        prompt_min=4, prompt_max=prompt_max,
        output_min=1, output_max=args.gen, seed=args.seed,
        prompt_mode={"iid": "iid", "zipf": "pool", "unique": "unique"}[args.prompts],
        prompt_pool=args.prompt_pool,
    ))
    nodes = [
        FleetNode(
            i,
            ServeEngine(cfg, params, max_slots=args.slots, cache_len=cache_len,
                        prompt_bucket=bucket, fastpath=not args.no_fastpath,
                        prefix_cache=args.prefix_cache),
            admission=AdmissionControl(max_queue=args.max_queue,
                                       policy=args.admission),
            reloader=(HotReloader(args.restore, params) if args.follow else None),
        )
        for i in range(args.fleet)
    ]
    if args.follow:
        # start from the newest complete checkpoint already on disk
        for node in nodes:
            node.maybe_reload()
    fleet = ServingFleet(nodes, gen,
                         reload_every=args.reload_every if args.follow else 0)
    rep = fleet.run(max_requests=args.requests, max_ticks=1_000_000)

    f = rep.fleet
    reloads = sum(n.reloader.reloads for n in nodes if n.reloader)
    print(f"fleet={args.fleet}x{args.slots} rate={args.rate}/node "
          f"offered={rep.offered} completed={f['completed']} "
          f"rejected={f['rejected']} shed={f['shed']} ticks={rep.ticks}")
    print(f"ttft ticks p50/p95/p99 = {f['p50_ttft_ticks']:.0f}/"
          f"{f['p95_ttft_ticks']:.0f}/{f['p99_ttft_ticks']:.0f}  "
          f"ttft ms p50/p99 = {f['p50_ttft_ms']:.1f}/{f['p99_ttft_ms']:.1f}  "
          f"{f['tok_per_s']:.1f} tok/s  {f['per_token_ms']:.1f} ms/token")
    print(f"queue depth mean/max = {f['mean_queue_depth']:.2f}/"
          f"{f['max_queue_depth']:.0f}  slot occupancy = {f['slot_occupancy']:.2f}"
          + (f"  reloads = {reloads}" if args.follow else ""))
    print(f"cache_hit_rate = {f['cache_hit_rate']:.3f}  "
          f"prefill_skipped = {f['prefill_skipped']:.0f}")
    if args.metrics_out:
        payload = {
            "arch": cfg.name,
            "fleet": args.fleet,
            "slots": args.slots,
            "rate": args.rate,
            "offered": rep.offered,
            "ticks": rep.ticks,
            "wall_seconds": rep.wall_seconds,
            "metrics": f,
            "nodes": rep.node_summaries,
        }
        if args.follow:
            payload["reloads"] = reloads
            payload["reload_steps"] = [n.reloader.step for n in nodes]
        with open(args.metrics_out, "w") as fh:
            json.dump(payload, fh, indent=2, default=float)
        print(f"metrics -> {args.metrics_out}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--restore", default=None,
                    help="model checkpoint to load: an exact .npz file, the "
                         "same path without the .npz suffix, or a step-tagged "
                         "prefix (resolves to the latest <prefix>_<step>.npz, "
                         "the spelling launch/train.py --checkpoint writes)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None,
                    help="write final serving metrics to this JSON file "
                         "(same flag as launch/train.py)")
    fleet = ap.add_argument_group("fleet mode (decentralized serving)")
    fleet.add_argument("--fleet", type=int, default=0, metavar="N",
                       help="serve as a fleet of N nodes (continuous batching "
                            "+ admission control + seeded load generator) "
                            "instead of one fixed batch")
    fleet.add_argument("--rate", type=float, default=0.2,
                       help="offered load per node, requests/engine-tick")
    fleet.add_argument("--requests", type=int, default=64,
                       help="total requests to offer across the fleet")
    fleet.add_argument("--slots", type=int, default=2,
                       help="continuous-batching slots per node")
    fleet.add_argument("--max-queue", type=int, default=12,
                       help="bounded pending-queue length per node")
    fleet.add_argument("--admission", choices=("reject", "shed_oldest"),
                       default="reject", help="overload policy")
    fleet.add_argument("--follow", action="store_true",
                       help="poll --restore (a step-tagged prefix) while "
                            "serving and hot-reload each new complete "
                            "checkpoint (train-and-serve)")
    fleet.add_argument("--reload-every", type=int, default=16,
                       help="poll cadence in engine ticks for --follow")
    fleet.add_argument("--prompts", choices=("iid", "zipf", "unique"),
                       default="iid",
                       help="prompt repetition structure: iid (historical "
                            "stream), zipf (hot pool of --prompt-pool prompts "
                            "-- the prefix-cache workload), unique (provably "
                            "distinct prompts, zero-hit-rate control)")
    fleet.add_argument("--prompt-pool", type=int, default=64,
                       help="pool size for --prompts zipf")
    fleet.add_argument("--prefix-cache", type=int, default=64,
                       help="prefix KV cache entries per engine (0 disables)")
    fleet.add_argument("--no-fastpath", action="store_true",
                       help="serve with the legacy engine (no prefix cache, "
                            "batch-1 prefill, full-pool decode) -- tick "
                            "metrics are bit-identical, only wall differs")
    args = ap.parse_args()

    if args.follow and not (args.fleet and args.restore):
        ap.error("--follow needs --fleet N and --restore <step-tagged prefix>")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    S = args.prompt_len
    if cfg.ssm_state:
        S = max(S, cfg.ssm_chunk)
        S -= S % cfg.ssm_chunk
    cache_len = args.cache_len or (S + args.gen)

    key = jax.random.PRNGKey(args.seed)
    params = T.init_model(key, cfg)
    if args.restore and not args.follow:
        fname = _resolve_restore(args.restore)
        try:
            params = restore(fname, params)
        except KeyError as e:
            raise SystemExit(
                f"--restore: {fname} does not hold a bare model parameter tree "
                f"({e}); full trainer-state checkpoints from launch/train.py "
                "serve via their companion '<prefix>_model.npz' consensus file"
            ) from None
        print(f"restored params from {fname}")

    if args.fleet:
        _run_fleet(args, cfg, params)
        return

    batch = {"tokens": jax.random.randint(key, (args.batch, S), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_context, cfg.d_model), jnp.float32
        ) * 0.02
    if cfg.num_patches > 0:
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model), jnp.float32
        ) * 0.02

    prefill = jax.jit(st.make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(st.make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1:, :], axis=-1)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(S + i))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1, :] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1:, :], axis=-1)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0

    tokens = np.asarray(jnp.concatenate(out, axis=1))
    per_token_ms = t_decode / max(args.gen - 1, 1) * 1e3
    print(f"arch={cfg.name} prefill({args.batch}x{S})={t_prefill:.2f}s "
          f"decode {args.gen - 1} steps={t_decode:.2f}s "
          f"({per_token_ms:.1f} ms/token)")
    print("generated token ids (first row):", tokens[0][:24].tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN in decode logits"
    if args.metrics_out:
        with open(args.metrics_out, "w") as fh:
            json.dump({
                "arch": cfg.name,
                "batch": args.batch,
                "prompt_len": S,
                "gen": args.gen,
                "prefill_seconds": t_prefill,
                "decode_seconds": t_decode,
                "per_token_ms": per_token_ms,
            }, fh, indent=2)
        print(f"metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
