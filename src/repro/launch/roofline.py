"""Roofline-term extraction from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth_per_chip
    collective = collective_bytes_per_device / ICI_link_bandwidth

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (the post-SPMD
per-device module).  Collective bytes are NOT in cost_analysis: we parse the
optimized HLO text, build a name->shape table, and sum the *operand* sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["HW", "collective_bytes", "roofline_terms", "RooflineReport"]

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# "f32[16,1024]{1,0}" or "bf16[2,3,4]" or "f32[]"
_SHAPE_RE = re.compile(r"^(\w+)\[([\d,]*)\]")
# "  %name = <shape-or-tuple> opcode(...operands...)"
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one (possibly tuple) HLO shape string."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes summed over the module (per device)."""
    # name -> result shape string (first token(s) before the opcode)
    shapes: dict[str, str] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    out = {k: 0 for k in _COLLECTIVES}
    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_shape, opcode, rest = m.groups()
        kind = next((c for c in _COLLECTIVES if opcode.startswith(c)), None)
        if kind is None:
            continue
        # operand names: %foo.1 references inside the call parens
        ops = re.findall(r"%([\w.\-]+)", rest)
        ob = sum(_shape_bytes(shapes.get(o, "")) for o in ops)
        if ob == 0:  # fallback: use the result shape
            ob = _shape_bytes(result_shape)
        out[kind] += ob
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops: float  # per device
    bytes_accessed: float  # per device
    coll_bytes: dict[str, int]  # per device, by kind
    model_flops: float  # 6*N(active)*tokens, global
    chips: int
    mem_per_device: dict | None = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        total = self.flops * self.chips
        return (self.model_flops / total) if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.flops,
            "hlo_bytes_per_dev": self.bytes_accessed,
            "coll_bytes": dict(self.coll_bytes),
            "useful_flops_frac": self.useful_flops_frac,
            "mem_per_device": self.mem_per_device,
        }


def roofline_terms(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    model_flops: float,
) -> RooflineReport:
    """Derive per-device roofline terms.

    Primary source: the trip-count-aware HLO analyzer (``hlo_cost``) —
    XLA's own ``cost_analysis()`` counts ``while`` (scan) bodies once and
    would undercount layer-scanned models by ~num_layers x.
    """
    from repro.launch.hlo_cost import analyze_hlo

    hlo = compiled.as_text()
    c = analyze_hlo(hlo)
    flops = float(c.flops)
    byts = float(c.bytes)
    coll = {k: int(v) for k, v in c.coll.items()}
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception:
        pass
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        flops=flops,
        bytes_accessed=byts,
        coll_bytes=coll,
        model_flops=model_flops,
        chips=chips,
        mem_per_device=mem,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6 * N_active * tokens processed."""
    from repro.models.transformer import active_param_count

    n_active = active_param_count(cfg)
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens  # forward only
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/seq, fwd only
