"""Step-function builders: the glue between the model zoo and the trainers.

``make_trainer(cfg, num_nodes, ...)`` wires an architecture's ``lm_loss``
into a composed AD-GDA :class:`~repro.core.trainer.DecentralizedTrainer`
(paper Algorithm 1) — optimizer, schedule and gossip dispatch are all
selectable here, which is what the ``repro.launch.train`` CLI exposes.
``make_prefill_step`` / ``make_decode_step`` build the serving entry points
on the *consensus* model (no node axis).
"""
from __future__ import annotations

import jax

from repro.core.adgda import ADGDAConfig, adgda_trainer
from repro.core.trainer import DecentralizedTrainer
from repro.models import transformer as T
from repro.models.config import ModelConfig

__all__ = [
    "make_trainer",
    "make_prefill_step",
    "make_decode_step",
    "abstract_params",
    "abstract_trainer_state",
]


def make_trainer(
    cfg: ModelConfig,
    num_nodes: int,
    *,
    topology: str = "ring",
    topology_schedule: str | None = None,
    dropout: float = 0.0,
    topology_p: float | None = None,
    topology_seed: int = 0,
    fault_spec: str | None = None,
    compressor: str = "q4b",
    alpha: float = 0.01,
    eta_theta: float = 0.1,
    eta_lambda: float = 0.01,
    track_average: bool = False,
    packed_gossip: bool = True,
    fused_gossip: bool = False,
    gossip_backend: str = "rolled",
    mesh=None,
    node_axes="data",
    robust: bool = True,
    microbatches: int = 1,
    grad_accum_dtype: str = "float32",
    local_steps: int = 1,
    consensus: str = "choco",
    tracker_gamma: float | None = None,
    tracker_compressor: str | None = None,
    optimizer: str = "sgd",
    schedule: str = "exp",
    lr_decay: float = 1.0,
    warmup: int = 0,
    total_steps: int = 1000,
    momentum: float = 0.0,
    nesterov: bool = False,
    spmd_axis_name=None,
) -> DecentralizedTrainer:
    def loss_fn(params, batch, rng):
        return T.lm_loss(params, batch, cfg, rng)

    adgda_cfg = ADGDAConfig(
        num_nodes=num_nodes,
        topology=topology,
        topology_schedule=topology_schedule,
        dropout=dropout,
        topology_p=topology_p,
        topology_seed=topology_seed,
        fault_spec=fault_spec,
        compressor=compressor,
        alpha=alpha,
        eta_theta=eta_theta,
        eta_lambda=eta_lambda,
        track_average=track_average,
        packed_gossip=packed_gossip,
        fused_gossip=fused_gossip,
        gossip_backend=gossip_backend,
        robust=robust,
        microbatches=microbatches,
        grad_accum_dtype=grad_accum_dtype,
        local_steps=local_steps,
        consensus=consensus,
        tracker_gamma=tracker_gamma,
        tracker_compressor=tracker_compressor,
        optimizer=optimizer,
        schedule=schedule,
        lr_decay=lr_decay,
        warmup=warmup,
        total_steps=total_steps,
        momentum=momentum,
        nesterov=nesterov,
        spmd_axis_name=spmd_axis_name,
    )
    return adgda_trainer(adgda_cfg, loss_fn, mesh=mesh, node_axes=node_axes)


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return T.prefill(params, batch, cfg, cache_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens, pos):
        return T.decode_step(params, tokens, cache, pos, cfg)

    return decode_step


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the model parameters (no allocation)."""
    return jax.eval_shape(lambda k: T.init_model(k, cfg), jax.random.PRNGKey(0))


def abstract_cache(cfg: ModelConfig, batch: int, length: int):
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, length))


def abstract_trainer_state(trainer: DecentralizedTrainer, cfg: ModelConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(trainer.init, params, jax.random.PRNGKey(0))


# deprecated alias (pre-refactor name)
abstract_adgda_state = abstract_trainer_state
