"""Distributed launch layer: production meshes, sharding rules, dry-run,
train/serve drivers.  Importing this package never touches jax device state."""
