"""Production meshes (TPU v5e).

Single pod: (data=16, model=16) = 256 chips.  Multi-pod: a leading "pod"
axis of 2 -> 512 chips; AD-GDA nodes map to the flattened ("pod","data")
axes so gossip's ring neighbors land on ICI within a pod and only the
ring's two pod-boundary edges cross DCN — exactly the thin-cut regime the
compressed gossip targets (DESIGN §3).

Functions, not module constants, so importing never initializes devices.
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_cpu_mesh",
    "make_node_mesh",
    "node_axes",
    "NODE_AXIS",
]

NODE_AXIS = "nodes"  # logical name used in PartitionSpecs for the AD-GDA node dim


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 512 if multi_pod else 256
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"production mesh needs {need} devices, found {len(devices)} — "
            "run via repro.launch.dryrun (which forces 512 host devices) or on real hardware"
        )
    return jax.make_mesh(shape, axes, devices=devices[:need])


def node_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the AD-GDA node dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_nodes(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("pod", 1) * sizes["data"])


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU smoke/integration tests on the real local devices."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_node_mesh(num_nodes: int):
    """Mesh whose ``data`` axis carries the gossip node shards — the target
    of the ``ppermute`` exchange backend (core/exchange.py).

    Uses the largest available device count that divides ``num_nodes`` so
    every device hosts an equal contiguous node block (the backend's
    requirement); on a single-device host this degenerates to a (1, 1) mesh
    and the neighbor exchanges run as local rolls.  Force a multi-device CPU
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    avail = len(jax.devices())
    data = max(k for k in range(1, min(avail, num_nodes) + 1) if num_nodes % k == 0)
    return jax.make_mesh((data, 1), ("data", "model"), devices=jax.devices()[:data])
