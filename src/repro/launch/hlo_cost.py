"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` (XLA HloCostAnalysis) counts each ``while`` body
ONCE — with layers executed under ``lax.scan`` (which we rely on to keep
compile times tractable), flops/bytes/collectives inside the loop are
undercounted by the trip count.  This module re-derives the three roofline
inputs from the optimized HLO text, multiplying loop bodies by their
``backend_config known_trip_count``:

  * flops: dot ops (2 * prod(result) * K from the contracting dims) +
    1 flop/element for arithmetic ops — dots dominate every assigned arch;
  * bytes: operands + result of every top-level (post-fusion) instruction —
    fusion internals are register/VMEM traffic, the boundaries are HBM;
  * collective bytes: operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, by kind.

All quantities are per-device (the module is the post-GSPMD per-partition
program).
"""
from __future__ import annotations

import dataclasses
import math
import re

__all__ = ["Cost", "analyze_hlo", "analyze_compiled"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)

# opcodes whose results we count as 1 flop / element
_ARITH = {
    "add", "subtract", "multiply", "divide", "power", "exponential", "log",
    "tanh", "rsqrt", "sqrt", "negate", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "sine", "cosine", "floor", "ceil", "abs",
    "sign", "atan2", "remainder", "clamp", "reduce", "exponential-minus-one",
    "log-plus-one", "logistic", "erf",
}

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict | None = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in _COLLECTIVES}

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k]
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n, {k: v * n for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    def wire_bytes(self, num_partitions: int) -> float:
        """Estimated bytes actually *transmitted* per device.

        ``coll`` holds operand sizes, which undercounts gather-style
        collectives: a ring all-gather of a shard S on n devices relays
        (n-1) shards through every link, a ring all-reduce moves
        ~2 S (n-1)/n, etc.  collective-permute is the only kind whose
        operand size IS its wire size — which is exactly why the ppermute
        gossip backend is benchmarked on this number (bench_exchange).
        """
        n = max(int(num_partitions), 1)
        c = self.coll
        return (
            c["collective-permute"]
            + c["all-gather"] * (n - 1)
            + c["reduce-scatter"] * (n - 1) / n
            + c["all-reduce"] * 2.0 * (n - 1) / n
            + c["all-to-all"] * (n - 1) / n
        )


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    """(elements, bytes) of a shape string; tuples are summed."""
    elems = byts = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _shape_dims(shape_str: str) -> list[int]:
    m = re.search(r"\w+\[([\d,]*)\]", shape_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str  # operand list + attrs


def _parse_instr(line: str) -> Instr | None:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    m = re.match(r"%?([\w.\-]+)\s*=\s*(.*)$", line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # shape: either a tuple "( ... )" or a single token
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape, rem = rhs[: i + 1], rhs[i + 1 :].lstrip()
                    break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape, rem = rhs[:sp], rhs[sp + 1 :].lstrip()
    om = re.match(r"([\w\-]+)\((.*)$", rem)
    if not om:
        return None
    return Instr(name, shape, om.group(1), om.group(2))


def _split_computations(text: str) -> tuple[dict[str, list[Instr]], str]:
    comps: dict[str, list[Instr]] = {}
    entry = ""
    current = None
    for line in text.splitlines():
        hm = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
        if hm and not line.startswith(" "):
            current = hm.group(2)
            comps[current] = []
            if hm.group(1):
                entry = current
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is not None:
            inst = _parse_instr(line)
            if inst:
                comps[current].append(inst)
    return comps, entry


def _operand_names(rest: str) -> list[str]:
    """Operand refs up to the closing paren of the op's argument list."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                arglist = rest[:i]
                break
    else:
        arglist = rest
    return re.findall(r"%([\w.\-]+)", arglist)


def _attr(rest: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(rest: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
    return int(m.group(1)) if m else 1


def _dot_flops(inst: Instr, shapes: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(inst.shape)
    ops = _operand_names(inst.rest)
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.rest)
    if m and ops:
        lhs_dims = _shape_dims(shapes.get(ops[0], ""))
        for d in (int(x) for x in m.group(1).split(",") if x):
            if d < len(lhs_dims):
                k *= lhs_dims[d]
    return 2.0 * out_elems * k


def analyze_hlo(text: str) -> Cost:
    comps, entry = _split_computations(text)
    # global name -> shape (HLO value names are module-unique post-optimization)
    shapes: dict[str, str] = {}
    for instrs in comps.values():
        for inst in instrs:
            shapes[inst.name] = inst.shape

    memo: dict[str, Cost] = {}
    fused_memo: dict[str, float] = {}
    fusion_bytes_memo: dict[str, float] = {}

    def fusion_bytes(inst: Instr, comp_name: str | None) -> float:
        """HBM traffic of a fusion: slice-consumed parameters count only the
        sliced region (XLA fuses dynamic-slice of the scan xs into the body
        fusion — the full array is an *operand* but only a slice is read);
        a dynamic-update-slice root writes only the update region."""
        _, rb = _shape_elems_bytes(inst.shape)
        ops = _operand_names(inst.rest)
        if comp_name is None or comp_name not in comps:
            return rb + sum(_shape_elems_bytes(shapes.get(o, ""))[1] for o in ops)
        instrs = comps[comp_name]
        # parameter index -> name, and uses
        param_names = {}
        for fi in instrs:
            if fi.opcode == "parameter":
                m = re.match(r"(\d+)", fi.rest)
                if m:
                    param_names[int(m.group(1))] = fi.name
        read = 0.0
        for idx, opnd in enumerate(ops):
            pname = param_names.get(idx)
            full = _shape_elems_bytes(shapes.get(opnd, ""))[1]
            if pname is None:
                read += full
                continue
            uses = [fi for fi in instrs if pname in _operand_names(fi.rest)]
            if uses and all(u.opcode in ("dynamic-slice", "slice", "gather") for u in uses):
                read += sum(_shape_elems_bytes(u.shape)[1] for u in uses)
            elif uses and all(
                u.opcode == "dynamic-update-slice" and _operand_names(u.rest)[:1] == [pname]
                for u in uses
            ):
                read += 0.0  # in-place DUS destination: aliased, not read
            else:
                read += full
        root = instrs[-1] if instrs else None
        write = rb
        if root is not None and root.opcode == "dynamic-update-slice":
            rops = _operand_names(root.rest)
            if len(rops) > 1:
                write = _shape_elems_bytes(shapes.get(rops[1], ""))[1]
        return read + write

    def fused_flops(comp: str) -> float:
        """Flops inside a fusion computation (bytes are register traffic)."""
        if comp in fused_memo:
            return fused_memo[comp]
        total = 0.0
        for inst in comps.get(comp, []):
            if inst.opcode in ("dot", "dot-general"):
                total += _dot_flops(inst, shapes)
            elif inst.opcode in _ARITH:
                e, _ = _shape_elems_bytes(inst.shape)
                total += e
            elif inst.opcode == "fusion":
                sub = _attr(inst.rest, "calls")
                if sub:
                    total += fused_flops(sub)
        fused_memo[comp] = total
        return total

    def cost_of(comp: str) -> Cost:
        if comp in memo:
            return memo[comp]
        memo[comp] = Cost()  # break cycles defensively
        c = Cost()
        for inst in comps.get(comp, []):
            op = inst.opcode
            # ---- bytes: operands + result at top (post-fusion) level.
            # Slicing ops only touch the sliced region, not the full operand
            # (critical inside scan bodies, where the full stacked xs array is
            # an operand every iteration); update ops are in-place.
            if op in ("dynamic-slice", "gather", "slice"):
                _, rb = _shape_elems_bytes(inst.shape)
                c.bytes += 2.0 * rb
            elif op == "dynamic-update-slice":
                ops = _operand_names(inst.rest)
                ub = _shape_elems_bytes(shapes.get(ops[1], ""))[1] if len(ops) > 1 else 0
                c.bytes += 2.0 * ub
            elif op == "scatter":
                ops = _operand_names(inst.rest)
                ub = _shape_elems_bytes(shapes.get(ops[2], ""))[1] if len(ops) > 2 else 0
                ib = _shape_elems_bytes(shapes.get(ops[1], ""))[1] if len(ops) > 1 else 0
                c.bytes += 2.0 * ub + ib
            elif op == "fusion":
                c.bytes += fusion_bytes(inst, _attr(inst.rest, "calls"))
            elif op not in _SKIP_BYTES:
                _, rb = _shape_elems_bytes(inst.shape)
                ob = sum(_shape_elems_bytes(shapes.get(o, ""))[1] for o in _operand_names(inst.rest))
                c.bytes += rb + ob
            # ---- flops / recursion / collectives
            if op in ("dot", "dot-general"):
                c.flops += _dot_flops(inst, shapes)
            elif op == "fusion":
                sub = _attr(inst.rest, "calls")
                if sub:
                    c.flops += fused_flops(sub)
            elif op == "while":
                trip = _trip_count(inst.rest)
                body = _attr(inst.rest, "body")
                cond = _attr(inst.rest, "condition")
                inner = Cost()
                if body:
                    inner += cost_of(body)
                if cond:
                    inner += cost_of(cond)
                c += inner.scaled(trip)
            elif op in ("call", "async-start", "custom-call"):
                sub = _attr(inst.rest, "to_apply") or _attr(inst.rest, "called_computation")
                if sub:
                    c += cost_of(sub)
            elif op == "conditional":
                # count the most expensive branch
                branches = re.findall(r"branch_computations=\{([^}]*)\}", inst.rest)
                names = re.findall(r"%([\w.\-]+)", branches[0]) if branches else []
                tf = [_attr(inst.rest, "true_computation"), _attr(inst.rest, "false_computation")]
                names += [n for n in tf if n]
                if names:
                    best = max((cost_of(n) for n in names), key=lambda x: x.flops + x.bytes, default=Cost())
                    c += best
            elif op in _ARITH:
                e, _ = _shape_elems_bytes(inst.shape)
                c.flops += e
            # async pairs: count the -start (its operand is the sent buffer),
            # skip the -done (its operand is the start's result — counting
            # both would double every async collective's bytes)
            kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
            if kind is not None and not op.endswith("-done"):
                ob = sum(_shape_elems_bytes(shapes.get(o, ""))[1] for o in _operand_names(inst.rest))
                if ob == 0:
                    _, ob = _shape_elems_bytes(inst.shape)
                c.coll[kind] += ob
        memo[comp] = c
        return c

    return cost_of(entry) if entry else Cost()


def analyze_compiled(compiled) -> Cost:
    """Cost of a ``jax.jit(...).lower(...).compile()`` executable — parses
    the optimized (post-GSPMD, per-partition) HLO text."""
    return analyze_hlo(compiled.as_text())
