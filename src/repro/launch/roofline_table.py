"""Aggregate experiments/dryrun/*.json into the §Roofline markdown table.

  PYTHONPATH=src python -m repro.launch.roofline_table [--mesh 16x16] [--tag TAG]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS
from repro.configs.shapes import SHAPES

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def load_rows(mesh: str = "16x16", tag: str = "") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, f"*_{mesh}{('_' + tag) if tag else ''}.json"))):
        base = os.path.basename(f)
        if not tag and base.count("_") > 2 and any(
            base.endswith(f"_{mesh}_{t}.json") for t in ("",)
        ):
            pass
        with open(f) as fh:
            r = json.load(fh)
        if tag and r.get("tag") != tag:
            continue
        if not tag and r.get("tag"):
            continue
        rows.append(r)
    return rows


def fmt_row(r: dict) -> str:
    dom = r["dominant"]
    coll = sum(r["coll_bytes"].values()) / 1e9
    temp = (r.get("mem_per_device") or {}).get("temp_bytes")
    temp_gb = f"{temp / 2**30:.1f}" if temp else "—"
    return (
        f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:9.1f} | "
        f"{r['memory_s']*1e3:9.1f} | {r['collective_s']*1e3:9.1f} | **{dom}** | "
        f"{r['useful_flops_frac']*100:5.1f}% | {coll:7.1f} | {temp_gb} |"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.configs import get_config

    rows = load_rows(args.mesh, args.tag)
    order = {get_config(a).name: i for i, a in enumerate(ARCHS)}
    shape_order = {s: i for i, s in enumerate(SHAPES)}
    rows.sort(key=lambda r: (order.get(r["arch"], 99), shape_order.get(r["shape"], 9)))

    print(f"Mesh {args.mesh} ({512 if 'x16x16' in args.mesh and args.mesh.startswith('2') else 256} chips)"
          + (f", variant tag: {args.tag}" if args.tag else " (paper-faithful baseline)"))
    print("| arch | shape | compute ms | memory ms | collective ms | dominant | useful FLOPs | coll GB/dev | temp GiB/dev |")
    print("|---|---|---:|---:|---:|---|---:|---:|---:|")
    for r in rows:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
