"""End-to-end AD-GDA training driver.

Runs the paper's Algorithm 1 on any assigned architecture with the synthetic
heterogeneous LM pipeline.  On real hardware pass ``--mesh prod`` /
``--mesh multipod``; on this CPU container use the default local mesh with a
reduced config (``--reduced``), which is what ``examples/train_transformer.py``
demonstrates.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 100 --nodes 4 --compressor q4b --topology ring

Fault-tolerant / time-varying runs gossip over a topology schedule with
optional per-round Bernoulli node dropout, and long runs are survivable:
``--checkpoint ckpt/run --checkpoint-every 50`` persists the **entire**
trainer state (theta, lambda, optimizer moments, CHOCO trackers, rng, step)
and ``--resume`` picks up from the latest checkpoint bit-identically to an
uninterrupted run (the synthetic data stream is deterministic and is
fast-forwarded to the resume step):

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 200 --topology-schedule roundrobin:ring,torus --dropout 0.2 \
      --checkpoint ckpt/run --checkpoint-every 50 --resume
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_latest, save
from repro.configs import get_config
from repro.data import node_token_stream
from repro.launch import steps as st
from repro.launch.mesh import make_node_mesh
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="2-layer smoke-scale variant (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--topology-schedule", default=None,
                    help="time-varying wire: 'roundrobin:ring,torus', "
                         "'matching[:P]', or a static topology name")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-round Bernoulli node-dropout probability")
    ap.add_argument("--topology-p", type=float, default=None,
                    help="edge probability for --topology erdos_renyi")
    ap.add_argument("--topology-seed", type=int, default=0,
                    help="graph-sampling seed (erdos_renyi, matching schedules)")
    ap.add_argument("--fault-spec", default=None,
                    help="wire-fault injection, e.g. 'drop:0.05,corrupt:0.01,"
                         "stale:2' — per-(edge,round) message drop/corrupt/"
                         "dup/delay with digest detection and staleness-"
                         "bounded self-healing resync (repro.core.faults)")
    ap.add_argument("--compressor", default="q4b")
    ap.add_argument("--alpha", type=float, default=0.01)
    ap.add_argument("--eta-theta", type=float, default=0.05)
    ap.add_argument("--eta-lambda", type=float, default=0.01)
    ap.add_argument("--optimizer", choices=("sgd", "adam"), default="sgd",
                    help="primal update rule (repro.optim)")
    ap.add_argument("--schedule", choices=("const", "exp", "cosine"), default="exp",
                    help="LR schedule; exp decays by --lr-decay per round")
    ap.add_argument("--lr-decay", type=float, default=1.0,
                    help="per-round decay factor for --schedule exp")
    ap.add_argument("--warmup", type=int, default=0, help="linear LR warmup rounds")
    ap.add_argument("--momentum", type=float, default=0.0, help="SGD momentum")
    ap.add_argument("--nesterov", action="store_true", help="Nesterov momentum (sgd)")
    ap.add_argument("--local-steps", type=int, default=1,
                    help="K local optimizer steps between gossip rounds (needs K x batch)")
    ap.add_argument("--consensus", choices=("choco", "gt"), default="choco",
                    help="'choco' = plain compressed gossip; 'gt' = gradient "
                         "tracking: a second CHOCO-compressed tracker variable "
                         "rides lane 2 of the same wire round, cancelling the "
                         "client drift large --local-steps induce under "
                         "heterogeneous data (2x per-round bits)")
    ap.add_argument("--tracker-compressor", default=None,
                    help="compression level for the gt tracker lane only "
                         "(e.g. kq2b beside a kq4b model lane); default "
                         "reuses --compressor on both lanes")
    ap.add_argument("--tracker-gamma", type=float, default=None,
                    help="consensus step size for the gt tracker lane "
                         "(default: same resolution as the model lane)")
    ap.add_argument("--fused-gossip", action="store_true",
                    help="single-pass Pallas gossip (requires a kq* compressor)")
    ap.add_argument("--gossip-backend", choices=("rolled", "ppermute"), default="rolled",
                    help="wire model: 'rolled' simulates the network on the "
                         "stacked array (reference oracle); 'ppermute' runs "
                         "the gossip under shard_map, exchanging only packed "
                         "compressed payloads between graph neighbors via "
                         "collective-permute (multi-device: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N on CPU)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None, help="path prefix for npz checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=100,
                    help="save the full trainer state every N completed rounds")
    ap.add_argument("--resume", action="store_true",
                    help="restore the full trainer state from the latest "
                         "--checkpoint file and continue (bit-identical to an "
                         "uninterrupted run)")
    ap.add_argument("--metrics-out", default=None,
                    help="write final losses/consensus_err to this JSON file")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    seq = args.seq
    if cfg.ssm_state:
        seq = max(seq, cfg.ssm_chunk)
        seq -= seq % cfg.ssm_chunk

    mesh = None
    if args.gossip_backend == "ppermute":
        # place the node shards: the data axis carries contiguous node blocks
        # and the SPMD gossip's collective-permutes run between its devices
        mesh = make_node_mesh(args.nodes)
        print(f"gossip backend=ppermute over mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"({args.nodes // mesh.devices.shape[0]} node(s)/device)")

    trainer = st.make_trainer(
        cfg,
        args.nodes,
        topology=args.topology,
        topology_schedule=args.topology_schedule,
        dropout=args.dropout,
        topology_p=args.topology_p,
        topology_seed=args.topology_seed,
        fault_spec=args.fault_spec,
        compressor=args.compressor,
        alpha=args.alpha,
        eta_theta=args.eta_theta,
        eta_lambda=args.eta_lambda,
        optimizer=args.optimizer,
        schedule=args.schedule,
        lr_decay=args.lr_decay,
        warmup=args.warmup,
        total_steps=args.steps,
        momentum=args.momentum,
        nesterov=args.nesterov,
        local_steps=args.local_steps,
        consensus=args.consensus,
        tracker_gamma=args.tracker_gamma,
        tracker_compressor=args.tracker_compressor,
        fused_gossip=args.fused_gossip,
        gossip_backend=args.gossip_backend,
        mesh=mesh,
        track_average=False,
    )

    key = jax.random.PRNGKey(args.seed)
    params = T.init_model(key, cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    wire = args.topology_schedule or args.topology
    if args.dropout:
        wire += f"+drop{args.dropout:g}"
    if args.fault_spec:
        wire += f"+faults[{args.fault_spec}]"
    if args.consensus == "gt":
        wire += f"+gt[{trainer.consensus.wire_format}]"
    print(f"arch={cfg.name} params={n_params:,} nodes={args.nodes} "
          f"compressor={args.compressor} topology={wire}")

    init_rng = jax.random.PRNGKey(args.seed + 1)
    start_step = 0
    if args.resume:
        if not args.checkpoint:
            raise SystemExit("--resume requires --checkpoint")
        # restore the *entire* trainer state into the abstract template — no
        # recompute, and the continuation is bit-identical to a run that
        # never stopped.  restore_latest skips any unreadable file and falls
        # back to the last complete checkpoint instead of crashing.
        template = jax.eval_shape(trainer.init, params, init_rng)
        state, found = restore_latest(args.checkpoint, template)
        if found is None:
            print(f"--resume: no loadable checkpoint under {args.checkpoint!r}; starting fresh")
            state = trainer.init(params, init_rng)
        else:
            start_step = found
            print(f"resumed full trainer state from step {found}")
    else:
        state = trainer.init(params, init_rng)

    # one round consumes local_steps x the per-node batch (K local updates)
    round_batch = args.batch_per_node * args.local_steps
    stream = node_token_stream(args.nodes, round_batch, seq, cfg.vocab_size, seed=args.seed)
    for _ in range(start_step):  # deterministic stream: fast-forward to resume point
        next(stream)

    def make_batch(tokens):
        batch = {"tokens": jnp.asarray(tokens)}
        if cfg.is_encdec:
            batch["frames"] = jnp.zeros(
                (args.nodes, round_batch, cfg.encoder_context, cfg.d_model), jnp.float32
            )
        if cfg.num_patches > 0:
            batch["patches"] = jnp.zeros(
                (args.nodes, round_batch, cfg.num_patches, cfg.d_model), jnp.float32
            )
        return batch

    aux = None
    t0 = time.time()
    for step in range(start_step, args.steps):
        state, aux = trainer.step(state, make_batch(next(stream)))
        if step % args.log_every == 0 or step == args.steps - 1:
            losses = np.asarray(aux["losses"])
            alive = (
                f"alive={int(np.asarray(aux['participation']).sum())}/{args.nodes}  "
                if "participation" in aux else ""
            )
            print(
                f"step {step:5d}  worst={losses.max():.4f}  mean={losses.mean():.4f}  "
                f"consensus={float(aux['consensus_err']):.3e}  {alive}"
                f"lambda_max={float(aux['lambda_mean'].max()):.3f}  "
                f"bits/round={trainer.bits_per_round(state):.3e}  "
                f"({(time.time() - t0) / (step - start_step + 1):.2f}s/step)"
            )
        done = step + 1
        if args.checkpoint and done % args.checkpoint_every == 0 and done < args.steps:
            fname = save(args.checkpoint, state, step=done)
            print(f"checkpointed full trainer state to {fname}")

    if args.checkpoint:
        fname = save(args.checkpoint, state, step=args.steps)
        base = args.checkpoint[:-4] if args.checkpoint.endswith(".npz") else args.checkpoint
        model_file = save(base + "_model", trainer.network_mean(state))
        print(f"saved final state to {fname}, consensus model to {model_file}")

    if args.metrics_out and aux is not None:
        metrics = {
            "final_step": args.steps,
            "losses": [float(x) for x in np.asarray(aux["losses"])],
            "worst_loss": float(np.asarray(aux["losses"]).max()),
            "consensus_err": float(aux["consensus_err"]),
        }
        with open(args.metrics_out, "w") as f:
            json.dump(metrics, f, indent=2)
        print(f"wrote metrics to {args.metrics_out}")


if __name__ == "__main__":
    main()
