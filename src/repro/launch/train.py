"""End-to-end AD-GDA training driver.

Runs the paper's Algorithm 1 on any assigned architecture with the synthetic
heterogeneous LM pipeline.  On real hardware pass ``--mesh prod`` /
``--mesh multipod``; on this CPU container use the default local mesh with a
reduced config (``--reduced``), which is what ``examples/train_transformer.py``
demonstrates.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 100 --nodes 4 --compressor q4b --topology ring
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import get_config
from repro.data import node_token_stream
from repro.launch import steps as st
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="2-layer smoke-scale variant (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--compressor", default="q4b")
    ap.add_argument("--alpha", type=float, default=0.01)
    ap.add_argument("--eta-theta", type=float, default=0.05)
    ap.add_argument("--eta-lambda", type=float, default=0.01)
    ap.add_argument("--optimizer", choices=("sgd", "adam"), default="sgd",
                    help="primal update rule (repro.optim)")
    ap.add_argument("--schedule", choices=("const", "exp", "cosine"), default="exp",
                    help="LR schedule; exp decays by --lr-decay per round")
    ap.add_argument("--lr-decay", type=float, default=1.0,
                    help="per-round decay factor for --schedule exp")
    ap.add_argument("--warmup", type=int, default=0, help="linear LR warmup rounds")
    ap.add_argument("--momentum", type=float, default=0.0, help="SGD momentum")
    ap.add_argument("--nesterov", action="store_true", help="Nesterov momentum (sgd)")
    ap.add_argument("--local-steps", type=int, default=1,
                    help="K local optimizer steps between gossip rounds (needs K x batch)")
    ap.add_argument("--fused-gossip", action="store_true",
                    help="single-pass Pallas gossip (requires a kq* compressor)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None, help="path prefix for npz checkpoints")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    seq = args.seq
    if cfg.ssm_state:
        seq = max(seq, cfg.ssm_chunk)
        seq -= seq % cfg.ssm_chunk

    trainer = st.make_trainer(
        cfg,
        args.nodes,
        topology=args.topology,
        compressor=args.compressor,
        alpha=args.alpha,
        eta_theta=args.eta_theta,
        eta_lambda=args.eta_lambda,
        optimizer=args.optimizer,
        schedule=args.schedule,
        lr_decay=args.lr_decay,
        warmup=args.warmup,
        total_steps=args.steps,
        momentum=args.momentum,
        nesterov=args.nesterov,
        local_steps=args.local_steps,
        fused_gossip=args.fused_gossip,
        track_average=False,
    )

    key = jax.random.PRNGKey(args.seed)
    params = T.init_model(key, cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params:,} nodes={args.nodes} "
          f"compressor={args.compressor} topology={args.topology}")

    state = trainer.init(params, jax.random.PRNGKey(args.seed + 1))
    # one round consumes local_steps x the per-node batch (K local updates)
    round_batch = args.batch_per_node * args.local_steps
    stream = node_token_stream(args.nodes, round_batch, seq, cfg.vocab_size, seed=args.seed)

    def make_batch(tokens):
        batch = {"tokens": jnp.asarray(tokens)}
        if cfg.is_encdec:
            batch["frames"] = jnp.zeros(
                (args.nodes, round_batch, cfg.encoder_context, cfg.d_model), jnp.float32
            )
        if cfg.num_patches > 0:
            batch["patches"] = jnp.zeros(
                (args.nodes, round_batch, cfg.num_patches, cfg.d_model), jnp.float32
            )
        return batch

    t0 = time.time()
    for step in range(args.steps):
        state, aux = trainer.step(state, make_batch(next(stream)))
        if step % args.log_every == 0 or step == args.steps - 1:
            losses = np.asarray(aux["losses"])
            print(
                f"step {step:5d}  worst={losses.max():.4f}  mean={losses.mean():.4f}  "
                f"consensus={float(aux['consensus_err']):.3e}  "
                f"lambda_max={float(aux['lambda_mean'].max()):.3f}  "
                f"bits/round={trainer.bits_per_round(state):.3e}  "
                f"({(time.time() - t0) / (step + 1):.2f}s/step)"
            )
        if args.checkpoint and step and step % 100 == 0:
            save(args.checkpoint, trainer.network_mean(state), step=step)

    if args.checkpoint:
        fname = save(args.checkpoint, trainer.network_mean(state), step=args.steps)
        print(f"saved consensus model to {fname}")


if __name__ == "__main__":
    main()
