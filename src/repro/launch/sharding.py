"""PartitionSpec rules for every parameter/cache/batch pytree.

Megatron-style tensor parallelism over the ``model`` axis inside each
AD-GDA node; the node dimension (stacked leading axis of the AD-GDA state)
shards over ``data`` (x ``pod``).  Rules are name-based on the tree path and
check divisibility — a dim that doesn't divide the axis stays replicated.

Decode caches: KV heads shard over ``model`` when divisible; MQA/GQA-small
archs (kv < model axis) shard the cache *sequence* dim instead
(flash-decoding layout) — that is what makes granite-20b (kv=1) fit 32k x 128.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "trainer_state_pspecs",
    "node_shardings",
    "adgda_state_pspecs",  # deprecated alias
    "shardings",
]


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def _leaf_spec(names: list[str], shape: tuple[int, ...], msize: int) -> tuple:
    """Spec for an *unstacked* model leaf (no node axis, no block axis)."""
    name = names[-1]
    div = lambda d: d < len(shape) and shape[d] % msize == 0 and shape[d] >= msize
    # NOTE: uneven (padded) sharding of parameters is rejected at the pjit
    # argument boundary, so head counts that don't divide the model axis
    # (llama4: 40 over 16) fall back to replication — the structural remedy
    # (TP sub-axis of 8, or context-parallel attention) is recorded in
    # EXPERIMENTS §Perf C3.

    if name == "table":  # embedding [V, d]: shard vocab
        return ("model", None) if div(0) else (None, None)
    if name == "wq":
        return (None, "model", None) if div(1) else (None, None, None)
    if name in ("wk", "wv"):
        return (None, "model", None) if div(1) else (None, None, None)
    if name == "wo":
        return ("model", None, None) if div(0) else (None, None, None)
    if name in ("bq", "bk", "bv"):
        return ("model", None) if div(0) else (None, None)
    if name in ("w_gate", "w_up"):
        if len(shape) == 3:  # MoE experts [E, d, f]: expert parallelism
            return ("model", None, None) if div(0) else (None, None, "model" if shape[2] % msize == 0 else None)
        return (None, "model") if div(1) else (None, None)
    if name == "w_down":
        if len(shape) == 3:
            return ("model", None, None) if div(0) else (None, "model" if shape[1] % msize == 0 else None, None)
        return ("model", None) if div(0) else (None, None)
    if name == "w1":
        return (None, "model") if div(1) else (None, None)
    if name == "w2":
        return ("model", None) if div(0) else (None, None)
    if name == "b1":
        return ("model",) if div(0) else (None,)
    if name == "in_proj":  # mamba2 [d, 2di+2N+H]: column-parallel
        return (None, "model") if div(1) else (None, None)
    if name == "out_proj":
        return ("model", None) if div(0) else (None, None)
    if name in ("w_gate_branch", "w_in", "w_a", "w_x"):
        return (None, "model") if div(1) else (None, None)
    if name == "w_out":
        return ("model", None) if div(0) else (None, None)
    # router, norms, biases, conv weights, SSM scalars: replicate
    return (None,) * len(shape)


def param_pspecs(params: Any, mesh: Mesh, *, node_axes: tuple[str, ...] = ()) -> Any:
    """PartitionSpec tree mirroring ``params``.

    ``node_axes``: mesh axes of a leading stacked AD-GDA node dimension
    (e.g. ("data",) or ("pod", "data")) — prepended to every leaf spec.
    Stacked pattern-block leaves (under "blocks"/"encoder") get a leading
    ``None`` for the repeat dimension.
    """
    msize = _axis_size(mesh, "model")
    lead: tuple = (node_axes,) if node_axes else ()

    def spec_for(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        drop = len(lead)
        block = ("blocks" in names) or ("encoder" in names and "final_norm" not in names)
        drop += 1 if block else 0
        inner = _leaf_spec(names, shape[drop:], msize)
        full = lead + ((None,) if block else ()) + tuple(inner)
        return P(*full)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def batch_pspecs(batch: Any, mesh: Mesh, *, lead_axes: tuple[str, ...] = ("data",)) -> Any:
    """Token/frame/patch batches: shard the leading (node or batch) dim over
    ``lead_axes`` when divisible, else replicate."""
    lsize = 1
    for a in lead_axes:
        lsize *= _axis_size(mesh, a)

    def spec_for(path, leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % lsize == 0 and leaf.shape[0] >= lsize:
            return P(lead_axes, *(None,) * (leaf.ndim - 1))
        return P(*(None,) * leaf.ndim)

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def cache_pspecs(cache: Any, mesh: Mesh, batch: int, *, lead_axes: tuple[str, ...] = ("data",)) -> Any:
    """Decode-cache specs: batch over ``data`` (x ``pod``); heads over
    ``model`` when divisible, else the sequence dim (flash-decoding layout)."""
    msize = _axis_size(mesh, "model")
    dsize = 1
    for a in lead_axes:
        dsize *= _axis_size(mesh, a)
    batch_ax = lead_axes if batch % dsize == 0 and batch >= dsize else None

    def spec_for(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        block = "blocks" in names
        inner = shape[1:] if block else shape
        lead = (None,) if block else ()
        name = names[-1]
        if name in ("k", "v") and len(inner) == 4:
            b, s, kv, hd = inner
            if kv % msize == 0 and kv >= msize:
                spec = (batch_ax, None, "model", None)
            elif s % msize == 0 and s >= msize:
                spec = (batch_ax, "model", None, None)  # seq-sharded (MQA)
            else:
                spec = (batch_ax, None, None, None)
        elif name == "ssm" and len(inner) == 4:  # [B, H, P, N]
            b, h, p_, n = inner
            spec = (batch_ax, "model" if h % msize == 0 and h >= msize else None, None, None)
        elif name == "conv" and len(inner) == 3:  # [B, W, C]
            spec = (batch_ax, None, "model" if inner[2] % msize == 0 else None)
        elif name == "h" and len(inner) == 2:  # rglru state [B, dr]
            spec = (batch_ax, "model" if inner[1] % msize == 0 else None)
        elif len(inner) == 4 and names[-2] == "cross_kv" or (len(inner) == 4 and "cross_kv" in names):
            b, s, kv, hd = inner
            spec = (batch_ax, None, "model" if kv % msize == 0 and kv >= msize else None, None)
        else:
            spec = (batch_ax,) + (None,) * (len(inner) - 1) if inner else ()
        return P(*(lead + tuple(spec)))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def trainer_state_pspecs(state: Any, params_spec: Any, mesh: Mesh, node_axes: tuple[str, ...]):
    """Spec tree for a TrainerState: theta/hat/s and the optimizer moments
    like params (with node axis), lam [m, m] sharded on the node dim,
    scalars replicated."""
    from repro.core.gossip import CHOCOState
    from repro.core.trainer import GTState, TrainerState
    from repro.optim import OptState

    def choco_spec(cs):
        return CHOCOState(
            theta_hat=params_spec,
            s=params_spec,
            # NeighborCache mirrors are theta_hat-shaped ([m, ...]) —
            # one per union wire op, sharded like the params
            cache=tuple(params_spec for _ in cs.cache),
        )

    if isinstance(state.consensus, GTState):
        # gradient tracking: one CHOCOState per wire lane, plus the
        # theta-shaped tracker variable and previous displacement
        consensus_spec = GTState(
            model=choco_spec(state.consensus.model),
            tracker=choco_spec(state.consensus.tracker),
            y=params_spec,
            d_prev=params_spec,
        )
    elif isinstance(state.consensus, CHOCOState):
        consensus_spec = choco_spec(state.consensus)
    else:
        consensus_spec = ()

    return TrainerState(
        step=P(),
        theta=params_spec,
        lam=P(node_axes, None),
        opt=OptState(
            step=P(),
            mu=params_spec if state.opt.mu != () else (),
            nu=params_spec if state.opt.nu != () else (),
        ),
        consensus=consensus_spec,
        theta_avg=(
            param_pspecs(state.theta_avg, mesh) if state.theta_avg != () else ()
        ),  # no node axis
        rng=P(),
    )


def node_shardings(tree: Any, mesh: Mesh, num_nodes: int,
                   node_axes: tuple[str, ...] = ("data",)) -> Any:
    """NamedSharding tree that *places the node shards*: every stacked
    ``[num_nodes, ...]`` leaf gets its leading axis on ``node_axes``,
    everything else (scalar step counters, rng keys) is replicated.

    This is the input placement the ppermute gossip backend
    (core/exchange.py) expects when compiling a trainer step or a bare
    ``choco_round`` with explicit ``in_shardings`` (see
    benchmarks/bench_exchange.py); without it GSPMD may replicate the node
    axis and the neighbor exchanges degenerate to local copies.
    """
    node = NamedSharding(mesh, P(node_axes))
    repl = NamedSharding(mesh, P())

    def pick(leaf):
        shp = getattr(leaf, "shape", ())
        return node if len(shp) >= 1 and shp[0] == num_nodes else repl

    return jax.tree.map(pick, tree)


# deprecated alias (pre-refactor name)
adgda_state_pspecs = trainer_state_pspecs


def shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
