"""Mixing matrices: Assumption 3.1 (symmetric, doubly stochastic, rho in (0,1])."""
import numpy as np
import pytest

from repro.core import topology as topo


ALL = [
    topo.ring(10),
    topo.ring(16),
    topo.torus_2d(16),
    topo.torus_2d(25),
    topo.mesh(8),
    topo.star(10),
    topo.erdos_renyi(12, 0.4, seed=3),
]


@pytest.mark.parametrize("t", ALL, ids=lambda t: f"{t.name}{t.num_nodes}")
def test_doubly_stochastic_symmetric(t):
    w = t.mixing
    np.testing.assert_allclose(w, w.T, atol=1e-12)
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-12)
    assert (w >= -1e-12).all()


@pytest.mark.parametrize("t", ALL, ids=lambda t: f"{t.name}{t.num_nodes}")
def test_spectral_gap_in_range(t):
    assert 0.0 < t.spectral_gap <= 1.0 + 1e-12


@pytest.mark.parametrize("t", ALL, ids=lambda t: f"{t.name}{t.num_nodes}")
def test_supported_on_adjacency(t):
    off_graph = (t.adjacency == 0) & (np.abs(t.mixing) > 1e-12)
    assert not off_graph.any()


def test_mesh_is_one_shot_consensus():
    assert topo.mesh(8).spectral_gap == pytest.approx(1.0)


def test_denser_topologies_have_larger_gap():
    ring, torus, mesh = topo.ring(16), topo.torus_2d(16), topo.mesh(16)
    assert ring.spectral_gap < torus.spectral_gap < mesh.spectral_gap


@pytest.mark.parametrize("t", [topo.ring(10), topo.torus_2d(16), topo.mesh(6)])
def test_circulant_shift_decomposition_matches_matrix(t):
    m = t.num_nodes
    w_from_shifts = np.zeros((m, m))
    for shift, weight in t.shifts:
        w_from_shifts += weight * np.roll(np.eye(m), shift, axis=1)
    np.testing.assert_allclose(w_from_shifts, t.mixing, atol=1e-12)


def test_consensus_step_size_positive():
    for t in ALL:
        for delta in (1.0, 0.25, 0.06):
            g = t.consensus_step_size(delta)
            assert 0 < g <= 1.0, (t.name, delta, g)


def test_metropolis_on_star_doubly_stochastic():
    w = topo.metropolis_weights(topo.star(7).adjacency)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-12)
    np.testing.assert_allclose(w, w.T, atol=1e-12)
