"""Pallas kernels vs pure-jnp oracles: shape/dtype sweep, interpret=True."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.quantize import dequantize_pallas, quantize_pallas
from repro.kernels.topk import block_topk_pallas

KEY = jax.random.PRNGKey(42)


# ----------------------------------------------------------------- quantize
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("rows", [64, 512, 1536])
def test_quantize_kernel_matches_ref(bits, rows):
    x = jax.random.normal(KEY, (rows, 128), jnp.float32)
    xi = jax.random.uniform(jax.random.PRNGKey(1), x.shape)
    norm = jnp.linalg.norm(x)
    lvl_k, sign_k = quantize_pallas(x, xi, norm, bits, interpret=True)
    lvl_r, sign_r = ref.quantize_ref(x, xi, norm, bits)
    np.testing.assert_array_equal(np.asarray(lvl_k), np.asarray(lvl_r))
    np.testing.assert_array_equal(np.asarray(sign_k), np.asarray(sign_r))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_dequantize_kernel_matches_ref(bits):
    x = jax.random.normal(KEY, (512, 128), jnp.float32)
    xi = jax.random.uniform(jax.random.PRNGKey(1), x.shape)
    norm = jnp.linalg.norm(x)
    lvl, sign = ref.quantize_ref(x, xi, norm, bits)
    scale = norm / ((1 << bits) * ref.tau_for(x.size, bits))
    out_k = dequantize_pallas(lvl, sign, scale, bits, interpret=True)
    out_r = ref.dequantize_ref(lvl, sign, scale, bits)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantize_roundtrip_contraction(bits):
    """Kernel roundtrip must satisfy the Assumption-3.2 style error bound."""
    x = jax.random.normal(KEY, (1024, 128), jnp.float32)
    xi = jax.random.uniform(jax.random.PRNGKey(1), x.shape)
    norm = jnp.linalg.norm(x)
    lvl, sign = quantize_pallas(x, xi, norm, bits, interpret=True)
    tau = ref.tau_for(x.size, bits)
    scale = norm / ((1 << bits) * tau)
    xhat = dequantize_pallas(lvl, sign, scale, bits, interpret=True)
    err = float(jnp.sum((xhat - x) ** 2) / jnp.sum(x**2))
    assert err <= (1 - 1 / tau) + 0.1


def test_quantize_wire_size():
    """Packed payload is (bits+1)/8 bytes per element."""
    x = jax.random.normal(KEY, (512, 128), jnp.float32)
    xi = jax.random.uniform(KEY, x.shape)
    lvl, sign = quantize_pallas(x, xi, jnp.linalg.norm(x), 4, interpret=True)
    assert lvl.size == x.size // 2  # 2 levels / byte
    assert sign.size == x.size // 8  # 8 signs / byte


# -------------------------------------------------------------------- top-k
@pytest.mark.parametrize("block", [128, 512, 1024])
@pytest.mark.parametrize("nb", [4, 64, 300])
def test_topk_kernel_matches_ref(block, nb):
    x = jax.random.normal(KEY, (nb, block), jnp.float32)
    k = max(1, block // 4)
    out_k = block_topk_pallas(x, k, interpret=True)
    out_r = ref.block_topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_kernel_dtypes(dtype):
    x = jax.random.normal(KEY, (16, 256)).astype(dtype)
    out = block_topk_pallas(x, 64, interpret=True)
    assert out.dtype == dtype
    # kept entries match original values
    mask = np.asarray(out, np.float32) != 0
    np.testing.assert_allclose(
        np.asarray(out, np.float32)[mask], np.asarray(x, np.float32)[mask], rtol=1e-3
    )


@pytest.mark.parametrize("frac", [0.1, 0.25, 0.5])
def test_topk_kernel_count_and_energy(frac):
    block = 1024
    x = jax.random.normal(KEY, (32, block), jnp.float32)
    k = int(frac * block)
    out = np.asarray(block_topk_pallas(x, k, interpret=True))
    counts = (out != 0).sum(axis=1)
    assert (counts <= k).all()
    assert (counts >= k - 8).all()  # bisection converges to within ties
    # contraction: per-row residual energy <= (1 - frac) * energy + tolerance
    xn = np.asarray(x)
    res = ((xn - out) ** 2).sum(1)
    tot = (xn**2).sum(1)
    assert (res <= (1 - frac) * tot * 1.05 + 1e-6).all()


def test_topk_keeps_largest_entries():
    x = jnp.zeros((1, 128)).at[0, 7].set(10.0).at[0, 100].set(-9.0).at[0, 55].set(0.01)
    out = np.asarray(block_topk_pallas(x, 2, interpret=True))[0]
    assert out[7] == 10.0 and out[100] == -9.0
    assert (out != 0).sum() == 2


# ---------------------------------------------------------------- ops layer
@pytest.mark.parametrize("shape", [(1000,), (33, 77), (8, 16, 25)])
@pytest.mark.parametrize("bits", [4, 8])
def test_ops_quantize_roundtrip_arbitrary_shapes(shape, bits):
    x = jax.random.normal(KEY, shape, jnp.float32)
    payload = ops.quantize(x, KEY, bits=bits)
    xhat = ops.dequantize(payload, shape, jnp.float32, bits=bits)
    assert xhat.shape == shape
    err = float(jnp.sum((xhat - x) ** 2) / jnp.sum(x**2))
    assert err < 0.9


@pytest.mark.parametrize("shape", [(4096,), (100, 41)])
def test_ops_block_topk_arbitrary_shapes(shape):
    x = jax.random.normal(KEY, shape, jnp.float32)
    out = ops.block_topk(x, fraction=0.25, block=512)
    assert out.shape == shape
    err = float(jnp.sum((out - x) ** 2) / jnp.sum(x**2))
    assert err <= 0.75 * 1.1


def test_kernel_compressors_plug_into_gossip():
    from repro.core import gossip, topology

    topo = topology.ring(4)
    comp = ops.KernelQuantization(bits=4, interpret=True)
    theta = {"w": jax.random.normal(KEY, (4, 640))}
    state = gossip.choco_init(theta)
    t, s = gossip.choco_round(theta, state, topo, 0.3, comp, KEY)
    assert t["w"].shape == (4, 640)
    # average preservation still holds with the kernel compressor
    np.testing.assert_allclose(
        np.asarray(t["w"].mean(0)), np.asarray(theta["w"].mean(0)), atol=1e-4
    )


def test_kernel_vs_core_block_topk_equivalence():
    """Kernel bisection selection ~= exact top-k from the core compressor."""
    from repro.core.compression import BlockTopK

    x = jax.random.normal(KEY, (2048,), jnp.float32)
    exact = BlockTopK(fraction=0.25, block=512)(x)
    kern = ops.block_topk(x, fraction=0.25, block=512)
    # selections may differ at the threshold boundary; energies must agree
    e_exact = float(jnp.sum(exact**2))
    e_kern = float(jnp.sum(kern**2))
    assert abs(e_exact - e_kern) / e_exact < 0.02
