"""Load generator: seeded determinism, Poisson/Zipf marginals, and
kill/resume bit-parity through the checkpoint machinery (the PR-6
discipline: a resumed stream is indistinguishable from an uninterrupted
one)."""
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.serving import LoadGenConfig, LoadGenerator
from repro.serving.loadgen import bounded_zipf_probs


def _cfg(**kw):
    base = dict(num_nodes=3, rate=0.4, vocab_size=128, seed=0,
                prompt_min=4, prompt_max=32, output_min=1, output_max=8)
    base.update(kw)
    return LoadGenConfig(**base)


def _stream(gen, until):
    return [(n, tuple(r.prompt), r.max_new_tokens) for n, r in gen.poll(until)]


def test_same_seed_identical_streams():
    a, b = LoadGenerator(_cfg()), LoadGenerator(_cfg())
    sa, sb = _stream(a, 500), _stream(b, 500)
    assert len(sa) > 100
    assert sa == sb
    assert np.array_equal(a._next_time, b._next_time)  # arrival clocks too


def test_different_seed_differs():
    sa = _stream(LoadGenerator(_cfg(seed=0)), 300)
    sb = _stream(LoadGenerator(_cfg(seed=1)), 300)
    assert sa != sb


def test_request_is_pure_function_of_index():
    """request(n, i) must not depend on polling order or prior draws."""
    gen = LoadGenerator(_cfg())
    r1 = gen.request(2, 17)
    _stream(gen, 200)  # advance the stream arbitrarily
    r2 = gen.request(2, 17)
    assert r1.prompt == r2.prompt and r1.max_new_tokens == r2.max_new_tokens


def test_poisson_arrival_marginal():
    """Counts over T ticks ~ Poisson(rate*T): mean and variance agree, and
    exponential gaps have cv ~= 1."""
    rate, T = 0.5, 4000
    gen = LoadGenerator(_cfg(num_nodes=1, rate=rate))
    times = []
    t = gen._next_time[0]
    for i in range(int(rate * T * 2)):
        if t > T:
            break
        times.append(t)
        t += gen._gap(0, i + 1)
    n = len(times)
    assert abs(n - rate * T) < 4 * np.sqrt(rate * T)  # ~4 sigma
    gaps = np.diff(times)
    cv = gaps.std() / gaps.mean()
    assert abs(gaps.mean() - 1 / rate) < 0.15 * (1 / rate)
    assert 0.85 < cv < 1.15  # exponential: cv == 1


def test_zipf_length_marginal():
    """Empirical prompt-length frequencies track the bounded-Zipf pmf."""
    cfg = _cfg(num_nodes=1, rate=1.0)
    gen = LoadGenerator(cfg)
    lens = [len(gen.request(0, i).prompt) for i in range(4000)]
    counts = np.bincount(lens, minlength=cfg.prompt_max + 1)[cfg.prompt_min:]
    emp = counts / counts.sum()
    pmf = bounded_zipf_probs(cfg.prompt_zipf, cfg.prompt_min, cfg.prompt_max)
    # head ranks carry the mass; they must match within a few percent
    assert np.all(np.abs(emp[:4] - pmf[:4]) < 0.03), (emp[:4], pmf[:4])
    assert lens and min(lens) >= cfg.prompt_min and max(lens) <= cfg.prompt_max
    outs = [gen.request(0, i).max_new_tokens for i in range(2000)]
    assert min(outs) >= cfg.output_min and max(outs) <= cfg.output_max


def test_node_token_distributions_differ():
    """Same Zipf marginal, node-specific vocab permutation: head tokens of
    different nodes disagree."""
    gen = LoadGenerator(_cfg(rate=1.0, token_zipf=1.5))
    def head(node):
        toks = [t for i in range(300) for t in gen.request(node, i).prompt]
        return np.bincount(toks, minlength=128).argmax()
    assert len({head(0), head(1), head(2)}) > 1


def test_kill_resume_bit_parity(tmp_path):
    """Checkpoint the cursor mid-stream via repro.checkpoint (npz round
    trip), resume in a fresh generator: the continuation is bit-identical to
    the uninterrupted stream."""
    cfg = _cfg()
    ref = LoadGenerator(cfg)
    full = _stream(ref, 300) + _stream(ref, 600)

    a = LoadGenerator(cfg)
    first = _stream(a, 300)
    fname = save(str(tmp_path / "loadgen"), a.state())
    b = LoadGenerator(cfg)
    b.restore(restore(fname, b.state()))
    second = _stream(b, 600)
    assert first + second == full
    assert b.emitted == ref.emitted
    assert np.array_equal(b._next_time, ref._next_time)  # float clock bit-exact


def test_zero_rate_node_never_arrives():
    gen = LoadGenerator(_cfg(num_nodes=2, rate=(0.5, 0.0)))
    assert all(n == 0 for n, _ in gen.poll(500))


def test_payload_hook_rides_the_same_arrivals():
    """A custom payload sees identical arrival statistics (same clock lane)."""
    seen = []
    def payload(node, rng, plen, max_new):
        seen.append((node, plen, max_new))
        return ("custom", node)
    a = LoadGenerator(_cfg(), payload=payload)
    arr = a.poll(200)
    b = LoadGenerator(_cfg())
    ref = b.poll(200)
    assert [n for n, _ in arr] == [n for n, _ in ref]
    assert np.array_equal(a._next_time, b._next_time)
    # and the hook received the same per-request length draws (requests are
    # materialized per node, then merged by arrival time — compare as bags)
    assert sorted(seen) == sorted(
        (n, len(r.prompt), r.max_new_tokens) for n, r in ref
    )


def test_mean_request_tokens_matches_empirical():
    cfg = _cfg(num_nodes=1, rate=1.0)
    gen = LoadGenerator(cfg)
    outs = [gen.request(0, i).max_new_tokens for i in range(4000)]
    assert abs(np.mean(outs) - cfg.mean_request_tokens()) < 0.1


def test_pool_mode_repeats_prompts_deterministically():
    """mode="pool": prompts come from a small fixed per-node pool with Zipf
    popularity — repeats are common (the prefix-cache workload) and the
    stream stays a pure function of the config."""
    cfg = _cfg(prompt_mode="pool", prompt_pool=16, rate=0.8)
    a, b = LoadGenerator(cfg), LoadGenerator(cfg)
    sa, sb = _stream(a, 400), _stream(b, 400)
    assert sa == sb
    assert len(sa) > 100
    per_node_prompts = {}
    for n, prompt, _ in sa:
        per_node_prompts.setdefault(n, []).append(prompt)
    for n, prompts in per_node_prompts.items():
        distinct = len(set(prompts))
        assert distinct <= 16  # never more prompts than the pool
        assert distinct < len(prompts)  # repeats actually happen
    # arrival statistics are untouched: same clock as the iid stream
    iid = LoadGenerator(_cfg(rate=0.8))
    iid_stream = _stream(iid, 400)
    assert [n for n, *_ in sa] == [n for n, *_ in iid_stream]
    assert np.array_equal(a._next_time, iid._next_time)


def test_unique_mode_never_repeats_prompts():
    """mode="unique": the request index is stamped into the leading tokens,
    so every prompt is distinct — the zero-hit-rate control row."""
    gen = LoadGenerator(_cfg(prompt_mode="unique", rate=0.8))
    s = _stream(gen, 400)
    assert len(s) > 100
    per_node = {}
    for n, prompt, _ in s:
        per_node.setdefault(n, []).append(prompt)
    for prompts in per_node.values():
        assert len(set(prompts)) == len(prompts)


def test_pool_mode_kill_resume_bit_parity(tmp_path):
    """The resume cursor covers pool mode too (pool prompts are pure
    functions of (seed, node, rank), nothing extra to checkpoint)."""
    from repro.checkpoint import restore, save

    cfg = _cfg(prompt_mode="pool", prompt_pool=8, rate=0.6)
    ref = LoadGenerator(cfg)
    full = _stream(ref, 200) + _stream(ref, 400)

    gen = LoadGenerator(cfg)
    head = _stream(gen, 200)
    fname = save(str(tmp_path / "lg"), gen.state())
    resumed = LoadGenerator(cfg)
    resumed.restore(restore(fname, resumed.state()))
    tail = _stream(resumed, 400)
    assert head + tail == full


def test_unknown_prompt_mode_rejected():
    with pytest.raises(ValueError):
        _cfg(prompt_mode="zipf")
