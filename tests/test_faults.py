"""Wire-fault injection + self-healing NeighborCache (core/faults.py and the
faulted paths of core/exchange.py / core/trainer.py).

What must hold for the faulted wire to be trustworthy:

* **spec parsing** — the CLI syntax round-trips; "no faults" and "faults at
  rate zero" are the same program (both parse to None);
* **digest/garble** — the detection primitive catches every garble (the XOR
  is never the identity) and never fires on bit-identical content;
* **detection ground truth** — one faulted round's divergence verdicts match
  an independent reconstruction from the same fault key: every injected
  drop/corrupt/delay on a live edge is detected the round it happens, and
  nothing else is;
* **synced-mirror invariant** — whenever the state machine claims an edge is
  synced, its mirror IS bit-identical to the sender's theta_hat (the PR 5
  invariant, now conditional on the fault state), and resyncs do fire and
  restore divergent edges;
* **backend parity** — the rolled and ppermute backends produce bit-identical
  faulted trajectories (same _cached_round_body, structural);
* **determinism** — same seed + same spec => bit-identical runs (the
  kill-and-resume half of this lives in test_checkpoint.py);
* **billing** — dropped deliveries are not billed: under 50% drop the
  trainer's aux["bits_realized"] equals bits_per_round(mode="realized"),
  both reading the exchange's delivered-bits meter.

Hypothesis is used when the container has it; otherwise the property tests
run as a seeded sweep (same assertions, fixed draw set — no skipped
coverage, and no new dependency).
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import gossip, topology
from repro.core.compression import Identity, RandomQuantization
from repro.core.exchange import (
    choco_round_cached_local,
    mix_stacked_faulted_local,
)
from repro.core.faults import (
    FaultSpec,
    digest,
    garble,
    parse_fault_spec,
    sample_events,
)
from repro.core.topology import compile_schedule_plans, make_topology
from repro.core.wire import compile_union_wire
from repro.launch.mesh import make_cpu_mesh

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst

    HAVE_HYPOTHESIS = True
except ImportError:  # container image has no hypothesis; seeded sweep below
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------- spec parse
def test_parse_fault_spec_roundtrip():
    spec = parse_fault_spec("drop:0.05,corrupt:0.01,stale:2")
    assert spec == FaultSpec(drop=0.05, corrupt=0.01, stale=2)
    assert parse_fault_spec(str(spec)) == spec  # __str__ round-trips
    full = parse_fault_spec("drop:0.1,dup:0.02,delay:0.03,backoff:3,backoff_cap:16")
    assert full.dup == 0.02 and full.delay == 0.03
    assert full.backoff_base == 3 and full.backoff_cap == 16


def test_parse_fault_spec_zero_is_none():
    """'no faults configured' and 'faults at rate 0' are the same program."""
    assert parse_fault_spec(None) is None
    assert parse_fault_spec("") is None
    assert parse_fault_spec("drop:0") is None
    assert parse_fault_spec("drop:0,corrupt:0,stale:5") is None
    assert parse_fault_spec(FaultSpec()) is None  # all-zero spec object too
    spec = FaultSpec(drop=0.1)
    assert parse_fault_spec(spec) is spec


def test_parse_fault_spec_errors():
    with pytest.raises(ValueError, match="unknown fault-spec key"):
        parse_fault_spec("dorp:0.1")
    with pytest.raises(ValueError, match="key:value"):
        parse_fault_spec("drop=0.1")
    with pytest.raises(ValueError, match="in \\[0, 1\\]"):
        parse_fault_spec("drop:1.5")
    with pytest.raises(ValueError, match="sum"):
        parse_fault_spec("drop:0.6,corrupt:0.6")
    with pytest.raises(ValueError, match="stale"):
        FaultSpec(drop=0.1, stale=-1)


# ------------------------------------------------------------- digest/garble
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_digest_detects_every_garble(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 33)).astype(dtype)
    d = digest(x)
    assert d.shape == (4,) and d.dtype == jnp.int32
    # identical content -> identical digest, by construction
    assert (digest(jnp.array(np.asarray(x))) == d).all()
    # garble is bijective, never the identity, and always caught
    g = garble(x)
    assert not np.array_equal(np.asarray(g), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(garble(g)), np.asarray(x))
    assert (digest(g) != d).all()


def _digest_single_flip(seed: int, pos: int):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 17))
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    flipped = bits.at[pos % 2, pos % 17].set(bits[pos % 2, pos % 17] ^ 1)
    y = jax.lax.bitcast_convert_type(flipped, jnp.float32)
    assert int(digest(y)[pos % 2]) != int(digest(x)[pos % 2])


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(hst.integers(0, 2**20), hst.integers(0, 2**20))
    def test_digest_single_bit_flip(seed, pos):
        _digest_single_flip(seed, pos)

else:

    @pytest.mark.parametrize("seed,pos", [(s, p) for s in (0, 7, 123) for p in (0, 5, 33)])
    def test_digest_single_bit_flip(seed, pos):
        _digest_single_flip(seed, pos)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d", [2**12, 2**12 + 37])  # aligned + padded grids
def test_fused_encode_digest_parity(dtype, d):
    """The digest lane folded into the fused encode kernel equals the XLA
    ``digest(hat_new)`` — and turning it on changes no other output."""
    from repro.kernels.choco_fused import fused_round_leaf

    m = 4
    ks = jax.random.split(jax.random.PRNGKey(d), 3)
    leaf = jax.random.normal(ks[0], (m, d)).astype(dtype)
    hat = (jax.random.normal(ks[1], (m, d)) * 0.1).astype(dtype)
    s = jnp.zeros_like(leaf)
    shifts = ((1, 0.3), (3, 0.2))
    plain = fused_round_leaf(leaf, hat, s, ks[2], shifts, 0.5, 4)
    tn, hn, sn, dig = fused_round_leaf(
        leaf, hat, s, ks[2], shifts, 0.5, 4, with_digest=True
    )
    for a, b in zip(plain, (tn, hn, sn)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(dig), np.asarray(digest(hn)))
    # the lane detects a garbled hat like the XLA digest does
    assert (np.asarray(digest(garble(hn))) != np.asarray(dig)).all()


# ----------------------------------------------------------------- fixtures
def _theta(m, d, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "w": jax.random.normal(k1, (m, d)),
        "b": jax.random.normal(k2, (m,)),
    }


def _union_for(spec_or_topo, m, dropout=0.0, seed=1):
    if isinstance(spec_or_topo, str) and (":" in spec_or_topo or dropout):
        sched = topology.make_topology_schedule(
            spec_or_topo, m, dropout=dropout, seed=seed
        )
        return sched, compile_union_wire(compile_schedule_plans(sched))
    topo = make_topology(spec_or_topo, m)
    from repro.core.topology import compile_permute_plan

    return None, compile_union_wire((compile_permute_plan(topo),))


def _run_faulted_local(theta, rounds, spec, *, sched=None, topo=None,
                       union=None, comp=None, seed=0):
    comp = comp or RandomQuantization(bits=4)
    m = theta["w"].shape[0]
    state = gossip.choco_init(theta, cache_ops=union.n_ops, fault_ops=union.n_ops)

    @jax.jit
    def step(t, st, k, fk, s):
        return choco_round_cached_local(
            t, st, 0.3, comp, k, union=union, schedule=sched, topology=topo,
            step=s, faults=spec, fault_key=fk,
        )

    t = theta
    for i in range(rounds):
        t, state = step(
            t, state, jax.random.PRNGKey(100 + i),
            jax.random.fold_in(jax.random.PRNGKey(7 + seed), i), jnp.int32(i),
        )
    return t, state


# ------------------------------------------------- detection == ground truth
def test_divergence_detected_the_round_it_happens():
    """From an all-synced state, one faulted round's verdicts must equal an
    independent reconstruction from the same fault key: every live edge that
    drew drop/corrupt/delay diverges (dup and clean edges stay synced)."""
    m, d = 8, 40
    spec = FaultSpec(drop=0.25, corrupt=0.2, dup=0.1, delay=0.1, stale=2)
    theta = _theta(m, d)
    _, union = _union_for("ring", m)
    fkey = jax.random.PRNGKey(42)

    state = gossip.choco_init(theta, cache_ops=union.n_ops, fault_ops=union.n_ops)
    _, state = jax.jit(
        lambda t, st: choco_round_cached_local(
            t, st, 0.3, RandomQuantization(bits=4), jax.random.PRNGKey(0),
            union=union, step=jnp.int32(0), faults=spec, fault_key=fkey,
        )
    )(theta, state)

    ev = sample_events(spec, fkey, union.n_ops, m)
    exist = np.stack([np.asarray(s) >= 0 for s in union.senders])  # [n_ops, m]
    diverged = exist & np.asarray(ev.drop | ev.corrupt | ev.delay)
    assert diverged.any(), "draw produced no faults; pick a different key"

    fs = state.fault
    np.testing.assert_array_equal(
        np.asarray(fs.synced).T.astype(bool), exist & ~diverged | ~exist
    )
    np.testing.assert_array_equal(
        np.asarray(fs.detected), diverged.sum(0).astype(np.int32)
    )
    # no resync can have happened yet (stale bound not exceeded)
    assert int(np.asarray(fs.resyncs).sum()) == 0
    # delivered-bits meter: drops bill zero, dups twice, everything else once
    payload, dig, _ = __import__(
        "repro.core.exchange", fromlist=["_wire_msg_bits"]
    )._wire_msg_bits(RandomQuantization(bits=4), theta, gossip.BLOCK_SCAN_ELEMS)
    mult = np.where(np.asarray(ev.drop), 0.0, np.where(np.asarray(ev.dup), 2.0, 1.0))
    want_bits = np.zeros((m,))
    for k, snd in enumerate(union.senders):
        for i, j in enumerate(np.asarray(snd)):
            if j >= 0:
                want_bits[j] += mult[k, i] * (payload + dig)
    np.testing.assert_allclose(np.asarray(fs.bits), want_bits, rtol=1e-6)


# ------------------------------------- synced-mirror invariant + resync heal
def _assert_synced_mirrors_exact(state, union):
    """Every edge the state machine calls synced has a bit-identical mirror."""
    hats = jax.tree_util.tree_leaves(state.theta_hat)
    synced = np.asarray(state.fault.synced)  # [m, n_ops]
    checked = 0
    for k, snd in enumerate(union.senders):
        mirrors = jax.tree_util.tree_leaves(state.cache[k])
        for hat, mirror in zip(hats, mirrors):
            hat, mirror = np.asarray(hat), np.asarray(mirror)
            for i in range(hat.shape[0]):
                if snd[i] >= 0 and synced[i, k] > 0:
                    assert (mirror[i] == hat[snd[i]]).all(), (
                        f"op {k} node {i}: state machine claims synced but the "
                        f"mirror differs from sender {snd[i]}'s theta_hat"
                    )
                    checked += 1
    return checked


@pytest.mark.parametrize("spec_str,dropout", [
    ("ring", 0.0),
    ("matching:3", 0.25),
], ids=["static-ring", "matching-drop"])
def test_synced_mirror_invariant_and_resync(spec_str, dropout):
    """Across a faulted run the conditional mirror invariant holds every
    round, divergences accumulate, and resyncs fire and heal edges."""
    m, d, rounds = 8, 40, 10
    spec = FaultSpec(drop=0.3, corrupt=0.1, stale=1)
    theta = _theta(m, d)
    sched, union = _union_for(spec_str, m, dropout=dropout)
    topo = None if sched is not None else make_topology("ring", m)
    comp = RandomQuantization(bits=4)
    state = gossip.choco_init(theta, cache_ops=union.n_ops, fault_ops=union.n_ops)

    masked = dropout > 0

    @jax.jit
    def step(t, st, k, fk, s, mk=None):
        return choco_round_cached_local(
            t, st, 0.3, comp, k, union=union, schedule=sched, topology=topo,
            step=s, mask=mk, faults=spec, fault_key=fk,
        )

    t = theta
    checked = 0
    for i in range(rounds):
        kw = {}
        if masked:
            kw["mk"] = sched.mask_at(jax.random.PRNGKey(500 + i), i)
        t, state = step(
            t, state, jax.random.PRNGKey(100 + i),
            jax.random.fold_in(jax.random.PRNGKey(7), i), jnp.int32(i), **kw
        )
        checked += _assert_synced_mirrors_exact(state, union)

    assert checked > 0
    fs = state.fault
    assert int(np.asarray(fs.detected).sum()) > 0, "faults at 40% never diverged?"
    assert int(np.asarray(fs.resyncs).sum()) > 0, "no resync ever healed an edge"
    assert np.isfinite(np.asarray(jax.tree_util.tree_leaves(t)[0])).all()


def test_all_drop_wire_bills_zero_and_never_heals():
    """drop:1.0 — nothing is ever delivered: the meter stays at zero, no
    resync ever verifies, every live edge diverges immediately."""
    m, d = 6, 24
    spec = FaultSpec(drop=1.0, stale=1)
    theta = _theta(m, d)
    _, union = _union_for("ring", m)
    _, state = _run_faulted_local(theta, 5, spec, union=union)
    fs = state.fault
    assert float(np.asarray(fs.bits).sum()) == 0.0
    assert int(np.asarray(fs.resyncs).sum()) == 0
    assert not np.asarray(fs.synced).astype(bool).any()


# ------------------------------------------------- multi-lane fault isolation
def test_multilane_faulted_lane_isolation():
    """Per-lane fault machinery (ISSUE 8): each lane of a faulted multi-lane
    round draws its own fault events (fault_key folded per lane) and keeps
    its own recovery state — lane k of the 2-lane run is bit-identical to a
    single-lane faulted run keyed with lane_key, so a corrupted tracker-lane
    message can never stale (or heal) a model-lane mirror."""
    from repro.core.exchange import choco_round_cached_local_lanes

    m, d, rounds = 8, 40, 6
    spec = FaultSpec(drop=0.25, corrupt=0.15, stale=1)
    comp = RandomQuantization(bits=4)
    _, union = _union_for("ring", m)
    thetas0 = [_theta(m, d, seed=s) for s in (0, 1)]

    @jax.jit
    def step_lanes(ts, sts, k, fk, s):
        lanes = [gossip.LaneRound(t, st, 0.3, comp) for t, st in zip(ts, sts)]
        return choco_round_cached_local_lanes(
            lanes, k, union=union, step=s, faults=spec, fault_key=fk,
        )

    ts = list(thetas0)
    sts = [gossip.choco_init(t, cache_ops=union.n_ops, fault_ops=union.n_ops)
           for t in ts]
    for i in range(rounds):
        ts, sts = step_lanes(
            ts, sts, jax.random.PRNGKey(100 + i),
            jax.random.fold_in(jax.random.PRNGKey(7), i), jnp.int32(i),
        )
        ts, sts = list(ts), list(sts)
        # synced-mirror invariant holds per lane, every round
        for st in sts:
            _assert_synced_mirrors_exact(st, union)

    # per-lane reference: single-lane faulted runs with the folded keys
    for k in range(2):
        t = thetas0[k]
        state = gossip.choco_init(t, cache_ops=union.n_ops, fault_ops=union.n_ops)

        @jax.jit
        def step_one(t, st, key, fk, s):
            return choco_round_cached_local(
                t, st, 0.3, comp, key, union=union, step=s, faults=spec,
                fault_key=fk,
            )

        for i in range(rounds):
            t, state = step_one(
                t, state,
                gossip.lane_key(jax.random.PRNGKey(100 + i), k),
                gossip.lane_key(jax.random.fold_in(jax.random.PRNGKey(7), i), k),
                jnp.int32(i),
            )
        for a, b in zip(jax.tree_util.tree_leaves((ts[k], sts[k])),
                        jax.tree_util.tree_leaves((t, state))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the lanes really draw DIFFERENT events: identical inputs would make
    # identical fault state, and these started from different thetas but
    # share every key except the per-lane fold — their detected counters
    # must differ somewhere across a 6-round 40%-fault run
    assert not np.array_equal(np.asarray(sts[0].fault.detected),
                              np.asarray(sts[1].fault.detected)) or \
           not np.array_equal(np.asarray(sts[0].fault.synced),
                              np.asarray(sts[1].fault.synced)), (
        "both lanes drew identical fault events — fault_key not folded per lane"
    )
    # both lanes detect and heal independently under drop+corrupt churn
    for st in sts:
        assert int(np.asarray(st.fault.detected).sum()) > 0
        assert int(np.asarray(st.fault.resyncs).sum()) > 0


def test_gt_trainer_faulted_bits_meter():
    """Gradient-tracking under wire faults: the jitted realized-bits meter
    sums both lanes' delivered bits and matches bits_per_round(mode=
    'realized'); both lane fault machines accumulate independently."""
    from benchmarks.common import make_adgda
    from repro.data import rotated_minority_classification

    m = 6
    data = rotated_minority_classification(num_nodes=m, seed=0)
    trainer, init_fn, _ = make_adgda(
        "logistic", m, compressor="q4b", consensus="gt",
        fault_spec="drop:0.3,corrupt:0.1,stale:1",
    )
    state = trainer.init(init_fn(data.dim, data.num_classes), jax.random.PRNGKey(0))
    xb, yb = next(data.batches(20, seed=0))
    batch = (jnp.asarray(xb), jnp.asarray(yb))
    for _ in range(5):
        state, aux = trainer.step(state, batch)
        assert float(aux["bits_realized"]) == pytest.approx(
            trainer.bits_per_round(state, mode="realized")
        )
    cons = state.consensus
    det_m = int(np.asarray(cons.model.fault.detected).sum())
    det_t = int(np.asarray(cons.tracker.fault.detected).sum())
    assert det_m > 0 and det_t > 0, "both lanes should see faults at 40%"
    # independent per-lane draws: the two lanes' delivered-bits meters are
    # both live and (folded fault keys) not byte-for-byte the same stream
    bits_m = np.asarray(cons.model.fault.bits)
    bits_t = np.asarray(cons.tracker.fault.bits)
    assert bits_m.sum() > 0 and bits_t.sum() > 0
    assert not np.array_equal(bits_m, bits_t)


# --------------------------------------------------------- backend parity
def test_rolled_vs_ppermute_faulted_parity():
    """The rolled faulted round IS the ppermute body with one full-width
    shard — trajectories must be bit-identical, including the fault state."""
    m, d, rounds = 8, 40, 4
    spec = FaultSpec(drop=0.25, corrupt=0.1, stale=1)
    theta = _theta(m, d)
    sched = topology.make_topology_schedule("matching:3", m, dropout=0.0, seed=1)
    topo0 = sched.topology_at(0)
    union = compile_union_wire(compile_schedule_plans(sched))
    comp = RandomQuantization(bits=4)
    mesh = make_cpu_mesh(1, 1)

    def run(backend):
        state = gossip.choco_init(theta, cache_ops=union.n_ops, fault_ops=union.n_ops)
        kw = dict(backend=backend)
        if backend == "ppermute":
            kw["mesh"] = mesh

        @jax.jit
        def step(t, st, k, fk, s):
            return gossip.choco_round(
                t, st, topo0, 0.3, comp, k, packed=True, schedule=sched,
                step=s, union=union, faults=spec, fault_key=fk, **kw,
            )

        t = theta
        for i in range(rounds):
            t, state = step(
                t, state, jax.random.PRNGKey(100 + i),
                jax.random.fold_in(jax.random.PRNGKey(7), i), jnp.int32(i),
            )
        return t, state

    t_r, s_r = run("rolled")
    t_p, s_p = run("ppermute")
    for a, b in zip(jax.tree_util.tree_leaves((t_r, s_r)),
                    jax.tree_util.tree_leaves((t_p, s_p))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- determinism
def _determinism_case(seed: int, drop: float, corrupt: float):
    m, d = 6, 24
    spec = FaultSpec(drop=drop, corrupt=corrupt, stale=1)
    theta = _theta(m, d, seed=seed)
    _, union = _union_for("ring", m)
    t1, s1 = _run_faulted_local(theta, 3, spec, union=union, seed=seed)
    t2, s2 = _run_faulted_local(theta, 3, spec, union=union, seed=seed)
    for a, b in zip(jax.tree_util.tree_leaves((t1, s1)),
                    jax.tree_util.tree_leaves((t2, s2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        hst.integers(0, 1000),
        hst.floats(0.05, 0.45),
        hst.floats(0.0, 0.45),
    )
    def test_fault_determinism(seed, drop, corrupt):
        """Same seed + same spec -> bit-identical trajectories and fault
        state, for any rates."""
        _determinism_case(seed, drop, corrupt)

else:

    @pytest.mark.parametrize("seed,drop,corrupt", [
        (0, 0.3, 0.1), (1, 0.05, 0.45), (2, 0.45, 0.0),
    ])
    def test_fault_determinism(seed, drop, corrupt):
        """Same seed + same spec -> bit-identical trajectories and fault
        state (seeded sweep; hypothesis not in the container)."""
        _determinism_case(seed, drop, corrupt)


# --------------------------------------------------- memoryless faulted mix
def test_memoryless_all_drop_is_identity():
    """Exact/dual wire under drop:1.0: every edge leaves the mix, the
    surviving-subgraph rescale leaves each node with itself, zero bits."""
    m = 6
    topo = make_topology("ring", m)
    tree = {"lam": jax.random.normal(jax.random.PRNGKey(0), (m, m))}
    mixed, bits = mix_stacked_faulted_local(
        tree, topology=topo, faults=FaultSpec(drop=1.0),
        fault_key=jax.random.PRNGKey(3),
    )
    np.testing.assert_array_equal(np.asarray(mixed["lam"]), np.asarray(tree["lam"]))
    assert float(np.asarray(bits).sum()) == 0.0


def test_memoryless_faulted_mix_row_stochastic():
    """Under partial faults the faulted dense mix still averages with
    row-stochastic weights: mixing a constant tree returns it exactly."""
    m = 8
    topo = make_topology("ring", m)
    const = {"v": jnp.full((m, 3), 2.5)}
    mixed, bits = mix_stacked_faulted_local(
        const, topology=topo, faults=FaultSpec(drop=0.3, corrupt=0.2),
        fault_key=jax.random.PRNGKey(11),
    )
    np.testing.assert_allclose(np.asarray(mixed["v"]), 2.5, rtol=1e-6)
    assert float(np.asarray(bits).max()) > 0.0  # some deliveries billed


# ------------------------------------------------- satellite: realized bits
def test_trainer_bits_realized_under_heavy_drop():
    """Regression (billing bug): dropped deliveries are NOT billed — under
    50% drop the jitted aux meter equals bits_per_round(mode='realized'),
    both reading the exchange's delivered-bits meter, and sits well below
    the fault-free constant."""
    from benchmarks.common import make_adgda
    from repro.data import rotated_minority_classification

    from repro.core.exchange import _wire_msg_bits

    m = 6
    data = rotated_minority_classification(num_nodes=m, seed=0)
    # stale:9999 keeps resync traffic out of the picture, so the meter is
    # exactly (delivered hat-deltas) x (payload + digest lane)
    trainer, init_fn, _ = make_adgda(
        "logistic", m, compressor="q4b", fault_spec="drop:0.5,stale:9999"
    )
    state = trainer.init(init_fn(data.dim, data.num_classes), jax.random.PRNGKey(0))
    xb, yb = next(data.batches(20, seed=0))
    batch = (jnp.asarray(xb), jnp.asarray(yb))
    payload, dig, _ = _wire_msg_bits(
        trainer.compressor, state.theta, gossip.BLOCK_SCAN_ELEMS
    )
    full_all_nodes = float(trainer.consensus.union.out_degree.sum()) * (payload + dig)
    total = 0.0
    for _ in range(6):
        state, aux = trainer.step(state, batch)
        assert float(aux["bits_realized"]) == pytest.approx(
            trainer.bits_per_round(state, mode="realized")
        )
        total += float(np.asarray(state.consensus.fault.bits).sum())
    # half the deliveries dropped: summed over nodes, the measured traffic
    # must be strictly below billing every edge every round (deterministic,
    # seeded) — the old degree-formula billing would sit exactly at the bound
    assert 0.0 < total < 6 * full_all_nodes
