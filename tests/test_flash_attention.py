"""Flash attention Pallas kernel vs the pure-jnp oracle (interpret mode).

Sweeps shapes, dtypes, masks (causal / sliding window), and block sizes, and
cross-checks against the model's XLA attention path.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ops import flash_attention
from repro.kernels.ref import flash_attention_ref

KEY = jax.random.PRNGKey(0)


def _qkv(bh, sq, sk, hd, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = (jax.random.normal(k1, (bh, sq, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(k2, (bh, sk, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(k3, (bh, sk, hd)) * 0.5).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("sq,sk,hd,bq,bk", [
    (128, 128, 64, 32, 32),
    (256, 256, 128, 64, 64),
    (64, 256, 32, 32, 64),   # cross-length (query shorter than kv)
    (256, 256, 100, 64, 32), # non-128 head_dim
])
def test_kernel_matches_ref_causal(sq, sk, hd, bq, bk):
    q, k, v = _qkv(3, sq, sk, hd)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("window", [16, 64, 250])
def test_kernel_matches_ref_sliding_window(window):
    q, k, v = _qkv(2, 256, 256, 64, seed=1)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_kernel_dtypes(dtype, atol):
    q, k, v = _qkv(2, 128, 128, 64, dtype=dtype, seed=2)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol, rtol=1e-2
    )
    assert out.dtype == dtype


def test_kernel_skips_fully_masked_blocks_correctly():
    """Causal masking with small blocks: early q rows see few kv blocks."""
    q, k, v = _qkv(1, 256, 256, 32, seed=3)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_ops_wrapper_matches_model_attention():
    """ops.flash_attention over [B,S,H,hd] == the model's XLA attention."""
    from repro.models.config import ModelConfig
    from repro.models.layers import _repeat_kv, apply_attention, init_attention

    cfg = ModelConfig(
        name="t", family="dense", num_layers=1, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=97, head_dim=16, dtype="float32",
    )
    p = init_attention(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (2, 64, cfg.d_model))
    ref = apply_attention(p, x, cfg, causal=True)

    # reproduce the projection, run the kernel, project out
    from repro.models.layers import _project_qkv

    positions = jnp.arange(64)
    q, k, v = _project_qkv(p, x, x, cfg, positions, positions, False)
    k = _repeat_kv(k, cfg.num_heads)
    v = _repeat_kv(v, cfg.num_heads)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_padding_path():
    """Non-block-multiple sequence lengths round-trip through the padded path."""
    B, S, H, hd = 1, 100, 2, 32
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = 0.5 * jax.random.normal(k1, (B, S, H, hd))
    k = 0.5 * jax.random.normal(k2, (B, S, H, hd))
    v = 0.5 * jax.random.normal(k3, (B, S, H, hd))
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    ref = flash_attention_ref(qf, kf, vf, causal=True)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_online_softmax_invariance_to_block_size():
    """Defining property: the result must not depend on the kv block size."""
    q, k, v = _qkv(2, 128, 128, 64, seed=4)
    outs = [
        np.asarray(flash_attention_pallas(q, k, v, causal=True, block_q=32,
                                          block_k=bk, interpret=True))
        for bk in (16, 32, 64, 128)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=2e-5, rtol=1e-4)
