"""checkpoint/npz: round-trips, validation errors, latest_step, atomicity —
and the full-state kill-and-resume parity harness (ISSUE 3 acceptance:
resumed run == uninterrupted run bit-for-bit under jax.disable_jit)."""
import os
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import all_steps, latest_step, restore, restore_latest, save
from repro.core.adgda import ADGDAConfig, adgda_trainer


class Inner(NamedTuple):
    a: Any
    b: Any


class Outer(NamedTuple):
    x: Any
    items: Any
    d: Any


def _tree():
    return Outer(
        x=jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        items=[jnp.ones((4,), jnp.int32), Inner(a=jnp.zeros((2, 2)), b=jnp.float32(3.5))],
        d={"k1": jnp.arange(5, dtype=jnp.uint32), "k2": (jnp.ones(()), jnp.zeros((1, 1)))},
    )


# ------------------------------------------------------------- round trips
def test_roundtrip_nested_tree(tmp_path):
    tree = _tree()
    fname = save(str(tmp_path / "ckpt"), tree)
    assert fname.endswith(".npz") and os.path.exists(fname)
    out = restore(fname, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_roundtrip_into_shape_dtype_structs(tmp_path):
    tree = _tree()
    fname = save(str(tmp_path / "ckpt"), tree)
    template = jax.eval_shape(lambda: _tree())
    out = restore(fname, template)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------ errors
def test_shape_mismatch_raises(tmp_path):
    fname = save(str(tmp_path / "ckpt"), {"w": jnp.zeros((3, 3))})
    with pytest.raises(ValueError, match="shape"):
        restore(fname, {"w": jnp.zeros((2, 3))})


def test_missing_leaf_raises(tmp_path):
    fname = save(str(tmp_path / "ckpt"), {"w": jnp.zeros((3,))})
    with pytest.raises(KeyError, match="missing leaf"):
        restore(fname, {"w": jnp.zeros((3,)), "extra": jnp.zeros((1,))})


def test_dtype_cast_to_reference(tmp_path):
    fname = save(str(tmp_path / "ckpt"), {"w": jnp.arange(3, dtype=jnp.int32)})
    out = restore(fname, {"w": jnp.zeros((3,), jnp.float32)})
    assert np.asarray(out["w"]).dtype == np.float32


# ------------------------------------------------------- naming/discovery
def test_step_naming_strips_npz_suffix(tmp_path):
    """Regression: save('foo.npz', step=N) used to write foo.npz_N.npz."""
    f1 = save(str(tmp_path / "run.npz"), {"w": jnp.zeros(2)}, step=100)
    f2 = save(str(tmp_path / "run"), {"w": jnp.zeros(2)}, step=200)
    assert os.path.basename(f1) == "run_00000100.npz"
    assert os.path.basename(f2) == "run_00000200.npz"
    assert ".npz_" not in f1


def test_latest_step_discovery(tmp_path):
    prefix = str(tmp_path / "run")
    assert latest_step(prefix) is None
    for s in (10, 300, 20):
        save(prefix, {"w": jnp.zeros(2)}, step=s)
    assert latest_step(prefix) == 300
    # both path spellings find the same files
    assert latest_step(prefix + ".npz") == 300
    # unrelated files with similar names are not picked up
    (tmp_path / "run2_00000999.npz").write_bytes(b"")
    assert latest_step(prefix) == 300


def test_latest_step_missing_dir():
    assert latest_step("/nonexistent/dir/run") is None


# -------------------------------------------------------------- atomicity
def test_atomic_write_no_tmp_left_on_success(tmp_path):
    save(str(tmp_path / "ckpt"), {"w": jnp.zeros(2)}, step=1)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_atomic_write_tmp_cleaned_on_failure(tmp_path, monkeypatch):
    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError):
        save(str(tmp_path / "ckpt"), {"w": jnp.zeros(2)}, step=1)
    assert os.listdir(tmp_path) == []  # neither the .npz nor a stale .tmp
    assert latest_step(str(tmp_path / "ckpt")) is None


def test_crash_mid_save_keeps_previous_checkpoint(tmp_path, monkeypatch):
    """A process killed *during* np.savez (partial .tmp on disk) must leave
    the previous complete checkpoint as the resume point: the final name is
    only ever produced by os.replace after fsync."""
    prefix = str(tmp_path / "run")
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    save(prefix, tree, step=1)

    real_savez = np.savez

    def crash(f, **payload):
        real_savez(f, **payload)  # bytes hit the .tmp file...
        raise KeyboardInterrupt("killed mid-save")  # ...then the kill lands

    monkeypatch.setattr(np, "savez", crash)
    with pytest.raises(KeyboardInterrupt):
        save(prefix, {"w": jnp.full((4,), 9.0)}, step=2)
    monkeypatch.undo()

    # the interrupted step-2 save is invisible; step 1 is still loadable
    assert latest_step(prefix) == 1
    out, step = restore_latest(prefix, tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(4, dtype=np.float32))


def test_restore_latest_falls_back_past_corrupt(tmp_path):
    """restore_latest skips an unreadable newest file (e.g. truncated by an
    older non-atomic writer) and reports the fallback."""
    prefix = str(tmp_path / "run")
    tree = {"w": jnp.arange(3, dtype=jnp.float32)}
    save(prefix, tree, step=5)
    # a complete-looking but garbage step-7 file, as a non-atomic tool leaves
    (tmp_path / "run_00000007.npz").write_bytes(b"not a zip archive")
    assert all_steps(prefix) == [5, 7]

    messages = []
    out, step = restore_latest(prefix, tree, log=messages.append)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(3, dtype=np.float32))
    assert len(messages) == 1 and "run_00000007.npz" in messages[0]
    assert "falling back" in messages[0]

    # nothing loadable at all -> (None, None), not an exception
    empty = str(tmp_path / "other")
    assert restore_latest(empty, tree, log=messages.append) == (None, None)


# ------------------------------------------- full-state resume bit-parity
def _toy_loss(params, batch, rng):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - y) ** 2)


def _toy_batch(m, key, n=8, d=4):
    kx, ky = jax.random.split(key)
    return (jax.random.normal(kx, (m, n, d)), jax.random.normal(ky, (m, n)))


@pytest.mark.parametrize(
    "cfg_kwargs",
    [
        {"topology": "ring"},
        {"topology": "ring", "momentum": 0.9},
        {"topology": "ring", "optimizer": "adam", "momentum": 0.0},
        {"topology_schedule": "roundrobin:ring,torus", "dropout": 0.25},
        {"topology_schedule": "matching:3", "dropout": 0.5},
        {"topology": "ring", "fault_spec": "drop:0.2,corrupt:0.1,stale:2"},
        {
            "topology_schedule": "matching:3",
            "dropout": 0.25,
            "fault_spec": "drop:0.2,corrupt:0.1,stale:2",
        },
    ],
    ids=[
        "sgd", "momentum", "adam", "roundrobin-drop", "matching-drop",
        "faulted-ring", "faulted-matching-drop",
    ],
)
def test_kill_and_resume_bit_identical(tmp_path, cfg_kwargs):
    """Save the full TrainerState mid-run, rebuild everything from scratch,
    restore, continue — every leaf of the final state (theta, lam, optimizer
    moments, CHOCO trackers, rng, step) must match the uninterrupted run
    bit-for-bit."""
    m, total, cut = 6, 8, 4
    cfg = ADGDAConfig(num_nodes=m, compressor="q4b", eta_theta=0.1, **cfg_kwargs)
    params = {"w": jnp.zeros((4,)), "b": jnp.zeros(())}
    batches = [_toy_batch(m, jax.random.PRNGKey(100 + t)) for t in range(total)]

    with jax.disable_jit():
        trainer = adgda_trainer(cfg, _toy_loss)
        state = trainer.init(params, jax.random.PRNGKey(0))
        final_auxes = []
        for t in range(total):
            if t == cut:
                save(str(tmp_path / "run"), state, step=t)
            state, aux = trainer.step_impl(state, batches[t])
            final_auxes.append(aux)
        uninterrupted = state

        # "kill": fresh trainer + abstract template, restore, continue
        trainer2 = adgda_trainer(cfg, _toy_loss)
        found = latest_step(str(tmp_path / "run"))
        assert found == cut
        template = jax.eval_shape(trainer2.init, params, jax.random.PRNGKey(0))
        state2 = restore(str(tmp_path / f"run_{found:08d}.npz"), template)
        resumed_auxes = []
        for t in range(cut, total):
            state2, aux = trainer2.step_impl(state2, batches[t])
            resumed_auxes.append(aux)

    flat1, tdef1 = jax.tree_util.tree_flatten(uninterrupted)
    flat2, tdef2 = jax.tree_util.tree_flatten(state2)
    assert tdef1 == tdef2
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # aux streams (losses, consensus error) match bit-for-bit as well
    for a, b in zip(final_auxes[cut:], resumed_auxes):
        np.testing.assert_array_equal(np.asarray(a["losses"]), np.asarray(b["losses"]))
        np.testing.assert_array_equal(
            np.asarray(a["consensus_err"]), np.asarray(b["consensus_err"])
        )
