"""DRO primitives: simplex projection, regularizers, closed-form KL weights."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import dro


# ------------------------------------------------------------------ projection
def _proj_brute(v, grid=200001):
    """Reference projection via scalar bisection on the KKT threshold."""
    v = np.asarray(v, np.float64)
    lo, hi = v.min() - 1.0, v.max()
    for _ in range(100):
        mid = (lo + hi) / 2
        if np.maximum(v - mid, 0).sum() > 1.0:
            lo = mid
        else:
            hi = mid
    return np.maximum(v - (lo + hi) / 2, 0)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=2, max_size=20))
def test_projection_matches_reference(vals):
    v = jnp.asarray(vals, jnp.float32)
    out = np.asarray(dro.project_simplex(v))
    ref = _proj_brute(vals)
    np.testing.assert_allclose(out, ref, atol=1e-4)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-50, 50, allow_nan=False), min_size=2, max_size=32))
def test_projection_lands_on_simplex(vals):
    out = np.asarray(dro.project_simplex(jnp.asarray(vals, jnp.float32)))
    assert (out >= -1e-6).all()
    assert out.sum() == pytest.approx(1.0, abs=1e-4)


def test_projection_idempotent_on_simplex():
    lam = jnp.asarray([0.2, 0.3, 0.5])
    np.testing.assert_allclose(np.asarray(dro.project_simplex(lam)), np.asarray(lam), atol=1e-6)


def test_projection_vmap():
    v = jax.random.normal(jax.random.PRNGKey(0), (8, 10))
    out = jax.vmap(dro.project_simplex)(v)
    np.testing.assert_allclose(np.asarray(out.sum(-1)), 1.0, atol=1e-5)


# ------------------------------------------------------------------ regularizers
def test_chi2_zero_at_prior_negative_elsewhere():
    prior = jnp.asarray([0.25] * 4)
    assert float(dro.chi2_regularizer(prior, prior)) == pytest.approx(0.0)
    assert float(dro.chi2_regularizer(jnp.asarray([0.7, 0.1, 0.1, 0.1]), prior)) < 0


def test_kl_zero_at_prior_negative_elsewhere():
    prior = jnp.asarray([0.25] * 4)
    assert float(dro.kl_regularizer(prior, prior)) == pytest.approx(0.0)
    assert float(dro.kl_regularizer(jnp.asarray([0.7, 0.1, 0.1, 0.1]), prior)) < 0


def test_regularizers_concave_along_segments():
    prior = jnp.full((5,), 0.2)
    a = jnp.asarray([0.6, 0.1, 0.1, 0.1, 0.1])
    b = jnp.asarray([0.1, 0.1, 0.1, 0.1, 0.6])
    for reg in (dro.chi2_regularizer, dro.kl_regularizer):
        mid = reg(0.5 * a + 0.5 * b, prior)
        assert float(mid) >= 0.5 * float(reg(a, prior)) + 0.5 * float(reg(b, prior)) - 1e-6


def test_make_regularizer():
    assert dro.make_regularizer("chi2").name == "chi2"
    assert dro.make_regularizer("kl").name == "kl"
    with pytest.raises(ValueError):
        dro.make_regularizer("l2")


# ------------------------------------------------------------------ KL closed form
def test_kl_closed_form_is_argmax():
    """lambda* = argmax_lam <lam, f> - alpha * KL(lam || prior)."""
    key = jax.random.PRNGKey(1)
    losses = jax.random.uniform(key, (6,)) * 3
    prior = jnp.full((6,), 1 / 6)
    alpha = 2.0
    lam_star = dro.kl_closed_form_weights(losses, prior, alpha)

    def objective(lam):
        return jnp.dot(lam, losses) + alpha * dro.kl_regularizer(lam, prior)

    base = float(objective(lam_star))
    # perturb within the simplex: must not improve
    for seed in range(20):
        pert = jax.random.normal(jax.random.PRNGKey(seed), (6,)) * 0.01
        lam_p = dro.project_simplex(lam_star + pert)
        assert float(objective(lam_p)) <= base + 1e-5


def test_kl_closed_form_limits():
    losses = jnp.asarray([1.0, 2.0, 3.0])
    prior = jnp.full((3,), 1 / 3)
    # alpha -> inf: weights -> prior
    np.testing.assert_allclose(
        np.asarray(dro.kl_closed_form_weights(losses, prior, 1e6)), np.asarray(prior), atol=1e-5
    )
    # alpha -> 0: all mass on the worst node
    w = np.asarray(dro.kl_closed_form_weights(losses, prior, 1e-2))
    assert w.argmax() == 2 and w[2] > 0.99


# ------------------------------------------------------------------ dual gradient
def test_dual_gradient_structure():
    prior = jnp.full((4,), 0.25)
    lam = prior
    g = dro.dual_gradient(2.0, 1, lam, prior, alpha=0.5, regularizer=dro.chi2_regularizer)
    # at lam == prior the chi2 gradient is zero -> only the e_i term remains
    np.testing.assert_allclose(np.asarray(g), [0, 2.0, 0, 0], atol=1e-6)
