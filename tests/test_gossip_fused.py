"""Fused CHOCO gossip round: kernel-vs-ref oracles and bit-compatibility of
the fused choco_round fast path against the packed/unpacked reference paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gossip, topology
from repro.core.compression import make_compressor
from repro.kernels import choco_fused, ref
from repro.kernels.ops import KernelQuantization

KEY = jax.random.PRNGKey(0)


def _allclose_trees(a, b, atol):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol, rtol=0)


# ------------------------------------------------------- kernel-vs-ref oracles
@pytest.mark.parametrize("bits", [8, 4, 2])
def test_fused_encode_kernel_matches_ref(bits):
    m, rows = 4, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    tn = jax.random.normal(k1, (m, rows, ref.LANES))
    hat = 0.5 * jax.random.normal(k2, (m, rows, ref.LANES))
    xi = jax.random.uniform(k3, (m, rows, ref.LANES))
    norms = jnp.linalg.norm((tn - hat).reshape(m, -1), axis=1)
    scales = jnp.stack(
        [(1 << bits) / norms, norms / ((1 << bits) * ref.tau_for(rows * ref.LANES, bits))],
        axis=1,
    )
    lvl_k, sign_k, hat_k = choco_fused.fused_encode_pallas(
        tn, hat, xi, scales, bits, interpret=True
    )
    lvl_r, sign_r, hat_r = ref.fused_encode_ref(tn, hat, xi, scales, bits)
    np.testing.assert_array_equal(np.asarray(lvl_k), np.asarray(lvl_r))
    np.testing.assert_array_equal(np.asarray(sign_k), np.asarray(sign_r))
    np.testing.assert_allclose(np.asarray(hat_k), np.asarray(hat_r), atol=1e-6)


@pytest.mark.parametrize("bits", [8, 4])
def test_fused_mix_kernel_matches_ref(bits):
    m, rows, K = 6, 32, 3
    pack = 8 // bits
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    lvl = jax.random.randint(k1, (K, m, rows // pack, ref.LANES), 0, 256, jnp.uint8)
    sign = jax.random.randint(k2, (K, m, rows // 8, ref.LANES), 0, 256, jnp.uint8)
    s = jax.random.normal(k3, (m, rows, ref.LANES))
    wscale = jax.random.uniform(k4, (K, m), minval=0.0, maxval=0.1)
    out_k = choco_fused.fused_mix_pallas(lvl, sign, s, wscale, bits, interpret=True)
    out_r = ref.fused_mix_ref(lvl, sign, s, wscale, bits)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)


# --------------------------------------------- fused choco_round vs the oracles
@pytest.mark.parametrize("bits", [8, 4], ids=["q8b", "q4b"])
@pytest.mark.parametrize(
    "topo", [topology.ring(8), topology.torus_2d(16)], ids=["ring", "torus"]
)
def test_fused_round_matches_unpacked_oracle(topo, bits):
    """Acceptance: fused path bit-compatible (1e-5 f32) with packed=False."""
    m = topo.num_nodes
    comp = KernelQuantization(bits=bits)
    theta = {
        "w": jax.random.normal(KEY, (m, 1000)),
        "blk": jax.random.normal(jax.random.PRNGKey(1), (m, 3, 260)),
    }
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(7)
    t_f, s_f = gossip.choco_round(theta, state, topo, 0.2, comp, k, fused=True)
    t_o, s_o = gossip.choco_round(theta, state, topo, 0.2, comp, k, packed=False)
    _allclose_trees((t_f, s_f), (t_o, s_o), atol=1e-5)


@pytest.mark.parametrize("bits", [8, 4], ids=["q8b", "q4b"])
def test_fused_round_matches_packed_oracle(bits):
    comp = KernelQuantization(bits=bits)
    topo = topology.ring(8)
    theta = {"w": jax.random.normal(KEY, (8, 512))}
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(3)
    t_f, s_f = gossip.choco_round(theta, state, topo, 0.3, comp, k, fused=True)
    t_p, s_p = gossip.choco_round(theta, state, topo, 0.3, comp, k, packed=True)
    _allclose_trees((t_f, s_f), (t_p, s_p), atol=1e-5)


@pytest.mark.parametrize("m", [6, 16], ids=["single-batch", "multi-batch"])
def test_fused_round_mesh_topology(m):
    """Mesh is circulant with m shifts — the K-way mix must handle it, both
    within one SHIFT_BATCH (m=6) and across several batched calls (m=16)."""
    topo = topology.mesh(m)
    comp = KernelQuantization(bits=8)
    theta = {"w": jax.random.normal(KEY, (m, 300))}
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(5)
    t_f, s_f = gossip.choco_round(theta, state, topo, 0.2, comp, k, fused=True)
    t_o, s_o = gossip.choco_round(theta, state, topo, 0.2, comp, k, packed=False)
    _allclose_trees((t_f, s_f), (t_o, s_o), atol=1e-5)


def test_fused_round_bf16_multi_batch_matches_oracle():
    """bf16 leaves across >SHIFT_BATCH shifts: the mix accumulator must stay
    f32 between batches (one final cast), like the oracle."""
    m = 16  # mesh(16): K = 16 shifts = two SHIFT_BATCH batches
    topo = topology.mesh(m)
    comp = KernelQuantization(bits=8)
    theta = {"w": jax.random.normal(KEY, (m, 300)).astype(jnp.bfloat16)}
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(9)
    t_f, s_f = gossip.choco_round(theta, state, topo, 0.2, comp, k, fused=True)
    t_o, s_o = gossip.choco_round(theta, state, topo, 0.2, comp, k, packed=False)
    for a, b in zip(jax.tree_util.tree_leaves((t_f, s_f)), jax.tree_util.tree_leaves((t_o, s_o))):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5, rtol=0
        )


def test_step_without_init_resolves_gamma_from_state():
    """A step() traced without init() must not bake the placeholder gamma:
    the consensus re-resolves it from the state's own leaf shapes."""
    from repro.core import ADGDAConfig, TrainerState, adgda_trainer
    from repro.core.gossip import choco_init
    from repro.core.trainer import ChocoConsensus

    m, d = 4, 1 << 16
    cfg = ADGDAConfig(num_nodes=m, topology="ring", compressor="q8b",
                      eta_theta=0.01, eta_lambda=0.01)

    def loss_fn(params, batch, rng):
        return jnp.mean((params["w"] - batch) ** 2)

    trainer = adgda_trainer(cfg, loss_fn)
    placeholder_gamma = trainer.gamma  # resolved with the 4096-element stub
    # hand-rolled state, bypassing init() entirely (a checkpoint restore)
    theta = {"w": jnp.zeros((m, d))}
    state = TrainerState(
        step=jnp.zeros((), jnp.int32),
        theta=theta,
        lam=jnp.full((m, m), 1.0 / m),
        opt=trainer.local.init(theta),
        consensus=choco_init(theta),
        theta_avg={"w": jnp.zeros((d,), jnp.float32)},
        rng=jax.random.PRNGKey(0),
    )
    assert trainer.consensus._resolve_gamma(d) < placeholder_gamma  # larger d, smaller delta
    assert ChocoConsensus._encode_dim(theta) == d
    state2, aux = trainer.step(state, jnp.zeros((m, d)))
    assert np.isfinite(float(aux["mean_loss"]))


def test_fused_round_preserves_global_average():
    """CHOCO invariant: the gossip round preserves mean(theta) + mean(s-hat)."""
    topo = topology.ring(8)
    comp = KernelQuantization(bits=4)
    theta = {"w": jax.random.normal(KEY, (8, 640))}
    state = gossip.choco_init(theta)
    mean0 = theta["w"].mean(0)
    t, s = theta, state
    for i in range(5):
        t, s = gossip.choco_round(t, s, topo, 0.3, comp, jax.random.PRNGKey(i), fused=True)
    np.testing.assert_allclose(np.asarray(t["w"].mean(0)), np.asarray(mean0), atol=1e-4)


def test_fused_round_composes_with_scan_plan():
    """Chunk-scanned large leaves must route each chunk through the fused path."""
    topo = topology.ring(4)
    comp = KernelQuantization(bits=8)
    theta = {"blocks": jax.random.normal(KEY, (4, 6, 256))}
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(11)
    # block_scan_elems=8 forces the scan plan (6 chunks along axis 1)
    t_f, s_f = gossip.choco_round(
        theta, state, topo, 0.3, comp, k, fused=True, block_scan_elems=8
    )
    t_o, s_o = gossip.choco_round(
        theta, state, topo, 0.3, comp, k, packed=False, block_scan_elems=8
    )
    _allclose_trees((t_f, s_f), (t_o, s_o), atol=1e-5)
    assert t_f["blocks"].shape == (4, 6, 256)


def test_fused_round_jits():
    topo = topology.ring(4)
    comp = KernelQuantization(bits=4)
    theta = {"w": jax.random.normal(KEY, (4, 128))}
    state = gossip.choco_init(theta)

    @jax.jit
    def step(t, s, k):
        return gossip.choco_round(t, s, topo, 0.3, comp, k, fused=True)

    t, s = step(theta, state, KEY)
    assert t["w"].shape == (4, 128)


def test_fused_flag_falls_back_for_unsupported_compressor():
    """fused=True with a non-fused compressor must silently use the oracle."""
    topo = topology.ring(4)
    comp = make_compressor("q8b")  # pure-jnp, no fused capability
    theta = {"w": jax.random.normal(KEY, (4, 64))}
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(2)
    t_f, s_f = gossip.choco_round(theta, state, topo, 0.3, comp, k, fused=True)
    t_o, s_o = gossip.choco_round(theta, state, topo, 0.3, comp, k, packed=True)
    _allclose_trees((t_f, s_f), (t_o, s_o), atol=0.0)


def test_adgda_trainer_with_fused_gossip():
    """End-to-end: ADGDAConfig(fused_gossip=True, compressor='kq8b') trains."""
    from repro.core import ADGDAConfig, adgda_trainer

    m = 4
    cfg = ADGDAConfig(
        num_nodes=m, topology="ring", compressor="kq8b", fused_gossip=True,
        eta_theta=0.05, eta_lambda=0.05,
    )

    def loss_fn(params, batch, rng):
        return jnp.mean((params["w"] - batch) ** 2)

    trainer = adgda_trainer(cfg, loss_fn)
    batch = jnp.arange(m, dtype=jnp.float32).reshape(m, 1)
    state = trainer.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    for _ in range(3):
        state, aux = trainer.step(state, batch)
    assert np.isfinite(float(aux["mean_loss"]))
