"""Continuous-batching serving engine: slot reuse, per-slot positions, and
token-for-token agreement with the plain sequential decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _sequential_generate(cfg, params, prompt, n_new, cache_len):
    """Reference: plain prefill + one-at-a-time decode (batch 1)."""
    toks = jnp.asarray(np.array(prompt, np.int32))[None]
    logits, cache = T.prefill(params, {"tokens": toks}, cfg, cache_len=cache_len)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = T.decode_step(params, tok, cache, jnp.int32(pos), cfg)
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("qwen3-1.7b").reduced()
    params = T.init_model(KEY, cfg)
    return cfg, params


def test_engine_matches_sequential_decode(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist() for n in (7, 13, 21)]
    n_new = 6

    engine = ServeEngine(cfg, params, max_slots=2, cache_len=64, prompt_bucket=8)
    reqs = [Request(prompt=p, max_new_tokens=n_new) for p in prompts]
    engine.run(reqs)

    for p, r in zip(prompts, reqs):
        assert r.done
        ref = _sequential_generate(cfg, params, p, n_new, cache_len=64)
        assert r.output == ref, (r.output, ref)


def test_engine_slot_reuse_more_requests_than_slots(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(1)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, 5 + i).tolist(), max_new_tokens=3)
        for i in range(5)
    ]
    engine = ServeEngine(cfg, params, max_slots=2, cache_len=32, prompt_bucket=8)
    engine.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 3 for r in reqs)


def test_engine_eos_stops_early(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, 8).tolist()
    ref = _sequential_generate(cfg, params, prompt, 8, cache_len=64)
    eos = ref[2]  # force an early stop at the 3rd generated token
    r = Request(prompt=prompt, max_new_tokens=8, eos_id=eos)
    ServeEngine(cfg, params, max_slots=1, cache_len=64, prompt_bucket=8).run([r])
    assert r.done
    assert r.output[-1] == eos
    assert len(r.output) <= 8


def test_engine_recurrent_arch():
    """SSM family: exact-length prompts, O(1) state slots."""
    import dataclasses

    cfg = dataclasses.replace(get_config("mamba2-1.3b").reduced(), ssm_chunk=8)
    params = T.init_model(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, 16).tolist(), rng.integers(1, cfg.vocab_size, 8).tolist()]
    reqs = [Request(prompt=p, max_new_tokens=4) for p in prompts]
    engine = ServeEngine(cfg, params, max_slots=2, cache_len=64)
    engine.run(reqs)
    for p, r in zip(prompts, reqs):
        ref = _sequential_generate(cfg, params, p, 4, cache_len=64)
        assert r.output == ref, (r.output, ref)
