"""Continuous-batching serving engine: slot reuse, per-slot positions,
token-for-token agreement with the plain sequential decode path — plus the
fleet-hardening contracts: FIFO admission under slot contention, same-tick
slot release when a request completes at prefill, and the bucketed-prefill
warm-jit-cache claim (retrace counting)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _sequential_generate(cfg, params, prompt, n_new, cache_len):
    """Reference: plain prefill + one-at-a-time decode (batch 1)."""
    toks = jnp.asarray(np.array(prompt, np.int32))[None]
    logits, cache = T.prefill(params, {"tokens": toks}, cfg, cache_len=cache_len)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = T.decode_step(params, tok, cache, jnp.int32(pos), cfg)
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("qwen3-1.7b").reduced()
    params = T.init_model(KEY, cfg)
    return cfg, params


def test_engine_matches_sequential_decode(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist() for n in (7, 13, 21)]
    n_new = 6

    engine = ServeEngine(cfg, params, max_slots=2, cache_len=64, prompt_bucket=8)
    reqs = [Request(prompt=p, max_new_tokens=n_new) for p in prompts]
    engine.run(reqs)

    for p, r in zip(prompts, reqs):
        assert r.done
        ref = _sequential_generate(cfg, params, p, n_new, cache_len=64)
        assert r.output == ref, (r.output, ref)


def test_engine_slot_reuse_more_requests_than_slots(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(1)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, 5 + i).tolist(), max_new_tokens=3)
        for i in range(5)
    ]
    engine = ServeEngine(cfg, params, max_slots=2, cache_len=32, prompt_bucket=8)
    engine.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 3 for r in reqs)


def test_engine_eos_stops_early(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, 8).tolist()
    ref = _sequential_generate(cfg, params, prompt, 8, cache_len=64)
    eos = ref[2]  # force an early stop at the 3rd generated token
    r = Request(prompt=prompt, max_new_tokens=8, eos_id=eos)
    ServeEngine(cfg, params, max_slots=1, cache_len=64, prompt_bucket=8).run([r])
    assert r.done
    assert r.output[-1] == eos
    assert len(r.output) <= 8


@pytest.fixture(scope="module")
def nowindow_setup():
    """Full-attention variant: with a sliding window the ring buffer wraps
    and the engine rightly falls back to exact-length prefill, so the
    bucketed warm-cache path needs window-free attention to exercise."""
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(), long_context_window=None)
    params = T.init_model(KEY, cfg)
    return cfg, params


def test_engine_fifo_admission_under_contention(dense_setup):
    """More requests than slots: admission follows submit order exactly and
    every request's TTFT is its queue wait."""
    cfg, params = dense_setup
    rng = np.random.default_rng(4)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, 6 + i).tolist(), max_new_tokens=2)
        for i in range(6)
    ]
    engine = ServeEngine(cfg, params, max_slots=1, cache_len=32, prompt_bucket=8)
    engine.run(reqs)
    admits = [r.admit_tick for r in reqs]
    assert all(r.done for r in reqs)
    assert admits == sorted(admits), admits  # FIFO: admit order == submit order
    assert all(r.ttft_ticks == r.admit_tick - r.submit_tick >= 0 for r in reqs)
    assert all(r.finish_tick >= r.admit_tick for r in reqs)


def test_engine_prefill_complete_releases_slot_same_tick(dense_setup):
    """A single-token request completes at prefill; with one slot, the next
    pending request must be admitted the SAME tick (fixpoint admission), not
    a tick later."""
    cfg, params = dense_setup
    rng = np.random.default_rng(5)
    first = Request(prompt=rng.integers(1, cfg.vocab_size, 5).tolist(), max_new_tokens=1)
    second = Request(prompt=rng.integers(1, cfg.vocab_size, 7).tolist(), max_new_tokens=2)
    engine = ServeEngine(cfg, params, max_slots=1, cache_len=32, prompt_bucket=8)
    engine.run([first, second])
    assert first.done and second.done
    assert first.finish_tick == first.admit_tick == 0
    assert second.admit_tick == 0  # admitted into the slot freed this tick


def test_engine_eos_at_prefill_releases_slot_same_tick(dense_setup):
    """EOS emitted as the final prompt-prefill token: the slot frees that
    tick and the queued request takes it immediately."""
    cfg, params = dense_setup
    rng = np.random.default_rng(6)
    prompt = rng.integers(1, cfg.vocab_size, 8).tolist()
    ref = _sequential_generate(cfg, params, prompt, 1, cache_len=64)
    eos = ref[0]  # the token the prefill emits
    first = Request(prompt=prompt, max_new_tokens=8, eos_id=eos)
    second = Request(prompt=rng.integers(1, cfg.vocab_size, 9).tolist(), max_new_tokens=2)
    engine = ServeEngine(cfg, params, max_slots=1, cache_len=64, prompt_bucket=8)
    engine.run([first, second])
    assert first.done and first.output == [eos]
    assert first.finish_tick == first.admit_tick == 0
    assert second.admit_tick == 0 and second.done


def test_engine_prefill_retraces_bounded_by_buckets(nowindow_setup):
    """The warm-cache claim, pinned: serving many prompt lengths compiles
    the prefill once per BUCKET (not once per length) and the decode exactly
    once, regardless of traffic mix — and a second engine over the same
    shapes compiles NOTHING, because fast-path programs are process-shared."""
    from repro.serving.engine import PROGRAMS

    cfg, params = nowindow_setup
    PROGRAMS.clear()
    rng = np.random.default_rng(7)
    engine = ServeEngine(cfg, params, max_slots=2, cache_len=48, prompt_bucket=8)
    # lengths spanning exactly two buckets (<=8 and <=16), many of each
    for n in (3, 5, 7, 8, 11, 13, 16, 4, 9, 15):
        engine.run([Request(prompt=rng.integers(1, cfg.vocab_size, n).tolist(),
                            max_new_tokens=3)])
    assert engine.prefill_traces == 2, engine.prefill_traces
    assert engine.decode_traces == 1, engine.decode_traces
    # a third bucket compiles exactly one more prefill, no decode retrace
    engine.run([Request(prompt=rng.integers(1, cfg.vocab_size, 20).tolist(),
                        max_new_tokens=3)])
    assert engine.prefill_traces == 3 and engine.decode_traces == 1
    # shared program cache: a fresh engine with identical shapes is warm
    twin = ServeEngine(cfg, params, max_slots=2, cache_len=48, prompt_bucket=8)
    twin.run([Request(prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                      max_new_tokens=3)])
    assert twin.prefill_traces == 0 and twin.decode_traces == 0


def test_engine_fastpath_matches_legacy(nowindow_setup):
    """fastpath=False restores the original per-request engine; the fast
    path must agree token-for-token AND tick-for-tick (the suite-S bit-
    identity gate in miniature)."""
    cfg, params = nowindow_setup
    rng = np.random.default_rng(8)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (5, 9, 5, 13, 7, 5, 9)]  # repeats -> prefix-cache hits
    runs = {}
    for fast in (True, False):
        reqs = [Request(prompt=list(p), max_new_tokens=4) for p in prompts]
        engine = ServeEngine(cfg, params, max_slots=2, cache_len=48,
                             prompt_bucket=8, fastpath=fast)
        engine.run(reqs)
        runs[fast] = reqs
    for fast_r, legacy_r in zip(runs[True], runs[False]):
        assert fast_r.output == legacy_r.output
        assert fast_r.admit_tick == legacy_r.admit_tick
        assert fast_r.finish_tick == legacy_r.finish_tick


def test_engine_batched_prefill_parity(nowindow_setup):
    """Same-bucket requests admitted together run as ONE batched prefill;
    every row must match the batch-1 sequential reference exactly."""
    cfg, params = nowindow_setup
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist() for n in (4, 6, 7, 8)]
    reqs = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    engine = ServeEngine(cfg, params, max_slots=4, cache_len=48, prompt_bucket=8)
    before = engine.prefill_traces
    engine.run(reqs)
    # all four share the 8-bucket: exactly one prefill program was built
    assert engine.prefill_traces - before <= 1
    for p, r in zip(prompts, reqs):
        ref = _sequential_generate(cfg, params, p, 5, cache_len=48)
        assert r.output == ref, (r.output, ref)


def test_engine_legacy_prefills_lru_bounded(dense_setup):
    """Satellite: many distinct exact-length prefills (windowed arch) no
    longer grow the per-engine jit cache without bound."""
    cfg, params = dense_setup  # reduced qwen3: 16-token window -> exact lengths
    rng = np.random.default_rng(10)
    engine = ServeEngine(cfg, params, max_slots=1, cache_len=32,
                         fastpath=False, max_prefill_programs=3)
    for n in (4, 5, 6, 7, 8, 9):
        engine.run([Request(prompt=rng.integers(1, cfg.vocab_size, n).tolist(),
                            max_new_tokens=2)])
    assert len(engine._prefills) == 3
    assert engine.prefill_evictions == 3
    assert engine.stats()["prefill_programs"] == 3.0


def test_engine_recurrent_arch():
    """SSM family: exact-length prompts, O(1) state slots."""
    import dataclasses

    cfg = dataclasses.replace(get_config("mamba2-1.3b").reduced(), ssm_chunk=8)
    params = T.init_model(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, 16).tolist(), rng.integers(1, cfg.vocab_size, 8).tolist()]
    reqs = [Request(prompt=p, max_new_tokens=4) for p in prompts]
    engine = ServeEngine(cfg, params, max_slots=2, cache_len=64)
    engine.run(reqs)
    for p, r in zip(prompts, reqs):
        ref = _sequential_generate(cfg, params, p, 4, cache_len=64)
        assert r.output == ref, (r.output, ref)
