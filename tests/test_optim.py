"""repro/optim: optimizers (sgd/momentum/nesterov, adam) and LR schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import OptState, adam, make_schedule, sgd


def _tree(v):
    return {"w": jnp.asarray(v, jnp.float32)}


def _apply(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


# ---------------------------------------------------------------------- sgd
def test_sgd_plain_step():
    opt = sgd(0.1)
    params = _tree([1.0, 2.0])
    state = opt.init(params)
    assert state.mu == () and state.nu == ()  # no momentum buffer carried
    updates, state = opt.update(_tree([0.5, -1.0]), state, params)
    np.testing.assert_allclose(np.asarray(updates["w"]), [-0.05, 0.1], rtol=1e-6)
    assert int(state.step) == 1


def test_sgd_momentum_accumulates():
    opt = sgd(1.0, momentum=0.5)
    params = _tree([0.0])
    state = opt.init(params)
    g = _tree([1.0])
    u1, state = opt.update(g, state, params)
    u2, state = opt.update(g, state, params)
    # mu_1 = 1, mu_2 = 0.5*1 + 1 = 1.5
    np.testing.assert_allclose(np.asarray(u1["w"]), [-1.0])
    np.testing.assert_allclose(np.asarray(u2["w"]), [-1.5])
    np.testing.assert_allclose(np.asarray(state.mu["w"]), [1.5])


def test_sgd_nesterov_lookahead():
    """Nesterov update is -lr*(momentum*mu_new + g), plain is -lr*mu_new."""
    g = _tree([1.0])
    params = _tree([0.0])
    plain = sgd(1.0, momentum=0.9)
    nest = sgd(1.0, momentum=0.9, nesterov=True)
    sp, sn = plain.init(params), nest.init(params)
    up, sp = plain.update(g, sp, params)
    un, sn = nest.update(g, sn, params)
    np.testing.assert_allclose(np.asarray(up["w"]), [-1.0])  # mu = 1
    np.testing.assert_allclose(np.asarray(un["w"]), [-(0.9 * 1.0 + 1.0)], rtol=1e-6)
    # second step: mu = 0.9 + 1 = 1.9; nesterov -(0.9*1.9 + 1)
    up, _ = plain.update(g, sp, params)
    un, _ = nest.update(g, sn, params)
    np.testing.assert_allclose(np.asarray(up["w"]), [-1.9], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(un["w"]), [-(0.9 * 1.9 + 1.0)], rtol=1e-6)


# --------------------------------------------------------------------- adam
def test_adam_bias_correction_first_step():
    """At t=1 the bias-corrected moments make the step ~lr*sign(g) regardless
    of the gradient magnitude: m_hat = g, v_hat = g^2."""
    for gval in (0.001, 1.0, 250.0):
        opt = adam(0.1, b1=0.9, b2=0.999, eps=1e-8)
        params = _tree([0.0])
        state = opt.init(params)
        updates, state = opt.update(_tree([gval]), state, params)
        expected = -0.1 * gval / (abs(gval) + 1e-8)
        np.testing.assert_allclose(np.asarray(updates["w"]), [expected], rtol=1e-5)


def test_adam_bias_correction_trajectory():
    """Against a hand-rolled reference over several steps."""
    b1, b2, eps, lr = 0.9, 0.95, 1e-8, 0.05
    opt = adam(lr, b1=b1, b2=b2, eps=eps)
    params = _tree([0.3, -0.7])
    state = opt.init(params)
    m = np.zeros(2)
    v = np.zeros(2)
    rng = np.random.default_rng(0)
    for t in range(1, 6):
        g = rng.normal(size=2).astype(np.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        ref = -lr * (m / (1 - b1**t)) / (np.sqrt(v / (1 - b2**t)) + eps)
        updates, state = opt.update(_tree(g), state, params)
        np.testing.assert_allclose(np.asarray(updates["w"]), ref, rtol=1e-5)
        params = _apply(params, updates)


def test_adam_weight_decay_pulls_to_zero():
    opt = adam(0.1, weight_decay=0.1)
    params = _tree([10.0])
    state = opt.init(params)
    updates, _ = opt.update(_tree([0.0]), state, params)
    assert float(updates["w"][0]) < 0  # decay term alone pushes down


# ----------------------------------------------------------------- schedules
def test_schedule_const_and_exp():
    c = make_schedule("const", 0.3)
    e = make_schedule("exp", 0.3, decay=0.9)
    for t in (0, 3, 10):
        assert float(c(jnp.int32(t))) == pytest.approx(0.3)
        assert float(e(jnp.int32(t))) == pytest.approx(0.3 * 0.9**t, rel=1e-6)


def test_schedule_cosine_endpoints():
    s = make_schedule("cosine", 1.0, total_steps=100)
    assert float(s(jnp.int32(0))) == pytest.approx(1.0)
    assert float(s(jnp.int32(50))) == pytest.approx(0.5, abs=1e-6)
    assert float(s(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
    assert float(s(jnp.int32(500))) == pytest.approx(0.0, abs=1e-6)  # clamps


def test_schedule_warmup_ramps_linearly():
    s = make_schedule("const", 0.8, warmup=10)
    assert float(s(jnp.int32(0))) == pytest.approx(0.0)
    assert float(s(jnp.int32(5))) == pytest.approx(0.4, rel=1e-6)
    assert float(s(jnp.int32(10))) == pytest.approx(0.8, rel=1e-6)
    assert float(s(jnp.int32(50))) == pytest.approx(0.8, rel=1e-6)


def test_schedule_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown schedule"):
        make_schedule("linear", 0.1)(jnp.int32(0))


def test_optimizers_jit_and_carry_state():
    """OptState threads through jit/scan (the trainer's usage pattern)."""
    sched = make_schedule("exp", 0.1, decay=0.99)
    for opt in (sgd(sched, momentum=0.9), adam(sched)):
        params = _tree(np.linspace(-1, 1, 8))
        state = opt.init(params)

        @jax.jit
        def run(params, state):
            def body(carry, _):
                p, s = carry
                g = jax.tree.map(lambda x: 2 * x, p)  # grad of sum(x^2)
                u, s = opt.update(g, s, p)
                return (_apply(p, u), s), None

            return jax.lax.scan(body, (params, state), None, length=20)[0]

        params2, state2 = run(params, state)
        assert int(state2.step) == 20
        assert float(jnp.abs(params2["w"]).sum()) < float(jnp.abs(params["w"]).sum())
