"""Substrate-layer tests: data pipeline, optimizers, checkpointing, sharding
rules, and the trip-count-aware HLO cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as hst

from repro.data import (
    class_shard_classification,
    contrast_shift_classification,
    instrument_shift_classification,
    node_token_stream,
)
from repro.optim import adam, make_schedule, sgd

KEY = jax.random.PRNGKey(0)


# -------------------------------------------------------------------- data
def test_class_shard_is_deterministic_and_skewed():
    d1 = class_shard_classification(num_nodes=6, seed=7)
    d2 = class_shard_classification(num_nodes=6, seed=7)
    np.testing.assert_array_equal(d1.x, d2.x)
    # each node stores exactly one class
    for i in range(6):
        assert len(np.unique(d1.y[i])) == 1
    assert d1.num_classes == 6


def test_contrast_shift_val_sets():
    d = contrast_shift_classification(num_nodes=8, low_nodes=2, high_nodes=2)
    assert d.val_names == ["low_contrast", "high_contrast", "original"]
    assert d.x.shape[0] == 8


def test_instrument_shift_distorts_minority():
    d = instrument_shift_classification(num_nodes=6, minority_nodes=2, seed=0)
    # minority node features differ in distribution from majority
    assert abs(d.x[0].mean() - d.x[5].mean()) > 1e-3 or abs(d.x[0].std() - d.x[5].std()) > 1e-3


def test_batches_shapes():
    d = class_shard_classification(num_nodes=4, n_per_node=64)
    xb, yb = next(d.batches(16))
    assert xb.shape == (4, 16, d.dim)
    assert yb.shape == (4, 16)


def test_token_stream_node_skew():
    gen = node_token_stream(num_nodes=3, batch_per_node=2, seq_len=512, vocab_size=64, seed=0)
    toks = next(gen)
    assert toks.shape == (3, 2, 512)
    # same Zipf marginal, different permutation: per-node top token differs
    tops = [np.bincount(toks[i].ravel(), minlength=64).argmax() for i in range(3)]
    assert len(set(tops)) > 1


# ------------------------------------------------------------------- optim
def test_sgd_quadratic_converges():
    opt = sgd(0.1, momentum=0.9)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(300):
        g = {"x": 2 * params["x"]}
        up, state = opt.update(g, state)
        params = jax.tree.map(lambda p, u: p + u, params, up)
    assert float(jnp.abs(params["x"]).max()) < 1e-3


def test_adam_quadratic_converges():
    opt = adam(0.1)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        g = {"x": 2 * params["x"]}
        up, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, up)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_schedules():
    exp = make_schedule("exp", 1.0, decay=0.5)
    assert float(exp(jnp.int32(2))) == pytest.approx(0.25)
    cos = make_schedule("cosine", 1.0, total_steps=100)
    assert float(cos(jnp.int32(0))) == pytest.approx(1.0)
    assert float(cos(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
    warm = make_schedule("const", 1.0, warmup=10)
    assert float(warm(jnp.int32(5))) == pytest.approx(0.5)


@given(hst.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_property_sgd_step_is_linear_in_grad(seed):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=4).astype(np.float32)
    opt = sgd(0.3)
    st0 = opt.init({"p": jnp.zeros(4)})
    u1, _ = opt.update({"p": jnp.asarray(g)}, st0)
    u2, _ = opt.update({"p": jnp.asarray(2 * g)}, st0)
    np.testing.assert_allclose(np.asarray(u2["p"]), 2 * np.asarray(u1["p"]), rtol=1e-5)


# -------------------------------------------------------------- sharding
def test_param_pspecs_rank_matches_everywhere():
    from jax.sharding import PartitionSpec

    from repro.configs import ARCHS, get_config
    from repro.launch import steps as st
    from repro.launch.sharding import param_pspecs

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), dtype=object)

    for arch in ARCHS:
        cfg = get_config(arch)
        params = st.abstract_params(cfg)
        specs = param_pspecs(params, FakeMesh())
        flat_p = jax.tree_util.tree_leaves(params)
        flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s):
            assert len(s) <= p.ndim, (arch, p.shape, s)
            # sharded dims must be at least the axis size (uneven sharding is
            # allowed — GSPMD pads; attention heads use it, e.g. 40 over 16)
            for dim, ax in enumerate(s):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([16 for a in axes]))
                assert p.shape[dim] >= size, (arch, p.shape, s)


def test_node_stacked_pspecs_have_lead_axis():
    from jax.sharding import PartitionSpec

    from repro.configs import get_config
    from repro.launch import steps as st
    from repro.launch.sharding import param_pspecs

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        devices = np.empty((2, 16, 16), dtype=object)

    cfg = get_config("qwen3-1.7b")
    params = st.abstract_params(cfg)
    # node-stacked state as the AD-GDA trainer holds it: leading axis m=32
    params = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((32,) + p.shape, p.dtype), params
    )
    specs = param_pspecs(params, FakeMesh(), node_axes=("pod", "data"))
    for s in jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, PartitionSpec)):
        assert s[0] == ("pod", "data")


def test_cache_pspecs_mqa_shards_sequence():
    from jax.sharding import PartitionSpec

    from repro.configs import get_config
    from repro.launch.sharding import cache_pspecs
    from repro.models import transformer as T

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), dtype=object)

    cfg = get_config("granite-20b")  # kv=1 -> MQA
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, 32768))
    specs = cache_pspecs(cache, FakeMesh(), 128)
    k_spec = specs["blocks"][0]["k"]
    assert k_spec[2] == "model"  # sequence dim sharded (flash-decoding layout)


# -------------------------------------------------------------- hlo_cost
def test_hlo_cost_multiplies_scan_trip_count():
    from repro.launch.hlo_cost import analyze_hlo

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    n, trip = 128, 7
    xs = jax.ShapeDtypeStruct((n, n), jnp.float32)
    ws = jax.ShapeDtypeStruct((trip, n, n), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    c = analyze_hlo(compiled.as_text())
    matmul_flops = 2 * n**3
    assert c.flops >= trip * matmul_flops * 0.99
    assert c.flops <= trip * matmul_flops * 1.5  # + tanh etc.
    # XLA's own analysis counts the body once — ours must exceed it
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert c.flops > float(ca["flops"]) * (trip - 1) / trip


def test_hlo_cost_counts_collectives_with_trip():
    from repro.launch.hlo_cost import analyze_hlo

    hlo = """
HloModule test

%body (arg: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %arg = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[64,64] get-tuple-element(%arg), index=1
  %ar = f32[64,64] all-reduce(%x), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[64,64]) tuple(%ni, %ar)
}

%cond (arg2: (s32[], f32[64,64])) -> pred[] {
  %arg2 = (s32[], f32[64,64]) parameter(0)
  %i2 = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64] parameter(0)
  %zero = s32[] constant(0)
  %t = (s32[], f32[64,64]) tuple(%zero, %p)
  %w = (s32[], f32[64,64]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %cp = f32[64,64] collective-permute(%p), source_target_pairs={{0,1},{1,0}}
  ROOT %r = f32[64,64] get-tuple-element(%w), index=1
}
"""
    c = analyze_hlo(hlo)
    ar_bytes = 64 * 64 * 4
    assert c.coll["all-reduce"] == 5 * ar_bytes
    assert c.coll["collective-permute"] == ar_bytes


def test_hlo_cost_dot_contracting_dims():
    from repro.launch.hlo_cost import analyze_hlo

    f = jax.jit(lambda a, b: jnp.einsum("bik,bkj->bij", a, b))
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    compiled = f.lower(a, b).compile()
    c = analyze_hlo(compiled.as_text())
    assert c.flops == pytest.approx(2 * 4 * 32 * 16 * 64, rel=0.05)
