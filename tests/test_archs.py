"""Per-assigned-architecture smoke tests (deliverable f).

Each test instantiates the REDUCED variant of the family (2-3 layers,
d_model <= 512, <= 4 experts), runs one forward and one AD-GDA train step on
CPU, and asserts output shapes + finiteness.  The FULL configs are exercised
only by the dry-run (launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch import steps as st
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _reduced(arch):
    cfg = get_config(arch)
    layers = 3 if cfg.family == "hybrid" else 2  # hybrid: cover rglru AND local_attn
    return cfg.reduced(layers=layers)


def _batch(cfg, nodes=None, b=2, s=64):
    if cfg.ssm_state:
        s = max(s, cfg.ssm_chunk)
        s -= s % cfg.ssm_chunk
    lead = (nodes, b) if nodes else (b,)
    batch = {"tokens": jax.random.randint(KEY, lead + (s,), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = 0.02 * jax.random.normal(KEY, lead + (cfg.encoder_context, cfg.d_model))
    if cfg.num_patches > 0:
        batch["patches"] = 0.02 * jax.random.normal(KEY, lead + (cfg.num_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", [a.replace("_", "-") for a in ARCHS])
def test_forward_shapes_and_finite(arch):
    cfg = _reduced(arch)
    params = T.init_model(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: T.forward(p, b, cfg))(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", [a.replace("_", "-") for a in ARCHS])
def test_one_adgda_train_step(arch):
    cfg = _reduced(arch)
    m = 2
    trainer = st.make_trainer(cfg, m, compressor="q8b", eta_theta=0.01)
    params = T.init_model(KEY, cfg)
    state = trainer.init(params, KEY)
    state, aux = trainer.step(state, _batch(cfg, nodes=m, b=1, s=32))
    assert aux["losses"].shape == (m,)
    assert np.isfinite(np.asarray(aux["losses"])).all()
    assert np.isfinite(np.asarray(aux["consensus_err"]))
    # lambda stays a distribution at every node
    lam = np.asarray(state.lam)
    np.testing.assert_allclose(lam.sum(-1), 1.0, atol=1e-5)
    assert (lam >= -1e-6).all()
    # theta stayed finite
    for leaf in jax.tree_util.tree_leaves(state.theta):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", [a.replace("_", "-") for a in ARCHS])
def test_decode_step_shapes(arch):
    cfg = _reduced(arch)
    params = T.init_model(KEY, cfg)
    B, S = 2, 32
    batch = _batch(cfg, b=B, s=S)
    S = batch["tokens"].shape[-1]
    logits, cache = jax.jit(lambda p, b: T.prefill(p, b, cfg, cache_len=S + 8))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1:], -1)
    dlogits, cache2 = jax.jit(lambda p, t, c, pos: T.decode_step(p, t, c, pos, cfg))(
        params, tok, cache, jnp.int32(S)
    )
    assert dlogits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(dlogits, np.float32)).all()


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (guard against accidental edits)."""
    spec = {
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "mamba2-1.3b": (48, 2048, 16, 16, 0, 50280),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    }
    for arch, (L, d, H, KV, ff, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size) == (
            L, d, H, KV, ff, V,
        ), arch
        assert cfg.source, f"{arch} missing citation"
    # MoE details
    ds = get_config("deepseek-moe-16b")
    assert (ds.num_experts, ds.experts_per_token, ds.num_shared_experts) == (64, 6, 2)
    ll = get_config("llama4-scout-17b-a16e")
    assert (ll.num_experts, ll.experts_per_token) == (16, 1)
    mm = get_config("mamba2-1.3b")
    assert mm.ssm_state == 128
    rg = get_config("recurrentgemma-2b")
    assert rg.layer_pattern == ("rglru", "rglru", "local_attn")


def test_long_context_support_flags():
    """long_500k policy: native for ssm/hybrid, windowed for dense/moe,
    skipped for full-attention audio/vlm (DESIGN §Arch-applicability)."""
    from repro.configs.shapes import SHAPES, supports_shape

    long = SHAPES["long_500k"]
    native_or_windowed = [
        "mamba2-1.3b", "recurrentgemma-2b", "qwen3-1.7b", "qwen3-4b",
        "command-r-35b", "granite-20b", "deepseek-moe-16b", "llama4-scout-17b-a16e",
    ]
    skipped = ["whisper-small", "internvl2-2b"]
    for a in native_or_windowed:
        assert supports_shape(get_config(a), long), a
    for a in skipped:
        assert not supports_shape(get_config(a), long), a
