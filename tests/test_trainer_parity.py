"""Bit-for-bit parity: composed trainers vs. the pre-refactor monoliths.

The composable `DecentralizedTrainer` (repro/core/trainer.py) replaced the
monolithic ADGDA / DRDSGD / DRFA classes.  These tests pin the composed
factories to the *seed* implementations' trajectories exactly: the reference
steppers below are line-for-line copies of the seed trainers' math (git
d343f53, src/repro/core/{adgda,baselines}.py), built on the same
gossip/dro/topology primitives.

Exact (assert_array_equal) paths: single-step (momentum on/off, robust on/
off), microbatched, packed/unpacked/fused gossip, identity+mesh mixing,
DR-DSGD, DRFA.  Bit-for-bit equality is asserted under ``jax.disable_jit()``
(canonical op-by-op IEEE rounding): XLA's FMA contraction depends on the
fusion context, so two *different jitted programs* around the identical op
sequence can each legally deviate from canonical rounding by 1 ULP (verified:
the seed program itself differs from its own eager execution).  The jitted
paths are additionally pinned to ULP-level agreement with a tight allclose.

The local-steps oracle applies the dual weighting before the learning rate
(the seed multiplied (eta*g)*scale, the optimizer route is eta*(g*scale)) and
is pinned to ~ULP in both modes instead.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dro
from repro.core.adgda import ADGDAConfig, adgda_trainer
from repro.core.baselines import (
    DRDSGDConfig,
    DRFAConfig,
    choco_sgd,
    drdsgd_trainer,
    drfa_trainer,
)
from repro.core.gossip import choco_init, choco_round, mix_stacked
from repro.core.trainer import ChocoConsensus

M = 4
KEY = jax.random.PRNGKey(7)


# ===================================================================== seed refs
class SeedADGDA:
    """The seed ADGDA trainer's math, verbatim (single-step + microbatched)."""

    def __init__(self, config: ADGDAConfig, loss_fn, prior=None):
        self.config = config
        self.loss_fn = loss_fn
        self.topology, self.compressor = config.build()
        m = config.num_nodes
        self.prior = jnp.full((m,), 1.0 / m) if prior is None else jnp.asarray(prior)
        self.regularizer = dro.make_regularizer(config.regularizer)

    def _resolve_gamma(self, d: int) -> float:
        delta = getattr(self.compressor, "delta", 1.0)
        if hasattr(self.compressor, "delta_for"):
            delta = self.compressor.delta_for(max(int(d), 1))
        if self.config.gamma == "theory":
            return self.topology.consensus_step_size(max(delta, 1e-3))
        if self.config.gamma is not None:
            return float(self.config.gamma)
        return 0.5 * max(delta, 1e-3)

    def init(self, params, rng):
        m = self.config.num_nodes
        stacked = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m,) + p.shape).copy(), params)
        lam = jnp.broadcast_to(self.prior[None], (m, m)).copy()
        return dict(
            step=jnp.zeros((), jnp.int32),
            theta=stacked,
            lam=lam,
            choco=choco_init(stacked),
            momentum=jax.tree.map(jnp.zeros_like, stacked) if self.config.momentum > 0 else (),
            rng=jnp.array(rng, copy=True),
        )

    def step(self, state, batch):
        cfg = self.config
        m = cfg.num_nodes
        rng, gossip_key, *node_keys = jax.random.split(state["rng"], m + 2)
        node_keys = jnp.stack(node_keys)

        t = state["step"].astype(jnp.float32)
        eta_th = cfg.eta_theta * jnp.power(cfg.lr_decay, t)

        if cfg.robust:
            scale = (jnp.diagonal(state["lam"]) / self.prior).astype(jnp.float32)
        else:
            scale = jnp.ones((m,), jnp.float32)

        if cfg.microbatches > 1:
            k = cfg.microbatches
            acc_dt = jnp.dtype(cfg.grad_accum_dtype)

            def to_mb(leaf):
                return leaf.reshape((m, k, leaf.shape[1] // k) + leaf.shape[2:]).swapaxes(0, 1)

            mb = jax.tree.map(to_mb, batch)

            def mb_body(carry, mbatch):
                acc_l, acc_g = carry
                l, g = jax.vmap(jax.value_and_grad(self.loss_fn))(state["theta"], mbatch, node_keys)
                acc_g = jax.tree.map(lambda a, gg: a + (gg.astype(acc_dt) / k), acc_g, g)
                return (acc_l + l / k, acc_g), None

            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), state["theta"])
            (losses, grads), _ = jax.lax.scan(
                mb_body, (jnp.zeros((m,), jnp.float32), zeros_g), mb
            )
        else:
            losses, grads = jax.vmap(jax.value_and_grad(self.loss_fn))(
                state["theta"], batch, node_keys
            )

        def sgd(g, mom):
            g = g.astype(jnp.float32) * scale.reshape((m,) + (1,) * (g.ndim - 1))
            if cfg.momentum > 0:
                mom = cfg.momentum * mom + g
                g = mom
            return g, mom

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        if cfg.momentum > 0:
            flat_m = tdef.flatten_up_to(state["momentum"])
            stepped = [sgd(g, mo) for g, mo in zip(flat_g, flat_m)]
            momentum = jax.tree_util.tree_unflatten(tdef, [s[1] for s in stepped])
        else:
            stepped = [sgd(g, None) for g in flat_g]
            momentum = ()
        update = jax.tree_util.tree_unflatten(tdef, [s[0] for s in stepped])
        theta_half = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - eta_th * u).astype(p.dtype),
            state["theta"],
            update,
        )

        eta_la = cfg.eta_lambda
        if cfg.robust:
            node_ids = jnp.arange(m)
            dual_grads = jax.vmap(
                lambda f, i, l: dro.dual_gradient(
                    f, i, l, self.prior, cfg.alpha, self.regularizer
                )
            )(losses, node_ids, state["lam"])
            lam_half = jax.vmap(dro.project_simplex)(state["lam"] + eta_la * dual_grads)
            lam_new = mix_stacked(lam_half, self.topology)
        else:
            lam_new = state["lam"]

        gamma = self._resolve_gamma(ChocoConsensus._encode_dim(theta_half))
        theta_new, choco_new = choco_round(
            theta_half, state["choco"], self.topology, gamma, self.compressor,
            gossip_key, packed=cfg.packed_gossip, fused=cfg.fused_gossip,
        )
        return dict(
            step=state["step"] + 1, theta=theta_new, lam=lam_new,
            choco=choco_new, momentum=momentum, rng=rng,
        ), losses


class SeedDRDSGD:
    """The seed DRDSGD trainer's math, verbatim."""

    def __init__(self, config: DRDSGDConfig, loss_fn, prior=None):
        from repro.core.topology import make_topology

        self.config = config
        self.loss_fn = loss_fn
        self.topology = make_topology(config.topology, config.num_nodes)
        m = config.num_nodes
        self.prior = jnp.full((m,), 1.0 / m) if prior is None else jnp.asarray(prior)

    def init(self, params, rng):
        m = self.config.num_nodes
        stacked = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m,) + p.shape).copy(), params)
        return dict(
            step=jnp.zeros((), jnp.int32),
            theta=stacked,
            momentum=jax.tree.map(jnp.zeros_like, stacked),
            rng=jnp.array(rng, copy=True),
        )

    def step(self, state, batch):
        cfg = self.config
        m = cfg.num_nodes
        rng, *node_keys = jax.random.split(state["rng"], m + 1)
        node_keys = jnp.stack(node_keys)

        losses, grads = jax.vmap(jax.value_and_grad(self.loss_fn))(state["theta"], batch, node_keys)
        lam = dro.kl_closed_form_weights(losses, self.prior, cfg.alpha)
        scale = (lam / self.prior).astype(jnp.float32)

        t = state["step"].astype(jnp.float32)
        eta = cfg.eta_theta * jnp.power(cfg.lr_decay, t)

        def upd(p, g, mo):
            g = g.astype(jnp.float32) * scale.reshape((m,) + (1,) * (g.ndim - 1))
            mo = cfg.momentum * mo + g
            return (p.astype(jnp.float32) - eta * mo).astype(p.dtype), mo

        flat_p, tdef = jax.tree_util.tree_flatten(state["theta"])
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["momentum"])
        stepped = [upd(p, g, mo) for p, g, mo in zip(flat_p, flat_g, flat_m)]
        theta_half = jax.tree_util.tree_unflatten(tdef, [s[0] for s in stepped])
        momentum = jax.tree_util.tree_unflatten(tdef, [s[1] for s in stepped])

        theta_new = mix_stacked(theta_half, self.topology)
        return dict(step=state["step"] + 1, theta=theta_new, momentum=momentum, rng=rng), lam


class SeedDRFA:
    """The seed DRFA trainer's math, verbatim."""

    def __init__(self, config: DRFAConfig, loss_fn, prior=None):
        self.config = config
        self.loss_fn = loss_fn
        m = config.num_nodes
        self.prior = jnp.full((m,), 1.0 / m) if prior is None else jnp.asarray(prior)
        self.num_sampled = max(1, int(round(config.participation * m)))

    def init(self, params, rng):
        return dict(
            step=jnp.zeros((), jnp.int32),
            theta=jax.tree.map(lambda x: jnp.array(x, copy=True), params),
            lam=self.prior,
            rng=jnp.array(rng, copy=True),
        )

    def step(self, state, batch):
        cfg = self.config
        m = cfg.num_nodes
        k = self.num_sampled
        rng, sample_key, *node_keys = jax.random.split(state["rng"], m + 2)
        node_keys = jnp.stack(node_keys)

        gumbel = -jnp.log(-jnp.log(jax.random.uniform(sample_key, (m,)) + 1e-20) + 1e-20)
        scores = jnp.log(state["lam"] + 1e-20) + gumbel
        _, sampled = jax.lax.top_k(scores, k)
        mask = jnp.zeros((m,), jnp.float32).at[sampled].set(1.0)

        t = state["step"].astype(jnp.float32)
        eta = cfg.eta_theta * jnp.power(cfg.lr_decay, t)

        def local_train(theta0, client_batch, key):
            def body(theta, mb):
                loss, g = jax.value_and_grad(self.loss_fn)(theta, mb, key)
                theta = jax.tree.map(
                    lambda p, gg: (p.astype(jnp.float32) - eta * gg.astype(jnp.float32)).astype(p.dtype),
                    theta,
                    g,
                )
                return theta, loss

            theta_k, losses = jax.lax.scan(body, theta0, client_batch)
            return theta_k, losses.mean()

        theta_rep = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m,) + p.shape), state["theta"])
        theta_locals, local_losses = jax.vmap(local_train)(theta_rep, batch, node_keys)

        wsum = mask.sum()
        theta_new = jax.tree.map(
            lambda x: (
                (x.astype(jnp.float32) * mask.reshape((m,) + (1,) * (x.ndim - 1))).sum(0) / wsum
            ).astype(x.dtype),
            theta_locals,
        )

        loss_vec = local_losses * mask * (m / jnp.maximum(wsum, 1.0))
        lam_new = dro.project_simplex(state["lam"] + cfg.eta_lambda * cfg.local_steps * loss_vec)
        return dict(step=state["step"] + 1, theta=theta_new, lam=lam_new, rng=rng), local_losses


# ===================================================================== helpers
def _data(d=6, b=8, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(M, b, d)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(M, b)).astype(np.float32) + np.arange(M)[:, None])
    return {"x": x, "y": y}


def _loss(params, batch, rng):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _params(d=6):
    rng = np.random.default_rng(3)
    return {
        "w": jnp.asarray(rng.normal(size=(d,)).astype(np.float32) * 0.1),
        "b": jnp.zeros(()),
    }


def _assert_tree_equal(a, b, err=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, z in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(z), err_msg=err)


def _assert_tree_close(a, b, err="", rtol=3e-6, atol=1e-7):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, z in zip(la, lb):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(z, np.float32),
            rtol=rtol, atol=atol, err_msg=err,
        )


def _run_pair(cfg: ADGDAConfig, steps=6, factory=adgda_trainer):
    batch = _data()
    params = _params()
    seed = SeedADGDA(cfg, _loss)
    new = factory(cfg, _loss)

    # bit-for-bit under canonical op-by-op rounding
    with jax.disable_jit():
        s_old = seed.init(params, KEY)
        s_new = new.init(params, KEY)
        for t in range(steps):
            s_old, losses_old = seed.step(s_old, batch)
            s_new, aux = new.step_impl(s_new, batch)
            _assert_tree_equal(s_old["theta"], s_new.theta, f"theta diverged at round {t}")
            _assert_tree_equal(s_old["lam"], s_new.lam, f"lambda diverged at round {t}")
            _assert_tree_equal(s_old["choco"].theta_hat, s_new.consensus.theta_hat, f"hat at {t}")
            _assert_tree_equal(s_old["choco"].s, s_new.consensus.s, f"s at {t}")
            np.testing.assert_array_equal(np.asarray(losses_old), np.asarray(aux["losses"]))
            np.testing.assert_array_equal(np.asarray(s_old["rng"]), np.asarray(s_new.rng))

    # jitted: ULP-level (XLA FMA contraction varies with fusion context)
    s_old = seed.init(params, KEY)
    s_new = new.init(params, KEY)
    seed_step = jax.jit(seed.step)
    for t in range(steps):
        s_old, _ = seed_step(s_old, batch)
        s_new, _ = new.step(s_new, batch)
    _assert_tree_close(s_old["theta"], s_new.theta, "jitted theta diverged")
    _assert_tree_close(s_old["lam"], s_new.lam, "jitted lambda diverged")
    np.testing.assert_array_equal(np.asarray(s_old["rng"]), np.asarray(s_new.rng))


# ======================================================================= tests
def test_adgda_parity_packed_momentum():
    _run_pair(ADGDAConfig(num_nodes=M, topology="ring", compressor="q8b", alpha=0.05,
                          eta_theta=0.05, eta_lambda=0.05, lr_decay=0.995, momentum=0.9,
                          track_average=False))


def test_adgda_parity_unpacked():
    _run_pair(ADGDAConfig(num_nodes=M, topology="ring", compressor="q4b", alpha=0.05,
                          eta_theta=0.05, eta_lambda=0.05, packed_gossip=False,
                          track_average=False))


def test_adgda_parity_fused_gossip():
    """The fused path dispatches to the single-pass Pallas kernels, which
    cannot run op-by-op (interpret mode requires tracing), so this parity is
    jitted-vs-jitted: the round's numerics live inside the Pallas kernel
    (identical program in both trainers), asserted bit-for-bit; the
    surrounding oracle/dual ops to ULP."""
    cfg = ADGDAConfig(num_nodes=M, topology="ring", compressor="kq8b", alpha=0.05,
                      eta_theta=0.05, eta_lambda=0.05, fused_gossip=True,
                      track_average=False)
    batch, params = _data(), _params()
    seed = SeedADGDA(cfg, _loss)
    new = adgda_trainer(cfg, _loss)
    s_old = seed.init(params, KEY)
    s_new = new.init(params, KEY)
    jstep = jax.jit(seed.step)
    for t in range(6):
        s_old, _ = jstep(s_old, batch)
        s_new, _ = new.step(s_new, batch)
        _assert_tree_close(s_old["theta"], s_new.theta, f"fused theta at {t}",
                           rtol=1e-6, atol=1e-7)
        _assert_tree_close(s_old["choco"].s, s_new.consensus.s, f"fused s at {t}",
                           rtol=1e-6, atol=1e-7)
        np.testing.assert_array_equal(np.asarray(s_old["rng"]), np.asarray(s_new.rng))


def test_adgda_parity_identity_mesh():
    _run_pair(ADGDAConfig(num_nodes=M, topology="mesh", compressor="none", alpha=0.05,
                          eta_theta=0.05, eta_lambda=0.05, track_average=False))


def test_adgda_parity_microbatched():
    _run_pair(ADGDAConfig(num_nodes=M, topology="ring", compressor="q8b", alpha=0.05,
                          eta_theta=0.05, eta_lambda=0.05, microbatches=2, momentum=0.8,
                          track_average=False))


def test_choco_sgd_parity():
    cfg = ADGDAConfig(num_nodes=M, topology="ring", compressor="q4b",
                      eta_theta=0.1, lr_decay=0.99, robust=False, track_average=False)
    _run_pair(cfg, factory=lambda c, l: choco_sgd(c, l))


def test_adgda_local_steps_close():
    """The local-steps oracle reorders the (eta, grad, lam-weight) product —
    seed computed (eta*g)*scale, the optimizer route computes eta*(g*scale) —
    so this path is pinned to ~ULP-level agreement, not bit equality."""
    K = 3
    cfg = ADGDAConfig(num_nodes=M, topology="ring", compressor="q8b", alpha=0.05,
                      eta_theta=0.05, eta_lambda=0.05, local_steps=K, track_average=False)
    batch = _data(b=K * 4)
    params = _params()

    # seed local-steps reference (git d343f53): inline SGD, shared eta per round
    seed = SeedADGDA(cfg, _loss)
    new = adgda_trainer(cfg, _loss)

    def seed_step(state, batch):
        m = cfg.num_nodes
        rng, gossip_key, *node_keys = jax.random.split(state["rng"], m + 2)
        node_keys = jnp.stack(node_keys)
        t = state["step"].astype(jnp.float32)
        eta_th = cfg.eta_theta * jnp.power(cfg.lr_decay, t)
        scale = (jnp.diagonal(state["lam"]) / seed.prior).astype(jnp.float32)

        def to_k(leaf):
            return leaf.reshape((m, K, leaf.shape[1] // K) + leaf.shape[2:]).swapaxes(0, 1)

        kb = jax.tree.map(to_k, batch)

        def local_body(theta, mbatch):
            l, g = jax.vmap(jax.value_and_grad(_loss))(theta, mbatch, node_keys)
            theta = jax.tree.map(
                lambda p, gg: (
                    p.astype(jnp.float32)
                    - eta_th * gg.astype(jnp.float32) * scale.reshape((m,) + (1,) * (gg.ndim - 1))
                ).astype(p.dtype),
                theta,
                g,
            )
            return theta, l

        theta_half, losses_k = jax.lax.scan(local_body, state["theta"], kb)
        losses = losses_k.mean(0)

        node_ids = jnp.arange(m)
        dual_grads = jax.vmap(
            lambda f, i, l: dro.dual_gradient(f, i, l, seed.prior, cfg.alpha, seed.regularizer)
        )(losses, node_ids, state["lam"])
        lam_half = jax.vmap(dro.project_simplex)(state["lam"] + cfg.eta_lambda * dual_grads)
        lam_new = mix_stacked(lam_half, seed.topology)

        gamma = seed._resolve_gamma(ChocoConsensus._encode_dim(theta_half))
        theta_new, choco_new = choco_round(
            theta_half, state["choco"], seed.topology, gamma, seed.compressor,
            gossip_key, packed=cfg.packed_gossip,
        )
        return dict(step=state["step"] + 1, theta=theta_new, lam=lam_new,
                    choco=choco_new, momentum=(), rng=rng)

    s_old = seed.init(params, KEY)
    s_new = new.init(params, KEY)
    jstep = jax.jit(seed_step)
    for _ in range(12):
        s_old = jstep(s_old, batch)
        s_new, _ = new.step(s_new, batch)
    _assert_tree_close(s_old["theta"], s_new.theta, "local-steps theta diverged",
                       rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(s_old["rng"]), np.asarray(s_new.rng))


def test_drdsgd_parity():
    cfg = DRDSGDConfig(num_nodes=M, topology="ring", alpha=2.0, eta_theta=0.05,
                       lr_decay=0.99, momentum=0.9)
    batch = _data()
    params = _params()
    seed = SeedDRDSGD(cfg, _loss)
    new = drdsgd_trainer(cfg, _loss)
    with jax.disable_jit():
        s_old = seed.init(params, KEY)
        s_new = new.init(params, KEY)
        for t in range(6):
            s_old, lam_old = seed.step(s_old, batch)
            s_new, aux = new.step_impl(s_new, batch)
            _assert_tree_equal(s_old["theta"], s_new.theta, f"theta diverged at round {t}")
            np.testing.assert_array_equal(np.asarray(lam_old), np.asarray(aux["lambda_mean"]))
            np.testing.assert_array_equal(np.asarray(s_old["rng"]), np.asarray(s_new.rng))
    s_old = seed.init(params, KEY)
    s_new = new.init(params, KEY)
    jstep = jax.jit(seed.step)
    for t in range(6):
        s_old, _ = jstep(s_old, batch)
        s_new, _ = new.step(s_new, batch)
    _assert_tree_close(s_old["theta"], s_new.theta, "jitted theta diverged")


def test_drfa_parity():
    cfg = DRFAConfig(num_nodes=M, participation=0.5, local_steps=3,
                     eta_theta=0.05, eta_lambda=0.05, lr_decay=0.99)
    rng = np.random.default_rng(5)
    d = 6
    batch = {
        "x": jnp.asarray(rng.normal(size=(M, 3, 4, d)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(size=(M, 3, 4)).astype(np.float32)),
    }
    params = _params(d)
    seed = SeedDRFA(cfg, _loss)
    new = drfa_trainer(cfg, _loss)
    with jax.disable_jit():
        s_old = seed.init(params, KEY)
        s_new = new.init(params, KEY)
        for t in range(6):
            s_old, losses_old = seed.step(s_old, batch)
            s_new, aux = new.step_impl(s_new, batch)
            _assert_tree_equal(s_old["theta"], s_new.theta, f"theta diverged at round {t}")
            np.testing.assert_array_equal(np.asarray(s_old["lam"]), np.asarray(s_new.lam))
            np.testing.assert_array_equal(np.asarray(losses_old), np.asarray(aux["losses"]))
            np.testing.assert_array_equal(np.asarray(s_old["rng"]), np.asarray(s_new.rng))
    s_old = seed.init(params, KEY)
    s_new = new.init(params, KEY)
    jstep = jax.jit(seed.step)
    for t in range(6):
        s_old, _ = jstep(s_old, batch)
        s_new, _ = new.step(s_new, batch)
    _assert_tree_close(s_old["theta"], s_new.theta, "jitted theta diverged")
    np.testing.assert_array_equal(np.asarray(s_old["lam"]), np.asarray(s_new.lam))


def test_bf16_leaf_parity():
    """Mixed-precision model: bf16 leaf exercises the cast-to-f32/back path."""
    cfg = ADGDAConfig(num_nodes=M, topology="ring", compressor="q8b", alpha=0.05,
                      eta_theta=0.05, eta_lambda=0.05, momentum=0.9, track_average=False)
    batch = _data()
    params = _params()
    params["w"] = params["w"].astype(jnp.bfloat16)

    def loss(p, b, r):
        pred = b["x"] @ p["w"].astype(jnp.float32) + p["b"]
        return jnp.mean((pred - b["y"]) ** 2)

    seed = SeedADGDA(cfg, loss)
    new = adgda_trainer(cfg, loss)
    with jax.disable_jit():
        s_old = seed.init(params, KEY)
        s_new = new.init(params, KEY)
        for t in range(6):
            s_old, _ = seed.step(s_old, batch)
            s_new, _ = new.step_impl(s_new, batch)
            _assert_tree_equal(s_old["theta"], s_new.theta, f"theta diverged at round {t}")
