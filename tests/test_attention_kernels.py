"""Attention kernel suite: parity vs kernels/ref.py oracles + dispatch wiring.

Covers the three PR-10 kernels (sliding-window, block-sparse, fused decode)
plus the model-level `attn_kernel` / `quantized_kv` flags:

* mask parity across shape x dtype x window sweeps (hypothesis widens the
  sweep where available);
* BlockSparsePattern construction invariants (diagonal liveness, density,
  bitmap validation);
* decode parity: f32 kernel == ref bit-for-bit tolerance, int8 quantized-KV
  within documented tolerance of f32, and quantized_kv=False decode
  bit-identical to the pre-kernel XLA path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.block_sparse import BlockSparsePattern, block_sparse_attention_pallas
from repro.kernels.decode import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import (
    block_sparse_attention_ref,
    decode_attention_ref,
    flash_attention_ref,
    quantize_kv_ref,
)
from repro.kernels.sliding_window import sliding_window_attention_pallas
from repro.models.config import ModelConfig

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _qkv(key, bh, s, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return tuple(jax.random.normal(k, (bh, s, hd), jnp.float32).astype(dtype) for k in ks)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=1e-4)


# ------------------------------------------------------------ sliding window
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "s,hd,window,bq,bk",
    [
        (256, 64, 64, 128, 128),
        (512, 64, 128, 128, 128),
        (256, 32, 17, 64, 128),   # window unaligned to blocks
        (384, 64, 300, 128, 64),  # window wider than most of the band
        (256, 64, 1, 128, 128),   # degenerate: self-only
    ],
)
def test_sliding_window_parity(s, hd, window, bq, bk, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(s * window), 2, s, hd, dtype)
    out = sliding_window_attention_pallas(
        q, k, v, window=window, block_q=bq, block_k=bk, interpret=True
    )
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_sliding_window_matches_masked_flash():
    """The kernel and the mask-only flash baseline agree — same math, the
    sliding-window kernel just never loads out-of-band blocks."""
    q, k, v = _qkv(jax.random.PRNGKey(7), 2, 512, 64, jnp.float32)
    fast = sliding_window_attention_pallas(q, k, v, window=96, interpret=True)
    slow = flash_attention_pallas(
        q, k, v, causal=True, window=96, interpret=True, skip_blocks=False
    )
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow), atol=2e-5, rtol=1e-4)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        s_blocks=st.integers(2, 6),
        window=st.integers(1, 400),
        hd=st.sampled_from([32, 64]),
    )
    def test_sliding_window_parity_hypothesis(s_blocks, window, hd):
        s = 64 * s_blocks
        q, k, v = _qkv(jax.random.PRNGKey(s * 1000 + window), 1, s, hd)
        out = sliding_window_attention_pallas(
            q, k, v, window=window, block_q=64, block_k=64, interpret=True
        )
        ref = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


# -------------------------------------------------------------- block sparse
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "make",
    [
        lambda s: BlockSparsePattern.causal_pattern(s, s, 64, 64),
        lambda s: BlockSparsePattern.windowed(s, s, 100, 64, 64),
        lambda s: BlockSparsePattern.strided(s, s, local_blocks=2, stride=3, block_q=64, block_k=64),
    ],
    ids=["causal", "windowed", "strided"],
)
def test_block_sparse_parity(make, dtype):
    s = 384
    pattern = make(s)
    q, k, v = _qkv(jax.random.PRNGKey(11), 2, s, 64, dtype)
    out = block_sparse_attention_pallas(q, k, v, pattern, interpret=True)
    ref = block_sparse_attention_ref(q, k, v, pattern)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_block_sparse_causal_equals_flash():
    s = 256
    pattern = BlockSparsePattern.causal_pattern(s, s, 128, 128)
    q, k, v = _qkv(jax.random.PRNGKey(13), 2, s, 64)
    out = block_sparse_attention_pallas(q, k, v, pattern, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_block_sparse_pattern_invariants():
    p = BlockSparsePattern.windowed(512, 512, 100, 64, 64)
    assert 0.0 < p.density() < 1.0
    # every q block keeps its diagonal block live
    nq = 512 // 64
    for i in range(nq):
        assert p.bitmap[i, min(((i + 1) * 64 - 1) // 64, nq - 1)] != 0
    # strided density drops monotonically with stride
    d3 = BlockSparsePattern.strided(512, 512, local_blocks=1, stride=3, block_q=64, block_k=64).density()
    d5 = BlockSparsePattern.strided(512, 512, local_blocks=1, stride=5, block_q=64, block_k=64).density()
    assert d5 < d3

    with pytest.raises(ValueError):  # dead diagonal
        bad = np.zeros((4, 4), np.int32)
        BlockSparsePattern.from_bitmap(bad, block_q=64, block_k=64)
    with pytest.raises(ValueError):  # live where causal fully masks
        bad = np.full((4, 4), 2, np.int32)
        BlockSparsePattern.from_bitmap(bad, block_q=64, block_k=64)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        s_blocks=st.integers(2, 5),
        local=st.integers(1, 3),
        stride=st.integers(2, 4),
    )
    def test_block_sparse_strided_hypothesis(s_blocks, local, stride):
        s = 64 * s_blocks
        pattern = BlockSparsePattern.strided(
            s, s, local_blocks=local, stride=stride, block_q=64, block_k=64
        )
        q, k, v = _qkv(jax.random.PRNGKey(s + 17 * local + stride), 1, s, 32)
        out = block_sparse_attention_pallas(q, k, v, pattern, interpret=True)
        ref = block_sparse_attention_ref(q, k, v, pattern)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


# -------------------------------------------------------------------- decode
@pytest.mark.parametrize("kv,g", [(4, 1), (2, 2), (1, 4)])
@pytest.mark.parametrize("filled", ["partial", "full"])
def test_decode_f32_parity(kv, g, filled):
    B, hd, L = 2, 64, 512
    ks = jax.random.split(jax.random.PRNGKey(kv * 10 + g), 3)
    q = jax.random.normal(ks[0], (B, kv, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, kv, hd), jnp.float32)
    n = L if filled == "full" else 300
    valid = jnp.arange(L)[None, :] < jnp.array([[n], [max(n - 100, 1)]])
    out = decode_attention_pallas(q, k, v, valid, block_l=128, interpret=True)
    ref = decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_decode_quantized_parity_and_tolerance():
    B, KV, G, hd, L = 2, 2, 2, 64, 1024
    ks = jax.random.split(jax.random.PRNGKey(21), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, KV, hd), jnp.float32)
    valid = jnp.arange(L)[None, :] < jnp.array([[700], [L]])
    kq, ksc = quantize_kv_ref(k)
    vq, vsc = quantize_kv_ref(v)
    out = decode_attention_pallas(
        q, kq, vq, valid, k_scale=ksc, v_scale=vsc, block_l=256, interpret=True
    )
    ref = decode_attention_ref(q, kq, vq, valid, k_scale=ksc, v_scale=vsc)
    # kernel vs fused-dequant oracle: exact math parity
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)
    # quantized vs f32 decode: documented tolerance (int8 symmetric per
    # (slot, kv-head) quantization holds attention outputs within ~2e-2)
    f32 = decode_attention_ref(q, k, v, valid)
    assert float(jnp.abs(out - f32).max()) < 2e-2


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 2, 32))
    qv, sc = quantize_kv_ref(x)
    assert qv.dtype == jnp.int8 and sc.shape == x.shape[:-1]
    deq = qv.astype(jnp.float32) * sc[..., None]
    assert float(jnp.abs(deq - x).max()) <= float(sc.max()) * 0.5 + 1e-6
    # all-zero rows survive exactly
    z, zs = quantize_kv_ref(jnp.zeros((2, 3, 1, 8)))
    assert not z.any() and not zs.any()


# ---------------------------------------------------- model-level dispatch
def _smoke_cfg(**kw):
    return ModelConfig(
        name="ak", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32", **kw,
    )


def _greedy_run(params, cfg, tokens, steps=5, cache_len=40):
    from repro.models import transformer as T

    logits, cache = T.prefill(params, {"tokens": tokens}, cfg, cache_len=cache_len)
    outs = [logits[:, -1]]
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    S = tokens.shape[1]
    for i in range(steps):
        lg, cache = T.decode_step(params, tok, cache, S + i, cfg)
        outs.append(lg[:, -1])
        tok = jnp.argmax(lg[:, -1], -1)[:, None]
    return jnp.stack(outs)


def test_flags_off_bit_identical():
    """attn_kernel=None + quantized_kv=False is the exact pre-kernel path."""
    from repro.models import transformer as T

    cfg = _smoke_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 97)
    a = _greedy_run(params, cfg, tokens)
    b = _greedy_run(params, cfg, tokens)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    cache = T.init_cache(cfg, 2, 40)
    flat = jax.tree_util.tree_leaves(cache)
    assert all(leaf.dtype != jnp.int8 for leaf in flat)


@pytest.mark.parametrize("kernel", ["flash", "block_sparse"])
def test_attn_kernel_flag_close_to_baseline(kernel):
    from repro.models import transformer as T

    cfg = _smoke_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 97)
    ref = _greedy_run(params, cfg, tokens)
    out = _greedy_run(params, dataclasses.replace(cfg, attn_kernel=kernel), tokens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=1e-3)


def test_quantized_kv_flag_end_to_end():
    from repro.models import transformer as T

    cfg = _smoke_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 97)
    ref = _greedy_run(params, cfg, tokens)
    qcfg = dataclasses.replace(cfg, quantized_kv=True)
    cache = T.init_cache(qcfg, 2, 40)
    kinds = {leaf.dtype for leaf in jax.tree_util.tree_leaves(cache)}
    assert np.dtype("int8") in kinds  # cache really is quantized
    out = _greedy_run(params, qcfg, tokens)
    assert float(jnp.abs(out - ref).max()) < 0.15


def test_windowed_arch_all_flags():
    from repro.models import transformer as T

    cfg = _smoke_cfg(sliding_window=8, layer_pattern=("attn", "local_attn"))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 97)
    ref = _greedy_run(params, cfg, tokens)
    for kw, tol in [
        (dict(attn_kernel="flash"), 2e-3),
        (dict(attn_kernel="block_sparse"), 2e-3),
        (dict(quantized_kv=True), 0.15),
    ]:
        out = _greedy_run(params, dataclasses.replace(cfg, **kw), tokens)
        assert float(jnp.abs(out - ref).max()) < tol, kw


def test_ops_wrappers_model_layout():
    """[B, S, H, hd]-layout wrappers agree with the folded refs, including
    pad/unpad for non-block-multiple sequence lengths."""
    B, S, H, hd = 2, 200, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd), jnp.float32) for kk in ks)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    ref = flash_attention_ref(fold(q), fold(k), fold(v), causal=True, window=50)
    out = ops.sliding_window_attention(q, k, v, window=50)
    np.testing.assert_allclose(
        np.asarray(fold(out)), np.asarray(ref), atol=2e-5, rtol=1e-4
    )

    # decode wrapper: grouped heads vs repeat_kv reference
    KV, G, L = 2, 2, 256
    kd = jax.random.normal(ks[0], (B, L, KV, hd), jnp.float32)
    vd = jax.random.normal(ks[1], (B, L, KV, hd), jnp.float32)
    qd = jax.random.normal(ks[2], (B, 1, KV * G, hd), jnp.float32)
    valid = jnp.arange(L)[None, :] < 200
    valid = jnp.broadcast_to(valid, (B, L))
    out = ops.decode_attention_kernel(qd, kd, vd, valid, impl="pallas")
    ref = decode_attention_ref(qd.reshape(B, KV, G, hd), kd, vd, valid).reshape(B, 1, KV * G, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)
    # the xla_fused impl is the same math without Pallas
    out2 = ops.decode_attention_kernel(qd, kd, vd, valid, impl="xla_fused")
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref), atol=2e-5, rtol=1e-4)
