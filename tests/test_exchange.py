"""SPMD neighbor-exchange backend (core/exchange.py).

Two layers of coverage:

* in-process tests on the default single-device mesh — the backend's code
  path is identical (shard_map with a trivial node axis; shifts degenerate
  to local rolls), so parity, dispatch and error contracts are exercised in
  the tier-1 suite without touching the global jax device count;
* the real multi-device parity grid lives in
  ``tests/exchange_parity_main.py`` and must run in a SUBPROCESS because
  ``--xla_force_host_platform_device_count`` is locked in at jax init —
  ``test_multi_device_parity_grid`` spawns it on 4 forced host devices.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import gossip, topology
from repro.core.compression import Identity, RandomQuantization
from repro.core.exchange import mix_stacked_ppermute, node_mesh_info
from repro.core.topology import compile_schedule_plans
from repro.core.trainer import ChocoConsensus
from repro.core.wire import compile_union_wire
from repro.kernels.ops import KernelQuantization
from repro.launch.mesh import make_cpu_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh1():
    return make_cpu_mesh(1, 1)


def _worst(a, b):
    return max(
        float(np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


@pytest.mark.parametrize(
    "comp,exact",
    [
        (Identity(), False),
        (RandomQuantization(bits=4), False),
        (KernelQuantization(bits=4), True),
    ],
    ids=["identity", "q4b", "kq4b"],
)
def test_single_device_parity(comp, exact):
    """Same backend code path on a (1, 1) mesh — tier-1-cheap parity."""
    mesh = _mesh1()
    topo = topology.ring(4)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 96))}
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(7)
    a = jax.jit(lambda t, s: gossip.choco_round(t, s, topo, 0.3, comp, k))(theta, state)
    b = jax.jit(
        lambda t, s: gossip.choco_round(
            t, s, topo, 0.3, comp, k, backend="ppermute", mesh=mesh
        )
    )(theta, state)
    worst = _worst(a, b)
    assert worst == 0.0 if exact else worst < 2e-6


def test_single_device_masked_schedule_parity():
    mesh = _mesh1()
    sched = topology.make_topology_schedule("roundrobin:ring,torus", 8)
    union = compile_union_wire(compile_schedule_plans(sched))
    topo0 = sched.topology_at(0)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 64))}
    state = gossip.choco_init(theta, cache_ops=union.n_ops)
    mask = jnp.array([1, 1, 0, 1, 1, 1, 0, 1], jnp.float32)
    comp = RandomQuantization(bits=4)
    k = jax.random.PRNGKey(3)
    step = jnp.int32(1)
    a = gossip.choco_round(
        theta, state, topo0, 0.25, comp, k,
        mixing=sched.mixing_at(step, mask), mask=mask,
    )
    b = gossip.choco_round(
        theta, state, topo0, 0.25, comp, k, mask=mask,
        backend="ppermute", mesh=mesh, schedule=sched, step=step,
    )
    # theta / hat / s agree with the rolled memory-full oracle (the oracle
    # has no NeighborCache — compare the shared fields only)
    a_cmp = (a[0], a[1].theta_hat, a[1].s)
    b_cmp = (b[0], b[1].theta_hat, b[1].s)
    assert _worst(a_cmp, b_cmp) < 2e-6


def test_time_varying_requires_cache():
    """A time-varying ppermute round without the NeighborCache is rejected
    (silently zero-initializing mid-run would break the mirror invariant)."""
    mesh = _mesh1()
    sched = topology.make_topology_schedule("roundrobin:ring,torus", 8)
    theta = {"w": jnp.zeros((8, 16))}
    state = gossip.choco_init(theta)  # no cache_ops
    with pytest.raises(ValueError, match="NeighborCache"):
        gossip.choco_round(
            theta, state, sched.topology_at(0), 0.25, Identity(),
            jax.random.PRNGKey(0), backend="ppermute", mesh=mesh,
            schedule=sched, step=jnp.int32(0),
        )


def test_wire_mix_matches_mix_stacked():
    mesh = _mesh1()
    topo = topology.ring(6)
    lam = jax.random.normal(jax.random.PRNGKey(2), (6, 6))
    a = gossip.mix_stacked(lam, topo)
    b = mix_stacked_ppermute(lam, topo, mesh=mesh)
    assert _worst(a, b) == 0.0


def test_backend_dispatch_contracts():
    topo = topology.ring(4)
    theta = {"w": jnp.zeros((4, 8))}
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="requires a mesh"):
        gossip.choco_round(theta, state, topo, 0.3, Identity(), k, backend="ppermute")
    with pytest.raises(ValueError, match="unknown gossip backend"):
        gossip.choco_round(theta, state, topo, 0.3, Identity(), k, backend="nope")
    with pytest.raises(ValueError, match="schedule/step/mask"):
        gossip.choco_round(
            theta, state, topo, 0.3, Identity(), k,
            mixing=jnp.eye(4), backend="ppermute", mesh=_mesh1(),
        )
    with pytest.raises(ValueError, match="requires a mesh"):
        ChocoConsensus(topo, Identity(), backend="ppermute")
    with pytest.raises(ValueError, match="unknown gossip backend"):
        ChocoConsensus(topo, Identity(), backend="nope")


def test_node_mesh_info_divisibility():
    mesh = _mesh1()
    axes, ndev, block = node_mesh_info(mesh, "data", 6)
    assert axes == ("data",) and ndev == 1 and block == 6
    with pytest.raises(ValueError, match="no axes"):
        node_mesh_info(mesh, ("pod",), 4)


def test_irregular_single_device_parity():
    """A single-device mesh has no wire: irregular graphs run their EdgeStep
    permutes locally (the uneven-ratio rejection only applies across real
    devices — that error is exercised in exchange_parity_main.py)."""
    mesh = _mesh1()
    er = topology.erdos_renyi(4, 0.6, seed=0)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(4), (4, 64))}
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(0)
    a = gossip.choco_round(theta, state, er, 0.3, RandomQuantization(bits=4), k)
    b = gossip.choco_round(
        theta, state, er, 0.3, RandomQuantization(bits=4), k,
        backend="ppermute", mesh=mesh,
    )
    assert _worst(a, b) < 2e-6


@pytest.mark.parametrize("quick", [True], ids=["quick"])
def test_multi_device_parity_grid(quick):
    """The acceptance grid on 4 forced host devices (subprocess: the device
    count is locked at jax init).  ~2-4 min of shard_map compiles."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(REPO, "src"), env.get("PYTHONPATH")] if p
    )
    cmd = [sys.executable, os.path.join(REPO, "tests", "exchange_parity_main.py")]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        pytest.fail(
            f"parity grid failed (rc={proc.returncode}):\n"
            f"{proc.stdout[-4000:]}\n{proc.stderr[-4000:]}"
        )
    assert "ALL" in proc.stdout and "PARITY CHECKS PASSED" in proc.stdout
