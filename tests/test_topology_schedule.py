"""Time-varying, fault-tolerant consensus: TopologySchedule + dropout masks.

Invariants under test:
  * every phase of every schedule is symmetric doubly stochastic (Assumption
    3.1 round-wise), including the Metropolis rescale on an arbitrary
    surviving subgraph;
  * a static schedule with no dropout is *bit-identical* to the plain
    Topology fast paths (packed / unpacked / fused dispatch);
  * dropped nodes skip their local update and gossip contribution but keep
    their CHOCO trackers frozen, so they can rejoin consistently;
  * the erdos_renyi factory is reachable through make_topology (regression:
    it was implemented but unregistered).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gossip
from repro.core import topology as topo
from repro.core.adgda import ADGDAConfig, adgda_trainer
from repro.core.compression import RandomQuantization

KEY = jax.random.PRNGKey(0)


def _assert_doubly_stochastic(w, atol=1e-6):
    w = np.asarray(w)
    np.testing.assert_allclose(w, w.T, atol=atol)
    np.testing.assert_allclose(w.sum(0), 1.0, atol=atol)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=atol)
    assert (w >= -atol).all()


# ------------------------------------------------------------- construction
def test_erdos_renyi_reachable_through_factory():
    t = topo.make_topology("erdos_renyi", 12, p=0.4, seed=3)
    assert t.name == "erdos_renyi" and t.num_nodes == 12
    _assert_doubly_stochastic(t.mixing, atol=1e-12)
    # default p works without kwargs (the CLI path)
    assert topo.make_topology("erdos_renyi", 8).num_nodes == 8


@pytest.mark.parametrize(
    "spec", ["ring", "roundrobin:ring,torus,mesh", "matching", "matching:3"]
)
def test_schedule_phases_doubly_stochastic(spec):
    s = topo.make_topology_schedule(spec, 12, seed=1)
    for t in range(2 * s.period):
        _assert_doubly_stochastic(s.mixing_at(jnp.int32(t)))
        # host-side view agrees with the traced bank
        np.testing.assert_allclose(
            np.asarray(s.mixing_at(jnp.int32(t))), s.topology_at(t).mixing, atol=1e-6
        )


def test_static_schedule_unwraps_and_is_static():
    s = topo.make_topology_schedule("ring", 8)
    assert s.is_static and s.period == 1 and s.dropout_rate == 0.0
    assert not topo.make_topology_schedule("ring", 8, dropout=0.2).is_static
    assert not topo.make_topology_schedule("roundrobin:ring,torus", 16).is_static


def test_matching_schedule_is_one_peer():
    s = topo.make_topology_schedule("matching:5", 10, seed=0)
    assert s.max_degree == 1
    for phase in s.topologies:
        deg = (phase.adjacency - np.eye(10)).sum(1)
        assert deg.max() <= 1


def test_worst_phase_analysis():
    s = topo.make_topology_schedule("roundrobin:ring,mesh", 16)
    assert s.spectral_gap == pytest.approx(topo.ring(16).spectral_gap)
    assert s.max_degree == topo.mesh(16).max_degree
    assert s.consensus_step_size(0.5) == pytest.approx(
        topo.ring(16).consensus_step_size(0.5)
    )


def test_matching_theory_gamma_positive():
    """Regression: every single-matching phase is disconnected (gap 0), so
    the worst-phase Theorem 4.1 gamma would silently be 0 and consensus
    would never move; the schedule must fall back to the period-mean W."""
    s = topo.make_topology_schedule("matching:8", 10, seed=0)
    g = s.consensus_step_size(0.25)
    assert 0.0 < g <= 1.0
    # a schedule that never connects has no theory gamma at all
    frozen = topo.TopologySchedule(
        [topo.Topology("frozen", np.eye(4), np.eye(4), None)] * 2
    )
    with pytest.raises(ValueError, match="never connects"):
        frozen.consensus_step_size(0.25)


def test_mask_without_mixing_uses_masked_metropolis():
    """Regression: choco_round(mask=...) with no explicit mixing must not
    fall back to the full static weights — dead nodes would keep full-weight
    influence on their neighbors.  The backfill must be the Metropolis
    rescale on the surviving subgraph (identity rows for the dead)."""
    m = 8
    ring = topo.ring(m)
    comp = RandomQuantization(bits=4)
    theta = {"w": jax.random.normal(KEY, (m, 32))}
    state = gossip.choco_init(theta)
    mask = jnp.asarray([1, 1, 0, 1, 0, 1, 1, 1], jnp.float32)
    t_a, s_a = gossip.choco_round(theta, state, ring, 0.3, comp, KEY, mask=mask)
    t_b, s_b = gossip.choco_round(
        theta, state, ring, 0.3, comp, KEY,
        mixing=topo.masked_metropolis(ring.adjacency, mask), mask=mask,
    )
    for a, b in zip(
        jax.tree_util.tree_leaves((t_a, s_a)), jax.tree_util.tree_leaves((t_b, s_b))
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------ dropout masks
def test_masked_metropolis_doubly_stochastic_any_mask():
    t = topo.erdos_renyi(10, 0.4, seed=7)
    for i, mask in enumerate(
        [np.ones(10), np.zeros(10), (np.arange(10) % 2).astype(float)]
    ):
        w = topo.masked_metropolis(t.adjacency, jnp.asarray(mask))
        _assert_doubly_stochastic(w)
        dead = mask == 0
        wd = np.asarray(w)
        # dead nodes degenerate to the identity row/column
        assert np.allclose(wd[dead].sum(1), 1.0)
        assert np.allclose(np.diag(wd)[dead], 1.0), i


def test_bernoulli_dropout_mask_and_rescale():
    s = topo.make_topology_schedule("ring", 8, dropout=0.4)
    mask = s.mask_at(jax.random.PRNGKey(3), jnp.int32(0))
    assert mask.shape == (8,) and set(np.unique(np.asarray(mask))) <= {0.0, 1.0}
    _assert_doubly_stochastic(s.mixing_at(jnp.int32(0), mask))
    # all-alive mask reproduces plain Metropolis == the base ring weights
    np.testing.assert_allclose(
        np.asarray(s.mixing_at(jnp.int32(0), jnp.ones(8))),
        topo.ring(8).mixing,
        atol=1e-6,
    )


# ------------------------------------- masked CHOCO round: freeze + rejoin
def test_dropped_nodes_frozen_and_rejoin_consistent():
    m = 8
    sched = topo.make_topology_schedule("ring", m, dropout=0.5)
    comp = RandomQuantization(bits=4)
    theta = {"w": jax.random.normal(KEY, (m, 32)), "b": jax.random.normal(KEY, (m,))}
    state = gossip.choco_init(theta)
    ring = sched.topology_at(0)

    mask = jnp.asarray([1, 1, 0, 1, 0, 1, 1, 1], jnp.float32)
    dead = np.asarray(mask) == 0
    mixing = sched.mixing_at(jnp.int32(0), mask)
    t1, s1 = gossip.choco_round(
        theta, state, ring, 0.3, comp, KEY, mixing=mixing, mask=mask
    )
    for old, new in zip(jax.tree_util.tree_leaves(theta), jax.tree_util.tree_leaves(t1)):
        assert np.array_equal(np.asarray(new)[dead], np.asarray(old)[dead])
    for leaf in jax.tree_util.tree_leaves((s1.theta_hat, s1.s)):
        assert np.array_equal(np.asarray(leaf)[dead], np.zeros_like(np.asarray(leaf)[dead]))

    # rejoin: everyone alive next round — the round must still preserve the
    # global average of theta (CHOCO invariant) and contract consensus
    all_alive = jnp.ones((m,), jnp.float32)
    t, s = t1, s1
    mean0 = np.asarray(t["w"]).mean(0)
    for i in range(250):
        t, s = gossip.choco_round(
            t, s, ring, 0.3, comp, jax.random.PRNGKey(i),
            mixing=sched.mixing_at(jnp.int32(i), all_alive), mask=all_alive,
        )
    np.testing.assert_allclose(np.asarray(t["w"]).mean(0), mean0, atol=1e-4)
    var0 = ((np.asarray(t1["w"]) - np.asarray(t1["w"]).mean(0)) ** 2).sum()
    var = ((np.asarray(t["w"]) - np.asarray(t["w"]).mean(0)) ** 2).sum()
    assert var < 0.05 * var0


def test_masked_round_tracker_identity():
    """Alive nodes' s must equal the true neighbor tracker
    sum_j w_ij(t) theta_hat_j(t) after the round (memory-full CHOCO form);
    gamma=0 leaves theta itself untouched."""
    m = 6
    sched = topo.make_topology_schedule("ring", m, dropout=0.3)
    theta = {"w": jax.random.normal(KEY, (m, 16))}
    state = gossip.choco_init(theta)
    comp = RandomQuantization(bits=8)
    mask = jnp.asarray([1, 0, 1, 1, 0, 1], jnp.float32)
    mixing = sched.mixing_at(jnp.int32(0), mask)
    t1, s1 = gossip.choco_round(
        theta, state, sched.topology_at(0), 0.0, comp, KEY, mixing=mixing, mask=mask
    )
    # gamma=0: no averaging step, so theta is untouched and only hat/s move
    np.testing.assert_array_equal(np.asarray(t1["w"]), np.asarray(theta["w"]))
    alive = np.asarray(mask) == 1
    tracker = np.asarray(mixing) @ np.asarray(s1.theta_hat["w"])
    np.testing.assert_allclose(
        np.asarray(s1.s["w"])[alive], tracker[alive], atol=1e-5
    )


# ----------------------------------------------- trainer-level integration
def _toy_loss(params, batch, rng):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - y) ** 2)


def _toy_batch(m, key, n=8, d=4):
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (m, n, d))
    y = jax.random.normal(ky, (m, n))
    return (x, y)


def _toy_params(d=4):
    return {"w": jnp.zeros((d,)), "b": jnp.zeros(())}


def _run(cfg_kwargs, steps=4, m=6, seed=0):
    cfg = ADGDAConfig(num_nodes=m, compressor="q4b", eta_theta=0.1, **cfg_kwargs)
    trainer = adgda_trainer(cfg, _toy_loss)
    state = trainer.init(_toy_params(), jax.random.PRNGKey(seed))
    auxes = []
    with jax.disable_jit():
        for t in range(steps):
            state, aux = trainer.step_impl(state, _toy_batch(m, jax.random.PRNGKey(100 + t)))
            auxes.append(aux)
    return state, auxes


def test_static_schedule_bit_identical_to_plain_topology():
    """dropout=0 + static schedule must take the exact pre-schedule code path."""
    s_plain, _ = _run({"topology": "ring"})
    s_sched, _ = _run({"topology": "ring", "topology_schedule": "ring"})
    for a, b in zip(jax.tree_util.tree_leaves(s_plain), jax.tree_util.tree_leaves(s_sched)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_static_schedule_bit_identical_packed_and_unpacked():
    for packed in (True, False):
        s_plain, _ = _run({"topology": "ring", "packed_gossip": packed})
        s_sched, _ = _run(
            {"topology": "ring", "topology_schedule": "ring", "packed_gossip": packed}
        )
        for a, b in zip(
            jax.tree_util.tree_leaves(s_plain), jax.tree_util.tree_leaves(s_sched)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_static_schedule_bit_identical_fused():
    """The fused Pallas dispatch must also be untouched by a static schedule
    (jitted-vs-jitted: the fused kernel can't run op-by-op in interpret
    mode, so compare the two jitted programs — identical trainers compile to
    identical programs)."""
    def run_jitted(cfg_kwargs, steps=2, m=6, seed=0):
        cfg = ADGDAConfig(
            num_nodes=m, compressor="kq4b", fused_gossip=True, eta_theta=0.1,
            **cfg_kwargs,
        )
        trainer = adgda_trainer(cfg, _toy_loss)
        state = trainer.init(_toy_params(), jax.random.PRNGKey(seed))
        for t in range(steps):
            state, _ = trainer.step(state, _toy_batch(m, jax.random.PRNGKey(100 + t)))
        return state

    s_plain = run_jitted({"topology": "ring"})
    s_sched = run_jitted({"topology": "ring", "topology_schedule": "ring"})
    for a, b in zip(
        jax.tree_util.tree_leaves(s_plain), jax.tree_util.tree_leaves(s_sched)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dropout_trainer_freezes_dropped_nodes():
    m = 6
    cfg = ADGDAConfig(
        num_nodes=m, topology="ring", dropout=0.5, compressor="q4b",
        eta_theta=0.1, momentum=0.9,
    )
    trainer = adgda_trainer(cfg, _toy_loss)
    assert trainer.schedule is not None and trainer.schedule.dropout_rate == 0.5
    state = trainer.init(_toy_params(), jax.random.PRNGKey(0))
    with jax.disable_jit():
        for t in range(6):
            prev = state
            state, aux = trainer.step_impl(state, _toy_batch(m, jax.random.PRNGKey(t)))
            mask = np.asarray(aux["participation"])
            dead = mask == 0
            if not dead.any():
                continue
            # dropped nodes: theta, optimizer momentum, CHOCO trackers frozen
            for old, new in zip(
                jax.tree_util.tree_leaves(
                    (prev.theta, prev.opt.mu, prev.consensus)
                ),
                jax.tree_util.tree_leaves(
                    (state.theta, state.opt.mu, state.consensus)
                ),
            ):
                o, n = np.asarray(old), np.asarray(new)
                if o.ndim >= 1 and o.shape[0] == m:
                    assert np.array_equal(n[dead], o[dead])


def test_roundrobin_trainer_converges_consensus():
    m = 8
    cfg = ADGDAConfig(
        num_nodes=m, topology_schedule="roundrobin:ring,torus",
        compressor="q8b", eta_theta=0.0, robust=False,
    )
    trainer = adgda_trainer(cfg, _toy_loss)
    params = {"w": jnp.ones((4,)), "b": jnp.ones(())}
    state = trainer.init(params, jax.random.PRNGKey(0))
    # diverge the replicas, then let the schedule gossip them back together
    theta = jax.tree.map(
        lambda x: x + jax.random.normal(jax.random.PRNGKey(1), x.shape), state.theta
    )
    state = state._replace(theta=theta)
    err0 = None
    with jax.disable_jit():
        for t in range(120):
            state, aux = trainer.step_impl(state, _toy_batch(m, jax.random.PRNGKey(t)))
            if err0 is None:
                err0 = float(aux["consensus_err"])
    assert float(aux["consensus_err"]) < 0.05 * err0


def test_exact_consensus_accepts_schedule():
    from repro.core.trainer import ExactConsensus

    sched = topo.make_topology_schedule("roundrobin:ring,mesh", 6)
    cons = ExactConsensus(sched)
    x = {"w": jax.random.normal(KEY, (6, 5))}
    out0, _ = cons.mix(x, (), None, None, step=jnp.int32(0))
    out1, _ = cons.mix(x, (), None, None, step=jnp.int32(1))  # mesh phase
    np.testing.assert_allclose(
        np.asarray(out1["w"]), np.tile(np.asarray(x["w"]).mean(0), (6, 1)), atol=1e-5
    )
    assert not np.allclose(np.asarray(out0["w"]), np.asarray(out1["w"]))


def test_dropout_run_is_deterministic_given_seed():
    """The mask stream comes from the trainer rng — same seed, same run."""
    a, auxa = _run({"topology": "ring", "dropout": 0.3}, steps=5)
    b, auxb = _run({"topology": "ring", "dropout": 0.3}, steps=5)
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(
        np.asarray(auxa[-1]["participation"]), np.asarray(auxb[-1]["participation"])
    )
