"""CHOCO-GOSSIP: average preservation, consensus convergence, packed == dense."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gossip, topology
from repro.core.compression import BlockTopK, Identity, RandomQuantization, TopK

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("topo", [topology.ring(8), topology.torus_2d(16), topology.mesh(6)])
def test_mix_preserves_average(topo):
    x = jax.random.normal(KEY, (topo.num_nodes, 33))
    mixed = gossip.mix_stacked(x, topo)
    np.testing.assert_allclose(np.asarray(mixed.mean(0)), np.asarray(x.mean(0)), atol=1e-5)


@pytest.mark.parametrize("topo", [topology.ring(8), topology.star(8), topology.erdos_renyi(8, 0.5)])
def test_repeated_mixing_reaches_consensus(topo):
    x = jax.random.normal(KEY, (topo.num_nodes, 5))
    target = x.mean(0)
    for _ in range(400):
        x = gossip.mix_stacked(x, topo)
    np.testing.assert_allclose(np.asarray(x), np.tile(np.asarray(target), (topo.num_nodes, 1)), atol=1e-4)


def test_mix_matches_matrix_product():
    topo = topology.ring(10)
    x = jax.random.normal(KEY, (10, 7))
    mixed = gossip.mix_stacked(x, topo)
    ref = topo.mixing @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(mixed), ref, atol=1e-5)


@pytest.mark.parametrize(
    "comp",
    [Identity(), RandomQuantization(bits=8), TopK(fraction=0.5), BlockTopK(fraction=0.5, block=64)],
    ids=["identity", "q8b", "top50", "btop50"],
)
def test_choco_preserves_global_average_of_private_plus_errors(comp):
    """CHOCO invariant: mean(theta) is preserved by the gossip round."""
    topo = topology.ring(8)
    theta = {"w": jax.random.normal(KEY, (8, 64)), "b": jax.random.normal(KEY, (8, 3))}
    state = gossip.choco_init(theta)
    mean0 = jax.tree.map(lambda x: x.mean(0), theta)
    t, s = theta, state
    for i in range(5):
        t, s = gossip.choco_round(t, s, topo, gamma=0.3, compressor=comp, key=jax.random.PRNGKey(i))
    mean5 = jax.tree.map(lambda x: x.mean(0), t)
    for a, b in zip(jax.tree_util.tree_leaves(mean0), jax.tree_util.tree_leaves(mean5)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize(
    "comp",
    [RandomQuantization(bits=6), BlockTopK(fraction=0.5, block=64)],
    ids=["q6b", "btop50"],
)
def test_choco_converges_to_consensus(comp):
    topo = topology.ring(6)
    theta = {"w": jax.random.normal(KEY, (6, 128))}
    # theory gamma (Thm 4.1) is very conservative; the paper grid-searches
    # gamma in practice (§5.1.1) — use a practical value here.
    delta = comp.delta_for(128) if hasattr(comp, "delta_for") else comp.delta
    gamma = 0.4 * delta
    state = gossip.choco_init(theta)
    t, s = theta, state

    def consensus_err(tree):
        return sum(
            float(jnp.sum((l - l.mean(0, keepdims=True)) ** 2))
            for l in jax.tree_util.tree_leaves(tree)
        )

    err0 = consensus_err(t)
    for i in range(300):
        t, s = gossip.choco_round(t, s, topo, gamma, comp, jax.random.PRNGKey(i))
    assert consensus_err(t) < 1e-3 * err0


@pytest.mark.parametrize(
    "comp",
    [RandomQuantization(bits=4), BlockTopK(fraction=0.25, block=64), TopK(fraction=0.25)],
    ids=["q4b", "btop25", "top25"],
)
def test_packed_path_matches_dense_path(comp):
    """Rolling the packed payload must equal decode-then-mix exactly."""
    topo = topology.ring(8)
    theta = {"w": jax.random.normal(KEY, (8, 256))}
    state = gossip.choco_init(theta)
    k = jax.random.PRNGKey(7)
    t_packed, s_packed = gossip.choco_round(theta, state, topo, 0.2, comp, k, packed=True)
    t_dense, s_dense = gossip.choco_round(theta, state, topo, 0.2, comp, k, packed=False)
    for a, b in zip(jax.tree_util.tree_leaves((t_packed, s_packed)), jax.tree_util.tree_leaves((t_dense, s_dense))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_choco_round_jits():
    topo = topology.ring(4)
    comp = RandomQuantization(bits=8)
    theta = {"w": jax.random.normal(KEY, (4, 32))}
    state = gossip.choco_init(theta)

    @jax.jit
    def step(t, s, k):
        return gossip.choco_round(t, s, topo, 0.3, comp, k)

    t, s = step(theta, state, KEY)
    assert t["w"].shape == (4, 32)


def test_payload_bits_accounting():
    topo = topology.ring(8)  # degree 2
    theta = {"w": jnp.zeros((8, 1000))}
    bits_id = gossip.payload_bits(Identity(), theta, topo)
    assert bits_id == pytest.approx(2 * 32000)
    bits_q4 = gossip.payload_bits(RandomQuantization(bits=4), theta, topo)
    assert bits_q4 < bits_id / 5


def test_block_scanned_gossip_preserves_average_and_consensus():
    """Large stacked leaves take the chunk-scanned path (per-layer
    transients); it must keep CHOCO's average-preservation + contraction
    properties, exactly like the whole-leaf path."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.compression import make_compressor
    from repro.core.gossip import CHOCOState, choco_init, choco_round
    from repro.core.topology import make_topology

    m, nb, rows = 4, 6, 64
    topo = make_topology("ring", m)
    comp = make_compressor("q8b")
    key = jax.random.PRNGKey(0)
    theta = {"blocks": jax.random.normal(key, (m, nb, rows))}
    state = choco_init(theta)
    gamma = 0.4

    # force the scanned path with a tiny threshold
    mean0 = np.asarray(theta["blocks"]).mean(0)
    errs = []
    for t in range(60):
        key, sub = jax.random.split(key)
        theta, state = choco_round(
            theta, state, topo, gamma, comp, sub, block_scan_elems=8
        )
        leaf = np.asarray(theta["blocks"], np.float32)
        np.testing.assert_allclose(leaf.mean(0), mean0, atol=1e-3, rtol=1e-4)
        errs.append(((leaf - leaf.mean(0)) ** 2).sum())
    assert errs[-1] < 0.05 * errs[0]  # consensus contraction

    # scanned path == whole-leaf path semantics up to per-chunk quant scale:
    # both contract; compare variance trajectories loosely
    theta2 = {"blocks": jax.random.normal(jax.random.PRNGKey(0), (m, nb, rows))}
    state2 = choco_init(theta2)
    key2 = jax.random.PRNGKey(0)
    for t in range(60):
        key2, sub = jax.random.split(key2)
        theta2, state2 = choco_round(
            theta2, state2, topo, gamma, comp, sub, block_scan_elems=1 << 30
        )
    leaf2 = np.asarray(theta2["blocks"], np.float32)
    err_whole = ((leaf2 - leaf2.mean(0)) ** 2).sum()
    assert err_whole < 0.05 * errs[0]


def test_payload_bits_scalar_leaf_regression():
    """A stacked 1-D leaf [m] is ONE scalar per node: payload_bits must bill
    d=1 for it, not d=m (regression: the old `leaf.ndim == 1` branch used
    shape[0], inflating scalar leaves m-fold)."""
    topo_ring = topology.ring(8)  # degree 2
    theta = {"w": jnp.zeros((8, 100)), "scale": jnp.zeros((8,))}
    bits = gossip.payload_bits(Identity(), theta, topo_ring)
    assert bits == pytest.approx(2 * (100 + 1) * 32.0)
    # independent of the node count: same per-node payload on a bigger graph
    theta16 = {"w": jnp.zeros((16, 100)), "scale": jnp.zeros((16,))}
    assert gossip.payload_bits(Identity(), theta16, topology.ring(16)) == bits
