"""Model-internals correctness: decode paths must reproduce the parallel
(train/prefill) forward pass token-for-token, mixers must satisfy their
defining recurrences, MoE dispatch must conserve gates and respect capacity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import apply_attention, decode_attention, init_attention, init_attn_cache
from repro.models.moe import apply_moe, capacity_for, init_moe
from repro.models.rglru import apply_rglru, decode_rglru, init_rglru, init_rglru_cache
from repro.models.ssm import decode_mamba2, init_mamba2, init_mamba2_cache, mamba2_scan

KEY = jax.random.PRNGKey(42)


def _dense_cfg(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=97, head_dim=16, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


# ------------------------------------------------------- decode == forward
def _teacher_force(cfg, S_prefill, S_total, batch_extra=None, atol=2e-4):
    params = T.init_model(KEY, cfg)
    B = 2
    tokens = jax.random.randint(KEY, (B, S_total), 0, cfg.vocab_size)
    batch = {"tokens": tokens, **(batch_extra or {})}
    full_logits, _ = T.forward(params, batch, cfg)

    pre = {"tokens": tokens[:, :S_prefill], **(batch_extra or {})}
    plogits, cache = T.prefill(params, pre, cfg, cache_len=S_total)
    np.testing.assert_allclose(
        np.asarray(plogits), np.asarray(full_logits[:, :S_prefill]), atol=atol, rtol=1e-3
    )
    decode = jax.jit(lambda p, t, c, pos: T.decode_step(p, t, c, pos, cfg))
    for i in range(S_prefill, S_total):
        dlogits, cache = decode(params, tokens[:, i : i + 1], cache, jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(dlogits[:, 0]),
            np.asarray(full_logits[:, i]),
            atol=atol,
            rtol=1e-3,
            err_msg=f"decode step {i}",
        )


def test_dense_decode_matches_forward():
    _teacher_force(_dense_cfg(), S_prefill=8, S_total=16)


def test_qknorm_gqa_decode_matches_forward():
    _teacher_force(_dense_cfg(qk_norm=True, num_kv_heads=1), S_prefill=8, S_total=14)


def test_mamba2_decode_matches_forward():
    cfg = get_config("mamba2-1.3b").reduced()
    cfg = dataclasses.replace(cfg, ssm_chunk=8)
    _teacher_force(cfg, S_prefill=16, S_total=24, atol=2e-3)


def test_rglru_hybrid_decode_matches_forward():
    cfg = get_config("recurrentgemma-2b").reduced(layers=3)
    _teacher_force(cfg, S_prefill=8, S_total=14, atol=1e-3)


def test_moe_decode_matches_forward():
    cfg = get_config("deepseek-moe-16b").reduced()
    # generous capacity so routing is identical between batched and 1-token runs
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    _teacher_force(cfg, S_prefill=8, S_total=12, atol=2e-3)


def test_whisper_decode_matches_forward():
    cfg = get_config("whisper-small").reduced()
    frames = 0.02 * jax.random.normal(KEY, (2, cfg.encoder_context, cfg.d_model))
    _teacher_force(cfg, S_prefill=8, S_total=12, batch_extra={"frames": frames}, atol=1e-3)


def test_vlm_patch_fusion_changes_only_prefix_logits():
    cfg = get_config("internvl2-2b").reduced()
    params = T.init_model(KEY, cfg)
    tokens = jax.random.randint(KEY, (1, 24), 0, cfg.vocab_size)
    p1 = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, cfg.num_patches, cfg.d_model))
    p2 = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (1, cfg.num_patches, cfg.d_model))
    l1, _ = T.forward(params, {"tokens": tokens, "patches": p1}, cfg)
    l2, _ = T.forward(params, {"tokens": tokens, "patches": p2}, cfg)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))  # patches matter
    # causal: logits before the first patch-position... all positions >= 0 see
    # patches, but swapping TEXT tokens after position k must not affect < k
    t2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % cfg.vocab_size)
    l3, _ = T.forward(params, {"tokens": t2, "patches": p1}, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l3[:, :-1]), atol=1e-5)


# ------------------------------------------------------------ ring buffers
def test_sliding_window_ring_buffer_decode():
    """Windowed decode == full attention restricted to the window."""
    cfg = _dense_cfg(num_layers=1, sliding_window=4, layer_pattern=("local_attn",))
    params = T.init_model(KEY, cfg)
    S = 12
    tokens = jax.random.randint(KEY, (1, S), 0, cfg.vocab_size)
    full_logits, _ = T.forward(params, {"tokens": tokens}, cfg)  # window-masked
    _, cache = T.prefill(params, {"tokens": tokens[:, :4]}, cfg, cache_len=S)
    decode = jax.jit(lambda p, t, c, pos: T.decode_step(p, t, c, pos, cfg))
    for i in range(4, S):
        dlogits, cache = decode(params, tokens[:, i : i + 1], cache, jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(dlogits[:, 0]), np.asarray(full_logits[:, i]), atol=2e-4, rtol=1e-3,
            err_msg=f"step {i}",
        )


def test_long_context_window_cache_is_window_sized():
    cfg = get_config("granite-20b").reduced()
    cache = T.init_cache(cfg, batch=1, length=1 << 16)
    k = cache["blocks"][0]["k"]
    assert k.shape[2 - 0] <= cfg.long_context_window  # [nb, B, W, kv, hd]


# ----------------------------------------------------------------- mixers
def test_mamba2_chunking_invariance():
    """SSD output must not depend on the chunk size (defining property)."""
    cfg = get_config("mamba2-1.3b").reduced()
    params = init_mamba2(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (2, 32, cfg.d_model))
    outs = []
    for chunk in (4, 8, 16, 32):
        c = dataclasses.replace(cfg, ssm_chunk=chunk)
        y, _ = mamba2_scan(params, x, c, return_state=False)
        outs.append(np.asarray(y))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-4, rtol=1e-3)


def test_mamba2_state_equals_sequential_recurrence():
    cfg = dataclasses.replace(get_config("mamba2-1.3b").reduced(), ssm_chunk=4)
    params = init_mamba2(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (1, 8, cfg.d_model))
    y_par, st = mamba2_scan(params, x, cfg, return_state=True)
    cache = init_mamba2_cache(cfg, 1)
    ys = []
    for i in range(8):
        y_i, cache = decode_mamba2(params, x[:, i : i + 1], cache, cfg)
        ys.append(y_i)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st["ssm"]), np.asarray(cache["ssm"]), atol=1e-4, rtol=1e-3)


def test_rglru_scan_equals_sequential():
    cfg = get_config("recurrentgemma-2b").reduced()
    params = init_rglru(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (2, 12, cfg.d_model))
    y_par, st = apply_rglru(params, x, cfg, return_state=True)
    cache = init_rglru_cache(cfg, 2)
    ys = []
    for i in range(12):
        y_i, cache = decode_rglru(params, x[:, i : i + 1], cache, cfg)
        ys.append(y_i)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(cache["h"]), atol=1e-4, rtol=1e-3)


def test_gqa_equals_full_mha_when_kv_repeated():
    """GQA with kv groups == heads must equal standard MHA (same weights)."""
    cfg = _dense_cfg(num_kv_heads=4)
    p = init_attention(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (2, 8, cfg.d_model))
    y = apply_attention(p, x, cfg)
    # build an equivalent kv=2 config whose wk/wv repeat groups explicitly
    cfg2 = _dense_cfg(num_kv_heads=2)
    p2 = dict(p)
    p2["wk"] = p["wk"][:, ::2, :]
    p2["wv"] = p["wv"][:, ::2, :]
    y2 = apply_attention(p2, x, cfg2)
    # not equal in general — but equal when the two kv heads per group coincide
    p3 = dict(p)
    p3["wk"] = jnp.repeat(p2["wk"], 2, axis=1)
    p3["wv"] = jnp.repeat(p2["wv"], 2, axis=1)
    y3 = apply_attention(p3, x, cfg)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y3), atol=1e-5)


def test_query_chunked_attention_matches_unchunked():
    from repro.models import layers as L

    cfg = _dense_cfg()
    p = init_attention(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (1, 64, cfg.d_model))
    y_full = apply_attention(p, x, cfg)
    old_thr, old_chunk = L.CHUNK_THRESHOLD, L.QUERY_CHUNK
    try:
        L.CHUNK_THRESHOLD, L.QUERY_CHUNK = 16, 16
        y_chunked = apply_attention(p, x, cfg)
    finally:
        L.CHUNK_THRESHOLD, L.QUERY_CHUNK = old_thr, old_chunk
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_chunked), atol=1e-5)


# -------------------------------------------------------------------- moe
def test_moe_gates_sum_to_one_and_capacity_respected():
    cfg = get_config("deepseek-moe-16b").reduced()
    params = init_moe(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = apply_moe(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.99  # Switch aux >= 1 at balance (=E*sum(me*ce) ~ 1)


def test_moe_zero_capacity_drop_consistency():
    """With huge capacity nothing is dropped: output must equal the dense
    computation of the same top-k expert mixture."""
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=100.0, num_shared_experts=0)
    params = init_moe(KEY, cfg)
    x = 0.1 * jax.random.normal(KEY, (1, 8, cfg.d_model))
    y, _ = apply_moe(params, x, cfg)

    # dense reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, cfg.experts_per_token)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for k in range(cfg.experts_per_token):
            e = int(ei[t, k])
            h = jax.nn.silu(xt[t] @ params["w_gate"][e]) * (xt[t] @ params["w_up"][e])
            acc = acc + gv[t, k] * (h @ params["w_down"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)), np.asarray(ref), atol=1e-4, rtol=1e-3)


def test_moe_capacity_formula():
    cfg = get_config("deepseek-moe-16b").reduced()
    c = capacity_for(1024, cfg)
    assert c >= cfg.capacity_factor * 1024 * cfg.experts_per_token / cfg.num_experts
    assert c % 8 == 0


# ------------------------------------------------------------- accounting
def test_active_params_less_than_total_for_moe():
    for arch in ("deepseek-moe-16b", "llama4-scout-17b-a16e"):
        cfg = get_config(arch)
        total, active = T.param_count(cfg), T.active_param_count(cfg)
        assert active < total
        assert active > 0


def test_param_count_full_configs_plausible():
    approx = {
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "qwen3-4b": (3.2e9, 4.8e9),
        "command-r-35b": (28e9, 40e9),
        "granite-20b": (18e9, 24e9),
        "mamba2-1.3b": (1.0e9, 1.7e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "llama4-scout-17b-a16e": (80e9, 120e9),  # total (17B active)
        "recurrentgemma-2b": (2.2e9, 3.5e9),
        "whisper-small": (0.2e9, 0.35e9),
        "internvl2-2b": (1.6e9, 2.4e9),
    }
    for arch, (lo, hi) in approx.items():
        n = T.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:,} not in [{lo:.1e}, {hi:.1e}]"
