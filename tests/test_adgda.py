"""AD-GDA algorithm: minimax convergence, robustness vs. CHOCO-SGD, baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ADGDAConfig,
    DRDSGDConfig,
    DRFAConfig,
    adgda_trainer,
    choco_sgd,
    drdsgd_trainer,
    drfa_trainer,
)

M = 6  # nodes


def _quadratic_loss(offsets):
    """Node i's loss: f_i(theta) = 0.5 ||theta - mu_i||^2 (convex, heterogeneous)."""
    mus = jnp.asarray(offsets)

    def loss_fn(params, batch, rng):
        mu = batch["mu"]
        return 0.5 * jnp.sum((params["w"] - mu) ** 2)

    batch = {"mu": mus}
    return loss_fn, batch, mus


def _run(trainer, params, batch, steps, seed=0):
    state = trainer.init(params, jax.random.PRNGKey(seed))
    aux = None
    for _ in range(steps):
        state, aux = trainer.step(state, batch)
    return state, aux


def test_adgda_converges_to_robust_solution():
    """With strong heterogeneity the robust theta should balance worst nodes.

    Quadratics with means spread on a line: DRO solution shifts towards the
    extreme nodes relative to the mean of the means.
    """
    offsets = [[-4.0], [-0.5], [0.0], [0.0], [0.5], [4.0]]
    loss_fn, batch, mus = _quadratic_loss(offsets)
    cfg = ADGDAConfig(
        num_nodes=M, topology="ring", compressor="q8b", alpha=0.05,
        eta_theta=0.05, eta_lambda=0.05, lr_decay=0.995,
    )
    trainer = adgda_trainer(cfg, loss_fn)
    params = {"w": jnp.zeros((1,))}
    state, aux = _run(trainer, params, batch, steps=600)

    losses = np.asarray(aux["losses"])
    # worst-node losses should be nearly balanced between the two extremes
    assert abs(losses[0] - losses[-1]) < 0.5 * max(losses[0], losses[-1]) + 0.3
    # lambda concentrates on the extreme nodes
    lam = np.asarray(aux["lambda_mean"])
    assert lam[0] + lam[-1] > 0.5
    # consensus reached
    assert float(aux["consensus_err"]) < 5e-2


def test_adgda_beats_choco_sgd_on_worst_node():
    offsets = [[-3.0], [0.0], [0.0], [0.0], [0.0], [3.0]]
    loss_fn, batch, _ = _quadratic_loss(offsets)
    cfg = ADGDAConfig(num_nodes=M, topology="ring", compressor="q8b",
                      alpha=0.05, eta_theta=0.05, eta_lambda=0.05)
    robust_state, robust_aux = _run(adgda_trainer(cfg, loss_fn), {"w": jnp.zeros((1,))}, batch, 500)
    sgd_state, sgd_aux = _run(choco_sgd(cfg, loss_fn), {"w": jnp.zeros((1,))}, batch, 500)
    # symmetric problem: same consensus mean, but check worst-loss tracking
    assert float(robust_aux["worst_loss"]) <= float(sgd_aux["worst_loss"]) + 1e-3


def test_adgda_beats_choco_sgd_asymmetric():
    """Asymmetric populations: 5 nodes at 0, 1 outlier — the standard risk
    minimizer parks near 0 and the outlier suffers; DRO balances."""
    offsets = [[0.0]] * 5 + [[6.0]]
    loss_fn, batch, _ = _quadratic_loss(offsets)
    cfg = ADGDAConfig(num_nodes=M, topology="ring", compressor="q4b",
                      alpha=0.01, eta_theta=0.05, eta_lambda=0.1)
    _, robust_aux = _run(adgda_trainer(cfg, loss_fn), {"w": jnp.zeros((1,))}, batch, 800)
    _, sgd_aux = _run(choco_sgd(cfg, loss_fn), {"w": jnp.zeros((1,))}, batch, 800)
    assert float(robust_aux["worst_loss"]) < 0.7 * float(sgd_aux["worst_loss"])


def test_lambda_stays_on_simplex():
    offsets = [[float(i)] for i in range(M)]
    loss_fn, batch, _ = _quadratic_loss(offsets)
    cfg = ADGDAConfig(num_nodes=M, alpha=0.1, eta_lambda=0.5)  # aggressive dual lr
    trainer = adgda_trainer(cfg, loss_fn)
    state = trainer.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    for _ in range(50):
        state, _ = trainer.step(state, batch)
        lam = np.asarray(state.lam)
        np.testing.assert_allclose(lam.sum(-1), 1.0, atol=1e-4)
        assert (lam >= -1e-6).all()


def test_choco_sgd_matches_uncompressed_sgd_direction():
    """With Identity compression + mesh topology, CHOCO-SGD's network mean
    after one step equals centralized SGD on the average loss."""
    offsets = [[1.0], [2.0], [3.0], [4.0], [5.0], [6.0]]
    loss_fn, batch, mus = _quadratic_loss(offsets)
    cfg = ADGDAConfig(num_nodes=M, topology="mesh", compressor="none",
                      eta_theta=0.1, robust=False)
    trainer = choco_sgd(cfg, loss_fn)
    state = trainer.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    state, _ = trainer.step(state, batch)
    mean_w = float(np.asarray(trainer.network_mean(state)["w"])[0])
    # centralized: w1 = 0 - 0.1 * mean(0 - mu_i) = 0.1 * mean(mu)
    assert mean_w == pytest.approx(0.1 * float(mus.mean()), abs=1e-5)


def test_theory_gamma_accepted():
    loss_fn, batch, _ = _quadratic_loss([[0.0]] * M)
    cfg = ADGDAConfig(num_nodes=M, compressor="q4b", gamma="theory")
    trainer = adgda_trainer(cfg, loss_fn)
    assert 0 < trainer.gamma < 0.1


# --------------------------------------------------------------------- baselines
def test_drdsgd_converges_and_weights_worst():
    offsets = [[0.0]] * 5 + [[4.0]]
    loss_fn, batch, _ = _quadratic_loss(offsets)
    cfg = DRDSGDConfig(num_nodes=M, topology="ring", alpha=1.0, eta_theta=0.05)
    trainer = drdsgd_trainer(cfg, loss_fn)
    state, aux = _run(trainer, {"w": jnp.zeros((1,))}, batch, 500)
    lam = np.asarray(aux["lambda_mean"])
    assert lam[-1] == lam.max()  # worst node gets the largest weight
    _, sgd_aux = _run(
        choco_sgd(ADGDAConfig(num_nodes=M, topology="ring", compressor="none", eta_theta=0.05), loss_fn),
        {"w": jnp.zeros((1,))}, batch, 500)
    assert float(aux["worst_loss"]) < float(sgd_aux["worst_loss"])


def test_drfa_runs_and_improves_worst_node():
    offsets = [[0.0]] * 5 + [[4.0]]
    loss_fn, _, mus = _quadratic_loss(offsets)
    cfg = DRFAConfig(num_nodes=M, local_steps=4, eta_theta=0.05, eta_lambda=0.05)
    trainer = drfa_trainer(cfg, loss_fn)
    # batch: [m, K, ...]
    batch = {"mu": jnp.broadcast_to(mus[:, None, :], (M, 4, 1))}
    state, aux = _run(trainer, {"w": jnp.zeros((1,))}, batch, 300)
    w = float(np.asarray(state.theta["w"])[0])
    assert 0.2 < w < 4.0  # pulled towards the outlier, away from plain mean (0.67)
    assert float(aux["worst_loss"]) < 0.5 * 16.0 / 2  # better than w=0


def test_bits_per_round_ordering():
    loss_fn, batch, _ = _quadratic_loss([[0.0]] * M)
    params = {"w": jnp.zeros((1000,))}
    cfg_q4 = ADGDAConfig(num_nodes=M, topology="ring", compressor="q4b")
    cfg_id = ADGDAConfig(num_nodes=M, topology="ring", compressor="none")
    t_q4, t_id = adgda_trainer(cfg_q4, loss_fn), adgda_trainer(cfg_id, loss_fn)
    s_q4 = t_q4.init(params, jax.random.PRNGKey(0))
    s_id = t_id.init(params, jax.random.PRNGKey(0))
    assert t_q4.bits_per_round(s_q4) < 0.3 * t_id.bits_per_round(s_id)
