"""NeighborCache / union-wire invariants (core/wire.py + the cached
time-varying round in core/exchange.py).

The contract that makes the hat-delta wire sound:

* **mirror invariant** — after ANY prefix of masked/scheduled rounds, every
  cache entry is BIT-IDENTICAL to the sender's own ``theta_hat`` (the
  receiver applies the decoded delta with the same arithmetic the sender
  applies), across schedule specs, dropout masks, and payload formats;
* **oracle parity** — the cached round reproduces the rolled *memory-full*
  f32 oracle (``gossip._round_leaf_masked``: dense W(t) products over the
  full public copies) to f32 rounding, while shipping only compressed bytes;
* **format equivalence** — packed payload wire vs dense-q wire are
  bit-identical (decode commutes with the permute);
* **bank round-trip** — the union wire's per-phase weight banks reconstruct
  each phase's dense mixing matrix exactly.

All on the single-device mesh (same backend code path as the multi-device
grid in exchange_parity_main.py, which re-checks the invariant on 4 real
devices), so this runs in the tier-1 suite.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import gossip, topology
from repro.core.compression import Identity, RandomQuantization
from repro.core.topology import compile_schedule_plans
from repro.core.wire import DENSE, HAT_DELTA, PAYLOAD, compile_union_wire
from repro.kernels.ops import KernelQuantization
from repro.launch.mesh import make_cpu_mesh

SCHEDULES = [
    ("ring+drop", "ring", 0.4),
    ("rr+drop", "roundrobin:ring,torus", 0.25),
    ("rr-sched", "roundrobin:ring,torus", 0.0),
    ("matching", "matching:4", 0.3),
]
COMPRESSORS = [
    ("identity", lambda: Identity(), True),
    ("q4b", lambda: RandomQuantization(bits=4), True),
    ("q4b-unpacked", lambda: RandomQuantization(bits=4), False),
    ("kq4b", lambda: KernelQuantization(bits=4), True),
]


def _mesh1():
    return make_cpu_mesh(1, 1)


def _masks(sched, m, rounds, seed):
    """Per-round participation masks the way the trainer draws them."""
    keys = jax.random.split(jax.random.PRNGKey(seed), rounds)
    return [sched.mask_at(k, t) for t, k in enumerate(keys)]


def _run_ppermute(theta, sched, comp, packed, masks, mesh):
    union = compile_union_wire(compile_schedule_plans(sched))
    topo0 = sched.topology_at(0)
    state = gossip.choco_init(theta, cache_ops=union.n_ops)
    masked = masks[0] is not None

    @jax.jit
    def step(t, s, k, st, mk=None):
        return gossip.choco_round(
            t, s, topo0, 0.3, comp, k, packed=packed, mask=mk,
            backend="ppermute", mesh=mesh, schedule=sched, step=st,
        )

    t = theta
    for i, mask in enumerate(masks):
        kw = dict(mk=mask) if masked else {}
        t, state = step(t, state, jax.random.PRNGKey(100 + i), jnp.int32(i), **kw)
    return t, state, union


def _run_rolled_oracle(theta, sched, comp, masks):
    topo0 = sched.topology_at(0)
    state = gossip.choco_init(theta)
    masked = masks[0] is not None

    @jax.jit
    def step(t, s, k, mx, mk=None):
        return gossip.choco_round(
            t, s, topo0, 0.3, comp, k, mixing=mx, mask=mk,
        )

    t = theta
    for i, mask in enumerate(masks):
        kw = dict(mk=mask) if masked else {}
        t, state = step(t, state, jax.random.PRNGKey(100 + i),
                        sched.mixing_at(jnp.int32(i), mask), **kw)
    return t, state


def _assert_cache_invariant(state, union):
    hats = jax.tree_util.tree_leaves(state.theta_hat)
    for k, snd in enumerate(union.senders):
        for hat, mirror in zip(hats, jax.tree_util.tree_leaves(state.cache[k])):
            hat, mirror = np.asarray(hat), np.asarray(mirror)
            for i in range(hat.shape[0]):
                if snd[i] >= 0:
                    assert (mirror[i] == hat[snd[i]]).all(), (
                        f"op {k} node {i}: mirror diverged from sender "
                        f"{snd[i]}'s theta_hat"
                    )


def _worst(a, b):
    return max(
        float(np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


@pytest.mark.parametrize("cname,make_comp,packed", COMPRESSORS,
                         ids=[c[0] for c in COMPRESSORS])
@pytest.mark.parametrize("sname,spec,dropout", SCHEDULES,
                         ids=[s[0] for s in SCHEDULES])
def test_cache_invariant_and_oracle_parity(sname, spec, dropout, cname,
                                           make_comp, packed):
    m, d, rounds = 8, 96, 4
    mesh = _mesh1()
    sched = topology.make_topology_schedule(spec, m, dropout=dropout, seed=1)
    theta = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (m, d)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (m, 5)),
    }
    masks = _masks(sched, m, rounds, seed=3)
    comp = make_comp()

    tp, sp, union = _run_ppermute(theta, sched, comp, packed, masks, mesh)
    # 1. mirror invariant: bit-identical to sender hats after any prefix
    _assert_cache_invariant(sp, union)
    # 2. parity with the rolled memory-full f32 oracle
    to, so = _run_rolled_oracle(theta, sched, comp, masks)
    worst = _worst((to, so.theta_hat, so.s), (tp, sp.theta_hat, sp.s))
    assert worst < 3e-6, f"hat-delta round diverged from oracle: {worst:.3e}"


def test_packed_and_dense_wire_bit_identical():
    """decode(recv(payload)) == recv(decode(payload)): the hat-delta payload
    wire and the dense-q cross-check wire are the same numbers, bitwise."""
    m = 8
    mesh = _mesh1()
    sched = topology.make_topology_schedule("roundrobin:ring,torus", m, dropout=0.3, seed=0)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(2), (m, 64))}
    masks = _masks(sched, m, 3, seed=5)
    comp = RandomQuantization(bits=4)
    a = _run_ppermute(theta, sched, comp, True, masks, mesh)[:2]
    b = _run_ppermute(theta, sched, comp, False, masks, mesh)[:2]
    assert _worst(a, b) == 0.0


def test_union_bank_roundtrip_exact():
    """w_bank/self_bank/senders reconstruct every phase's dense W exactly."""
    for spec in ("ring", "roundrobin:ring,torus", "matching:4", "erdos_renyi"):
        sched = topology.make_topology_schedule(spec, 8, seed=2)
        plans = compile_schedule_plans(sched)
        union = compile_union_wire(plans)
        for p in range(union.period):
            w = np.zeros((8, 8))
            np.fill_diagonal(w, union.self_bank[p])
            for k, snd in enumerate(union.senders):
                i = np.nonzero(snd >= 0)[0]
                w[i, snd[i]] += union.w_bank[p, k, i] * union.active[p, k, i]
            assert np.allclose(w, sched.topologies[p].mixing, atol=1e-7), (
                f"{spec} phase {p}: bank does not reconstruct W"
            )


def test_union_dedup_and_out_degree():
    sched = topology.make_topology_schedule("roundrobin:ring,torus", 8)
    union = compile_union_wire(compile_schedule_plans(sched))
    # ring shares its ±1 shifts with the torus phase: union is 4 ops, not 6
    assert union.n_ops == 4
    assert union.max_out_degree == 4
    assert union.realized_out_degree(np.array([1, 0, 1, 1, 1, 1, 1, 1])) == 4.0
    # single-phase round-trips to its own plan
    static = compile_union_wire(compile_schedule_plans(
        topology.make_topology_schedule("ring", 8)))
    assert static.n_ops == 2 and static.max_out_degree == 2


def test_wire_formats_and_bits_accounting():
    from repro.core.compression import make_compressor
    from repro.core.trainer import ChocoConsensus, ExactConsensus, FedAvg

    mesh = _mesh1()
    ring = topology.ring(8)
    sched = topology.make_topology_schedule("ring", 8, dropout=0.2)
    comp = make_compressor("q4b")
    theta = {"w": jnp.zeros((8, 100))}

    static = ChocoConsensus(ring, comp, 0.3)
    assert static.wire_format is PAYLOAD
    cached = ChocoConsensus(sched, comp, 0.3, backend="ppermute", mesh=mesh)
    assert cached.wire_format is HAT_DELTA
    assert ExactConsensus(ring).wire_format is DENSE
    assert FedAvg(4).wire_format is DENSE

    # the cached union wire bills its out-degree; ring union degree == 2, so
    # max-mode bits match the static upper bound (per-edge cost unchanged)
    assert cached.bits_per_round(theta, mode="max") == static.bits_per_round(theta, mode="max")
    # expected: sender-survival only (a dead receiver's deltas are deferred
    # re-sync traffic, not avoided traffic)
    assert cached.bits_per_round(theta, mode="expected") == pytest.approx(
        0.8 * static.bits_per_round(theta, mode="max")
    )
    mask = jnp.array([1, 1, 1, 0, 1, 1, 1, 1], jnp.float32)
    assert cached.bits_per_round(theta, mode="realized", mask=mask) == (
        static.bits_per_round(theta, mode="max")
    )
    # traced accumulator agrees with the host-side accounting
    traced = float(cached.bits_realized(theta, jnp.int32(0), mask))
    assert traced == pytest.approx(
        cached.bits_per_round(theta, mode="realized", mask=mask)
    )


def test_trainer_bits_realized_aux():
    """The jitted realized-bits meter: static runs report the constant;
    masked runs report the round's measured traffic."""
    from benchmarks.common import make_adgda
    from repro.data import rotated_minority_classification

    m = 6
    data = rotated_minority_classification(num_nodes=m, seed=0)
    trainer, init_fn, _ = make_adgda("logistic", m, compressor="q4b", dropout=0.3)
    state = trainer.init(init_fn(data.dim, data.num_classes), jax.random.PRNGKey(0))
    xb, yb = next(data.batches(20, seed=0))
    prev_step = int(state.step)
    state, aux = trainer.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
    want = trainer.bits_per_round(
        state, mode="realized", step=prev_step, mask=aux["participation"]
    )
    assert float(aux["bits_realized"]) == pytest.approx(want)

    trainer2, init_fn, _ = make_adgda("logistic", m, compressor="q4b")
    state2 = trainer2.init(init_fn(data.dim, data.num_classes), jax.random.PRNGKey(0))
    state2, aux2 = trainer2.step(state2, (jnp.asarray(xb), jnp.asarray(yb)))
    assert float(aux2["bits_realized"]) == pytest.approx(trainer2.bits_per_round(state2))


def test_baselines_ppermute_parity_single_device():
    """ExactConsensus (DR-DSGD) and FedAvg (DRFA) under backend='ppermute'
    reproduce their rolled oracles on the single-device mesh (the real
    4-device wire runs in exchange_parity_main.py)."""
    from repro.core.baselines import (
        DRDSGDConfig, DRFAConfig, drdsgd_trainer, drfa_trainer,
    )

    mesh = _mesh1()
    m, dim, C = 6, 10, 3

    def loss_fn(params, batch, rng):
        x, y = batch
        logits = x @ params["w"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return (logz - gold).mean()

    params = {"w": jnp.zeros((dim, C))}

    def run(tr, stacked_k=None, steps=3):
        st = tr.init(params, jax.random.PRNGKey(4))
        if stacked_k:
            batch = (
                jax.random.normal(jax.random.PRNGKey(0), (m, stacked_k, 6, dim)),
                jax.random.randint(jax.random.PRNGKey(1), (m, stacked_k, 6), 0, C),
            )
        else:
            batch = (
                jax.random.normal(jax.random.PRNGKey(0), (m, 6, dim)),
                jax.random.randint(jax.random.PRNGKey(1), (m, 6), 0, C),
            )
        for _ in range(steps):
            st, _ = tr.step(st, batch)
        return st

    dcfg = dict(num_nodes=m, eta_theta=0.2)
    a = run(drdsgd_trainer(DRDSGDConfig(**dcfg), loss_fn))
    b = run(drdsgd_trainer(DRDSGDConfig(**dcfg, gossip_backend="ppermute"),
                           loss_fn, mesh=mesh))
    assert _worst(a, b) < 2e-6

    fcfg = dict(num_nodes=m, local_steps=2, eta_theta=0.2, eta_lambda=0.1)
    a = run(drfa_trainer(DRFAConfig(**fcfg), loss_fn), stacked_k=2)
    b = run(drfa_trainer(DRFAConfig(**fcfg, gossip_backend="ppermute"),
                         loss_fn, mesh=mesh), stacked_k=2)
    assert _worst(a, b) < 2e-6


# ------------------------------------------------------------ multi-lane wire
def _lane_thetas(theta, n_lanes):
    """Distinct per-lane inputs from one template (lane k shifted by k)."""
    return [
        jax.tree.map(lambda x: x + 0.1 * k, theta) for k in range(n_lanes)
    ]


def _multilane_vs_single_case(spec, dropout, n_lanes, backend, packed=True,
                              rounds=3, seed=7):
    """encode->permute->decode of an n-lane round is bit-exact per lane:
    lane k of choco_round_lanes equals a single-lane run keyed with
    lane_key(key, k), for every lane count x schedule x dropout x backend."""
    m, d = 6, 48
    mesh = _mesh1() if backend == "ppermute" else None
    sched = topology.make_topology_schedule(spec, m, dropout=dropout, seed=1)
    topo0 = sched.topology_at(0)
    comp = RandomQuantization(bits=4)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(seed), (m, d)),
             "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (m, 3))}
    union = compile_union_wire(compile_schedule_plans(sched))
    cache_ops = union.n_ops if backend == "ppermute" else 0
    masks = _masks(sched, m, rounds, seed=seed + 2) if dropout > 0 else [None] * rounds

    masked = dropout > 0
    # the rolled backend consumes an explicit dense W(t) (what
    # ChocoConsensus.mix resolves); ppermute compiles the wire program from
    # schedule + step.  One jitted fn per side so rounds share a compile.
    if backend == "rolled":
        extra = lambda i, mask: (sched.mixing_at(jnp.int32(i), mask), mask)

        @jax.jit
        def ml_step(thetas, states, key, mixing, mask=None):
            lanes = [gossip.LaneRound(t, s, 0.3, comp)
                     for t, s in zip(thetas, states)]
            return gossip.choco_round_lanes(
                lanes, topo0, key, mixing=mixing, mask=mask, packed=packed)

        @jax.jit
        def sl_step(t, s, key, mixing, mask=None):
            return gossip.choco_round(
                t, s, topo0, 0.3, comp, key, mixing=mixing, mask=mask,
                packed=packed)
    else:
        extra = lambda i, mask: ((jnp.int32(i), mask) if masked
                                 else (jnp.int32(i),))

        @jax.jit
        def ml_step(thetas, states, key, step, mask=None):
            lanes = [gossip.LaneRound(t, s, 0.3, comp)
                     for t, s in zip(thetas, states)]
            return gossip.choco_round_lanes(
                lanes, topo0, key, backend="ppermute", mesh=mesh,
                schedule=sched, step=step, mask=mask, packed=packed)

        @jax.jit
        def sl_step(t, s, key, step, mask=None):
            return gossip.choco_round(
                t, s, topo0, 0.3, comp, key, backend="ppermute", mesh=mesh,
                schedule=sched, step=step, mask=mask, packed=packed)

    # n-lane trajectory
    thetas = _lane_thetas(theta, n_lanes)
    states = [gossip.choco_init(t, cache_ops=cache_ops) for t in thetas]
    for i, mask in enumerate(masks):
        thetas, states = ml_step(thetas, states, jax.random.PRNGKey(100 + i),
                                 *extra(i, mask))
        thetas, states = list(thetas), list(states)

    # per-lane single-lane reference with the folded key stream
    for k in range(n_lanes):
        t = _lane_thetas(theta, n_lanes)[k]
        s = gossip.choco_init(t, cache_ops=cache_ops)
        for i, mask in enumerate(masks):
            lk = gossip.lane_key(jax.random.PRNGKey(100 + i), k)
            t, s = sl_step(t, s, lk, *extra(i, mask))
        assert _worst((thetas[k], states[k].theta_hat, states[k].s),
                      (t, s.theta_hat, s.s)) == 0.0, (
            f"lane {k}/{n_lanes} not bit-exact vs single-lane run "
            f"({spec}, dropout={dropout}, {backend})"
        )
    return states, union, cache_ops


@pytest.mark.parametrize("backend", ["rolled", "ppermute"])
@pytest.mark.parametrize("n_lanes", [2, 3])
@pytest.mark.parametrize("sname,spec,dropout", SCHEDULES,
                         ids=[s[0] for s in SCHEDULES])
def test_multilane_roundtrip_bit_exact(sname, spec, dropout, n_lanes, backend):
    states, union, cache_ops = _multilane_vs_single_case(
        spec, dropout, n_lanes, backend,
    )
    # per-lane mirror invariant: every lane keeps its own synced NeighborCache
    if cache_ops:
        for st in states:
            _assert_cache_invariant(st, union)


def test_multilane_unpacked_matches_packed():
    """Lane isolation is format-independent: the unpacked dense-q wire ships
    the same numbers as the packed payload wire, per lane."""
    a, _, _ = _multilane_vs_single_case(
        "roundrobin:ring,torus", 0.25, 2, "ppermute", packed=True)
    b, _, _ = _multilane_vs_single_case(
        "roundrobin:ring,torus", 0.25, 2, "ppermute", packed=False)
    for sa, sb in zip(a, b):
        assert _worst((sa.theta_hat, sa.s), (sb.theta_hat, sb.s)) == 0.0


def test_gt_tracker_off_bit_identical_to_choco():
    """K=1 tracker-off GradientTrackingConsensus == ChocoConsensus, bitwise,
    on both backends (the ISSUE-8 parity anchor)."""
    from repro.core.compression import make_compressor
    from repro.core.trainer import ChocoConsensus, GradientTrackingConsensus

    m = 8
    ring = topology.ring(m)
    comp = make_compressor("q4b")
    theta = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, 40))}
    key = jax.random.PRNGKey(3)
    for kw in ({}, {"backend": "ppermute", "mesh": _mesh1()}):
        cc = ChocoConsensus(ring, comp, 0.3, **kw)
        gc = GradientTrackingConsensus(ring, comp, 0.3, tracker=False, **kw)
        tc, sc = cc.mix(theta, cc.init(theta), key, None)
        tg, sg = gc.mix(theta, gc.init(theta), key, None, theta_prev=theta)
        assert _worst((tc, sc.theta_hat, sc.s), (tg, sg.theta_hat, sg.s)) == 0.0
        assert str(gc.wire_format) == str(cc.wire_format)


def test_gt_wire_format_and_bits_accounting():
    """Two-lane gt: wire_format gains the tracker lane, bits_per_round is
    exactly 2x the single-lane cost (per lane via bits_per_lane), and the
    trainer's per_iteration=True divides the two-lane cost by K."""
    from repro.core.compression import make_compressor
    from repro.core.trainer import ChocoConsensus, GradientTrackingConsensus
    from repro.core.wire import GT_LANES, Lane, WireFormat

    m = 8
    ring = topology.ring(m)
    sched = topology.make_topology_schedule("ring", m, dropout=0.2)
    comp = make_compressor("q4b")
    theta = {"w": jnp.zeros((m, 100))}

    cc = ChocoConsensus(ring, comp, 0.3)
    gc = GradientTrackingConsensus(ring, comp, 0.3)
    assert [str(l) for l in gc.wire_format] == ["payload", "tracker:payload"]
    assert gc.bits_per_round(theta) == 2.0 * cc.bits_per_round(theta)
    lanes = gc.bits_per_lane(theta)
    assert set(lanes) == {"model", "tracker"}
    assert sum(lanes.values()) == gc.bits_per_round(theta)
    # cached union wire -> two hat-delta lanes (the GT_LANES format)
    gcs = GradientTrackingConsensus(sched, comp, 0.3, backend="ppermute",
                                    mesh=_mesh1())
    assert str(gcs.wire_format) == str(GT_LANES) == "hat-delta+tracker:hat-delta"
    assert len(WireFormat((Lane("hat-delta"), Lane("digest", "tracker")))) == 2

    # trainer-level per-iteration accounting: the two-lane round spread
    # over K local iterations (the PR-2 DRFA fix, mirrored for gt)
    from benchmarks.common import make_adgda
    from repro.data import rotated_minority_classification

    data = rotated_minority_classification(num_nodes=6, seed=0)
    for k in (1, 4):
        tr, init_fn, _ = make_adgda("logistic", 6, compressor="q4b",
                                    consensus="gt", local_steps=k)
        st = tr.init(init_fn(data.dim, data.num_classes), jax.random.PRNGKey(0))
        assert tr.bits_per_round(st, per_iteration=True) == pytest.approx(
            tr.bits_per_round(st) / k
        )


def test_gt_tracker_compressor_none_matches_shared_compressor():
    """An explicit tracker compressor equal to the model lane's (with default
    gamma resolution) is bit-identical to the shared-compressor wire — the
    tracker_compressor=None legacy path is the same arithmetic."""
    from repro.core.compression import make_compressor
    from repro.core.trainer import GradientTrackingConsensus

    m = 8
    ring = topology.ring(m)
    comp = make_compressor("q4b")
    theta = {"w": jax.random.normal(jax.random.PRNGKey(1), (m, 40))}
    key = jax.random.PRNGKey(5)
    ga = GradientTrackingConsensus(ring, comp, None)
    gb = GradientTrackingConsensus(ring, comp, None, tracker_compressor="q4b")
    ta, sa = ga.mix(theta, ga.init(theta), key, None, theta_prev=theta)
    tb, sb = gb.mix(theta, gb.init(theta), key, None, theta_prev=theta)
    assert _worst((ta, sa.model.s, sa.tracker.s, sa.y),
                  (tb, sb.model.s, sb.tracker.s, sb.y)) == 0.0
    assert str(ga.wire_format) == str(gb.wire_format)


def test_gt_tracker_compressor_coarser_lane_bills_fewer_bits():
    """A q2b tracker beside a q4b model lane: the round runs, the tracker
    lane is billed at ITS compressor's cost (bits_per_lane), and the
    realized total scales by (1 + q2b/q4b) instead of 2x."""
    from repro.core.compression import make_compressor
    from repro.core.gossip import payload_total_bits
    from repro.core.trainer import GradientTrackingConsensus

    m = 8
    ring = topology.ring(m)
    comp = make_compressor("q4b")
    theta = {"w": jax.random.normal(jax.random.PRNGKey(2), (m, 64))}
    gc = GradientTrackingConsensus(ring, comp, None, tracker_compressor="q2b")
    t, s = gc.mix(theta, gc.init(theta), jax.random.PRNGKey(7), None,
                  theta_prev=theta)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(t))
    lanes = gc.bits_per_lane(theta)
    base = GradientTrackingConsensus(ring, comp, None)
    ref = base.bits_per_lane(theta)
    assert lanes["model"] == ref["model"]
    assert lanes["tracker"] < ref["tracker"]
    assert gc.bits_per_round(theta) == sum(lanes.values())
    tc = make_compressor("q2b")
    ratio = 1.0 + payload_total_bits(tc, theta) / payload_total_bits(comp, theta)
    assert float(gc.bits_realized(theta, None, None)) == pytest.approx(
        ratio / 2.0 * float(base.bits_realized(theta, None, None))
    )


def test_tracker_compressor_requires_gt_consensus():
    from benchmarks.common import make_adgda

    with pytest.raises(ValueError, match="tracker_compressor"):
        make_adgda("logistic", 6, compressor="q4b", consensus="choco",
                   tracker_compressor="q2b")


def test_gt_trainer_matches_mean_trajectory():
    """Network-mean invariant: with doubly-stochastic mixing the gt mean
    trajectory follows plain local SGD's (gossip preserves both lane means),
    so after any number of rounds mean(y) == mean(d_prev)."""
    from benchmarks.common import make_adgda
    from repro.data import rotated_minority_classification

    m = 6
    data = rotated_minority_classification(num_nodes=m, seed=0)
    tr, init_fn, _ = make_adgda("logistic", m, compressor="q4b",
                                consensus="gt", local_steps=2)
    st = tr.init(init_fn(data.dim, data.num_classes), jax.random.PRNGKey(0))
    gen = data.batches(40, seed=0)
    for _ in range(5):
        xb, yb = next(gen)
        st, _ = tr.step(st, (jnp.asarray(xb), jnp.asarray(yb)))
    for y, d in zip(jax.tree_util.tree_leaves(st.consensus.y),
                    jax.tree_util.tree_leaves(st.consensus.d_prev)):
        ym = np.asarray(y, np.float64).mean(0)
        dm = np.asarray(d, np.float64).mean(0)
        assert np.abs(ym - dm).max() < 1e-5, "tracker mean diverged from mean displacement"


def test_hypothesis_random_masks_keep_invariant():
    """Property test: arbitrary alive/dead patterns over arbitrary phase
    offsets never break the mirror invariant or the oracle parity."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    m = 6
    mesh = _mesh1()
    sched = topology.make_topology_schedule("roundrobin:ring,torus", m, dropout=0.5)
    union = compile_union_wire(compile_schedule_plans(sched))
    topo0 = sched.topology_at(0)
    comp = RandomQuantization(bits=4)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(8), (m, 32))}

    @jax.jit
    def step_p(t, s, k, st, mk):
        return gossip.choco_round(
            t, s, topo0, 0.3, comp, k, mask=mk, backend="ppermute",
            mesh=mesh, schedule=sched, step=st,
        )

    @jax.jit
    def step_o(t, s, k, mx, mk):
        return gossip.choco_round(t, s, topo0, 0.3, comp, k, mixing=mx, mask=mk)

    @settings(max_examples=8, deadline=None)
    @given(
        bits=st.lists(st.integers(0, (1 << m) - 1), min_size=1, max_size=3),
        step0=st.integers(0, 5),
    )
    def prop(bits, step0):
        masks = [
            jnp.array([(b >> i) & 1 for i in range(m)], jnp.float32)
            for b in bits
        ]
        state_p = gossip.choco_init(theta, cache_ops=union.n_ops)
        state_o = gossip.choco_init(theta)
        tp = to = theta
        for i, mask in enumerate(masks):
            step = jnp.int32(step0 + i)
            tp, state_p = step_p(tp, state_p, jax.random.PRNGKey(50 + i), step, mask)
            to, state_o = step_o(to, state_o, jax.random.PRNGKey(50 + i),
                                 sched.mixing_at(step, mask), mask)
        _assert_cache_invariant(state_p, union)
        assert _worst((to, state_o.theta_hat), (tp, state_p.theta_hat)) < 3e-6

    prop()
