"""ppermute-vs-rolled parity grid — run as a SUBPROCESS on a forced
multi-device CPU host (the device count must be set before jax initializes):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python tests/exchange_parity_main.py [--quick]

Exercised grid (the ISSUE-4 acceptance bar):

* static ring/torus (2 nodes per device) and erdos_renyi (1 node per device)
  x {identity, q4b packed+unpacked, kq4b packed, top25};
* the fused single-pass Pallas path (kq4b), jitted-vs-jitted;
* a dropout-masked time-varying schedule (roundrobin ring+torus) and a
  one-peer matching schedule — now on the NeighborCache hat-delta wire,
  with the mirror invariant (cache bit-identical to sender hats) re-checked
  on real devices after every scenario;
* full AD-GDA trainer steps on both backends (dual gossip riding the
  permutes), wire-honest DR-DSGD/DRFA baselines (ExactConsensus dense
  permutes / FedAvg psum), plus an eager (disable_jit) bit-identity check.

Parity levels: kernel-format payload paths (kq4b packed / fused) and eager
execution must be BIT-IDENTICAL; jitted f32 paths whose oracle is a dense
matmul (or whose mul-add chains XLA may contract to FMA differently across
the two programs) must agree to ~1 ULP per round (atol/rtol 2e-6 over 3
rounds).  Invoked by tests/test_exchange.py and the CI parity smoke job.
"""
import sys

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ADGDAConfig, adgda_trainer, gossip, topology
from repro.core.compression import Identity, RandomQuantization, TopK
from repro.core.exchange import mix_stacked_ppermute
from repro.kernels.ops import KernelQuantization
from repro.launch.mesh import make_cpu_mesh

CHECKS = []


def check(name, a, b, *, exact):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    worst = 0.0
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.shape == y.shape and x.dtype == y.dtype, name
        worst = max(worst, float(np.abs(x.astype(np.float64) - y.astype(np.float64)).max()))
    level = "EXACT" if exact else "~ULP "
    ok = worst == 0.0 if exact else worst < 2e-6
    CHECKS.append((name, level, worst, ok))
    print(f"{'PASS' if ok else 'FAIL'} [{level}] {name}: worst |diff| = {worst:.3e}")
    assert ok, f"{name}: parity violated (worst {worst:.3e}, wanted {level})"


def gossip_grid(mesh, quick):
    m_big, d = 8, 300
    theta8 = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (m_big, d)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (m_big, 7)),
    }
    theta4 = jax.tree.map(lambda x: x[:4], theta8)

    def run(theta, topo, comp, nrounds=3, **kw):
        state = gossip.choco_init(theta)
        f = jax.jit(lambda t, s, k: gossip.choco_round(t, s, topo, 0.25, comp, k, **kw))
        t, s = theta, state
        for i in range(nrounds):
            t, s = f(t, s, jax.random.PRNGKey(10 + i))
        return t, s

    combos = [
        ("identity", Identity(), dict(), False),
        ("q4b-unpacked", RandomQuantization(bits=4), dict(packed=False), False),
        ("q4b-packed", RandomQuantization(bits=4), dict(packed=True), False),
        ("kq4b-packed", KernelQuantization(bits=4), dict(packed=True), True),
        ("top25", TopK(fraction=0.25), dict(), True),
        ("kq4b-fused", KernelQuantization(bits=4), dict(fused=True), True),
    ]
    torus_combos = combos if not quick else [
        c for c in combos if c[0] in ("identity", "kq4b-packed", "kq4b-fused")
    ]
    topos = [("ring8", topology.ring(8), combos),
             ("torus8", topology.torus_2d(8), torus_combos)]
    for tname, topo, cs in topos:
        for cname, comp, kw, exact in cs:
            a = run(theta8, topo, comp, **kw)
            b = run(theta8, topo, comp, **kw, backend="ppermute", mesh=mesh)
            check(f"static/{tname}/{cname}", a, b, exact=exact)

    # irregular graph: one node per device
    er = topology.erdos_renyi(4, 0.6, seed=1)
    for cname, comp, kw, _ in combos[:4]:
        a = run(theta4, er, comp, **kw)
        b = run(theta4, er, comp, **kw, backend="ppermute", mesh=mesh)
        check(f"static/er4/{cname}", a, b, exact=False)


def _shared(t, s):
    """(theta, hat, s): the fields both backends carry — the rolled oracle
    has no NeighborCache."""
    return t, s.theta_hat, s.s


def _cache_invariant(name, state, union):
    """The NeighborCache invariant: after ANY prefix of masked/scheduled
    rounds, every mirror is BIT-IDENTICAL to the sender's theta_hat."""
    hats = jax.tree_util.tree_leaves(state.theta_hat)
    worst_bad = 0
    for k, snd in enumerate(union.senders):
        for hat, cleaf in zip(hats, jax.tree_util.tree_leaves(state.cache[k])):
            hat, cleaf = np.asarray(hat), np.asarray(cleaf)
            for i in range(hat.shape[0]):
                if snd[i] >= 0 and not (cleaf[i] == hat[snd[i]]).all():
                    worst_bad += 1
    ok = worst_bad == 0
    CHECKS.append((name, "EXACT", float(worst_bad), ok))
    print(f"{'PASS' if ok else 'FAIL'} [EXACT] {name}: {worst_bad} stale mirror rows")
    assert ok, f"{name}: NeighborCache diverged from sender hats"


def time_varying(mesh, quick):
    from repro.core.topology import compile_schedule_plans
    from repro.core.wire import compile_union_wire

    m, d = 8, 200
    theta = {"w": jax.random.normal(jax.random.PRNGKey(2), (m, d))}
    state = gossip.choco_init(theta)
    sched = topology.make_topology_schedule("roundrobin:ring,torus", m)
    union = compile_union_wire(compile_schedule_plans(sched))
    state_c = gossip.choco_init(theta, cache_ops=union.n_ops)
    topo0 = sched.topology_at(0)
    mask = jnp.array([1.0, 0.0, 1.0, 1.0, 1.0, 0.0, 1.0, 1.0])

    for cname, comp in [("identity", Identity()), ("q4b", RandomQuantization(bits=4))]:
        def oracle():
            t, s = theta, state
            f = jax.jit(lambda t, s, k, mx: gossip.choco_round(
                t, s, topo0, 0.25, comp, k, mixing=mx, mask=mask))
            for i in range(3):
                t, s = f(t, s, jax.random.PRNGKey(20 + i), sched.mixing_at(jnp.int32(i), mask))
            return t, s

        def spmd():
            t, s = theta, state_c
            f = jax.jit(lambda t, s, k, st: gossip.choco_round(
                t, s, topo0, 0.25, comp, k, mask=mask,
                backend="ppermute", mesh=mesh, schedule=sched, step=st))
            for i in range(3):
                t, s = f(t, s, jax.random.PRNGKey(20 + i), jnp.int32(i))
            return t, s

        to, so = oracle()
        tp, sp = spmd()
        check(f"masked-roundrobin/{cname}", _shared(to, so), _shared(tp, sp), exact=False)
        _cache_invariant(f"cache-invariant/masked-roundrobin/{cname}", sp, union)

    # one-peer matchings (irregular phases, one node per device)
    m4 = 4
    theta4 = {"w": jax.random.normal(jax.random.PRNGKey(3), (m4, d))}
    state4 = gossip.choco_init(theta4)
    msched = topology.make_topology_schedule("matching:3", m4, seed=0)
    munion = compile_union_wire(compile_schedule_plans(msched))
    state4_c = gossip.choco_init(theta4, cache_ops=munion.n_ops)
    mt0 = msched.topology_at(0)
    comp = RandomQuantization(bits=4)

    def oracle_m():
        t, s = theta4, state4
        f = jax.jit(lambda t, s, k, mx: gossip.choco_round(t, s, mt0, 0.25, comp, k, mixing=mx))
        for i in range(4):
            t, s = f(t, s, jax.random.PRNGKey(30 + i), msched.mixing_at(jnp.int32(i), None))
        return t, s

    def spmd_m():
        t, s = theta4, state4_c
        f = jax.jit(lambda t, s, k, st: gossip.choco_round(
            t, s, mt0, 0.25, comp, k, backend="ppermute", mesh=mesh,
            schedule=msched, step=st))
        for i in range(4):
            t, s = f(t, s, jax.random.PRNGKey(30 + i), jnp.int32(i))
        return t, s

    to, so = oracle_m()
    tp, sp = spmd_m()
    check("matching/q4b", _shared(to, so), _shared(tp, sp), exact=False)
    _cache_invariant("cache-invariant/matching/q4b", sp, munion)


def faulted_parity(mesh, quick):
    """Faulted-wire grid on real devices: {drop, corrupt} x {CHOCO, Exact},
    rolled vs ppermute.  The faulted round is the SAME _cached_round_body on
    both backends, so parity is BIT-EXACT — and the conditional mirror
    invariant (synced edges bit-identical to sender hats) holds on the
    sharded wire too."""
    from repro.core.exchange import (
        choco_round_cached_local, mix_stacked_faulted_local,
    )
    from repro.core.faults import FaultSpec
    from repro.core.topology import compile_schedule_plans
    from repro.core.wire import compile_union_wire

    m, d = 8, 120
    theta = {"w": jax.random.normal(jax.random.PRNGKey(4), (m, d))}
    sched = topology.make_topology_schedule("roundrobin:ring,torus", m)
    union = compile_union_wire(compile_schedule_plans(sched))
    topo0 = sched.topology_at(0)
    specs = [("drop", FaultSpec(drop=0.3, stale=1)),
             ("corrupt", FaultSpec(corrupt=0.3, stale=1))]

    comp = RandomQuantization(bits=4)
    for fname, spec in specs:
        def run_choco(backend):
            st = gossip.choco_init(theta, cache_ops=union.n_ops,
                                   fault_ops=union.n_ops)
            kw = dict(backend=backend)
            if backend == "ppermute":
                kw["mesh"] = mesh

            f = jax.jit(lambda t, s, k, fk, i: gossip.choco_round(
                t, s, topo0, 0.25, comp, k, schedule=sched, step=i,
                union=union, faults=spec, fault_key=fk, **kw))
            t = theta
            for i in range(3):
                t, st = f(t, st, jax.random.PRNGKey(40 + i),
                          jax.random.fold_in(jax.random.PRNGKey(8), i),
                          jnp.int32(i))
            return t, st

        a = run_choco("rolled")
        b = run_choco("ppermute")
        check(f"faulted/{fname}/choco", a, b, exact=True)
        _faulted_mirror_invariant(f"faulted-mirror/{fname}/choco", b[1], union)

        def run_exact(ppermute):
            t = theta
            for i in range(3):
                fk = jax.random.fold_in(jax.random.PRNGKey(9), i)
                if ppermute:
                    t, bits = mix_stacked_ppermute(
                        t, topo0, mesh=mesh, schedule=sched, step=jnp.int32(i),
                        union=union, faults=spec, fault_key=fk)
                else:
                    t, bits = mix_stacked_faulted_local(
                        t, union=union, schedule=sched, step=jnp.int32(i),
                        faults=spec, fault_key=fk)
            return t, bits

        a = run_exact(False)
        b = run_exact(True)
        check(f"faulted/{fname}/exact", a, b, exact=True)


def _faulted_mirror_invariant(name, state, union):
    """Conditional mirror invariant under faults: every edge the recovery
    state machine calls synced is bit-identical to the sender's hat."""
    hats = jax.tree_util.tree_leaves(state.theta_hat)
    synced = np.asarray(state.fault.synced)
    bad = 0
    for k, snd in enumerate(union.senders):
        for hat, cleaf in zip(hats, jax.tree_util.tree_leaves(state.cache[k])):
            hat, cleaf = np.asarray(hat), np.asarray(cleaf)
            for i in range(hat.shape[0]):
                if snd[i] >= 0 and synced[i, k] > 0 and not (cleaf[i] == hat[snd[i]]).all():
                    bad += 1
    ok = bad == 0
    CHECKS.append((name, "EXACT", float(bad), ok))
    print(f"{'PASS' if ok else 'FAIL'} [EXACT] {name}: {bad} bad synced mirrors")
    assert ok, f"{name}: synced mirror diverged from sender hat"


def trainer_parity(mesh, quick):
    def loss_fn(params, batch, rng):
        x, y = batch
        logits = x @ params["w"] + params["b"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return (logz - gold).mean()

    m, dim, C = 8, 20, 3
    params = {"w": jnp.zeros((dim, C)), "b": jnp.zeros((C,))}
    batch = (
        jax.random.normal(jax.random.PRNGKey(0), (m, 16, dim)),
        jax.random.randint(jax.random.PRNGKey(1), (m, 16), 0, C),
    )

    def run(extra, mesh_arg=None, steps=5):
        base = dict(num_nodes=m, topology="ring", compressor="q4b", alpha=0.05,
                    eta_theta=0.3, eta_lambda=0.2)
        base.update(extra)
        tr = adgda_trainer(ADGDAConfig(**base), loss_fn, mesh=mesh_arg)
        st = tr.init(params, jax.random.PRNGKey(42))
        for _ in range(steps):
            st, aux = tr.step(st, batch)
        return st

    def strip_cache(st):
        # the ppermute backend's consensus state carries the NeighborCache;
        # the rolled oracle has none — compare the shared fields
        cons = st.consensus
        if hasattr(cons, "cache"):
            cons = (cons.theta_hat, cons.s)
        return st._replace(consensus=cons)

    variants = [("adgda-ring", {}),
                ("fused-kq4b", dict(compressor="kq4b", fused_gossip=True))]
    if not quick:
        variants.append(
            ("rr+drop", dict(topology_schedule="roundrobin:ring,torus", dropout=0.25))
        )
    for name, kw in variants:
        a = run(kw)
        b = run(dict(kw, gossip_backend="ppermute"), mesh_arg=mesh)
        check(f"trainer/{name}", strip_cache(a), strip_cache(b), exact=False)


def baselines_parity(mesh, quick):
    """Wire-honest baselines: ExactConsensus (DR-DSGD) and FedAvg (DRFA)
    under backend='ppermute' reproduce their rolled oracles — every trainer
    in bench_comparison can now run mesh-native."""
    from repro.core.baselines import (
        DRDSGDConfig, DRFAConfig, drdsgd_trainer, drfa_trainer,
    )

    def loss_fn(params, batch, rng):
        x, y = batch
        logits = x @ params["w"] + params["b"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return (logz - gold).mean()

    m, dim, C = 8, 12, 3
    params = {"w": jnp.zeros((dim, C)), "b": jnp.zeros((C,))}

    def run(make, steps=4, drfa=False):
        tr = make()
        st = tr.init(params, jax.random.PRNGKey(7))
        if drfa:  # stacked layout: [m, K, b, ...]
            batch = (
                jax.random.normal(jax.random.PRNGKey(0), (m, 3, 8, dim)),
                jax.random.randint(jax.random.PRNGKey(1), (m, 3, 8), 0, C),
            )
        else:
            batch = (
                jax.random.normal(jax.random.PRNGKey(0), (m, 8, dim)),
                jax.random.randint(jax.random.PRNGKey(1), (m, 8), 0, C),
            )
        for _ in range(steps):
            st, _ = tr.step(st, batch)
        return st

    dcfg = dict(num_nodes=m, eta_theta=0.2, alpha=6.0)
    a = run(lambda: drdsgd_trainer(DRDSGDConfig(**dcfg), loss_fn))
    b = run(lambda: drdsgd_trainer(
        DRDSGDConfig(**dcfg, gossip_backend="ppermute"), loss_fn, mesh=mesh))
    check("baseline/drdsgd", a, b, exact=False)

    fcfg = dict(num_nodes=m, local_steps=3, eta_theta=0.2, eta_lambda=0.1)
    a = run(lambda: drfa_trainer(DRFAConfig(**fcfg), loss_fn), drfa=True)
    b = run(lambda: drfa_trainer(
        DRFAConfig(**fcfg, gossip_backend="ppermute"), loss_fn, mesh=mesh),
        drfa=True)
    check("baseline/drfa", a, b, exact=False)


def gt_parity(mesh, quick):
    """ISSUE-8 cell: gradient tracking on the multi-lane wire.  With the
    tracker off, GradientTrackingConsensus must be BIT-IDENTICAL to
    ChocoConsensus on both backends (the lane refactor cannot perturb the
    legacy single-lane path); with the tracker on, the rolled per-lane loop
    and the one-shard_map-body two-lane ppermute round agree to ~1 ULP
    across real devices (q4b, same bar as the single-lane static grid)."""
    from repro.core.trainer import ChocoConsensus, GradientTrackingConsensus

    m, d = 8, 64
    topo = topology.ring(m)
    comp = RandomQuantization(bits=4)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(11), (m, d)),
             "b": jax.random.normal(jax.random.PRNGKey(12), (m, 5))}
    theta_prev = jax.tree.map(lambda x: 0.9 * x, theta)

    def run(make, backend, rounds=3):
        kw = dict(backend=backend)
        if backend == "ppermute":
            kw["mesh"] = mesh
        gc = make(**kw)
        st = gc.init(theta)
        f = jax.jit(lambda t, tp, s, k: gc.mix(t, s, k, None, theta_prev=tp))
        t, tp = theta, theta_prev
        for i in range(rounds):
            t2, st = f(t, tp, st, jax.random.PRNGKey(50 + i))
            tp, t = t, t2
        return t, st

    for backend in ("rolled", "ppermute"):
        a = run(lambda **kw: ChocoConsensus(topo, comp, 0.25, **kw), backend)
        b = run(lambda **kw: GradientTrackingConsensus(
            topo, comp, 0.25, tracker=False, **kw), backend)
        check(f"gt-off/{backend}/q4b", a, b, exact=True)

    a = run(lambda **kw: GradientTrackingConsensus(topo, comp, 0.25, **kw),
            "rolled")
    b = run(lambda **kw: GradientTrackingConsensus(topo, comp, 0.25, **kw),
            "ppermute")
    check("gt-on/rolled-vs-ppermute/q4b", a, b, exact=False)


def eager_bit_identity(mesh):
    """disable_jit: both backends execute op-by-op — bit-identical even for
    the paths whose jitted programs differ by FMA contraction."""
    m, d = 4, 48
    topo = topology.ring(m)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(5), (m, d))}
    state = gossip.choco_init(theta)
    comp = RandomQuantization(bits=4)
    with jax.disable_jit():
        a = gossip.choco_round(theta, state, topo, 0.25, comp, jax.random.PRNGKey(9))
        b = gossip.choco_round(theta, state, topo, 0.25, comp, jax.random.PRNGKey(9),
                               backend="ppermute", mesh=mesh)
    check("eager/ring4/q4b", a, b, exact=True)


def wire_mix_parity(mesh):
    topo = topology.ring(8)
    lam = jax.random.normal(jax.random.PRNGKey(6), (8, 8))
    a = jax.jit(lambda x: gossip.mix_stacked(x, topo))(lam)
    b = jax.jit(lambda x: mix_stacked_ppermute(x, topo, mesh=mesh))(lam)
    # jit-vs-jit: XLA may FMA-contract the standalone global mul-add chain
    # but not the permute-broken one -> ~1 ULP (eager is bit-exact)
    check("wire-mix/ring8", a, b, exact=False)


def uneven_ratio_rejected(mesh):
    """Across real devices, irregular graphs need one node per device."""
    er = topology.erdos_renyi(8, 0.5, seed=0)  # block = 2 on 4 devices
    theta = {"w": jnp.zeros((8, 16))}
    state = gossip.choco_init(theta)
    try:
        gossip.choco_round(theta, state, er, 0.3, Identity(),
                           jax.random.PRNGKey(0), backend="ppermute", mesh=mesh)
    except ValueError as e:
        assert "one node per device" in str(e)
        print("PASS [ERROR] uneven-ratio irregular graph rejected")
        return
    raise AssertionError("block=2 irregular graph was not rejected")


def main():
    quick = "--quick" in sys.argv
    ndev = len(jax.devices())
    assert ndev >= 4, (
        f"need >= 4 devices, found {ndev}: run with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4"
    )
    mesh = make_cpu_mesh(data=4)
    uneven_ratio_rejected(mesh)
    gossip_grid(mesh, quick)
    time_varying(mesh, quick)
    faulted_parity(mesh, quick)
    trainer_parity(mesh, quick)
    baselines_parity(mesh, quick)
    gt_parity(mesh, quick)
    wire_mix_parity(mesh)
    eager_bit_identity(mesh)
    exact = sum(1 for _, lv, _, _ in CHECKS if lv == "EXACT")
    print(f"\nALL {len(CHECKS)} PARITY CHECKS PASSED ({exact} bit-exact)")


if __name__ == "__main__":
    main()
