"""Prefix KV cache contracts (serving fast path): hit-vs-miss bit-identical
outputs, LRU eviction, invalidation on hot reload (the garbled-cache analog
of the torn-checkpoint test — stale slices must never be served under new
weights), and recurrent/windowed-arch bypass."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              long_context_window=None)
    params = T.init_model(KEY, cfg)
    return cfg, params


def _serve(engine, prompt, n_new=4):
    req = Request(prompt=list(prompt), max_new_tokens=n_new)
    engine.run([req])
    return req.output


def test_prefix_hit_is_bit_identical(setup):
    """The same prompt served twice: the second pass skips the prefill
    (cache hit) and must emit the exact same tokens."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, 7).tolist()
    engine = ServeEngine(cfg, params, max_slots=2, cache_len=48, prompt_bucket=8)
    first = _serve(engine, prompt)
    assert engine.prefix_hits == 0 and engine.prefix_misses == 1
    second = _serve(engine, prompt)
    assert engine.prefix_hits == 1
    assert engine.prefill_skipped == 1
    assert second == first
    # a different prompt in the same bucket is a miss, not a false hit
    other = rng.integers(1, cfg.vocab_size, 7).tolist()
    _serve(engine, other)
    assert engine.prefix_hits == 1 and engine.prefix_misses == 2
    assert engine.stats()["cache_hit_rate"] == pytest.approx(1 / 3)


def test_prefix_lru_eviction(setup):
    """The cache is bounded: the least-recently-used prompt is evicted and
    must prefill again (counted), while a touched entry survives."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, 6).tolist() for _ in range(3)]
    engine = ServeEngine(cfg, params, max_slots=1, cache_len=48,
                         prompt_bucket=8, prefix_cache=2)
    _serve(engine, prompts[0])
    _serve(engine, prompts[1])
    _serve(engine, prompts[0])       # touch 0: now 1 is the LRU entry
    _serve(engine, prompts[2])       # evicts 1
    assert engine.prefix_evictions == 1
    hits = engine.prefix_hits
    _serve(engine, prompts[1])       # miss: it was evicted ({0,2} -> evict 0)
    assert engine.prefix_hits == hits
    assert engine.prefix_evictions == 2
    _serve(engine, prompts[1])       # immediate re-serve: now a hit
    assert engine.prefix_hits == hits + 1


def test_prefix_invalidated_on_hot_reload(setup):
    """Reassigning engine.params (the fleet hot-reload hook) clears the
    cache: post-reload generations must reflect the NEW weights, never a
    stale slice computed under the old ones."""
    cfg, params = setup
    params2 = T.init_model(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, 9).tolist()

    engine = ServeEngine(cfg, params, max_slots=1, cache_len=48, prompt_bucket=8)
    old_out = _serve(engine, prompt)
    assert engine.prefix_misses == 1

    engine.params = params2  # hot reload
    assert engine.prefix_invalidations == 1
    assert engine.stats()["prefix_entries"] == 0.0
    new_out = _serve(engine, prompt)
    assert engine.prefix_misses == 2  # recomputed, not served stale

    fresh = ServeEngine(cfg, params2, max_slots=1, cache_len=48, prompt_bucket=8)
    assert new_out == _serve(fresh, prompt)
    assert new_out != old_out  # different weights actually changed the tokens


def test_prefix_bypassed_for_recurrent_arch():
    """SSM states absorb every consumed token — a cached slice is
    position-dependent, so the prefix cache must not even count lookups."""
    cfg = dataclasses.replace(get_config("mamba2-1.3b").reduced(), ssm_chunk=8)
    params = T.init_model(KEY, cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, 16).tolist()
    engine = ServeEngine(cfg, params, max_slots=1, cache_len=64)
    a = _serve(engine, prompt)
    b = _serve(engine, prompt)
    assert engine.prefix_hits == engine.prefix_misses == 0
    assert engine.prefill_skipped == 0
    assert a == b  # determinism comes from the model, not the cache


def test_prefix_bypassed_for_windowed_arch():
    """A wrapped sliding-window ring buffer attends every slot; the engine
    prefills at exact length and must bypass the prefix cache."""
    cfg = get_config("qwen3-1.7b").reduced()  # 16-token sliding window
    params = T.init_model(KEY, cfg)
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, cfg.vocab_size, 7).tolist()
    engine = ServeEngine(cfg, params, max_slots=1, cache_len=32, prompt_bucket=8)
    assert engine._windowed
    _serve(engine, prompt)
    _serve(engine, prompt)
    assert engine.prefix_hits == engine.prefix_misses == 0
