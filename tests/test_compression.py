"""Compression operators: Assumption 3.2 contraction + wire-format roundtrips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.compression import (
    BlockTopK,
    Identity,
    RandomQuantization,
    TopK,
    make_compressor,
)

KEY = jax.random.PRNGKey(0)


def contraction_ratio(comp, x, n_trials=32):
    """Monte-Carlo estimate of E||Q(x)-x||^2 / ||x||^2."""
    keys = jax.random.split(KEY, n_trials)
    errs = jnp.stack([jnp.sum((comp(x, k) - x) ** 2) for k in keys])
    return float(errs.mean() / jnp.maximum(jnp.sum(x**2), 1e-30))


# ------------------------------------------------------------------ assumption 3.2
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantization_contraction(bits):
    comp = RandomQuantization(bits=bits)
    x = jax.random.normal(jax.random.PRNGKey(1), (4096,))
    delta = comp.delta_for(4096)
    assert contraction_ratio(comp, x) <= (1 - delta) + 0.05


@pytest.mark.parametrize("fraction", [0.5, 0.25, 0.1])
def test_topk_contraction(fraction):
    comp = TopK(fraction=fraction)
    x = jax.random.normal(jax.random.PRNGKey(2), (2048,))
    # top-k is deterministic: exact bound, no expectation needed
    err = float(jnp.sum((comp(x) - x) ** 2) / jnp.sum(x**2))
    assert err <= (1 - fraction) + 1e-6


@pytest.mark.parametrize("fraction", [0.5, 0.25, 0.1])
def test_block_topk_contraction(fraction):
    comp = BlockTopK(fraction=fraction, block=256)
    x = jax.random.normal(jax.random.PRNGKey(3), (2048,))
    err = float(jnp.sum((comp(x) - x) ** 2) / jnp.sum(x**2))
    assert err <= (1 - fraction) + 1e-6


@settings(max_examples=25, deadline=None)
@given(
    data=st.lists(st.floats(-100, 100, allow_nan=False), min_size=8, max_size=300),
    fraction=st.sampled_from([0.1, 0.25, 0.5, 1.0]),
)
def test_property_topk_contraction_any_vector(data, fraction):
    x = jnp.asarray(data, jnp.float32)
    comp = TopK(fraction=fraction)
    err = float(jnp.sum((comp(x) - x) ** 2))
    assert err <= (1 - fraction) * float(jnp.sum(x**2)) + 1e-3


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    bits=st.sampled_from([2, 4, 6, 8]),
    d=st.sampled_from([64, 257, 1024]),
)
def test_property_quantization_contraction(seed, bits, d):
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    comp = RandomQuantization(bits=bits)
    ratio = contraction_ratio(comp, x, n_trials=8)
    assert ratio <= (1 - comp.delta_for(d)) + 0.15  # MC slack


# ------------------------------------------------------------------ exactness
def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3])
    out = TopK(fraction=0.25)(x)  # k = 2
    np.testing.assert_allclose(np.asarray(out), [0, -5.0, 0, 3.0, 0, 0, 0, 0], atol=1e-7)


def test_block_topk_is_per_block():
    # one huge value per block must always survive regardless of other blocks
    x = jnp.zeros((512,)).at[0].set(100.0).at[256].set(0.001)
    out = BlockTopK(fraction=0.01, block=256)(x)  # k_b >= 1 per block
    assert float(out[0]) == pytest.approx(100.0)
    assert float(out[256]) == pytest.approx(0.001)


def test_quantization_preserves_sign_and_scale():
    x = jnp.asarray([1.0, -1.0, 0.5, -0.5] * 64)
    comp = RandomQuantization(bits=8)
    q = comp(x, jax.random.PRNGKey(0))
    assert float(jnp.max(jnp.abs(q - x))) < 0.2
    assert (jnp.sign(q) * jnp.sign(x) >= 0).all()  # no sign flips


def test_identity_exact():
    x = jax.random.normal(KEY, (100,))
    np.testing.assert_array_equal(np.asarray(Identity()(x)), np.asarray(x))


# ------------------------------------------------------------------ payloads
def test_quantization_payload_is_packed_ints():
    comp = RandomQuantization(bits=4)
    payload = comp.encode(jax.random.normal(KEY, (1024,)), KEY)
    assert payload["levels"].dtype == jnp.uint8
    assert payload["signs"].dtype == jnp.bool_


def test_payload_roundtrip_under_jit_and_vmap():
    comp = BlockTopK(fraction=0.25, block=128)
    x = jax.random.normal(KEY, (4, 640))  # stacked node axis

    @jax.jit
    def roundtrip(xs):
        payload = jax.vmap(comp.encode)(xs, jax.random.split(KEY, 4))
        return jax.vmap(lambda p: comp.decode(p, (640,), jnp.float32))(payload)

    out = roundtrip(x)
    assert out.shape == x.shape
    # decoded values are a subset of the original entries
    mask = out != 0
    np.testing.assert_allclose(np.asarray(out[mask]), np.asarray(x[mask]), rtol=1e-6)


# ------------------------------------------------------------------ factory/bits
def test_make_compressor_specs():
    assert isinstance(make_compressor("none"), Identity)
    assert make_compressor("q4b").bits == 4
    assert make_compressor("top10").fraction == pytest.approx(0.10)
    assert make_compressor("btop25").fraction == pytest.approx(0.25)
    with pytest.raises(ValueError):
        make_compressor("bogus")


def test_bits_per_element_ordering():
    d = 1 << 20
    b4 = RandomQuantization(bits=4).bits_per_element(d)
    b8 = RandomQuantization(bits=8).bits_per_element(d)
    t10 = TopK(fraction=0.10).bits_per_element(d)
    assert b4 < b8 < 32
    assert t10 < 32


def test_topk_bits_exact_at_small_d():
    """bits_per_element must bill what encode actually transmits: 64 bits per
    *kept* element, k = k_for(d) — not the unrounded fraction (regression:
    raw `64 * fraction` was wrong whenever round(fraction * d) != fraction*d,
    and ignored the k >= 1 floor entirely)."""
    tk = TopK(fraction=0.25)
    for d in (1, 2, 3, 5, 10, 1024):
        k = tk.k_for(d)
        payload = tk.encode(jnp.arange(1.0, d + 1.0))
        assert payload["values"].shape[0] == k
        assert tk.bits_per_element(d) == pytest.approx(64.0 * k / d)
    # d=2 @ 25%: keeps 1 of 2 elements (k floor), i.e. 32 bits/elem, not 16
    assert tk.bits_per_element(2) == pytest.approx(32.0)
    # large d: converges to the fraction-based estimate
    assert tk.bits_per_element(1 << 20) == pytest.approx(64.0 * 0.25, rel=1e-5)


def test_dead_topk_mask_helper_removed():
    from repro.core import compression

    assert not hasattr(compression, "_topk_mask")
