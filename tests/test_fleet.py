"""Serving fleet: admission control under overload, hot reload that can
never serve a torn checkpoint, per-node quality tracking across reloads,
and the metrics layer's invariants."""
import numpy as np
import pytest

from repro.checkpoint import save, step_path
from repro.serving import (
    AdmissionControl,
    ClassifierEngine,
    EvalRequest,
    FleetNode,
    HotReloader,
    LoadGenConfig,
    LoadGenerator,
    ServingFleet,
)
from repro.serving.metrics import percentiles, summarize_fleet


def _apply(params, x):
    return x @ params["w"]


def _params(scale=1.0, dim=4, classes=3):
    return {"w": np.eye(dim, classes) * scale}


def _eval_payload(dim=4, classes=3):
    def payload(node, rng, plen, max_new):
        y = rng.integers(0, classes)
        x = np.zeros((1, dim), np.float32)
        x[0, y] = 1.0
        x += rng.normal(size=(1, dim)).astype(np.float32) * 0.05
        return EvalRequest(features=x, labels=np.asarray([y], np.int32))
    return payload


def _fleet(m=2, rate=0.8, max_queue=4, policy="reject", slots=2, seed=0, params=None):
    gen = LoadGenerator(
        LoadGenConfig(num_nodes=m, rate=rate, vocab_size=16, seed=seed),
        payload=_eval_payload(),
    )
    nodes = [
        FleetNode(
            i,
            ClassifierEngine(_apply, params or _params(), max_slots=slots),
            admission=AdmissionControl(max_queue=max_queue, policy=policy),
        )
        for i in range(m)
    ]
    return ServingFleet(nodes, gen)


# ---------------------------------------------------------------- admission
def test_fleet_completes_all_requests_under_light_load():
    fleet = _fleet(rate=0.3)
    rep = fleet.run(max_requests=80, max_ticks=2000)
    assert rep.offered >= 80
    assert rep.fleet["completed"] == rep.offered
    assert rep.fleet["rejected"] == 0 and rep.fleet["shed"] == 0
    assert rep.fleet["p50_ttft_ticks"] <= rep.fleet["p95_ttft_ticks"] <= rep.fleet["p99_ttft_ticks"]


def test_bounded_queue_rejects_under_overload():
    """Offered load >> capacity: the queue bound holds, overflow is rejected,
    and accounting is exact (completed + rejected == offered once drained)."""
    fleet = _fleet(m=1, rate=6.0, max_queue=3, slots=1)
    rep = fleet.run(max_requests=100, max_ticks=3000)
    assert rep.fleet["rejected"] > 0
    assert rep.fleet["max_queue_depth"] <= 3
    assert rep.fleet["completed"] + rep.fleet["rejected"] == rep.offered
    for r in fleet.nodes[0].requests:
        assert r.status in ("done", "rejected")


def test_shed_oldest_evicts_queued_not_arrivals():
    fleet = _fleet(m=1, rate=6.0, max_queue=3, slots=1, policy="shed_oldest")
    rep = fleet.run(max_requests=100, max_ticks=3000)
    assert rep.fleet["shed"] > 0 and rep.fleet["rejected"] == 0
    assert rep.fleet["max_queue_depth"] <= 3
    assert rep.fleet["completed"] + rep.fleet["shed"] == rep.offered
    node = fleet.nodes[0]
    shed = [r for r in node.requests if r.status == "shed"]
    done = [r for r in node.requests if r.status == "done"]
    # a shed request was evicted before service: it never got a first token
    assert all(r.admit_tick < 0 for r in shed)
    assert all(r.admit_tick >= 0 for r in done)


def test_admission_rejects_unknown_policy():
    with pytest.raises(ValueError):
        AdmissionControl(max_queue=2, policy="drop-newest")


# --------------------------------------------------------------- hot reload
def test_hot_reloader_never_serves_torn_checkpoint(tmp_path):
    """A garbage file at the newest step is skipped (with the fallback to
    the last complete one), and a subsequent atomic save is picked up."""
    prefix = str(tmp_path / "consensus")
    good = _params(scale=2.0)
    save(prefix, good, step=1)
    # a torn checkpoint, as a non-atomic writer would leave it
    with open(step_path(prefix, 2), "wb") as f:
        f.write(b"\x00garbage not a zip")

    logs = []
    rl = HotReloader(prefix, _params(), log=logs.append)
    tree, step = rl.poll()
    assert step == 1 and np.allclose(tree["w"], good["w"])
    assert rl.skipped == 1 and any("unreadable" in l for l in logs)

    # nothing new: poll is a no-op (the torn file is not retried as "new")
    assert rl.poll() is None

    newer = _params(scale=3.0)
    save(prefix, newer, step=3)
    tree, step = rl.poll()
    assert step == 3 and np.allclose(tree["w"], newer["w"])
    assert rl.reloads == 2


def test_hot_reloader_inflight_tmp_is_invisible(tmp_path):
    """The atomic-save machinery's in-flight .tmp file is never a candidate."""
    prefix = str(tmp_path / "consensus")
    save(prefix, _params(), step=1)
    with open(step_path(prefix, 2) + ".tmp", "wb") as f:
        f.write(b"partial write in progress")
    rl = HotReloader(prefix, _params())
    _, step = rl.poll()
    assert step == 1


def test_fleet_hot_reload_swaps_params_and_tracks_quality(tmp_path):
    """Nodes serving a broken model reload a good checkpoint mid-run: served
    accuracy recovers and the quality timeline records the transition."""
    prefix = str(tmp_path / "consensus")
    bad = {"w": -np.eye(4, 3)}  # anti-diagonal: always wrong
    good = _params(scale=1.0)

    rng = np.random.default_rng(0)
    val_x = np.eye(4, dtype=np.float32)[rng.integers(0, 3, 64)]
    val_y = val_x[:, :3].argmax(-1)

    def quality(params):
        pred = np.asarray(_apply(params, val_x)).argmax(-1)
        return {"acc": float((pred == val_y).mean())}

    gen = LoadGenerator(
        LoadGenConfig(num_nodes=1, rate=0.5, vocab_size=16, seed=3),
        payload=_eval_payload(),
    )
    node = FleetNode(
        0,
        ClassifierEngine(_apply, bad, max_slots=2),
        admission=AdmissionControl(max_queue=8),
        reloader=HotReloader(prefix, _params(), log=lambda s: None),
        quality_fn=quality,
    )
    fleet = ServingFleet([node], gen, reload_every=5)
    fleet.run(max_requests=30, max_ticks=200)
    assert node.reloader.reloads == 0  # nothing to load yet

    save(prefix, good, step=10)
    rep = fleet.run(max_requests=60, max_ticks=400)
    assert node.reloader.reloads == 1 and node.reloader.step == 10
    assert np.allclose(node.engine.params["w"], good["w"])
    # timeline: initial probe (step None, broken) then the reload (step 10)
    (s0, q0), (s1, q1) = node.quality_timeline
    assert s0 is None and q0["acc"] == 0.0
    assert s1 == 10 and q1["acc"] == 1.0
    # served requests after the reload are answered by the good model
    served_after = [
        r for r in node.requests
        if r.status == "done" and r.admit_tick is not None and r.admit_tick >= 0
        and r.admit_tick > 5 and r.labels is not None
    ]
    late = [r for r in served_after if r.admit_tick >= rep.ticks - 50]
    correct = [int(r.output[0]) == int(r.labels[0]) for r in late]
    assert correct and np.mean(correct) > 0.9


# ------------------------------------------------------------------ metrics
def test_percentiles_and_fleet_rollup():
    p = percentiles([1, 2, 3, 4, 100])
    assert p[50] <= p[95] <= p[99] == 100
    assert percentiles([])[99] == 0.0
    assert summarize_fleet([], [])["requests"] == 0


def test_metrics_ttft_is_queue_wait():
    """With one slot and single-tick service, the k-th of a burst of
    simultaneous arrivals waits exactly k ticks."""
    eng = ClassifierEngine(_apply, _params(), max_slots=1)
    node = FleetNode(0, eng, admission=AdmissionControl(max_queue=100))
    reqs = [_eval_payload()(0, np.random.default_rng(i), 0, 0) for i in range(5)]
    for r in reqs:
        node.offer(r, tick=0)
    for _ in range(6):
        node.tick()
    assert [r.ttft_ticks for r in reqs] == [0, 1, 2, 3, 4]


# ------------------------------------------------------------- batched probe
def test_batched_probe_matches_per_population_eval():
    """ONE concatenated forward must reproduce the per-population accuracies
    an eager per-node probe would compute."""
    from repro.serving import BatchedProbe

    rng = np.random.default_rng(0)
    params = _params(scale=2.0)
    pops = {}
    for name in ("a", "b", "c"):
        y = rng.integers(0, 3, 17)
        x = np.zeros((17, 4), np.float32)
        x[np.arange(17), y] = 1.0
        x += rng.normal(size=x.shape).astype(np.float32) * 0.3
        pops[name] = (x, y)
    probe = BatchedProbe(_apply, pops)
    got = probe.probe(params, step=0)
    for name, (x, y) in pops.items():
        ref = float((np.argmax(_apply(params, x), axis=-1) == y).mean())
        assert got[name]["acc"] == pytest.approx(ref)
    fn = probe.quality_fn("b")
    assert fn.accepts_step
    assert fn(params, step=0) == got["b"]


def test_batched_probe_memoizes_per_step():
    """N nodes probing the same checkpoint step share ONE device forward;
    a new step (even with an equal-valued tree) re-evaluates."""
    from repro.serving import BatchedProbe

    rng = np.random.default_rng(1)
    y = rng.integers(0, 3, 9)
    x = rng.normal(size=(9, 4)).astype(np.float32)
    probe = BatchedProbe(_apply, {"a": (x, y), "b": (x, y)})
    p1, p2 = _params(), _params()  # separate-but-equal trees (hot reload)
    fa, fb = probe.quality_fn("a"), probe.quality_fn("b")
    fa(p1, step=10)
    fb(p2, step=10)  # different object, same step -> memo hit
    assert probe.probe_forwards == 1
    fa(p1, step=20)
    assert probe.probe_forwards == 2
    fb(_params(scale=3.0), step=20)  # stale tree, same step: still shared
    assert probe.probe_forwards == 2


# ------------------------------------------------------------- retain="stats"
def test_retain_stats_summary_matches_retain_all():
    """retain="stats" streams requests into an accumulator; every gateable
    (tick-denominated) field and count must equal the list-based path."""
    reports = {}
    for retain in ("all", "stats"):
        gen = LoadGenerator(
            LoadGenConfig(num_nodes=2, rate=1.5, vocab_size=16, seed=3),
            payload=_eval_payload(),
        )
        nodes = [
            FleetNode(
                i,
                ClassifierEngine(_apply, _params(), max_slots=2),
                admission=AdmissionControl(max_queue=2, policy="reject"),
                retain=retain,
            )
            for i in range(2)
        ]
        reports[retain] = ServingFleet(nodes, gen).run(max_requests=120, max_ticks=4000)
    a, s = reports["all"], reports["stats"]
    assert a.offered == s.offered and a.ticks == s.ticks
    for key in ("requests", "completed", "rejected", "shed", "tokens",
                "p50_ttft_ticks", "p95_ttft_ticks", "p99_ttft_ticks",
                "mean_queue_depth", "max_queue_depth", "slot_occupancy"):
        assert a.fleet[key] == s.fleet[key], key
    assert s.fleet["requests"] == s.offered


def test_retain_stats_bounds_live_requests():
    """The accumulator path drops terminal Request objects every tick."""
    gen = LoadGenerator(
        LoadGenConfig(num_nodes=1, rate=1.0, vocab_size=16, seed=4),
        payload=_eval_payload(),
    )
    node = FleetNode(0, ClassifierEngine(_apply, _params(), max_slots=2),
                     admission=AdmissionControl(max_queue=4), retain="stats")
    ServingFleet([node], gen).run(max_requests=100, max_ticks=4000)
    assert node.stats.requests >= 100
    assert len(node.requests) == 0  # drained: nothing in flight
    with pytest.raises(ValueError):
        FleetNode(0, ClassifierEngine(_apply, _params()), retain="bogus")
