"""End-to-end behaviour of the full system: the paper's central claims on
the synthetic heterogeneous pipeline, driver round-trips, checkpointing.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ADGDAConfig, adgda_trainer, choco_sgd
from repro.data import (
    class_shard_classification,
    instrument_shift_classification,
    rotated_minority_classification,
)

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ shared setup
def _logistic_init(dim, classes):
    return {"w": jnp.zeros((dim, classes)), "b": jnp.zeros((classes,))}


def _logistic_loss(params, batch, rng):
    x, y = batch
    logits = x @ params["w"] + params["b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()


def _accuracy(params, x, y):
    pred = np.asarray(jnp.argmax(x @ params["w"] + params["b"], axis=-1))
    return float((pred == np.asarray(y)).mean())


def _train(trainer, data, steps=150, batch=64, seed=0):
    params = _logistic_init(data.dim, data.num_classes)
    state = trainer.init(params, jax.random.PRNGKey(seed))
    gen = data.batches(batch, seed=seed)
    for _ in range(steps):
        xb, yb = next(gen)
        state, _ = trainer.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
    return trainer.network_mean(state), state


def _worst_val_acc(params, data):
    return min(_accuracy(params, jnp.asarray(x), y) for x, y in zip(data.val_x, data.val_y))


# ------------------------------------------------------------- paper claims
def test_adgda_beats_choco_sgd_worst_node():
    """Paper Table 2's qualitative claim: distributionally robust training
    massively improves the worst-distribution accuracy at the same budget.
    Uses the rotated-minority construction (no linear model fits both
    sub-populations, so average-risk training sacrifices the minority)."""
    m = 10
    data = rotated_minority_classification(num_nodes=m, seed=1)
    common = dict(num_nodes=m, topology="ring", compressor="q4b", eta_theta=0.3, lr_decay=0.99)
    robust = adgda_trainer(ADGDAConfig(alpha=0.05, eta_lambda=0.2, **common), _logistic_loss)
    standard = choco_sgd(ADGDAConfig(**common), _logistic_loss)
    p_r, _ = _train(robust, data, steps=600, batch=50)
    p_s, _ = _train(standard, data, steps=600, batch=50)
    w_r, w_s = _worst_val_acc(p_r, data), _worst_val_acc(p_s, data)
    assert w_r > w_s + 0.05, f"robust {w_r:.3f} vs standard {w_s:.3f}"


def test_adgda_closes_instrument_gap():
    """COOS7-analog: the accuracy gap between the two 'microscopes' shrinks
    under AD-GDA (paper Fig. 2 / Table 4b)."""
    data = instrument_shift_classification(num_nodes=10, minority_nodes=2, seed=1)
    common = dict(num_nodes=10, topology="torus", compressor="q8b", eta_theta=0.5)
    robust = adgda_trainer(ADGDAConfig(alpha=0.01, eta_lambda=0.05, **common), _logistic_loss)
    standard = choco_sgd(ADGDAConfig(**common), _logistic_loss)
    p_r, _ = _train(robust, data, steps=200)
    p_s, _ = _train(standard, data, steps=200)

    def gap(p):
        accs = [_accuracy(p, jnp.asarray(x), y) for x, y in zip(data.val_x, data.val_y)]
        return abs(accs[0] - accs[1])

    assert gap(p_r) < gap(p_s) + 1e-6
    assert _worst_val_acc(p_r, data) >= _worst_val_acc(p_s, data) - 0.02


def test_smaller_alpha_more_robust():
    """Paper Table 4: smaller regularization -> less constrained adversary ->
    better worst-case accuracy (alpha=inf recovers standard training)."""
    m = 10
    data = rotated_minority_classification(num_nodes=m, seed=2)
    worst = {}
    for alpha in (100.0, 0.05):
        tr = adgda_trainer(
            ADGDAConfig(num_nodes=m, topology="ring", compressor="none",
                        alpha=alpha, eta_theta=0.3, eta_lambda=0.2, lr_decay=0.99),
            _logistic_loss,
        )
        p, _ = _train(tr, data, steps=600, batch=50)
        worst[alpha] = _worst_val_acc(p, data)
    assert worst[0.05] > worst[100.0] + 0.03, worst


def test_consensus_error_decreases():
    """CHOCO consensus: with a decaying step the node models converge."""
    m = 6
    data = class_shard_classification(num_nodes=m, dim=16, seed=0)
    tr = adgda_trainer(
        ADGDAConfig(num_nodes=m, topology="ring", compressor="q8b",
                    alpha=0.1, eta_theta=0.3, eta_lambda=0.02, lr_decay=0.97),
        _logistic_loss,
    )
    params = _logistic_init(data.dim, data.num_classes)
    state = tr.init(params, KEY)
    gen = data.batches(32, seed=0)
    errs = []
    for _ in range(120):
        xb, yb = next(gen)
        state, aux = tr.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
        errs.append(float(aux["consensus_err"]))
    assert np.mean(errs[-10:]) < 0.25 * max(errs) + 1e-8


def test_dual_variable_upweights_worst_node():
    """lambda must concentrate on the node with the largest loss."""
    m = 4
    rng = np.random.default_rng(0)
    x = rng.normal(size=(m, 256, 8)).astype(np.float32)
    w_true = rng.normal(size=(8,))
    y = np.stack([
        (x[i] @ w_true > 0).astype(np.int32) if i < 3
        else rng.integers(0, 2, 256).astype(np.int32)  # node 3: pure noise
        for i in range(m)
    ])
    tr = adgda_trainer(
        ADGDAConfig(num_nodes=m, topology="mesh", compressor="none",
                    alpha=0.05, eta_theta=0.3, eta_lambda=0.1),
        _logistic_loss,
    )
    state = tr.init(_logistic_init(8, 2), KEY)
    for _ in range(150):
        idx = rng.integers(0, 256, (m, 32))
        xb = np.take_along_axis(x, idx[:, :, None], 1)
        yb = np.take_along_axis(y, idx, 1)
        state, aux = tr.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
    lam = np.asarray(state.lam).mean(0)
    assert lam[3] == lam.max()
    assert lam[3] > 1.5 / m


# --------------------------------------------------------------- drivers
@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
         "--reduced", "--steps", "3", "--nodes", "2", "--batch-per-node", "1",
         "--seq", "32", "--log-every", "1",
         "--checkpoint", str(tmp_path / "ckpt")],
        capture_output=True, text=True, cwd="/root/repo", env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "worst=" in out.stdout


@pytest.mark.slow
def test_serve_driver_end_to_end():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "recurrentgemma-2b",
         "--reduced", "--batch", "2", "--prompt-len", "16", "--gen", "6"],
        capture_output=True, text=True, cwd="/root/repo", env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ms/token" in out.stdout


def test_checkpoint_roundtrip_model(tmp_path):
    from repro.checkpoint import latest_step, restore, save
    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config("qwen3-1.7b").reduced()
    params = T.init_model(KEY, cfg)
    fname = save(str(tmp_path / "model"), params, step=7)
    assert latest_step(str(tmp_path / "model")) == 7
    back = restore(fname, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_local_steps_trade_compute_for_communication():
    """Paper §6 extension: K local SGD steps between gossip rounds.  At an
    EQUAL communication budget (same number of gossip rounds) and a local
    learning rate scaled down to bound consensus drift, K=5 matches or beats
    the fully-communicating run — i.e. local computation substitutes for
    communication.  (The naive 1/K-rounds framing was measured first and
    refuted: at eta 0.3 the drift costs ~33 pts; recorded in EXPERIMENTS.)"""
    m = 8
    data = rotated_minority_classification(num_nodes=m, seed=0)

    def run(local_steps, eta, rounds=600):
        cfg = ADGDAConfig(num_nodes=m, topology="ring", compressor="q4b",
                          alpha=0.05, eta_theta=eta, eta_lambda=0.2,
                          lr_decay=0.99, local_steps=local_steps)
        tr = adgda_trainer(cfg, _logistic_loss)
        state = tr.init(_logistic_init(data.dim, data.num_classes), jax.random.PRNGKey(0))
        gen = data.batches(50 * local_steps, seed=0)
        for _ in range(rounds):
            xb, yb = next(gen)
            state, _ = tr.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
        return _worst_val_acc(tr.network_mean(state), data), tr.bits_per_round(state) * rounds

    w1, bits1 = run(1, eta=0.3)
    w5, bits5 = run(5, eta=0.1)
    assert bits5 == pytest.approx(bits1, rel=1e-6)  # same wire budget
    assert w5 > w1 - 0.03, (w5, w1)
