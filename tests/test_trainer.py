"""The composable DecentralizedTrainer API: shims, new compositions,
local_steps x momentum, bits accounting."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ADGDAConfig,
    ChocoConsensus,
    DecentralizedTrainer,
    DRDSGDConfig,
    DRFAConfig,
    ExactConsensus,
    LocalUpdate,
    ProjectedAscent,
    TrainerState,
    adgda_trainer,
    drfa_trainer,
)
from repro.core import dro
from repro.core.topology import make_topology
from repro.optim import make_schedule, sgd

M = 6


def _quadratic_loss():
    def loss_fn(params, batch, rng):
        return 0.5 * jnp.sum((params["w"] - batch["mu"]) ** 2)

    batch = {"mu": jnp.asarray([[-3.0], [0.0], [0.0], [0.0], [0.0], [3.0]])}
    return loss_fn, batch


# ------------------------------------------------------------------- shims
def test_deprecated_shims_importable_with_old_signatures():
    from repro.core import ADGDA, DRDSGD, DRFA
    from repro.core.adgda import ADGDAState  # noqa: F401 (alias import works)

    loss_fn, batch = _quadratic_loss()
    with pytest.warns(DeprecationWarning):
        tr = ADGDA(ADGDAConfig(num_nodes=M, compressor="q4b"), loss_fn)
    state = tr.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    state, aux = tr.step(state, batch)
    assert np.isfinite(float(aux["mean_loss"]))
    assert tr.bits_per_round(state) > 0
    assert isinstance(tr, DecentralizedTrainer)

    with pytest.warns(DeprecationWarning):
        tr = DRDSGD(DRDSGDConfig(num_nodes=M, alpha=1.0), loss_fn)
    state = tr.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    state, aux = tr.step(state, batch)
    assert np.isfinite(float(aux["worst_loss"]))

    with pytest.warns(DeprecationWarning):
        tr = DRFA(DRFAConfig(num_nodes=M, local_steps=2), loss_fn)
    kb = {"mu": jnp.broadcast_to(batch["mu"][:, None], (M, 2, 1))}
    state = tr.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    state, aux = tr.step(state, kb)
    assert np.isfinite(float(aux["worst_loss"]))


# ------------------------------------------------ local_steps x momentum
def test_local_steps_composes_with_momentum():
    """The seed trainer asserted local_steps and momentum mutually exclusive;
    with the optimizer carried in trainer state they compose."""
    loss_fn, _ = _quadratic_loss()
    K = 4
    # asymmetric: w=0 starts at worst 18; robust optimum balances to ~4.5
    offsets = jnp.asarray([[0.0]] * 5 + [[6.0]])
    cfg = ADGDAConfig(num_nodes=M, topology="ring", compressor="q8b", alpha=0.05,
                      eta_theta=0.03, eta_lambda=0.1, lr_decay=0.97,
                      local_steps=K, momentum=0.9)
    tr = adgda_trainer(cfg, loss_fn)
    kb = {"mu": jnp.repeat(offsets, K, axis=1)}
    state = tr.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    for _ in range(200):
        state, aux = tr.step(state, kb)
    # momentum buffer exists, is stacked, and was actually used
    assert state.opt.mu["w"].shape == (M, 1)
    assert float(jnp.abs(state.opt.mu["w"]).max()) > 0
    # moved substantially toward the robust solution despite K-step drift
    assert float(aux["worst_loss"]) < 9.0
    assert float(aux["consensus_err"]) < 0.5


def test_local_steps_one_equals_single_step_path():
    """K=1 must reduce to the single-step oracle bit-for-bit (same ops)."""
    loss_fn, batch = _quadratic_loss()
    base = ADGDAConfig(num_nodes=M, topology="ring", compressor="q8b", alpha=0.05,
                       eta_theta=0.05, eta_lambda=0.05, momentum=0.9)
    t1 = adgda_trainer(base, loss_fn)
    tk = adgda_trainer(dataclasses.replace(base, local_steps=1), loss_fn)
    s1 = t1.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    sk = tk.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    with jax.disable_jit():
        for _ in range(3):
            s1, _ = t1.step_impl(s1, batch)
            sk, _ = tk.step_impl(sk, batch)
    np.testing.assert_array_equal(np.asarray(s1.theta["w"]), np.asarray(sk.theta["w"]))


def test_local_steps_with_adam():
    """K local steps compose with any optimizer, not just SGD."""
    loss_fn, _ = _quadratic_loss()
    K = 3
    cfg = ADGDAConfig(num_nodes=M, topology="ring", compressor="q8b", alpha=0.05,
                      eta_theta=0.05, eta_lambda=0.05, local_steps=K, optimizer="adam")
    tr = adgda_trainer(cfg, loss_fn)
    kb = {"mu": jnp.repeat(jnp.asarray([[-3.0], [0.0], [0.0], [0.0], [0.0], [3.0]]), K, axis=1)}
    state = tr.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    for _ in range(30):
        state, aux = tr.step(state, kb)
    assert np.isfinite(float(aux["mean_loss"]))
    assert state.opt.nu["w"].shape == (M, 1)  # second moment carried


def test_local_steps_and_microbatches_mutually_exclusive():
    with pytest.raises(ValueError, match="do not compose"):
        LocalUpdate(optimizer=sgd(0.1), schedule=make_schedule("const", 0.1),
                    local_steps=2, microbatches=2)


# ----------------------------------------------------- new compositions
def test_adam_adgda_one_liner():
    loss_fn, batch = _quadratic_loss()
    cfg = ADGDAConfig(num_nodes=M, compressor="q4b", optimizer="adam",
                      schedule="cosine", warmup=5, total_steps=200,
                      eta_theta=0.3, alpha=0.05, eta_lambda=0.1)
    tr = adgda_trainer(cfg, loss_fn)
    state = tr.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    etas = []
    for _ in range(40):
        state, aux = tr.step(state, batch)
        etas.append(float(aux["eta_theta"]))
    assert etas[0] == pytest.approx(0.0)  # warmup starts at zero
    assert max(etas) <= 0.3 + 1e-6
    assert np.isfinite(float(aux["worst_loss"]))


def test_custom_composition_robust_exact_gossip():
    """Novel combination in a few lines: chi2 projected-ascent dual over
    *uncompressed* gossip — no new trainer class required."""
    loss_fn, batch = _quadratic_loss()
    topo = make_topology("ring", M)
    prior = jnp.full((M,), 1.0 / M)
    sched = make_schedule("exp", 0.05, decay=0.995)
    tr = DecentralizedTrainer(
        loss_fn,
        num_nodes=M,
        local=LocalUpdate(optimizer=sgd(sched, momentum=0.5), schedule=sched),
        dual=ProjectedAscent(prior=prior, alpha=0.05, eta_lambda=0.05,
                             regularizer=dro.make_regularizer("chi2"), topology=topo),
        consensus=ExactConsensus(topo),
        prior=prior,
    )
    state = tr.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    for _ in range(300):
        state, aux = tr.step(state, batch)
    lam = np.asarray(aux["lambda_mean"])
    assert lam[0] + lam[-1] > 0.5  # dual concentrates on the extremes
    assert float(aux["consensus_err"]) < 0.1


# ------------------------------------------------------- bits accounting
def test_drfa_honors_momentum():
    """The seed DRFA declared config.momentum but silently ignored it; the
    composed trainer honors it (documented behavior change, default 0.0
    unchanged)."""

    def loss_fn(params, b, rng):
        return 0.5 * jnp.sum((params["w"] - b) ** 2)

    kb = jnp.broadcast_to(jnp.arange(M, dtype=jnp.float32)[:, None, None], (M, 2, 1))
    tr = drfa_trainer(DRFAConfig(num_nodes=M, local_steps=2, momentum=0.9), loss_fn)
    state = tr.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    state, _ = tr.step(state, kb)
    assert state.opt.mu["w"].shape == (M, 1)
    assert float(jnp.abs(state.opt.mu["w"]).max()) > 0


def test_drfa_bits_per_iteration():
    def loss_fn(params, b, rng):
        return 0.5 * jnp.sum((params["w"] - b) ** 2)

    K = 10
    tr = drfa_trainer(DRFAConfig(num_nodes=M, local_steps=K, participation=0.5), loss_fn)
    state = tr.init({"w": jnp.zeros((100,))}, jax.random.PRNGKey(0))
    per_round = tr.bits_per_round(state)
    per_iter = tr.bits_per_round(state, per_iteration=True)
    assert per_round == pytest.approx(2.0 * 3 * 100 * 32.0)  # |U|=3 up+down f32
    assert per_iter == pytest.approx(per_round / K)


def test_adgda_bits_include_dual_gossip():
    loss_fn, _ = _quadratic_loss()
    cfg = ADGDAConfig(num_nodes=M, topology="ring", compressor="none")
    robust = adgda_trainer(cfg, loss_fn)
    frozen = adgda_trainer(dataclasses.replace(cfg, robust=False), loss_fn)
    params = {"w": jnp.zeros((50,))}
    sr = robust.init(params, jax.random.PRNGKey(0))
    sf = frozen.init(params, jax.random.PRNGKey(0))
    # robust pays the uncompressed lambda gossip (m floats/neighbor) on top
    assert robust.bits_per_round(sr) == frozen.bits_per_round(sf) + 32.0 * M * 2
    # per-iteration equals per-round when local_steps == 1
    assert robust.bits_per_round(sr, per_iteration=True) == robust.bits_per_round(sr)


def test_state_is_a_plain_namedtuple_pytree():
    """TrainerState round-trips through tree flatten/unflatten (checkpointing
    and sharding-spec construction rely on this)."""
    loss_fn, batch = _quadratic_loss()
    tr = adgda_trainer(ADGDAConfig(num_nodes=M, compressor="q4b", momentum=0.9), loss_fn)
    state = tr.init({"w": jnp.zeros((1,))}, jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(state)
    state2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(state2, TrainerState)
    state3, _ = tr.step(state2, batch)
    assert int(state3.step) == 1
