"""Permute-schedule compiler round-trips (core/topology.py).

Every topology family and every ``TopologySchedule`` phase must round-trip
adjacency -> permute schedule -> reconstructed mixing matrix EXACTLY
(element-level weight copies / the factories' own circulant accumulation),
and the dropout rescale computed the SPMD-local way (participation bits and
degrees travelling the plan's own exchanges) must reproduce
``masked_metropolis`` on the surviving subgraph.
"""
import numpy as np
import pytest

from repro.core import topology as T

FAMILY_CASES = [
    ("ring", 3), ("ring", 8), ("ring", 2),
    ("torus", 5), ("torus", 8), ("torus", 16),
    ("mesh", 1), ("mesh", 2), ("mesh", 6),
    ("star", 5), ("star", 9),
    ("erdos_renyi", 6), ("erdos_renyi", 9),
]


def _plan_cases():
    for name, m in FAMILY_CASES:
        yield name, m, T.make_topology(name, m)


@pytest.mark.parametrize("name,m,topo", list(_plan_cases()),
                         ids=[f"{n}{m}" for n, m, _ in _plan_cases()])
def test_factory_round_trip_exact(name, m, topo):
    """adjacency -> permute plan -> mixing matrix, bit-exact."""
    plan = T.compile_permute_plan(topo)
    np.testing.assert_array_equal(plan.mixing_matrix(), topo.mixing)
    # the op list covers the off-diagonal adjacency exactly once
    cover = np.zeros((m, m))
    for snd in plan.sender_maps():
        for i, j in enumerate(snd):
            if j >= 0:
                assert cover[i, j] == 0, "edge delivered twice"
                cover[i, j] = 1
    np.testing.assert_array_equal(cover, topo.adjacency - np.eye(m))


@pytest.mark.parametrize("name,m,topo", list(_plan_cases()),
                         ids=[f"{n}{m}" for n, m, _ in _plan_cases()])
def test_edge_steps_are_valid_permutes_in_sender_order(name, m, topo):
    plan = T.compile_permute_plan(topo)
    if plan.is_circulant:
        assert plan.steps == () and plan.shifts == topo.shifts
        return
    received: dict[int, list[int]] = {i: [] for i in range(m)}
    for step in plan.steps:
        srcs = [s for s, _ in step.perm]
        dsts = [d for _, d in step.perm]
        assert len(set(srcs)) == len(srcs), "ppermute needs distinct sources"
        assert len(set(dsts)) == len(dsts), "ppermute needs distinct destinations"
        for s, d in step.perm:
            assert step.weights[d] == topo.mixing[d, s]
            received[d].append(s)
    for i, senders in received.items():
        assert senders == sorted(senders), (
            "greedy scheduler must deliver each receiver's senders in "
            "ascending id order (deterministic accumulation order)"
        )


@pytest.mark.parametrize(
    "spec,m",
    [("roundrobin:ring,torus", 8), ("matching:5", 8), ("matching:4", 7),
     ("erdos_renyi", 6), ("roundrobin:ring,mesh,star", 6)],
)
def test_schedule_phases_round_trip_exact(spec, m):
    sched = T.make_topology_schedule(spec, m, seed=3)
    plans = T.compile_schedule_plans(sched)
    assert len(plans) == sched.period
    for plan, topo in zip(plans, sched.topologies):
        np.testing.assert_array_equal(plan.mixing_matrix(), topo.mixing)


@pytest.mark.parametrize("name,m", [("ring", 8), ("torus", 9), ("mesh", 5),
                                    ("star", 6), ("erdos_renyi", 8)])
def test_dropout_rescale_round_trip(name, m):
    """Masked-Metropolis weights computed from permuted participation bits
    (the SPMD-local form) == the dense masked_metropolis rescale."""
    topo = T.make_topology(name, m)
    plan = T.compile_permute_plan(topo)
    rng = np.random.default_rng(0)
    masks = [np.ones(m), np.zeros(m)]
    masks += [(rng.random(m) > 0.4).astype(np.float64) for _ in range(4)]
    for mask in masks:
        ref = np.asarray(T.masked_metropolis(topo.adjacency, mask))
        got = plan.masked_mixing_matrix(mask)
        np.testing.assert_allclose(got, ref, atol=2e-7, rtol=1e-6)
        # doubly stochastic for every mask
        np.testing.assert_allclose(got.sum(axis=0), 1.0, atol=1e-5)
        np.testing.assert_allclose(got.sum(axis=1), 1.0, atol=1e-5)


def test_schedule_phase_dropout_rescale():
    sched = T.make_topology_schedule("matching:4", 6, dropout=0.3, seed=1)
    plans = T.compile_schedule_plans(sched)
    rng = np.random.default_rng(2)
    for plan, topo in zip(plans, sched.topologies):
        mask = (rng.random(6) > 0.3).astype(np.float64)
        ref = np.asarray(T.masked_metropolis(topo.adjacency, mask))
        np.testing.assert_allclose(plan.masked_mixing_matrix(mask), ref,
                                   atol=2e-7, rtol=1e-6)


def test_exchange_ops_align_with_sender_maps():
    for _, _, topo in _plan_cases():
        plan = T.compile_permute_plan(topo)
        ops, maps = plan.exchange_ops(), plan.sender_maps()
        assert len(ops) == len(maps)
        m = plan.num_nodes
        for (kind, arg), snd in zip(ops, maps):
            if kind == "shift":
                np.testing.assert_array_equal(snd, (np.arange(m) - arg) % m)
            else:
                expect = np.full(m, -1)
                for s, d in arg:
                    expect[d] = s
                np.testing.assert_array_equal(snd, expect)


def test_expected_and_realized_degree():
    sched = T.make_topology_schedule("roundrobin:ring,torus", 16, dropout=0.3)
    assert sched.max_degree == 4
    assert sched.expected_degree == pytest.approx(3.0 * 0.49)
    mask = np.ones(16)
    mask[:4] = 0
    assert sched.realized_degree(0, mask) == 2.0  # ring phase
    assert sched.realized_degree(1, mask) == 4.0  # torus phase
    topo = T.ring(8)
    assert topo.expected_degree == topo.max_degree == 2
    assert topo.realized_degree(0, np.zeros(8)) == 0.0
