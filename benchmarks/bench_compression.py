"""Paper Table 2 — worst-case accuracy of AD-GDA vs CHOCO-SGD under
quantization (16/8/4 bit) and top-K sparsification (50/25/10 %), logistic and
fully-connected models, ring topology.

Validates: AD-GDA ~doubles worst-node accuracy over CHOCO-SGD at every
compression level; unbiased quantization degrades more gracefully than
biased sparsification at matched wire budget.
"""
from __future__ import annotations

from benchmarks.common import make_adgda, train_trainer, worst_avg
from repro.data import rotated_minority_classification

SCHEMES = ["q16b", "q8b", "q4b", "top50", "top25", "top10"]


def run(quick: bool = True, seeds=(0, 1)) -> list[dict]:
    m = 10
    steps = 600 if quick else 2000
    rows = []
    for model in ("logistic", "fc"):
        for comp in SCHEMES:
            for robust in (True, False):
                worst_accs, avg_accs = [], []
                for seed in seeds:
                    data = rotated_minority_classification(num_nodes=m, seed=seed)
                    trainer, init_fn, apply_fn = make_adgda(
                        model, m, robust=robust, compressor=comp, topology="ring",
                    )
                    params, _ = train_trainer(trainer, init_fn(data.dim, data.num_classes),
                                              data, steps, batch=50, seed=seed)
                    w, a = worst_avg(apply_fn, params, data)
                    worst_accs.append(w)
                    avg_accs.append(a)
                rows.append({
                    "table": "T2",
                    "model": model,
                    "algo": "AD-GDA" if robust else "CHOCO-SGD",
                    "compressor": comp,
                    "worst_acc": sum(worst_accs) / len(worst_accs),
                    "avg_acc": sum(avg_accs) / len(avg_accs),
                })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
