"""Paper Figures 3 & 4 — convergence of the worst-node loss under different
compression schemes (Fig. 3) and topologies (Fig. 4), fixed learning rate.

Validates: sublinear O(1/sqrt(T)) decrease; higher compression / sparser
topology -> flatter slope (consensus term), same asymptote.
Emits curve samples as CSV rows.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_adgda, train_trainer
from repro.data import class_shard_classification


def run(quick: bool = True, seeds=(0,)) -> list[dict]:
    m = 10
    steps = 300 if quick else 2000
    rows = []
    data = class_shard_classification(num_nodes=m, dim=24, sep=1.2, seed=0)

    def curve_rows(tag, variant, trainer, init_fn):
        params, info = train_trainer(
            trainer, init_fn(data.dim, data.num_classes), data, steps,
            seed=seeds[0], track_worst_loss=True,
        )
        sampled = info["curve"][:: max(len(info["curve"]) // 10, 1)]
        first, last = info["curve"][0][1], np.mean([c[1] for c in info["curve"][-3:]])
        for t, loss, bits in sampled:
            rows.append({"table": tag, "variant": variant, "step": t,
                         "worst_loss": loss, "gbits": bits / 1e9})
        assert last < first, f"{variant}: worst loss did not decrease"
        return last

    # Fig 3: compression schemes, fixed eta
    finals = {}
    for comp in ("none", "q8b", "q4b", "top25", "top10"):
        trainer, init_fn, _ = make_adgda(
            "logistic", m, robust=True, alpha=0.1, compressor=comp,
            topology="ring", eta_theta=0.1, lr_decay=1.0, eta_lambda=0.05,
        )
        finals[comp] = curve_rows("F3", comp, trainer, init_fn)

    # Fig 4: topologies under 4-bit quantization
    for topo in ("ring", "torus", "mesh"):
        trainer, init_fn, _ = make_adgda(
            "logistic", m, robust=True, alpha=0.1, compressor="q4b",
            topology=topo, eta_theta=0.1, lr_decay=1.0, eta_lambda=0.05,
        )
        curve_rows("F4", topo, trainer, init_fn)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
