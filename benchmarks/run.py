"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only T2,T5]

Paper-artifact map:
  T2  bench_compression    Table 2  (compression schemes x AD-GDA/CHOCO-SGD)
  T3  bench_topology       Table 3  (ring / torus / mesh)
  T4  bench_regularization Table 4  (alpha sweep, 3 setups)
  T5  bench_comparison     Table 5 + Fig. 5 (vs DRFA / DR-DSGD, bits)
  F3  bench_convergence    Figs. 3/4 (worst-loss curves)
  K   bench_kernels        Pallas kernels vs refs
  G   bench_gossip         fused vs packed vs unpacked CHOCO round
  FT  bench_faults         dropout / time-varying topology fault tolerance
  X   bench_exchange       rolled vs ppermute backend HLO collective bytes
  S   bench_serving        serving fleet: latency/SLO vs load, train-and-serve
Roofline/dry-run artifacts live in launch/dryrun.py (§Dry-run, §Roofline).

Each suite's rows are persisted to BENCH_<suite>.json next to this package's
parent (the repo root) so the perf trajectory is tracked across PRs.

Suite S additionally has an offline scale point outside this harness:
``python -m benchmarks.bench_serving --scale`` serves 10^6 offered requests
(fleet m2s2, hot-pool prompts, streaming retain="stats" accumulators) and
writes BENCH_S_SCALE.json — kept out of BENCH_S.json so the quick/full row
keys the regression gate matches on stay stable.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from benchmarks import (
    bench_comparison,
    bench_compression,
    bench_convergence,
    bench_exchange,
    bench_faults,
    bench_gossip,
    bench_kernels,
    bench_regularization,
    bench_serving,
    bench_topology,
)
from benchmarks.common import print_rows

SUITES = {
    "T2": bench_compression,
    "T3": bench_topology,
    "T4": bench_regularization,
    "T5": bench_comparison,
    "F3": bench_convergence,
    "K": bench_kernels,
    "G": bench_gossip,
    "FT": bench_faults,
    "X": bench_exchange,
    "S": bench_serving,
}

REPO_ROOT = Path(__file__).resolve().parent.parent


def persist(sid: str, rows: list[dict], quick: bool) -> Path:
    """Write one suite's rows to BENCH_<sid>.json in the repo root."""
    path = REPO_ROOT / f"BENCH_{sid}.json"
    payload = {
        "suite": sid,
        "module": SUITES[sid].__name__,
        "quick": quick,
        "rows": rows,
    }
    path.write_text(json.dumps(payload, indent=2, default=str) + "\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale iteration counts")
    ap.add_argument("--only", default=None, help="comma-separated suite ids (e.g. T2,K)")
    ap.add_argument(
        "--no-persist", action="store_true", help="skip writing BENCH_<suite>.json"
    )
    args = ap.parse_args()

    selected = args.only.split(",") if args.only else list(SUITES)
    unknown = [sid for sid in selected if sid not in SUITES]
    if unknown:
        ap.error(f"unknown suite id(s) {unknown}; choose from {sorted(SUITES)}")
    for sid in selected:
        mod = SUITES[sid]
        t0 = time.time()
        print(f"\n=== {sid}: {mod.__name__} ===")
        rows = mod.run(quick=not args.full)
        print_rows(rows)
        if not args.no_persist:
            path = persist(sid, rows, quick=not args.full)
            print(f"[{sid} rows -> {path.name}]")
        print(f"[{sid} done in {time.time() - t0:.1f}s]")


if __name__ == "__main__":
    main()
