"""Bench regression gate — fail CI when a suite regresses vs its committed
baseline.

  PYTHONPATH=src python -m benchmarks.check_regression --suite G [--threshold 0.25]

Re-runs the suite in quick mode and compares each row (matched on its
non-numeric key fields) against the committed ``BENCH_<suite>.json``.  Gated
metrics are *relative* or deterministic quantities so the gate is meaningful
across machines:

* suite **G** — ``speedup_fused_vs_packed`` (fused-gossip throughput
  relative to the packed path on the same host; absolute ms are
  machine-dependent and only reported).  Fails when the speedup drops more
  than ``threshold`` below the baseline AND lands below the absolute
  acceptance bar (1.5x, the PR-1 bar): a ratio that is merely lower than a
  lucky dev-machine baseline but still comfortably above the bar is not a
  regression — the committed baseline was not measured on the CI runner
  class.
* suite **X** — ``wire_bytes`` of the ppermute backend (a property of the
  compiled HLO, deterministic per jax/XLA version).  Fails when the wire
  bytes *grow* more than ``threshold`` above the baseline.
* suite **FT** — ``worst_acc`` per (schedule, dropout, fault_spec) row, plus
  baseline-free fault-mode invariants re-checked on every fresh run: under
  the ``drop:0.1`` wire-fault spec worst-node accuracy must stay within a
  fixed band of the fault-free twin row, every faulted row's consensus
  error must stay within 2x of fault-free (the ISSUE-6 acceptance bar),
  and the digest layer must have detected (and resynced) at least one
  divergence — a silent fault injector fails the gate.
* suite **S** — ``p99_ttft_ticks`` per (fleet, rate) latency row
  (tick-denominated TTFT is bit-deterministic given the loadgen seed),
  ``worst_node_acc`` per train-and-serve row, and ``speedup_fastpath``
  (fast-path wall clock vs the legacy-engine twin on identical traffic;
  2.0x absolute bar), plus baseline-free SLO invariants: every latency row
  at or below its fleet's measured knee (``rate <= knee_rate``) must have
  ``rejected == 0`` and ``p99_ttft_ticks`` within ``KNEE_INFLATION x
  max(p50_ttft_ticks, 1)``; every ``fastpath="off"`` twin row must match
  its fast row EXACTLY on every tick-denominated field (the fast path is a
  wall-clock lever only); the hot-pool ``prompts="zipf"`` row must show
  ``cache_hit_rate > 0.3`` and the ``prompts="unique"`` control exactly 0;
  ``completed + rejected + shed == requests`` on every latency row; the
  AD-GDA train-and-serve row's ``worst_node_acc`` must beat its unweighted
  twin's (the DRO-as-serving-SLO claim); and every train-and-serve row must
  have actually hot-reloaded (``reloads > 0``).

Every suite's gate lives in one shared ``SuiteSpec`` table below — gated
metrics, float scenario-axis fields exempt from the row-key rule, and the
baseline-free invariant hook — so a new suite adds one entry instead of
re-growing ad-hoc per-suite branches (FT did this ad hoc once; suite S is
the first through the shared table).

Rows present in only one side are reported but do not fail the gate (suites
grow across PRs); a metric regression does.

Timing metrics on small shared runners are noisy even as ratios (the suite
already takes min-of-N per timing), so an apparent regression triggers up to
``--retries`` fresh re-runs of the whole suite, keeping each row's *best*
value — the gate only fails when a drop is reproducible across every run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import Callable

REPO_ROOT = Path(__file__).resolve().parent.parent

# baseline-free invariants checked on every FRESH suite-X run (they also
# self-assert inside the bench, but re-asserting here keeps the gate honest
# even if someone relaxes the bench): masked/scheduled ppermute rounds must
# stay at compressed-payload scale per edge — an f32 theta_hat exchange
# regression is ~6x for kq4b and fails instantly.
MASKED_EDGE_RATIO = 1.1

# two-lane gradient-tracking rounds (gt_round_* rows): model + tracker
# hat-deltas ride one message, so per-edge bytes may reach 2x the single-lane
# compressed payload plus the scheduled wire's float overhead (ISSUE-8 bar).
GT_EDGE_RATIO = 2.1

# suite-FT fault-mode smoke (baseline-free, per fresh run): a `drop:0.1`
# wire-fault row's worst-node accuracy must land within this fixed band of
# its fault-free twin (same schedule, same node-dropout), and ANY faulted
# row's consensus error must stay within FAULT_CONSENSUS_RATIO x fault-free.
# Key names (`dropout`, `fault_spec`, `faults_detected`, `resyncs`) match
# bench_faults.py / BENCH_FT.json / the README fault table verbatim.
FAULT_ACC_BAND = 0.05
FAULT_CONSENSUS_RATIO = 2.0


def _ft_invariant_failures(fresh: dict) -> list:
    failures = []
    rows = [dict(r) for r in fresh.values()]
    clean = {(r["schedule"], r["dropout"]): r
             for r in rows if r.get("fault_spec", "none") == "none"}
    for row in rows:
        spec = row.get("fault_spec", "none")
        if spec == "none":
            continue
        scen = f"{row['schedule']}+{spec}"
        twin = clean.get((row["schedule"], row["dropout"]))
        if twin is None:
            print(f"REGRESSION {scen}: no fault-free twin row to band against")
            failures.append(((("scenario", scen),), "fault_free_twin", 1.0, 0.0))
            continue
        checks = [
            ("consensus_err", float(row["consensus_err"]),
             FAULT_CONSENSUS_RATIO * float(twin["consensus_err"]), "<="),
            ("faults_detected", float(row["faults_detected"]), 0.0, ">"),
            ("resyncs", float(row["resyncs"]), 0.0, ">"),
        ]
        if spec.startswith("drop:0.1"):
            checks.append(("worst_acc", float(row["worst_acc"]),
                           float(twin["worst_acc"]) - FAULT_ACC_BAND, ">="))
        for metric, got, bound, op in checks:
            ok = got <= bound if op == "<=" else (
                got > bound if op == ">" else got >= bound)
            print(f"{'ok' if ok else 'REGRESSION':10s} {scen}: "
                  f"{metric} {got:.4g} (must be {op} {bound:.4g})")
            if not ok:
                failures.append(((("scenario", scen),), metric, bound, got))
    failures += _ksweep_invariant_failures(rows)
    return failures


def _ksweep_invariant_failures(rows: list) -> list:
    """Gradient-tracking local-steps invariant (the ISSUE-8 acceptance bar),
    baseline-free: at the equal-realized-bits anchor — gt's two lanes at
    K=16 move the same total traffic as single-lane choco at K=8 over a
    fixed iteration budget — gradient tracking must convert the tracker
    lane into worst-node accuracy, and it must also win the same-K
    comparison at K=16 outright.  Key names (``consensus``, ``local_steps``,
    ``bits_total_realized``) match bench_faults.run_ksweep / BENCH_FT.json."""
    # rows with a tracker_compressor key run a coarser tracker lane — the
    # 2x-lane bits reasoning below does not apply to them, and they must
    # not shadow the plain gt@16 anchor cell
    ks = {(r.get("consensus"), r.get("local_steps")): r
          for r in rows if r.get("schedule") == "ksweep-ring"
          and not r.get("tracker_compressor")}
    if not ks:
        return []  # pre-ISSUE-8 baseline without the sweep: nothing to check
    failures = []
    gt16, ch8, ch16 = ks.get(("gt", 16)), ks.get(("choco", 8)), ks.get(("choco", 16))
    pairs = []
    if gt16 is not None and ch8 is not None:
        pairs.append(("gt@16 vs choco@8 (equal-bits anchor)", gt16, ch8, True))
    if gt16 is not None and ch16 is not None:
        pairs.append(("gt@16 vs choco@16 (same K)", gt16, ch16, False))
    if not pairs:
        print("REGRESSION ksweep: missing gt@16/choco@{8,16} anchor rows")
        return [((("scenario", "ksweep"),), "anchor_rows", 2.0, 0.0)]
    for name, gt, ch, check_bits in pairs:
        acc_gt, acc_ch = float(gt["worst_acc"]), float(ch["worst_acc"])
        ok = acc_gt > acc_ch
        print(f"{'ok' if ok else 'REGRESSION':10s} ksweep {name}: worst_acc "
              f"{acc_gt:.4g} (must be > {acc_ch:.4g})")
        if not ok:
            failures.append(((("scenario", f"ksweep:{name}"),),
                             "worst_acc", acc_ch, acc_gt))
        if check_bits:
            b_gt = float(gt["bits_total_realized"])
            b_ch = float(ch["bits_total_realized"])
            ok = b_gt <= 1.05 * b_ch  # "equal bits": gt may not outspend its anchor
            print(f"{'ok' if ok else 'REGRESSION':10s} ksweep {name}: total bits "
                  f"{b_gt:.4g} (must be <= 1.05x {b_ch:.4g})")
            if not ok:
                failures.append(((("scenario", f"ksweep:{name}"),),
                                 "bits_total_realized", 1.05 * b_ch, b_gt))
    return failures


def _x_invariant_failures(fresh: dict) -> list:
    failures = []
    for key, row in fresh.items():
        scen = dict(key).get("scenario", "")
        if row.get("backend") != "ppermute":
            continue
        if scen.startswith("gt_round"):
            ratio = GT_EDGE_RATIO  # two lanes per message
        elif (scen.startswith("choco_round_masked")
              or scen.startswith("choco_round_sched")):
            ratio = MASKED_EDGE_RATIO
        else:
            continue
        per_edge = float(row["per_edge_bytes"])
        payload = float(row["per_edge_payload_bytes"])
        ok = per_edge <= ratio * payload
        print(f"{'ok' if ok else 'REGRESSION':10s} {scen}: per-edge "
              f"{per_edge:.0f} B vs {ratio:g}x payload "
              f"{payload:.0f} B")
        if not ok:
            failures.append((key, "per_edge_bytes", payload, per_edge))
        ag = float(row.get("all_gather_bytes", 0.0))
        if ag > 0.0:
            print(f"REGRESSION {scen}: all-gather bytes {ag:.0f} (wire leak)")
            failures.append((key, "all_gather_bytes", 0.0, ag))
    return failures


def _s_invariant_failures(fresh: dict) -> list:
    """Suite-S baseline-free SLO checks (the README "Serving fleet" SLO,
    re-asserted on every fresh run so the gate stays honest even if the
    bench's own constants drift).  Key names (`rate`, `knee_rate`,
    `rejected`, `p50/p99_ttft_ticks`, `worst_node_acc`, `reloads`) match
    bench_serving.py / BENCH_S.json / the README verbatim."""
    from benchmarks.bench_serving import KNEE_INFLATION

    failures = []
    rows = [dict(r) for r in fresh.values()]

    # ---- fast-path contracts (ISSUE-9): the serving fast path is a WALL
    # CLOCK lever only.  (1) every fastpath="off" twin must match its fast
    # row on every tick-denominated field EXACTLY (logical time is pure);
    # (2) the hot-pool (zipf) row must actually hit the prefix cache and the
    # unique-prompt control must never; (3) admission conserves requests.
    TICK_FIELDS = ("requests", "completed", "rejected", "shed", "ticks",
                   "p50_ttft_ticks", "p95_ttft_ticks", "p99_ttft_ticks")
    lat = [r for r in rows if r.get("kind") == "latency"]
    for off in [r for r in lat if r.get("fastpath") == "off"]:
        match = [r for r in lat if r.get("fastpath") is None
                 and r["fleet"] == off["fleet"] and r["rate"] == off["rate"]
                 and r.get("prompts") == off.get("prompts")]
        scen = (f"{off['fleet']}@{off['rate']:g}"
                + (f"/{off['prompts']}" if off.get("prompts") else ""))
        if len(match) != 1:
            print(f"REGRESSION twin {scen}: {len(match)} fast rows match")
            failures.append(((("scenario", f"twin:{scen}"),),
                             "twin_match", 1.0, float(len(match))))
            continue
        on = match[0]
        bad = [k for k in TICK_FIELDS if float(on[k]) != float(off[k])]
        print(f"{'ok' if not bad else 'REGRESSION':10s} twin {scen}: "
              f"tick metrics {'bit-identical' if not bad else 'DIVERGED: ' + ','.join(bad)}")
        for k in bad:
            failures.append(((("scenario", f"twin:{scen}"),),
                             k, float(off[k]), float(on[k])))
    for row in lat:
        if row.get("fastpath") == "off" or "prompts" not in row:
            continue
        hit = float(row.get("cache_hit_rate", 0.0))
        if row["prompts"] == "zipf":
            ok, req = hit > 0.3, "> 0.3"
        else:  # unique: the guaranteed-zero-hit-rate control
            ok, req = hit == 0.0, "== 0"
        scen = f"{row['fleet']}@{row['rate']:g}/{row['prompts']}"
        print(f"{'ok' if ok else 'REGRESSION':10s} {scen}: "
              f"cache_hit_rate {hit:.4g} (must be {req})")
        if not ok:
            failures.append(((("scenario", scen),), "cache_hit_rate",
                             0.3 if row["prompts"] == "zipf" else 0.0, hit))
    for row in lat:
        total = float(row["completed"]) + float(row["rejected"]) + float(row["shed"])
        ok = total == float(row["requests"])
        if not ok:
            scen = f"{row['fleet']}@{row['rate']:g}"
            print(f"REGRESSION {scen}: completed+rejected+shed {total:g} "
                  f"!= requests {row['requests']}")
            failures.append(((("scenario", scen),), "request_conservation",
                             float(row["requests"]), total))

    for row in rows:
        if row.get("kind") != "latency" or row["rate"] > row["knee_rate"]:
            continue
        scen = f"{row['fleet']}@{row['rate']:g}"
        p99_bound = KNEE_INFLATION * max(float(row["p50_ttft_ticks"]), 1.0)
        checks = [
            ("rejected", float(row["rejected"]), 0.0, "<="),
            ("p99_ttft_ticks", float(row["p99_ttft_ticks"]), p99_bound, "<="),
        ]
        for metric, got, bound, op in checks:
            ok = got <= bound
            print(f"{'ok' if ok else 'REGRESSION':10s} {scen}: "
                  f"{metric} {got:.4g} (below the knee, must be {op} {bound:.4g})")
            if not ok:
                failures.append(((("scenario", scen),), metric, bound, got))
    ts = {r["algo"]: r for r in rows if r.get("kind") == "train_serve"}
    if ts:
        for algo, row in sorted(ts.items()):
            reloads = float(row["reloads"])
            ok = reloads > 0
            print(f"{'ok' if ok else 'REGRESSION':10s} train_serve/{algo}: "
                  f"reloads {reloads:g} (must be > 0)")
            if not ok:
                failures.append(((("scenario", f"train_serve/{algo}"),),
                                 "reloads", 1.0, reloads))
        if "adgda" in ts and "unweighted" in ts:
            a = float(ts["adgda"]["worst_node_acc"])
            u = float(ts["unweighted"]["worst_node_acc"])
            ok = a > u
            print(f"{'ok' if ok else 'REGRESSION':10s} train_serve: AD-GDA "
                  f"worst_node_acc {a:.4g} vs unweighted {u:.4g} (must win)")
            if not ok:
                failures.append(((("scenario", "train_serve"),),
                                 "worst_node_acc_gap", u, a))
        else:
            print("REGRESSION train_serve: need both adgda and unweighted rows")
            failures.append(((("scenario", "train_serve"),), "row_pair", 2.0,
                             float(len(ts))))
    return failures


def _k_invariant_failures(fresh: dict) -> list:
    """Suite-K baseline-free wins (the ISSUE-10 acceptance bars, re-measured
    on every fresh run rather than trusted from the committed JSON):

    * the sliding-window kernel must beat the window-*masked* flash kernel
      at long-seq/small-window — the whole point of skipping dead kv blocks;
    * the fused int8 quantized-KV decode must beat the pre-kernel f32
      XLA decode (repeat_kv + materialized softmax) at serving shapes.

    Both comparisons pair like-for-like execution technology (see
    bench_kernels.py), so the ratio survives cross-machine noise far better
    than absolute timings; the bar is deliberately just 1.0 with the retry
    absorber on top.
    """
    failures = []
    rows = [dict(r) for r in fresh.values()]
    required = {"attn_sliding_window": "float32", "decode_fused_int8": "int8"}
    for kern, dtype in sorted(required.items()):
        match = [r for r in rows
                 if r.get("kernel") == kern and r.get("dtype") == dtype]
        if not match:
            print(f"REGRESSION {kern}: row missing from fresh run")
            failures.append(((("scenario", kern),), "row_present", 1.0, 0.0))
            continue
        speedup = float(match[0]["speedup"])
        ok = speedup > 1.0
        print(f"{'ok' if ok else 'REGRESSION':10s} {kern}: speedup "
              f"{speedup:.3g}x vs {match[0]['baseline']} (must be > 1)")
        if not ok:
            failures.append(((("scenario", kern),), "speedup", 1.0, speedup))
    return failures


# ---------------------------------------------------------- the suite table
@dataclasses.dataclass(frozen=True)
class SuiteSpec:
    """Everything the gate knows about one suite, in one place.

    * ``gates`` — (metric, direction, absolute_ok) triples: "higher" =
      regression when it drops, "lower" = regression when it grows.  A
      non-None absolute_ok exempts values still on the right side of that
      bar from relative gating (cross-machine baselines make pure
      ratios-of-timings flaky).
    * ``axis_fields`` — scenario-axis fields that happen to be floats (so
      the generic "non-numeric fields are the key" rule would silently
      collapse a sweep onto one row): FT's node-dropout rate, S's offered
      rate.  String axes (``fault_spec``, ``fleet``) need no exemption —
      keep any new sweep axis a string where possible for the same reason.
    * ``invariants`` — baseline-free checks re-run on every fresh run
      (``fresh -> failures``); None when a suite has none.
    """

    gates: tuple = ()
    axis_fields: frozenset = frozenset()
    invariants: Callable[[dict], list] | None = None


SPECS = {
    "G": SuiteSpec(gates=(("speedup_fused_vs_packed", "higher", 1.5),)),
    "X": SuiteSpec(gates=(("wire_bytes", "lower", None),),
                   invariants=_x_invariant_failures),
    "FT": SuiteSpec(gates=(("worst_acc", "higher", None),),
                    axis_fields=frozenset({"dropout"}),
                    invariants=_ft_invariant_failures),
    "S": SuiteSpec(gates=(("p99_ttft_ticks", "lower", None),
                          ("worst_node_acc", "higher", None),
                          # fast-path wall-clock claim: >= 2x vs the legacy
                          # twin on the same traffic (absolute bar; timing
                          # ratios get the suite-G retry absorber)
                          ("speedup_fastpath", "higher", 2.0)),
                   axis_fields=frozenset({"rate"}),
                   invariants=_s_invariant_failures),
    # kernel-vs-baseline speedups: like-for-like technology ratios (Pallas
    # vs Pallas, XLA vs XLA — see bench_kernels.py), gated relatively with a
    # 1.05x absolute escape hatch, plus the two measured ISSUE-10 wins as
    # baseline-free invariants
    "K": SuiteSpec(gates=(("speedup", "higher", 1.05),),
                   invariants=_k_invariant_failures),
}


def _key(row: dict, axis_fields: frozenset = frozenset()) -> tuple:
    return tuple(
        (k, v) for k, v in sorted(row.items())
        if not isinstance(v, float) or k in axis_fields
    )


def _merge_best(suite: str, best: dict, fresh: dict) -> dict:
    """Keep each row's best gated-metric values across runs (direction-aware)."""
    out = dict(best)
    for key, new in fresh.items():
        old = out.get(key)
        if old is None:
            out[key] = new
            continue
        merged = dict(old)
        for metric, direction, _ in SPECS[suite].gates:
            if metric not in new or metric not in old:
                continue
            o, n = float(old[metric]), float(new[metric])
            merged[metric] = max(o, n) if direction == "higher" else min(o, n)
        out[key] = merged
    return out


def _evaluate(suite: str, baseline: dict, fresh: dict, threshold: float,
              verbose: bool) -> list:
    failures = []
    for key, new in fresh.items():
        old = baseline.get(key)
        if old is None:
            if verbose:
                print(f"NEW ROW (not gated): {dict(key)}")
            continue
        for metric, direction, absolute_ok in SPECS[suite].gates:
            if metric not in new or metric not in old:
                continue
            o, n = float(old[metric]), float(new[metric])
            if direction == "higher":
                bad = n < o * (1.0 - threshold)
                verdict = f"{metric} {o:.4g} -> {n:.4g} (floor {o * (1 - threshold):.4g})"
                if bad and absolute_ok is not None and n >= absolute_ok:
                    bad = False
                    verdict += f"; above the {absolute_ok:g} absolute bar, not gated"
            else:
                bad = n > o * (1.0 + threshold)
                verdict = f"{metric} {o:.4g} -> {n:.4g} (ceiling {o * (1 + threshold):.4g})"
                if bad and absolute_ok is not None and n <= absolute_ok:
                    bad = False
                    verdict += f"; below the {absolute_ok:g} absolute bar, not gated"
            if verbose:
                print(f"{'REGRESSION' if bad else 'ok':10s} {dict(key)}: {verdict}")
            if bad:
                failures.append((key, metric, o, n))
    return failures


def check(suite: str, threshold: float, retries: int = 1) -> int:
    from benchmarks.run import SUITES

    spec = SPECS[suite]

    def keyed(rows):
        return {_key(r, spec.axis_fields): r for r in rows}

    def invariants(fresh):
        return spec.invariants(fresh) if spec.invariants else []

    baseline_path = REPO_ROOT / f"BENCH_{suite}.json"
    if not baseline_path.exists():
        print(f"no committed baseline {baseline_path.name}; nothing to gate")
        return 0
    baseline = keyed(json.loads(baseline_path.read_text())["rows"])
    fresh = keyed(SUITES[suite].run(quick=True))

    failures = _evaluate(suite, baseline, fresh, threshold, verbose=True)
    failures += invariants(fresh)
    attempt = 0
    while failures and attempt < retries:
        attempt += 1
        print(f"\napparent regression — retry {attempt}/{retries} "
              "(timing noise is only believed when reproducible)")
        fresh = _merge_best(suite, fresh, keyed(SUITES[suite].run(quick=True)))
        failures = _evaluate(suite, baseline, fresh, threshold, verbose=True)
        failures += invariants(fresh)

    gone = [k for k in baseline if k not in fresh]
    for k in gone:
        print(f"GONE (not gated): {dict(k)}")
    if failures:
        print(f"\n{len(failures)} metric regression(s) beyond {threshold:.0%} "
              f"(reproduced across {attempt + 1} run(s))")
        return 1
    print(f"\ngate passed: {len(fresh)} rows within {threshold:.0%} of baseline")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suite", default="G", choices=sorted(SPECS))
    ap.add_argument("--threshold", type=float, default=0.25)
    ap.add_argument("--retries", type=int, default=1,
                    help="extra full-suite re-runs when a regression appears; "
                         "per-row best metric wins (timing noise absorber)")
    args = ap.parse_args()
    sys.exit(check(args.suite, args.threshold, args.retries))


if __name__ == "__main__":
    main()
