"""Shared benchmark harness: models, training loops, metrics, CSV output.

Every ``bench_*`` module maps to one paper table/figure and exposes
``run(quick=True) -> list[dict]`` rows.  ``benchmarks.run`` executes all of
them and prints CSV; each row carries the paper artifact it validates.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ADGDAConfig, adgda_trainer, choco_sgd
from repro.data import HeterogeneousDataset


# ------------------------------------------------------------------ models
def logistic_init(dim: int, classes: int):
    return {"w": jnp.zeros((dim, classes)), "b": jnp.zeros((classes,))}


def logistic_apply(params, x):
    return x @ params["w"] + params["b"]


def mlp_init(dim: int, classes: int, hidden: int = 25, seed: int = 0):
    """The paper's fully-connected model: 2 layers, 25 hidden units."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    s1, s2 = 1.0 / np.sqrt(dim), 1.0 / np.sqrt(hidden)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * s1,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, classes)) * s2,
        "b2": jnp.zeros((classes,)),
    }


def mlp_apply(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def make_loss(apply_fn):
    def loss(params, batch, rng):
        x, y = batch
        logits = apply_fn(params, x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return (logz - gold).mean()

    return loss


MODELS = {
    "logistic": (logistic_init, logistic_apply),
    "fc": (mlp_init, mlp_apply),
}


# ----------------------------------------------------------------- training
def train_trainer(trainer, init_params, data: HeterogeneousDataset, steps: int,
                  batch: int = 50, seed: int = 0, track_worst_loss: bool = False):
    """Run `steps` rounds; returns (consensus_params, info)."""
    state = trainer.init(init_params, jax.random.PRNGKey(seed))
    gen = data.batches(batch, seed=seed)
    curve = []
    bits = float(trainer.bits_per_round(state))
    bits_realized = None  # device-side accumulator of the jitted meter
    t0 = time.time()
    for t in range(steps):
        xb, yb = next(gen)
        state, aux = trainer.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
        if "bits_realized" in aux:
            br = aux["bits_realized"]
            bits_realized = br if bits_realized is None else bits_realized + br
        if track_worst_loss and (t % max(steps // 50, 1) == 0):
            curve.append((t, float(aux["worst_loss"]), (t + 1) * bits))
    info = {
        "bits_per_round": bits,
        "total_bits": bits * steps,
        "seconds": time.time() - t0,
        "curve": curve,
        "state": state,
    }
    if bits_realized is not None:
        # measured traffic from the in-graph realized-bits meter — one host
        # sync at the end, not per round
        info["bits_realized_total"] = float(bits_realized)
        info["bits_per_round_realized"] = float(bits_realized) / steps
    return trainer.network_mean(state), info


def accuracy(apply_fn, params, x, y) -> float:
    pred = np.asarray(jnp.argmax(apply_fn(params, jnp.asarray(x)), axis=-1))
    return float((pred == np.asarray(y)).mean())


def val_accuracies(apply_fn, params, data: HeterogeneousDataset) -> dict[str, float]:
    return {
        name: accuracy(apply_fn, params, x, y)
        for name, x, y in zip(data.val_names, data.val_x, data.val_y)
    }


def worst_avg(apply_fn, params, data: HeterogeneousDataset) -> tuple[float, float]:
    accs = val_accuracies(apply_fn, params, data)
    xs = np.concatenate(data.val_x)
    ys = np.concatenate(data.val_y)
    return min(accs.values()), accuracy(apply_fn, params, xs, ys)


def make_adgda(model: str, m: int, *, robust=True, alpha=0.05, topology="ring",
               compressor="q4b", eta_theta=0.3, eta_lambda=0.2, lr_decay=0.99,
               regularizer="chi2", **kw):
    init_fn, apply_fn = MODELS[model]
    cfg = ADGDAConfig(
        num_nodes=m, topology=topology, compressor=compressor, alpha=alpha,
        eta_theta=eta_theta, eta_lambda=eta_lambda, lr_decay=lr_decay,
        regularizer=regularizer, robust=robust, **kw,
    )
    loss = make_loss(apply_fn)
    trainer = adgda_trainer(cfg, loss) if robust else choco_sgd(cfg, loss)
    return trainer, init_fn, apply_fn


def print_rows(rows: list[dict]) -> None:
    """CSV print; suites with heterogeneous row kinds (e.g. suite S latency
    vs train_serve) get one header per distinct key set, in order."""
    if not rows:
        return
    keys = None
    for r in rows:
        if list(r.keys()) != keys:
            keys = list(r.keys())
            print(",".join(keys))
        print(",".join(f"{r[k]:.4f}" if isinstance(r[k], float) else str(r[k]) for k in keys))
