"""Paper Table 3 — effect of communication topology (ring / 2D torus / mesh)
on worst-node accuracy under 4-bit quantization and top-10% sparsification.

Validates: denser graphs (larger spectral gap) -> faster consensus -> higher
worst-case accuracy at a fixed round budget.
"""
from __future__ import annotations

from benchmarks.common import make_adgda, train_trainer, worst_avg
from repro.core import make_topology
from repro.data import rotated_minority_classification


def run(quick: bool = True, seeds=(0, 1)) -> list[dict]:
    m = 10
    steps = 600 if quick else 2000
    rows = []
    for model in ("logistic", "fc"):
        for comp in ("q4b", "top10"):
            for topo in ("ring", "torus", "mesh"):
                for robust in (True, False):
                    worst_accs = []
                    for seed in seeds:
                        data = rotated_minority_classification(num_nodes=m, seed=seed)
                        trainer, init_fn, apply_fn = make_adgda(
                            model, m, robust=robust, compressor=comp, topology=topo,
                        )
                        params, _ = train_trainer(trainer, init_fn(data.dim, data.num_classes),
                                                  data, steps, batch=50, seed=seed)
                        w, _ = worst_avg(apply_fn, params, data)
                        worst_accs.append(w)
                    rows.append({
                        "table": "T3",
                        "model": model,
                        "algo": "AD-GDA" if robust else "CHOCO-SGD",
                        "compressor": comp,
                        "topology": topo,
                        "spectral_gap": round(make_topology(topo, m).spectral_gap, 4),
                        "worst_acc": sum(worst_accs) / len(worst_accs),
                    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
