"""Paper Table 4 — effect of the regularization strength alpha on the
best/worst accuracy gap, across the three experimental setups (class-shard
F-MNIST analog, contrast-shift CIFAR analog, instrument-shift COOS7 analog).

Validates: smaller alpha -> a less constrained adversary -> smaller
best/worst gap, with the average accuracy essentially preserved.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_adgda, train_trainer, val_accuracies, worst_avg
from repro.data import (
    contrast_shift_classification,
    instrument_shift_classification,
    rotated_minority_classification,
)

SETUPS = {
    "rotated_minority": lambda seed: rotated_minority_classification(num_nodes=10, seed=seed),
    "cifar_analog": lambda seed: contrast_shift_classification(num_nodes=10, low_nodes=2, high_nodes=2, dim=24, seed=seed),
    "coos7_analog": lambda seed: instrument_shift_classification(num_nodes=10, minority_nodes=2, dim=24, seed=seed),
}


def run(quick: bool = True, seeds=(0, 1)) -> list[dict]:
    steps = 600 if quick else 2500
    rows = []
    for setup, make_data in SETUPS.items():
        for alpha in (10.0, 1.0, 0.01):
            worst, best, avg = [], [], []
            for seed in seeds:
                data = make_data(seed)
                trainer, init_fn, apply_fn = make_adgda(
                    "logistic", data.num_nodes, robust=True, alpha=alpha,
                    compressor="none", topology="torus",
                )
                params, _ = train_trainer(trainer, init_fn(data.dim, data.num_classes),
                                          data, steps, batch=50, seed=seed)
                accs = val_accuracies(apply_fn, params, data)
                w, a = worst_avg(apply_fn, params, data)
                worst.append(w)
                best.append(max(accs.values()))
                avg.append(a)
            rows.append({
                "table": "T4",
                "setup": setup,
                "alpha": alpha,
                "worst_acc": float(np.mean(worst)),
                "best_acc": float(np.mean(best)),
                "gap": float(np.mean(best) - np.mean(worst)),
                "avg_acc": float(np.mean(avg)),
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
