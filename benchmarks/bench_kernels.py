"""Suite K — Pallas kernel suite vs refs/baselines, with roofline columns.

Two row families:

* **compression** (`quantize_*`, `block_top*`) — wall time + the wire-size
  reduction each kernel buys, with the paper's contraction property asserted
  inline.
* **attention** (`attn_*`, `decode_*`) — each row times the suite kernel
  against an honest baseline *of the same execution technology* and reports
  ``speedup = us_baseline / us_kernel`` (the gated metric, see
  check_regression SPECS["K"]):

    - sliding-window kernel vs the flash kernel with its leading-block skip
      disabled (``skip_blocks=False`` — window *masking* without block
      skipping, both under the Pallas interpreter off-TPU);
    - block-sparse kernel vs the dense causal flash kernel;
    - fused int8 quantized-KV decode vs the engine's pre-kernel XLA decode
      (``_repeat_kv`` + materialized softmax over an f32 cache), both XLA.

  Every attention row asserts ref-parity (kernels/ref.py) on the exact
  tensors it times — a fast-but-wrong kernel fails the bench, not just the
  test suite.  Roofline columns follow launch/roofline.py vocabulary:
  ``hbm_mb_modeled`` is the kernel's modeled HBM traffic (the bytes a
  memory-bound op is bounded by) and ``bytes_x`` the baseline/kernel ratio —
  on TPU the wall-clock speedup of these memory-bound ops tracks ``bytes_x``;
  the CPU-measured ``speedup`` is the compute-proxy the gate pins.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.block_sparse import BlockSparsePattern, block_sparse_attention_pallas
from repro.kernels.decode import decode_attention_fused_xla
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import (
    block_sparse_attention_ref,
    decode_attention_ref,
    flash_attention_ref,
    quantize_kv_ref,
)
from repro.kernels.sliding_window import sliding_window_attention_pallas


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        best = min(best, time.time() - t0)
    return best * 1e6  # us (min-of-N: the gate's noise absorber expects it)


def _qkv(key, bh, s, hd, dtype):
    ks = jax.random.split(key, 3)
    return tuple(
        jax.random.normal(k, (bh, s, hd), jnp.float32).astype(dtype) for k in ks
    )


def _attn_bytes_mb(bh, s_q, kv_blocks_loaded, block_k, hd, itemsize):
    """Modeled HBM traffic of a streaming attention kernel: Q and O once,
    K and V once per *loaded* kv block (the roofline's memory-bound bound)."""
    qo = 2 * bh * s_q * hd * itemsize
    kv = 2 * kv_blocks_loaded * block_k * hd * itemsize
    return (qo + kv) / 2**20


def _sliding_rows(quick: bool) -> list[dict]:
    rows = []
    s = 2048 if quick else 8192
    window, hd, bh, bq, bk = 128, 64, 2, 128, 128
    for dtype in (jnp.float32,) if quick else (jnp.float32, jnp.bfloat16):
        q, k, v = _qkv(jax.random.PRNGKey(1), bh, s, hd, dtype)
        fast = jax.jit(
            lambda q, k, v: sliding_window_attention_pallas(
                q, k, v, window=window, block_q=bq, block_k=bk, interpret=True
            )
        )
        slow = jax.jit(
            lambda q, k, v: flash_attention_pallas(
                q, k, v, causal=True, window=window, block_q=bq, block_k=bk,
                interpret=True, skip_blocks=False,
            )
        )
        out, base = fast(q, k, v), slow(q, k, v)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        ref = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=tol, rtol=tol)
        np.testing.assert_allclose(
            np.asarray(base, np.float32), np.asarray(ref, np.float32),
            atol=tol, rtol=tol)
        us_k, us_b = _time(fast, q, k, v), _time(slow, q, k, v)
        nq = s // bq
        nkv_kernel = min(s // bk, (bq + window - 2) // bk + 2)
        # the masked baseline visits every causal block; the kernel only the band
        blocks_base = bh * sum(min(((i + 1) * bq - 1) // bk + 1, s // bk) for i in range(nq))
        blocks_kern = bh * nq * nkv_kernel
        isz = jnp.dtype(dtype).itemsize
        rows.append({
            "table": "K",
            "kernel": "attn_sliding_window",
            "baseline": "flash_window_masked",
            "dtype": jnp.dtype(dtype).name,
            "shape": f"bh{bh}_s{s}_hd{hd}_w{window}",
            "us_kernel": us_k,
            "us_baseline": us_b,
            "speedup": us_b / us_k,
            "hbm_mb_modeled": _attn_bytes_mb(bh, s, blocks_kern, bk, hd, isz),
            "bytes_x": blocks_base / blocks_kern,
        })
    return rows


def _block_sparse_rows(quick: bool) -> list[dict]:
    rows = []
    s, hd, bh, blk = (2048, 64, 2, 128) if quick else (4096, 64, 2, 128)
    q, k, v = _qkv(jax.random.PRNGKey(2), bh, s, hd, jnp.float32)
    dense_pat = BlockSparsePattern.causal_pattern(s, s, blk, blk)
    for name, pattern in [
        ("strided", BlockSparsePattern.strided(
            s, s, local_blocks=2, stride=4, block_q=blk, block_k=blk)),
        ("windowed", BlockSparsePattern.windowed(s, s, 256, blk, blk)),
    ]:
        fast = jax.jit(
            lambda q, k, v, p=pattern: block_sparse_attention_pallas(
                q, k, v, p, interpret=True)
        )
        slow = jax.jit(
            lambda q, k, v: flash_attention_pallas(
                q, k, v, causal=True, interpret=True)
        )
        out = fast(q, k, v)
        ref = block_sparse_attention_ref(q, k, v, pattern)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)
        us_k, us_b = _time(fast, q, k, v), _time(slow, q, k, v)
        blocks_kern = int((pattern.bitmap != 0).sum()) * bh
        blocks_base = int((dense_pat.bitmap != 0).sum()) * bh
        rows.append({
            "table": "K",
            "kernel": f"attn_block_sparse_{name}",
            "baseline": "flash_causal_dense",
            "dtype": "float32",
            "shape": f"bh{bh}_s{s}_hd{hd}",
            "density": pattern.density(),
            "us_kernel": us_k,
            "us_baseline": us_b,
            "speedup": us_b / us_k,
            "hbm_mb_modeled": _attn_bytes_mb(bh, s, blocks_kern, blk, hd, 4),
            "bytes_x": blocks_base / blocks_kern,
        })
    return rows


def _xla_decode_baseline(q, k, v, valid):
    """The engine's pre-kernel decode math: repeat kv heads to H, materialize
    the [B, H, 1, L] score row, softmax, contract — over the f32 cache."""
    B, KV, G, hd = q.shape
    H = KV * G
    kk = jnp.repeat(k, G, axis=2)  # [B, L, H, hd]
    vv = jnp.repeat(v, G, axis=2)
    qq = q.reshape(B, 1, H, hd)
    logits = jnp.einsum("bqhk,bshk->bhqs", qq, kk).astype(jnp.float32)
    logits = logits / np.sqrt(hd)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, vv).reshape(B, KV, G, hd)


def _decode_rows(quick: bool) -> list[dict]:
    rows = []
    B, KV, G, hd = 8, 4, 2, 64
    L = 4096 if quick else 16384
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, KV, hd), jnp.float32)
    valid = jnp.broadcast_to(jnp.arange(L)[None, :] < (L - 7), (B, L))

    base = jax.jit(_xla_decode_baseline)
    f32_ref = base(q, k, v, valid)
    cache_mb = 2 * B * L * KV * hd / 2**20  # per tick, k+v

    # fused f32: grouped heads contracted in place (no repeat_kv copy)
    fused_f32 = jax.jit(lambda q, k, v, m: decode_attention_fused_xla(q, k, v, m))
    out = fused_f32(q, k, v, valid)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(f32_ref), atol=2e-5, rtol=1e-4)
    us_b = _time(base, q, k, v, valid)
    us_k = _time(fused_f32, q, k, v, valid)
    rows.append({
        "table": "K",
        "kernel": "decode_fused_f32",
        "baseline": "xla_repeat_kv_f32",
        "dtype": "float32",
        "shape": f"B{B}_L{L}_kv{KV}_g{G}_hd{hd}",
        "us_kernel": us_k,
        "us_baseline": us_b,
        "speedup": us_b / us_k,
        "hbm_mb_modeled": cache_mb * 4,
        "bytes_x": float(G),  # repeat_kv reads/writes the cache G-fold
    })

    # fused int8 quantized-KV: 1/4 the cache bytes, dequant inside the
    # contractions; parity asserted against BOTH the quantized oracle (exact)
    # and the f32 decode (documented tolerance)
    kq, ksc = quantize_kv_ref(k)
    vq, vsc = quantize_kv_ref(v)
    fused_q = jax.jit(
        lambda q, kq, vq, m, ks_, vs_: decode_attention_fused_xla(
            q, kq, vq, m, k_scale=ks_, v_scale=vs_)
    )
    outq = fused_q(q, kq, vq, valid, ksc, vsc)
    np.testing.assert_allclose(
        np.asarray(outq),
        np.asarray(decode_attention_ref(q, kq, vq, valid, k_scale=ksc, v_scale=vsc)),
        atol=2e-5, rtol=1e-4)
    assert float(jnp.abs(outq - f32_ref).max()) < 2e-2  # int8 tolerance bar
    us_k = _time(fused_q, q, kq, vq, valid, ksc, vsc)
    rows.append({
        "table": "K",
        "kernel": "decode_fused_int8",
        "baseline": "xla_repeat_kv_f32",
        "dtype": "int8",
        "shape": f"B{B}_L{L}_kv{KV}_g{G}_hd{hd}",
        "us_kernel": us_k,
        "us_baseline": us_b,
        "speedup": us_b / us_k,
        "hbm_mb_modeled": cache_mb / 4 + B * L * KV * 8 / 2**20,  # int8 kv + scales
        "bytes_x": 4.0 * G,  # 1/4 bytes AND no G-fold repeat
    })
    return rows


def _compression_rows(quick: bool) -> list[dict]:
    rows = []
    d = 1 << 14 if quick else 1 << 20
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (d,))

    for bits in (8, 4):
        enc = jax.jit(lambda x, k: ops.quantize(x, k, bits=bits))
        payload = enc(x, key)
        dec = jax.jit(lambda p: ops.dequantize(p, (d,), jnp.float32, bits=bits))
        xq = dec(payload)
        # contraction property (Assumption 3.2): ||Q(x)-x||^2 <= (1-delta)||x||^2
        err = float(jnp.sum((xq - x) ** 2) / jnp.sum(x**2))
        delta = 1.0 / (1.0 + min(d / 2 ** (2 * bits), np.sqrt(d) / 2**bits))
        assert err <= (1 - delta) + 0.05, (bits, err)
        wire_bits = payload["levels"].size * 8 + payload["signs"].size * 8 + 32
        rows.append({
            "table": "K",
            "kernel": f"quantize_q{bits}b",
            "us_per_call": _time(enc, x, key),
            "rel_err": err,
            "compression_x": 32.0 * d / wire_bits,
        })

    for frac in (0.25, 0.10):
        topk = jax.jit(lambda x: ops.block_topk(x, fraction=frac))
        y = topk(x)
        nnz = int((np.asarray(y) != 0).sum())
        assert nnz <= int(frac * d * 1.1) + 128
        err = float(jnp.sum((y - x) ** 2) / jnp.sum(x**2))
        assert err <= 1.0 - 0.9 * frac  # contraction with delta ~= k/d
        rows.append({
            "table": "K",
            "kernel": f"block_top{int(frac * 100)}",
            "us_per_call": _time(topk, x),
            "rel_err": err,
            "compression_x": 1.0 / frac / 2,  # value+index per kept entry
        })
    return rows


def run(quick: bool = True) -> list[dict]:
    return (
        _compression_rows(quick)
        + _sliding_rows(quick)
        + _block_sparse_rows(quick)
        + _decode_rows(quick)
    )


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
