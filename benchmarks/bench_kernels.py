"""Kernel hot-spot benchmark — Pallas compression kernels vs pure-jnp refs.

Measures wall time per call (interpret mode on CPU — indicative only; the
BlockSpec tiling targets TPU VMEM), asserts allclose against ref.py, and
reports the wire-size reduction each kernel buys (the quantity that drives
the paper's communication saving).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import tau_for


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6  # us


def run(quick: bool = True) -> list[dict]:
    rows = []
    d = 1 << 14 if quick else 1 << 20
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (d,))

    for bits in (8, 4):
        enc = jax.jit(lambda x, k: ops.quantize(x, k, bits=bits))
        payload = enc(x, key)
        dec = jax.jit(lambda p: ops.dequantize(p, (d,), jnp.float32, bits=bits))
        xq = dec(payload)
        # contraction property (Assumption 3.2): ||Q(x)-x||^2 <= (1-delta)||x||^2
        err = float(jnp.sum((xq - x) ** 2) / jnp.sum(x**2))
        delta = 1.0 / (1.0 + min(d / 2 ** (2 * bits), np.sqrt(d) / 2**bits))
        assert err <= (1 - delta) + 0.05, (bits, err)
        wire_bits = payload["levels"].size * 8 + payload["signs"].size * 8 + 32
        rows.append({
            "table": "K",
            "kernel": f"quantize_q{bits}b",
            "us_per_call": _time(enc, x, key),
            "rel_err": err,
            "compression_x": 32.0 * d / wire_bits,
        })

    for frac in (0.25, 0.10):
        k = max(1, int(frac * d))
        topk = jax.jit(lambda x: ops.block_topk(x, fraction=frac))
        y = topk(x)
        nnz = int((np.asarray(y) != 0).sum())
        assert nnz <= int(frac * d * 1.1) + 128
        err = float(jnp.sum((y - x) ** 2) / jnp.sum(x**2))
        assert err <= 1.0 - 0.9 * frac  # contraction with delta ~= k/d
        rows.append({
            "table": "K",
            "kernel": f"block_top{int(frac * 100)}",
            "us_per_call": _time(topk, x),
            "rel_err": err,
            "compression_x": 1.0 / frac / 2,  # value+index per kept entry
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
